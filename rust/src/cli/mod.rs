//! The `pronto` command-line interface.
//!
//! ```text
//! pronto gen-trace  --out DIR [--nodes N] [--steps T] [--seed S]
//! pronto sim        [--scenario NAME|FILE.toml] [--json] [--config FILE]
//!                   [--policy pronto|sp|fd|pm|random|always|oracle]
//!                   [--replay CSV|DIR] [--replay-metric NAME]
//!                   [--trace-source auto|stream|materialized] [--threads N]
//! pronto scenarios  — list the built-in scenario catalog
//! pronto eval       [--config FILE] [--method pronto|sp|fd|pm] [--window W]
//!                   [--scenario NAME[,NAME…]] [--trace-source auto|stream|materialized]
//!                   [--threads N] [--out FILE] [--json]
//! pronto federate   [--config FILE] [--nodes N] [--fanout F]
//! pronto bench engine [--quick] [--no-scale] [--out FILE] [--sizes 100,1000,5000]
//!                   [--steps N] [--seed S] [--scenarios a,b,c] [--threads N]
//! pronto bench diff OLD.json NEW.json [--max-regress PCT] [--require-baseline]
//! pronto sweep      [--quick] [--steps N] [--seed S] [--threads N] [--out FILE]
//! pronto bench-tables [--table 1..3] [--quick]
//! pronto lint       [--json] [PATHS…] — determinism & safety static analysis
//! pronto inspect    [--compile] — artifact manifest + compile check
//! ```

mod args;

pub use args::Args;

use crate::baselines::*;
use crate::bench::{
    bench_engine, bench_engine_report, run_sweep, sweep_report, sweep_table, EngineBenchConfig,
    SweepConfig,
};
use crate::config::ProntoConfig;
use crate::scheduler::{
    Admission, CpuReadyOracle, NodeScheduler, ProntoPolicy, RandomPolicy,
};
use crate::sim::{
    evaluate_method, ArrivalPattern, DataCenterSim, DiscreteEventEngine, EvalConfig,
    FleetEvaluation, ReplaySchedule, Scenario, SimReport, CATALOG,
};
use crate::telemetry::{fleet_members, TraceGenerator, TraceSource, VmTrace, CPU_READY_IDX};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

const USAGE: &str = "\
pronto — federated task scheduling (PRONTO reproduction)

USAGE:
  pronto <COMMAND> [OPTIONS]

COMMANDS:
  gen-trace     generate synthetic VMware-style traces as CSV
  sim           run the cluster simulator (--scenario NAME|FILE.toml, --json,
                --replay CSV|DIR for trace-driven arrivals, --trace-source
                auto|stream|materialized for large fleets, --threads N for
                the parallel observe loop — reports stay byte-identical)
  scenarios     list the built-in scenario catalog
  eval          fleet evaluation of rejection-signal quality (Fig 6/7);
                --scenario NAME[,NAME...] runs the engine-driven
                prediction-quality sweep (lead time, precision/recall/F1,
                signal-to-decision latency) across all four methods and
                writes EVAL_quality.json
  federate      run the concurrent DASM federation
  bench         fleet-scale engine benchmark (`bench engine` writes
                BENCH_engine.json: events/s, wall time, peak queue depth;
                default sweeps end with a 100k-node large-fleet scale row,
                dropped by --no-scale or any --sizes/--scenarios override;
                `bench diff OLD NEW --max-regress PCT` gates on events/s
                regressions between two artifacts — sweep artifacts too;
                --require-baseline also fails on rows with no baseline)
  sweep         fault-injection sensitivity grid (fleet size x dispatch
                policy x rack-outage hazard; deterministic table on
                stdout, schema-versioned SWEEP_*.json via --out;
                --quick for the CI smoke grid)
  bench-tables  regenerate the paper tables (see also cargo bench)
  lint          determinism & safety static analysis over the source tree
                (wall-clock, rng-discipline, unordered-iter, env-registry,
                unsafe-audit, schema-pin; --json for machine output;
                exits non-zero on findings — see README for the rule
                table and `pronto-lint: allow(...)` pragma syntax)
  serve         stream trace CSVs through node pipelines, emit decisions
  inspect       show the AOT artifact manifest and compile status
  help          show this message

Options per command are documented in the README.
";

/// CLI entry point (wired from `main.rs`). Exits the process on error.
pub fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}

/// Dispatch; separated from [`main`] for testability.
pub fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "gen-trace" => cmd_gen_trace(rest),
        "sim" => cmd_sim(rest),
        "scenarios" => cmd_scenarios(rest),
        "eval" => cmd_eval(rest),
        "federate" => cmd_federate(rest),
        "bench" => cmd_bench(rest),
        "sweep" => cmd_sweep(rest),
        "bench-tables" => cmd_bench_tables(rest),
        "lint" => cmd_lint(rest),
        "serve" => cmd_serve(rest),
        "inspect" => cmd_inspect(rest),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}

fn load_config(args: &Args) -> Result<ProntoConfig> {
    match args.get("config") {
        Some(path) => ProntoConfig::load(Path::new(path)),
        None => Ok(ProntoConfig::default()),
    }
}

fn gen_fleet(cfg: &ProntoConfig) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(cfg.generator.clone(), cfg.seed);
    // Same membership rule as the streaming path (fleet_members), which
    // is what keeps the two trace sources byte-identical.
    fleet_members(cfg.nodes, cfg.fanout)
        .into_iter()
        .map(|(c, v)| gen.generate_vm_in_cluster(c, v, cfg.steps))
        .collect()
}

fn cmd_gen_trace(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    args.reject_unknown(&["out", "nodes", "steps", "seed", "config"])?;
    let mut cfg = load_config(&args)?;
    cfg.nodes = args.get_usize("nodes", cfg.nodes)?;
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let out = args.get("out").unwrap_or("traces");
    std::fs::create_dir_all(out).with_context(|| format!("creating {out}"))?;

    let fleet = gen_fleet(&cfg);
    for tr in &fleet {
        let path = Path::new(out).join(format!("cluster{}_vm{}.csv", tr.cluster_id, tr.vm_id));
        tr.write_csv(&path)?;
    }
    println!(
        "wrote {} traces x {} steps x {} metrics to {out}/",
        fleet.len(),
        cfg.steps,
        fleet[0].dim()
    );
    Ok(())
}

fn make_policy(
    name: &str,
    d: usize,
    idx: usize,
    cfg: &ProntoConfig,
) -> Result<Box<dyn Admission>> {
    Ok(match name {
        "pronto" => Box::new(ProntoPolicy::new(NodeScheduler::with_embedding(
            crate::fpca::FpcaEdge::new(d, cfg.fpca),
            cfg.reject,
        ))),
        "sp" => Box::new(ProntoPolicy::new(NodeScheduler::with_embedding(
            Spirit::new(d, SpiritConfig::default()),
            cfg.reject,
        ))),
        "fd" => Box::new(ProntoPolicy::new(NodeScheduler::with_embedding(
            FrequentDirections::new(d, cfg.fpca.initial_rank),
            cfg.reject,
        ))),
        // PM's oversampled sketch is the one randomized baseline; it
        // draws from the dedicated PM_BASELINE stream (the engine owns
        // ARRIVALS..HETERO) so adjacent nodes decorrelate — the
        // historical `seed ^ idx` left neighbours sharing most of
        // their generator state.
        "pm" => Box::new(ProntoPolicy::new(NodeScheduler::with_embedding(
            BlockPowerMethod::new(
                d,
                cfg.fpca.initial_rank,
                d,
                crate::rng::node_stream_seed(cfg.seed, crate::rng::streams::PM_BASELINE, idx),
            ),
            cfg.reject,
        ))),
        "random" => Box::new(RandomPolicy::new(0.2, cfg.seed ^ idx as u64)),
        "always" => Box::new(RandomPolicy::always_accept(cfg.seed ^ idx as u64)),
        "oracle" => Box::new(CpuReadyOracle::new(CPU_READY_IDX, cfg.sim.ready_threshold)),
        other => bail!("unknown policy '{other}'"),
    })
}

fn cmd_sim(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["json"])?;
    args.reject_unknown(&[
        "config", "policy", "nodes", "steps", "seed", "scenario", "replay", "replay-metric",
        "trace-source", "threads",
    ])?;
    if args.get("replay-metric").is_some() && args.get("replay").is_none() {
        bail!("--replay-metric requires --replay");
    }
    let mut cfg = load_config(&args)?;
    cfg.nodes = args.get_usize("nodes", cfg.nodes)?;
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    let policy = args.get("policy").unwrap_or("pronto");
    let json = args.flag("json");
    // Validate up front so a typo'd value fails loudly on every path —
    // the facade ignores the flag's *effect* but not its spelling.
    let trace_source = args.get("trace-source").unwrap_or("auto");
    if !matches!(trace_source, "auto" | "stream" | "materialized") {
        bail!("--trace-source '{trace_source}' (auto | stream | materialized)");
    }

    // --scenario routes through the discrete-event engine with the full
    // scenario feature set (churn, bursts, federation latency); without
    // it, the fixed-step façade runs the paper's steady-Poisson setting.
    // `--scenario none` escapes a config-pinned default back to the
    // fixed-step facade. `--replay CSV` without a scenario implies the
    // `replay` catalog entry (whose demo schedule the CSV then replaces).
    let scenario_arg = args
        .get("scenario")
        .map(str::to_string)
        .or_else(|| cfg.scenario.clone())
        .filter(|s| s != "none")
        .or_else(|| args.get("replay").map(|_| "replay".to_string()));
    let scenario = match &scenario_arg {
        Some(spec) => {
            let mut scenario = Scenario::resolve(spec)?;
            // Explicit CLI overrides win over the scenario's own sizing;
            // re-validate because overrides bypass the parser's checks.
            scenario.nodes = args.get_usize("nodes", scenario.nodes)?;
            scenario.steps = args.get_usize("steps", scenario.steps)?;
            scenario.seed = args.get_u64("seed", scenario.seed)?;
            // Observe-loop width: byte-identical reports at any value
            // (validated below), so this only changes wall time.
            scenario.threads = args.get_usize("threads", scenario.threads)?;
            // --replay swaps the arrival pattern for a trace-driven
            // schedule (a CSV file or a directory of per-VM CSVs).
            if let Some(csv) = args.get("replay") {
                scenario.arrivals = ArrivalPattern::Replay {
                    schedule: std::sync::Arc::new(ReplaySchedule::from_path(
                        Path::new(csv),
                        args.get("replay-metric"),
                    )?),
                };
            }
            scenario.validate()?;
            // Scenario sizing wins over the config file (documented in
            // SCENARIOS.md); CLI flags override both. Policies that read
            // the scoring threshold (oracle) must agree with the
            // scenario's scorer.
            cfg.nodes = scenario.nodes;
            cfg.steps = scenario.steps;
            cfg.seed = scenario.seed;
            cfg.sim.ready_threshold = scenario.ready_threshold;
            Some(scenario)
        }
        None => {
            // The fixed-step facade has no observe loop to shard; only
            // the no-op width is accepted (0 is as invalid as it is on
            // the scenario path).
            let threads = args.get_usize("threads", 1)?;
            if threads != 1 {
                bail!(
                    "--threads {threads} requires --scenario (the fixed-step facade \
                     is sequential; only --threads 1 is valid here)"
                );
            }
            // Keep the facade path reproducible from the printed report:
            // --seed drives the simulation RNG, not just trace generation.
            cfg.sim.seed = args.get_u64("seed", cfg.sim.seed)?;
            None
        }
    };

    let report = if let Some(scenario) = scenario {
        // Telemetry backing: `auto` streams large fleets (the two paths
        // are byte-identical per seed, so this only changes memory and
        // startup latency, never the report).
        let stream = match trace_source {
            "stream" => true,
            "materialized" => false,
            _ => {
                scenario.nodes >= 512
                    || scenario.nodes.saturating_mul(scenario.steps) >= 1_000_000
            }
        };
        let (source, dims) = if stream {
            let gen = TraceGenerator::new(cfg.generator.clone(), cfg.seed);
            let members = fleet_members(cfg.nodes, cfg.fanout);
            let source =
                TraceSource::streaming(&gen, &members, cfg.steps, scenario.score_window);
            (source, vec![cfg.generator.dim; cfg.nodes])
        } else {
            let fleet = gen_fleet(&cfg);
            let dims: Vec<usize> = fleet.iter().map(|t| t.dim()).collect();
            (TraceSource::materialized(fleet), dims)
        };
        let policies: Vec<Box<dyn Admission>> = dims
            .iter()
            .enumerate()
            .map(|(i, &d)| make_policy(policy, d, i, &cfg))
            .collect::<Result<_>>()?;
        // try_from_source: a malformed fleet (empty replay directory,
        // header-only CSVs) is a typed error on stderr, not an index
        // panic.
        let mut engine =
            DiscreteEventEngine::try_from_source(scenario.clone(), source, policies)?;
        if scenario.has_node_churn() {
            // Rejoining nodes restart with fresh policy state. Rack
            // outages in the failure layer churn nodes exactly like a
            // churn model, so they need the factory too.
            let cfg = cfg.clone();
            let name = policy.to_string();
            engine = engine.with_policy_factory(Box::new(move |node| {
                make_policy(&name, dims[node], node, &cfg)
                    .expect("policy validated at startup")
            }));
        }
        engine.run()
    } else {
        if trace_source == "stream" {
            bail!("--trace-source stream requires --scenario (the facade materializes)");
        }
        let fleet = gen_fleet(&cfg);
        let policies: Vec<Box<dyn Admission>> = fleet
            .iter()
            .enumerate()
            .map(|(i, t)| make_policy(policy, t.dim(), i, &cfg))
            .collect::<Result<_>>()?;
        DataCenterSim::new(cfg.sim.clone(), fleet, policies).run()
    };

    if json {
        println!("{}", report.to_json_string());
        return Ok(());
    }
    print_sim_report(&report, policy);
    Ok(())
}

fn print_sim_report(report: &SimReport, policy: &str) {
    println!(
        "simulation '{}': {} nodes x {} steps, policy = {policy}, seed = {}",
        report.scenario, report.nodes, report.steps, report.seed
    );
    println!("  jobs arrived        : {}", report.jobs_arrived);
    println!(
        "  accepted            : {} ({:.1}%)",
        report.jobs_accepted,
        100.0 * report.acceptance_rate()
    );
    println!(
        "  placement quality   : {:.1}%",
        100.0 * report.placement_quality()
    );
    println!(
        "  rejection precision : {:.1}%",
        100.0 * report.rejection_precision()
    );
    println!(
        "  completed/displaced : {} / {}",
        report.jobs_completed, report.jobs_displaced
    );
    println!("  peak in-flight jobs : {}", report.peak_inflight);
    if report.jobs_queued + report.jobs_dropped + report.jobs_preempted > 0 {
        println!(
            "  queueing            : {} parked (peak depth {}), mean wait {:.2} steps, \
             {} dropped",
            report.jobs_queued,
            report.peak_queue_len,
            report.mean_queue_delay_steps,
            report.jobs_dropped
        );
        println!(
            "  preemption          : {} preempted, {} migrated, {} lost",
            report.jobs_preempted, report.jobs_migrated, report.jobs_displaced
        );
        println!(
            "  utilization         : {:.1}% ({} queued / {} running at end)",
            100.0 * report.mean_utilization,
            report.jobs_still_queued,
            report.jobs_still_running
        );
    }
    if !report.mean_queue_delay_by_priority.is_empty() {
        let per: Vec<String> = report
            .mean_queue_delay_by_priority
            .iter()
            .enumerate()
            .map(|(p, d)| format!("p{p}={d:.2}"))
            .collect();
        println!("  queue delay by prio : {} steps (higher class serves first)", per.join(", "));
    }
    if report.slo_total > 0 {
        println!(
            "  SLO attainment      : {:.1}% ({} of {} deadlines met)",
            100.0 * report.slo_attainment(),
            report.slo_attained,
            report.slo_total
        );
    }
    if report.node_joins + report.node_leaves > 0 {
        println!(
            "  churn               : {} leaves, {} joins",
            report.node_leaves, report.node_joins
        );
    }
    if report.federation_pushes + report.federation_suppressed + report.federation_late_drops
        > 0
    {
        println!(
            "  federation          : {} pushes ({} suppressed, {} dropped late), \
             mean latency {:.2} steps",
            report.federation_pushes,
            report.federation_suppressed,
            report.federation_late_drops,
            report.mean_push_latency_steps
        );
    }
}

fn cmd_scenarios(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    args.reject_unknown(&[])?;
    println!("built-in scenarios (run with `pronto sim --scenario NAME`):");
    for name in CATALOG {
        let s = Scenario::named(name).expect("catalog entry");
        let churn = if s.has_node_churn() { "churn" } else { "stable" };
        let cap = match &s.capacity {
            Some(c) => {
                let mut tag = String::from(if c.pressure_enabled() {
                    ", finite+preempting"
                } else {
                    ", finite slots"
                });
                if !c.host_classes.is_empty() {
                    tag.push_str("/hetero");
                }
                if c.priority_levels > 1 {
                    tag.push_str("/priorities");
                }
                if c.slo_steps.is_some() {
                    tag.push_str("/slo");
                }
                match s.dispatch {
                    crate::sim::DispatchPolicy::SignalOnly => {}
                    crate::sim::DispatchPolicy::QueueAware => tag.push_str(", queue-aware"),
                    crate::sim::DispatchPolicy::LeastLoaded => tag.push_str(", least-loaded"),
                }
                tag
            }
            None => String::new(),
        };
        let lat = if s.federation.enabled {
            if s.federation.latency.is_instant() {
                "federated/instant"
            } else {
                "federated/delayed"
            }
        } else {
            "no federation"
        };
        let faults = match s.failures {
            Some(f) => {
                let mut tags = Vec::new();
                if f.rack_outages_enabled() {
                    tags.push("rack-outages");
                }
                if f.partitions_enabled() {
                    tags.push("partitions");
                }
                if f.stragglers_enabled() {
                    tags.push("stragglers");
                }
                if f.antagonist_enabled() {
                    tags.push("antagonist");
                }
                format!(", faults: {}", tags.join("+"))
            }
            None => String::new(),
        };
        println!(
            "  {name:<18} {} arrivals, {churn}, {lat}{cap}{faults}",
            arrival_kind(&s)
        );
    }
    println!("custom scenarios: `pronto sim --scenario path/to/scenario.toml`");
    println!("trace replay:     `pronto sim --replay traces/ [--replay-metric NAME]`");
    println!("(schema documented in rust/SCENARIOS.md)");
    Ok(())
}

fn arrival_kind(s: &Scenario) -> &'static str {
    match s.arrivals {
        ArrivalPattern::Poisson { .. } => "poisson",
        ArrivalPattern::Bursty { .. } => "bursty",
        ArrivalPattern::Diurnal { .. } => "diurnal",
        ArrivalPattern::Replay { .. } => "replay",
    }
}

/// CLI method names and their report tags, in sweep order.
const EVAL_METHODS: [(&str, &str); 4] =
    [("pronto", "PRONTO"), ("sp", "SP"), ("fd", "FD"), ("pm", "PM")];

/// Resolve `--method` (a single name or a comma list) against the four
/// embedding methods; `None` selects the full sweep.
fn eval_methods(arg: Option<&str>) -> Result<Vec<(&'static str, &'static str)>> {
    match arg {
        None => Ok(EVAL_METHODS.to_vec()),
        Some(list) => list
            .split(',')
            .map(str::trim)
            .filter(|m| !m.is_empty())
            .map(|m| {
                EVAL_METHODS
                    .iter()
                    .find(|(name, _)| *name == m)
                    .copied()
                    .ok_or_else(|| anyhow!("unknown method '{m}' (pronto | sp | fd | pm)"))
            })
            .collect(),
    }
}

fn cmd_eval(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["json"])?;
    args.reject_unknown(&[
        "config", "method", "window", "nodes", "steps", "seed", "threshold", "scenario",
        "trace-source", "threads", "out",
    ])?;
    let mut cfg = load_config(&args)?;
    cfg.nodes = args.get_usize("nodes", cfg.nodes)?;
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    // Validate every cheap knob before any trace generation: historically
    // the fleet was materialized first, so a typo'd --method burned the
    // whole generation pass, --nodes 0 panicked indexing fleet[0], and
    // --window 0/1 silently degenerated the half-window to nothing.
    let window = args.get_usize("window", 10)?;
    if window < 2 {
        bail!("--window must be >= 2 (the Figure-5 window needs both halves; got {window})");
    }
    let trace_source = args.get("trace-source").unwrap_or("auto");
    if !matches!(trace_source, "auto" | "stream" | "materialized") {
        bail!("--trace-source '{trace_source}' (auto | stream | materialized)");
    }
    let methods = eval_methods(args.get("method"))?;
    if methods.is_empty() {
        bail!("--method: empty list");
    }

    // --scenario switches to the engine-driven prediction-quality sweep
    // (EVAL_quality.json); without it, the historical per-trace Figure
    // 6/7 evaluation runs.
    if let Some(spec) = args.get("scenario") {
        return cmd_eval_quality(&args, &cfg, spec, window, trace_source, &methods);
    }
    for flag in ["out", "threads"] {
        if args.get(flag).is_some() {
            bail!("--{flag} requires --scenario (the quality sweep)");
        }
    }
    if args.flag("json") || args.get("trace-source").is_some() {
        bail!("--json/--trace-source require --scenario (the quality sweep)");
    }
    if cfg.nodes == 0 {
        bail!("--nodes must be >= 1 (the evaluation needs at least one VM trace)");
    }
    // Legacy mode evaluates one method (default pronto); the comma-list
    // sweep is a --scenario feature.
    let (method, tag) = if args.get("method").is_none() {
        EVAL_METHODS[0]
    } else if methods.len() == 1 {
        methods[0]
    } else {
        bail!("multiple methods require --scenario (the quality sweep)");
    };
    let eval_cfg = EvalConfig {
        window,
        ready_threshold: args.get_f64("threshold", cfg.sim.ready_threshold)?,
        reject: cfg.reject,
    };

    let fleet_traces = gen_fleet(&cfg);
    let d = fleet_traces[0].dim();
    let mut fleet = FleetEvaluation::new(tag);
    for (i, tr) in fleet_traces.iter().enumerate() {
        let ev = match method {
            "pronto" => evaluate_method(crate::fpca::FpcaEdge::new(d, cfg.fpca), tr, &eval_cfg),
            "sp" => evaluate_method(Spirit::new(d, SpiritConfig::default()), tr, &eval_cfg),
            "fd" => evaluate_method(
                FrequentDirections::new(d, cfg.fpca.initial_rank),
                tr,
                &eval_cfg,
            ),
            "pm" => evaluate_method(
                BlockPowerMethod::new(
                    d,
                    cfg.fpca.initial_rank,
                    d,
                    crate::rng::node_stream_seed(cfg.seed, crate::rng::streams::PM_BASELINE, i),
                ),
                tr,
                &eval_cfg,
            ),
            _ => unreachable!(),
        };
        fleet.push(ev);
    }

    println!("fleet evaluation: {} nodes, method = {tag}", cfg.nodes);
    println!("  mean prediction rate : {:.3}", fleet.mean_prediction_rate());
    println!("  mean downtime        : {:.3}", fleet.mean_downtime());
    let spikes: usize = fleet.nodes.iter().map(|n| n.ready_spikes).sum();
    let raises: usize = fleet.nodes.iter().map(|n| n.rejection_raises).sum();
    println!("  CPU Ready spikes     : {spikes}");
    println!("  rejection raises     : {raises}");
    Ok(())
}

/// The engine-driven prediction-quality sweep: scenarios × methods →
/// `EVAL_quality.json`. Every run records raised/spike timelines via
/// [`DiscreteEventEngine::with_signal_capture`] and reduces them with
/// [`crate::sim::score_report`]. Rows are byte-identical across
/// `--trace-source` and `--threads` (the document records neither).
fn cmd_eval_quality(
    args: &Args,
    base_cfg: &ProntoConfig,
    spec: &str,
    window: usize,
    trace_source: &str,
    methods: &[(&'static str, &'static str)],
) -> Result<()> {
    let names: Vec<&str> =
        spec.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if names.is_empty() {
        bail!("--scenario: empty list");
    }
    let mut rows = Vec::new();
    let mut resolved = Vec::new();
    for name in &names {
        let mut scenario = Scenario::resolve(name)?;
        // Same override/validation dance as `pronto sim --scenario`.
        scenario.nodes = args.get_usize("nodes", scenario.nodes)?;
        scenario.steps = args.get_usize("steps", scenario.steps)?;
        scenario.seed = args.get_u64("seed", scenario.seed)?;
        scenario.threads = args.get_usize("threads", scenario.threads)?;
        if args.get("threshold").is_some() {
            scenario.ready_threshold =
                args.get_f64("threshold", scenario.ready_threshold)?;
        }
        scenario.validate()?;
        let mut cfg = base_cfg.clone();
        cfg.nodes = scenario.nodes;
        cfg.steps = scenario.steps;
        cfg.seed = scenario.seed;
        cfg.sim.ready_threshold = scenario.ready_threshold;
        resolved.push(scenario.name.clone());
        for (method, tag) in methods {
            let report = run_quality_engine(&scenario, &cfg, method, trace_source)?;
            rows.push(crate::sim::score_report(&report, window, tag));
        }
    }

    let tags: Vec<&str> = methods.iter().map(|(_, t)| *t).collect();
    let doc = crate::sim::quality_report(window, &tags, &resolved, &rows);
    let out = args.get("out").unwrap_or("EVAL_quality.json");
    std::fs::write(out, format!("{doc}\n")).with_context(|| format!("writing {out}"))?;
    if args.flag("json") {
        println!("{doc}");
        return Ok(());
    }
    println!(
        "prediction quality: {} scenario(s) x {} method(s), window = {window} -> {out}",
        resolved.len(),
        methods.len()
    );
    for row in &rows {
        println!(
            "  {:<16} {:<7} recall {:.3}  precision {:.3}  f1 {:.3}  \
             lead p50 {:.0} steps  decision p50 {:.0} steps ({} samples)",
            row.scenario,
            row.method,
            row.recall,
            row.precision,
            row.f1,
            row.lead_p50,
            row.decision_p50,
            row.decision_samples
        );
    }
    Ok(())
}

/// One capture-enabled engine run for the quality sweep — the same
/// trace-source selection, policy wiring, and churn factory as
/// `pronto sim --scenario`, so quality rows describe exactly the runs
/// the simulator would execute.
fn run_quality_engine(
    scenario: &Scenario,
    cfg: &ProntoConfig,
    policy: &str,
    trace_source: &str,
) -> Result<SimReport> {
    let stream = match trace_source {
        "stream" => true,
        "materialized" => false,
        _ => {
            scenario.nodes >= 512
                || scenario.nodes.saturating_mul(scenario.steps) >= 1_000_000
        }
    };
    let (source, dims) = if stream {
        let gen = TraceGenerator::new(cfg.generator.clone(), cfg.seed);
        let members = fleet_members(cfg.nodes, cfg.fanout);
        let source = TraceSource::streaming(&gen, &members, cfg.steps, scenario.score_window);
        (source, vec![cfg.generator.dim; cfg.nodes])
    } else {
        let fleet = gen_fleet(cfg);
        let dims: Vec<usize> = fleet.iter().map(|t| t.dim()).collect();
        (TraceSource::materialized(fleet), dims)
    };
    let policies: Vec<Box<dyn Admission>> = dims
        .iter()
        .enumerate()
        .map(|(i, &d)| make_policy(policy, d, i, cfg))
        .collect::<Result<_>>()?;
    let mut engine = DiscreteEventEngine::try_from_source(scenario.clone(), source, policies)?
        .with_signal_capture();
    if scenario.has_node_churn() {
        let cfg = cfg.clone();
        let name = policy.to_string();
        engine = engine.with_policy_factory(Box::new(move |node| {
            make_policy(&name, dims[node], node, &cfg).expect("policy validated at startup")
        }));
    }
    Ok(engine.run())
}

fn cmd_federate(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &[])?;
    args.reject_unknown(&[
        "config", "nodes", "fanout", "steps", "epsilon", "push-every", "latency-mean",
    ])?;
    let mut cfg = load_config(&args)?;
    cfg.nodes = args.get_usize("nodes", cfg.nodes)?;
    cfg.fanout = args.get_usize("fanout", cfg.fanout)?;
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.epsilon = args.get_f64("epsilon", cfg.epsilon)?;
    cfg.push_every = args.get_usize("push-every", cfg.push_every)?;
    if cfg.push_every == 0 {
        bail!("--push-every must be >= 1");
    }
    if args.get("latency-mean").is_some() {
        // Explicit flag always wins over the config — including 0, which
        // restores instant delivery.
        let latency_mean = args.get_f64("latency-mean", 0.0)?;
        cfg.push_latency = if latency_mean > 0.0 {
            crate::federation::LatencyModel::Exponential { mean_steps: latency_mean }
        } else {
            crate::federation::LatencyModel::None
        };
    }

    let traces = gen_fleet(&cfg);
    let fed = crate::federation::ConcurrentFederation::new(
        crate::federation::TreeTopology::new(cfg.nodes, cfg.fanout),
        cfg.fpca.initial_rank,
        cfg.epsilon,
    )
    .with_push_every(cfg.push_every)
    .with_latency(cfg.push_latency, cfg.seed);
    // Timing belongs to the CLI: `run()` itself is wall-clock-free so
    // the federation path stays deterministic.
    let started = std::time::Instant::now();
    let report = fed.run(traces).with_wall(started.elapsed());
    println!(
        "federation: {} leaves, {} steps each",
        report.leaves, report.steps_per_leaf
    );
    println!("  wall          : {:?}", report.wall);
    println!("  throughput    : {:.0} obs/s", report.throughput());
    println!(
        "  pushes        : {} (suppressed {}, dropped late {})",
        report.pushes, report.suppressed, report.late_drops
    );
    println!("  global rank   : {}", report.global_view.rank());
    Ok(())
}

/// `pronto bench <engine|diff>`: the perf-trajectory tooling. `engine`
/// sweeps catalog scenarios over fleet sizes through the streaming trace
/// source and writes the machine-readable `BENCH_engine.json` artifact
/// (events/s, wall time, peak queue depth per run); `diff` compares two
/// such artifacts row by row and exits non-zero when any row's events/s
/// regressed past `--max-regress` percent (default 10).
fn cmd_bench(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["quick", "no-scale", "require-baseline"])?;
    match args.positional().first().map(String::as_str) {
        Some("engine") => cmd_bench_engine(&args),
        Some("diff") => cmd_bench_diff(&args),
        _ => bail!(
            "usage: pronto bench engine [--quick] [--no-scale] [--out FILE] \
             [--sizes 100,1000,5000] [--steps N] [--seed S] [--scenarios a,b,c] \
             [--threads N]\n\
             \x20      pronto bench diff OLD.json NEW.json [--max-regress PCT] \
             [--require-baseline]"
        ),
    }
}

fn cmd_bench_engine(args: &Args) -> Result<()> {
    args.reject_unknown(&["out", "sizes", "steps", "seed", "scenarios", "threads"])?;
    let mut cfg = if args.flag("quick") {
        EngineBenchConfig::quick()
    } else {
        // PRONTO_BENCH_QUICK=1 selects quick sizing too (CI smoke).
        EngineBenchConfig::from_env()
    };
    if let Some(sizes) = args.get("sizes") {
        cfg.sizes = sizes
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow!("--sizes: bad integer '{s}'"))
            })
            .collect::<Result<_>>()?;
        if cfg.sizes.is_empty() || cfg.sizes.contains(&0) {
            bail!("--sizes: need at least one positive fleet size");
        }
    }
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    if cfg.threads == 0 {
        bail!("--threads must be >= 1 (1 = the sequential observe loop)");
    }
    if let Some(list) = args.get("scenarios") {
        cfg.scenarios = list
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        if cfg.scenarios.is_empty() {
            bail!("--scenarios: empty list");
        }
    }
    // The default sweeps append the 100k-node large-fleet scale row. An
    // explicit --sizes/--scenarios override describes the *whole* sweep
    // (nobody asking for `--sizes 12` wants a surprise 100k run riding
    // along), and --no-scale drops the row from a default sweep.
    if args.flag("no-scale") || args.get("sizes").is_some() || args.get("scenarios").is_some() {
        cfg.scale_rows.clear();
    }
    let runs = bench_engine(&cfg)?;
    let doc = bench_engine_report(&cfg, &runs);
    let out = args.get("out").unwrap_or("BENCH_engine.json");
    std::fs::write(out, format!("{doc}\n")).with_context(|| format!("writing {out}"))?;
    println!("wrote {} engine bench runs to {out}", runs.len());
    Ok(())
}

/// `pronto bench diff OLD.json NEW.json [--max-regress PCT]`: the perf
/// regression gate. Prints the per-row comparison, then fails (non-zero
/// exit) when any joined row's events/s dropped by more than the
/// threshold. Compare artifacts from the same machine — the figures are
/// wall-clock-derived.
fn cmd_bench_diff(args: &Args) -> Result<()> {
    args.reject_unknown(&["max-regress"])?;
    let pos = args.positional();
    // pos[0] is the subcommand itself.
    if pos.len() != 3 {
        bail!(
            "usage: pronto bench diff OLD.json NEW.json [--max-regress PCT] \
             [--require-baseline]"
        );
    }
    let max_regress = args.get_f64("max-regress", 10.0)?;
    if !(max_regress.is_finite() && max_regress >= 0.0) {
        bail!("--max-regress: need a finite percentage >= 0, got {max_regress}");
    }
    let old_text = std::fs::read_to_string(&pos[1])
        .with_context(|| format!("reading old artifact {}", pos[1]))?;
    let new_text = std::fs::read_to_string(&pos[2])
        .with_context(|| format!("reading new artifact {}", pos[2]))?;
    let diff = crate::bench::bench_diff(&old_text, &new_text)?;
    print!("{}", diff.render());
    // Strict mode: a row with no baseline can't be gated, which is
    // exactly the hole --require-baseline closes — fail until the
    // baseline artifact is regenerated to cover the new rows.
    if args.flag("require-baseline") && !diff.only_new.is_empty() {
        let rows: Vec<String> =
            diff.only_new.iter().map(|(k, _)| k.to_string()).collect();
        bail!(
            "--require-baseline: {} row(s) have no baseline measurement: {}",
            diff.only_new.len(),
            rows.join(", ")
        );
    }
    let bad = diff.regressions_beyond(max_regress);
    if !bad.is_empty() {
        // `regressions_beyond` only returns rows with a computable delta
        // (zero-baseline rows are `n/a` and never gate).
        let rows: Vec<String> = bad
            .iter()
            .map(|r| format!("{} ({:+.1}%)", r.key, r.delta_pct.unwrap_or(0.0)))
            .collect();
        bail!(
            "{} row(s) regressed beyond {max_regress}% events/s: {}",
            bad.len(),
            rows.join(", ")
        );
    }
    println!(
        "ok: worst regression {:.1}% within the {max_regress}% budget ({} rows compared)",
        diff.worst_regression_pct(),
        diff.rows.len()
    );
    Ok(())
}

/// `pronto sweep [--quick] [--steps N] [--seed S] [--threads N]
/// [--out FILE]`: the fault-injection sensitivity grid. Runs fleet size
/// × dispatch policy × rack-outage hazard, prints the deterministic
/// counter table to stdout (byte-identical at any `--threads` width —
/// CI diffs two renders directly), and writes the schema-versioned
/// `SWEEP_*.json` artifact, which `pronto bench diff` joins by grid
/// coordinates.
fn cmd_sweep(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["quick"])?;
    args.reject_unknown(&["steps", "seed", "threads", "out"])?;
    let mut cfg = if args.flag("quick") {
        SweepConfig::quick()
    } else {
        // PRONTO_BENCH_QUICK=1 selects quick sizing too (CI smoke).
        SweepConfig::from_env()
    };
    cfg.steps = args.get_usize("steps", cfg.steps)?;
    if cfg.steps == 0 {
        bail!("--steps must be >= 1");
    }
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    if cfg.threads == 0 {
        bail!("--threads must be >= 1 (1 = the sequential observe loop)");
    }
    let rows = run_sweep(&cfg)?;
    sweep_table(&rows).print();
    let doc = sweep_report(&cfg, &rows);
    let out = args.get("out").unwrap_or("SWEEP_grid.json");
    std::fs::write(out, format!("{doc}\n")).with_context(|| format!("writing {out}"))?;
    println!("wrote {} sweep rows to {out}", rows.len());
    Ok(())
}

fn cmd_bench_tables(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["quick"])?;
    args.reject_unknown(&["table"])?;
    let which = args.get("table").map(|s| s.to_string());
    println!(
        "bench-tables regenerates the paper tables inline; the full harness\n\
         is `cargo bench` (one target per table/figure). Running: {}",
        which.as_deref().unwrap_or("1-3")
    );
    use crate::bench::experiments::*;
    // `--quick` selects the scale directly rather than mutating the
    // process environment (env-registry lint: `set_var` races threads).
    let scale = if args.flag("quick") {
        ExperimentScale::quick()
    } else {
        ExperimentScale::from_env()
    };
    let sel = |n: &str| which.is_none() || which.as_deref() == Some(n);
    if sel("1") {
        println!("\nTable 1 (RMSE):");
        for (name, c) in table1_rmse(&scale) {
            println!("  {name:<12} {:.2} {:.2} {:.2} {:.2}", c[0], c[1], c[2], c[3]);
        }
    }
    if sel("2") {
        println!("\nTable 2 (clustered SVM RMSE):");
        for (name, c) in table2_clustering(&scale) {
            println!("  {name:<14} {:.2} {:.2}", c[0], c[1]);
        }
    }
    if sel("3") {
        println!("\nTable 3 (RMSE by window):");
        let (labels, rows) = table3_windows(&scale);
        println!("  {:<12} {}", "method", labels.join("  "));
        for (name, cells) in rows {
            let vals: Vec<String> = cells.iter().map(|c| format!("{c:.1}")).collect();
            println!("  {name:<12} {}", vals.join("  "));
        }
    }
    Ok(())
}

/// `pronto lint [--json] [PATHS…]`: the determinism & safety
/// static-analysis pass over the source tree. Defaults to linting the
/// current directory; CI runs it from `rust/` as
/// `pronto lint --json . ../examples`. Exits non-zero (via the error
/// path) when any finding survives pragma filtering, so the CI job is
/// blocking by construction.
fn cmd_lint(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["json"])?;
    args.reject_unknown(&[])?;
    let roots: Vec<std::path::PathBuf> = if args.positional().is_empty() {
        vec![std::path::PathBuf::from(".")]
    } else {
        args.positional().iter().map(std::path::PathBuf::from).collect()
    };
    let report = crate::lint::lint_tree(&roots)
        .with_context(|| format!("linting {roots:?}"))?;
    if args.flag("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    if !report.is_clean() {
        bail!("pronto lint: {} finding(s)", report.findings.len());
    }
    Ok(())
}

/// Streaming playback: load every `*.csv` trace in a directory, run one
/// node pipeline per trace, and emit admission decisions as JSON lines —
/// the shape of a leader process consuming live telemetry. `--realtime`
/// sleeps the 20 s cadence between steps (default: full speed).
fn cmd_serve(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["realtime", "quiet"])?;
    args.reject_unknown(&["traces", "config", "max-steps"])?;
    let cfg = load_config(&args)?;
    let dir = args.get("traces").unwrap_or("traces");
    let max_steps = args.get_usize("max-steps", usize::MAX)?;

    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {dir}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "csv").unwrap_or(false))
        .collect();
    paths.sort();
    if paths.is_empty() {
        bail!("no .csv traces in {dir} (generate with `pronto gen-trace`)");
    }

    let mut nodes = Vec::new();
    let mut traces = Vec::new();
    for (i, p) in paths.iter().enumerate() {
        let tr = VmTrace::read_csv(p, i, 0)?;
        nodes.push(NodeScheduler::new(tr.dim(), cfg.reject));
        traces.push(tr);
    }
    let steps = traces.iter().map(VmTrace::len).min().unwrap().min(max_steps);
    eprintln!("serving {} nodes x {steps} steps from {dir}/", traces.len());

    let realtime = args.flag("realtime");
    let quiet = args.flag("quiet");
    use std::io::Write;
    let stdout = std::io::stdout();
    let mut out = std::io::BufWriter::new(stdout.lock());
    for t in 0..steps {
        for (i, (node, tr)) in nodes.iter_mut().zip(&traces).enumerate() {
            let accept = node.observe(tr.features(t));
            if !quiet {
                writeln!(
                    out,
                    r#"{{"t":{t},"node":{i},"accept":{accept},"ready_ms":{ready:.1}}}"#,
                    ready = tr.cpu_ready(t)
                )?;
            }
        }
        if !quiet {
            out.flush()?;
        }
        if realtime {
            std::thread::sleep(std::time::Duration::from_secs(20));
        }
    }
    // Final per-node summary on stderr (stdout stays machine-readable).
    for (i, node) in nodes.iter().enumerate() {
        eprintln!(
            "node {i}: downtime {:.2}%, rank {}",
            100.0 * node.stats().downtime(),
            node.estimate().rank()
        );
    }
    Ok(())
}

fn cmd_inspect(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["compile"])?;
    args.reject_unknown(&[])?;
    let dir = crate::runtime::artifacts_dir();
    println!("artifacts dir: {}", dir.display());
    if !crate::runtime::artifacts_available() {
        println!("manifest.json not found — run `make artifacts` first");
        return Ok(());
    }
    let manifest = crate::runtime::Manifest::load(&dir)?;
    let c = manifest.config;
    println!(
        "compiled config: dim={} rank={} block={} lag={}",
        c.dim, c.rank, c.block, c.lag
    );
    for (name, art) in &manifest.artifacts {
        let ins: Vec<String> = art
            .inputs
            .iter()
            .map(|t| format!("{}{:?}", t.name, t.shape))
            .collect();
        println!("  {name:<18} {} <- {}", art.file, ins.join(", "));
    }
    if args.flag("compile") {
        print!("compiling via PJRT CPU… ");
        let t0 = std::time::Instant::now();
        let _rt = crate::runtime::XlaRuntime::load(&dir)?;
        println!("ok in {:?}", t0.elapsed());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&argv(&["frobnicate"])).is_err());
    }

    #[test]
    fn help_runs() {
        assert!(run(&argv(&["help"])).is_ok());
        assert!(run(&[]).is_ok());
    }

    #[test]
    fn sim_smoke() {
        assert!(run(&argv(&[
            "sim", "--nodes", "3", "--steps", "300", "--policy", "always"
        ]))
        .is_ok());
    }

    #[test]
    fn eval_smoke() {
        assert!(run(&argv(&[
            "eval", "--nodes", "2", "--steps", "600", "--method", "sp"
        ]))
        .is_ok());
    }

    #[test]
    fn eval_rejects_bad_knobs_before_generating() {
        // Degenerate windows: SlidingWindow's w >= 2 contract, enforced
        // up front instead of silently halving to zero.
        assert!(run(&argv(&["eval", "--window", "0", "--nodes", "2", "--steps", "50"])).is_err());
        assert!(run(&argv(&["eval", "--window", "1", "--nodes", "2", "--steps", "50"])).is_err());
        // --nodes 0 used to panic indexing fleet_traces[0].
        assert!(run(&argv(&["eval", "--nodes", "0", "--steps", "50"])).is_err());
        // Unknown method used to bail only after materializing the fleet.
        assert!(
            run(&argv(&["eval", "--method", "psychic", "--nodes", "2", "--steps", "50"]))
                .is_err()
        );
        // Sweep-only flags without --scenario fail loudly.
        assert!(
            run(&argv(&["eval", "--method", "sp,fd", "--nodes", "2", "--steps", "50"])).is_err()
        );
        assert!(run(&argv(&["eval", "--out", "x.json", "--nodes", "2", "--steps", "50"]))
            .is_err());
        assert!(run(&argv(&["eval", "--threads", "2", "--nodes", "2", "--steps", "50"]))
            .is_err());
        assert!(run(&argv(&["eval", "--scenario", "not-a-scenario", "--json"])).is_err());
        assert!(run(&argv(&["eval", "--scenario", " , ", "--json"])).is_err());
    }

    #[test]
    fn eval_scenario_sweep_writes_quality_artifact() {
        let dir = std::env::temp_dir().join("pronto_cli_eval_quality");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("EVAL_quality.json");
        let out_s = out.to_string_lossy().to_string();
        assert!(run(&argv(&[
            "eval", "--scenario", "capacity", "--nodes", "4", "--steps", "150", "--method",
            "pronto,pm", "--out", &out_s,
        ]))
        .is_ok());
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = crate::ser::parse_json(&text).expect("valid EVAL_quality.json");
        assert_eq!(
            doc.get("eval").and_then(crate::ser::JsonValue::as_str),
            Some("quality")
        );
        assert_eq!(
            doc.get("schema_version").and_then(crate::ser::JsonValue::as_usize),
            Some(1)
        );
        let rows = doc.get("rows").and_then(crate::ser::JsonValue::as_array).unwrap();
        assert_eq!(rows.len(), 2, "one row per scenario x method");
        for (row, tag) in rows.iter().zip(["PRONTO", "PM"]) {
            assert_eq!(row.get("method").and_then(crate::ser::JsonValue::as_str), Some(tag));
            for key in ["recall", "precision", "f1", "lead_p50", "decision_p50"] {
                assert!(row.get(key).is_some(), "row missing {key}");
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn gen_trace_smoke() {
        let dir = std::env::temp_dir().join("pronto_cli_gen");
        let out = dir.to_string_lossy().to_string();
        assert!(run(&argv(&[
            "gen-trace", "--out", &out, "--nodes", "2", "--steps", "50"
        ]))
        .is_ok());
        assert!(dir.join("cluster0_vm0.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_smoke_roundtrip() {
        let dir = std::env::temp_dir().join("pronto_cli_serve");
        let out = dir.to_string_lossy().to_string();
        run(&argv(&["gen-trace", "--out", &out, "--nodes", "2", "--steps", "120"])).unwrap();
        assert!(run(&argv(&[
            "serve", "--traces", &out, "--max-steps", "100", "--quiet"
        ]))
        .is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sim_rejects_bad_policy() {
        assert!(
            run(&argv(&["sim", "--policy", "nope", "--nodes", "2", "--steps", "100"])).is_err()
        );
    }

    #[test]
    fn scenarios_command_lists_catalog() {
        assert!(run(&argv(&["scenarios"])).is_ok());
    }

    #[test]
    fn sim_scenario_smoke_all_named() {
        // 6 nodes clears the churn scenarios' min_alive floor of 4, so
        // the churn path actually runs in this smoke.
        for name in crate::sim::CATALOG {
            assert!(
                run(&argv(&[
                    "sim", "--scenario", name, "--nodes", "6", "--steps", "200", "--json"
                ]))
                .is_ok(),
                "scenario {name} failed"
            );
        }
    }

    #[test]
    fn sim_scenario_rejects_min_alive_at_or_above_nodes() {
        assert!(run(&argv(&["sim", "--scenario", "churn", "--nodes", "4"])).is_err());
    }

    #[test]
    fn sim_scenario_none_escapes_config_pinned_default() {
        let dir = std::env::temp_dir().join("pronto_cli_scn_none");
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = dir.join("pronto.toml");
        std::fs::write(
            &cfg,
            "[pronto]\nscenario = \"churn\"\nnodes = 3\nsteps = 150\n",
        )
        .unwrap();
        let cfg_s = cfg.to_string_lossy().to_string();
        // --scenario none ignores the pinned default and runs the
        // fixed-step facade with the config's own [pronto]/[sim] sizing
        // (3 nodes x 150 steps; the pinned churn scenario would use
        // catalog sizing instead).
        assert!(run(&argv(&[
            "sim", "--config", &cfg_s, "--scenario", "none", "--policy", "always"
        ]))
        .is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sim_rejects_bad_scenario() {
        assert!(run(&argv(&["sim", "--scenario", "not-a-scenario"])).is_err());
    }

    #[test]
    fn sim_trace_source_modes_run_and_garbage_is_rejected() {
        for mode in ["auto", "stream", "materialized"] {
            assert!(
                run(&argv(&[
                    "sim", "--scenario", "capacity", "--nodes", "4", "--steps", "120",
                    "--policy", "always", "--trace-source", mode, "--json",
                ]))
                .is_ok(),
                "mode {mode} failed"
            );
        }
        assert!(run(&argv(&[
            "sim", "--scenario", "capacity", "--trace-source", "psychic"
        ]))
        .is_err());
        // The facade path validates the spelling too, not just "stream".
        assert!(run(&argv(&[
            "sim", "--scenario", "none", "--trace-source", "psychic", "--nodes", "3",
            "--steps", "100"
        ]))
        .is_err());
        // The fixed-step facade has no streaming path.
        assert!(run(&argv(&[
            "sim", "--scenario", "none", "--trace-source", "stream", "--nodes", "3",
            "--steps", "100"
        ]))
        .is_err());
    }

    #[test]
    fn bench_engine_quick_writes_artifact() {
        let dir = std::env::temp_dir().join("pronto_cli_bench");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_engine.json");
        let out_s = out.to_string_lossy().to_string();
        assert!(run(&argv(&[
            "bench", "engine", "--quick", "--sizes", "12", "--steps", "80",
            "--scenarios", "large-fleet,flash-crowd", "--out", &out_s,
        ]))
        .is_ok());
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = crate::ser::parse_json(&text).expect("valid BENCH_engine.json");
        assert_eq!(
            doc.get("bench").and_then(crate::ser::JsonValue::as_str),
            Some("engine")
        );
        // One size x two scenarios = two runs.
        assert!(matches!(
            doc.get("runs"),
            Some(crate::ser::JsonValue::Array(a)) if a.len() == 2
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_requires_a_known_subcommand() {
        assert!(run(&argv(&["bench"])).is_err());
        assert!(run(&argv(&["bench", "nope"])).is_err());
        assert!(run(&argv(&["bench", "engine", "--sizes", "0"])).is_err());
        assert!(run(&argv(&["bench", "engine", "--scenarios", "nope", "--sizes", "2"])).is_err());
        assert!(run(&argv(&["bench", "engine", "--threads", "0", "--sizes", "2"])).is_err());
    }

    #[test]
    fn sim_threads_flag_is_validated_and_runs() {
        assert!(run(&argv(&[
            "sim", "--scenario", "capacity", "--nodes", "4", "--steps", "120", "--policy",
            "always", "--threads", "3", "--json",
        ]))
        .is_ok());
        // 0 is rejected by scenario validation, not clamped.
        assert!(run(&argv(&[
            "sim", "--scenario", "capacity", "--nodes", "4", "--steps", "120", "--threads", "0",
        ]))
        .is_err());
        // The fixed-step facade has no observe loop to shard; 0 is as
        // invalid there as on the scenario path.
        assert!(run(&argv(&[
            "sim", "--scenario", "none", "--nodes", "3", "--steps", "100", "--threads", "2",
        ]))
        .is_err());
        assert!(run(&argv(&[
            "sim", "--scenario", "none", "--nodes", "3", "--steps", "100", "--threads", "0",
        ]))
        .is_err());
        assert!(run(&argv(&[
            "sim", "--scenario", "none", "--nodes", "3", "--steps", "100", "--threads", "1",
            "--policy", "always",
        ]))
        .is_ok());
    }

    #[test]
    fn bench_diff_gates_on_synthetic_regression_fixtures() {
        let dir = std::env::temp_dir().join("pronto_cli_bench_diff");
        std::fs::create_dir_all(&dir).unwrap();
        let row = |eps: f64| {
            format!(
                r#"{{"scenario":"large-fleet","nodes":200,"threads":1,"events_per_sec":{eps}}}"#
            )
        };
        let doc = |eps: f64| {
            format!(r#"{{"bench":"engine","schema_version":2,"runs":[{}]}}"#, row(eps))
        };
        let old = dir.join("old.json");
        let ok_new = dir.join("ok.json");
        let bad_new = dir.join("bad.json");
        std::fs::write(&old, doc(100_000.0)).unwrap();
        std::fs::write(&ok_new, doc(95_000.0)).unwrap();
        // 15 % slower: past the default 10 % budget.
        std::fs::write(&bad_new, doc(85_000.0)).unwrap();
        let (old_s, ok_s, bad_s) = (
            old.to_string_lossy().to_string(),
            ok_new.to_string_lossy().to_string(),
            bad_new.to_string_lossy().to_string(),
        );
        assert!(run(&argv(&["bench", "diff", &old_s, &ok_s])).is_ok());
        assert!(
            run(&argv(&["bench", "diff", &old_s, &bad_s])).is_err(),
            "a >10% events/s regression must exit non-zero"
        );
        // A wider explicit budget admits the same fixture.
        assert!(run(&argv(&[
            "bench", "diff", &old_s, &bad_s, "--max-regress", "20"
        ]))
        .is_ok());
        // Bad invocations fail loudly.
        assert!(run(&argv(&["bench", "diff", &old_s])).is_err());
        assert!(run(&argv(&["bench", "diff", &old_s, "/no/such.json"])).is_err());
        assert!(run(&argv(&[
            "bench", "diff", &old_s, &ok_s, "--max-regress", "-3"
        ]))
        .is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_diff_require_baseline_rejects_new_only_rows() {
        let dir = std::env::temp_dir().join("pronto_cli_bench_diff_strict");
        std::fs::create_dir_all(&dir).unwrap();
        let row = |scenario: &str, eps: f64| {
            format!(
                r#"{{"scenario":"{scenario}","nodes":200,"threads":1,"events_per_sec":{eps}}}"#
            )
        };
        let old = dir.join("old.json");
        let new = dir.join("new.json");
        std::fs::write(
            &old,
            format!(
                r#"{{"bench":"engine","schema_version":2,"runs":[{}]}}"#,
                row("large-fleet", 100_000.0)
            ),
        )
        .unwrap();
        // NEW grows a row the baseline never measured.
        std::fs::write(
            &new,
            format!(
                r#"{{"bench":"engine","schema_version":2,"runs":[{},{}]}}"#,
                row("large-fleet", 101_000.0),
                row("flash-crowd", 55_000.0)
            ),
        )
        .unwrap();
        let (old_s, new_s) =
            (old.to_string_lossy().to_string(), new.to_string_lossy().to_string());
        // Default mode: the new row is reported, not fatal.
        assert!(run(&argv(&["bench", "diff", &old_s, &new_s])).is_ok());
        // Strict mode refuses to pass until the baseline covers it.
        assert!(
            run(&argv(&["bench", "diff", &old_s, &new_s, "--require-baseline"])).is_err(),
            "--require-baseline must fail on baseline-less rows"
        );
        // A fully covered diff passes strict mode.
        assert!(run(&argv(&["bench", "diff", &new_s, &new_s, "--require-baseline"])).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sweep_writes_grid_artifact_and_diffs_against_itself() {
        let dir = std::env::temp_dir().join("pronto_cli_sweep");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("SWEEP_grid.json");
        let out_s = out.to_string_lossy().to_string();
        assert!(run(&argv(&["sweep", "--quick", "--steps", "40", "--out", &out_s])).is_ok());
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = crate::ser::parse_json(&text).expect("valid SWEEP artifact");
        assert_eq!(doc.get("bench").and_then(crate::ser::JsonValue::as_str), Some("sweep"));
        assert_eq!(
            doc.get("schema_version").and_then(crate::ser::JsonValue::as_usize),
            Some(1)
        );
        let rows = doc.get("rows").and_then(crate::ser::JsonValue::as_array).unwrap();
        assert_eq!(rows.len(), 27, "quick grid is 3 sizes x 3 policies x 3 rates");
        assert!(rows.iter().all(|r| {
            r.get("scenario")
                .and_then(crate::ser::JsonValue::as_str)
                .is_some_and(|s| s.starts_with("sweep/"))
        }));
        // The artifact gates through the same diff path as engine
        // benches, strict mode included.
        assert!(run(&argv(&["bench", "diff", &out_s, &out_s, "--require-baseline"])).is_ok());
        // Bad knobs fail loudly.
        assert!(run(&argv(&["sweep", "--steps", "0"])).is_err());
        assert!(run(&argv(&["sweep", "--threads", "0"])).is_err());
        assert!(run(&argv(&["sweep", "--frobnicate", "1"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_engine_records_threads_in_rows() {
        let dir = std::env::temp_dir().join("pronto_cli_bench_threads");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_engine.json");
        let out_s = out.to_string_lossy().to_string();
        assert!(run(&argv(&[
            "bench", "engine", "--quick", "--sizes", "10", "--steps", "60", "--scenarios",
            "large-fleet", "--threads", "2", "--out", &out_s,
        ]))
        .is_ok());
        let text = std::fs::read_to_string(&out).unwrap();
        let doc = crate::ser::parse_json(&text).expect("valid artifact");
        let runs = doc.get("runs").and_then(crate::ser::JsonValue::as_array).unwrap();
        assert_eq!(
            runs[0].get("threads").and_then(crate::ser::JsonValue::as_usize),
            Some(2)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sim_replay_flag_drives_arrivals_from_csv() {
        let dir = std::env::temp_dir().join("pronto_cli_replay");
        std::fs::create_dir_all(&dir).unwrap();
        let csv = dir.join("arrivals.csv");
        let mut text = String::from("timestep,arrivals\n");
        for t in 0..60 {
            text.push_str(&format!("{t},{}\n", if t % 10 == 0 { 2 } else { 0 }));
        }
        std::fs::write(&csv, text).unwrap();
        let csv_s = csv.to_string_lossy().to_string();
        // --replay alone implies the `replay` scenario with the CSV's
        // schedule in place of the built-in demo.
        assert!(run(&argv(&[
            "sim", "--replay", &csv_s, "--nodes", "3", "--steps", "60", "--policy", "always",
            "--json"
        ]))
        .is_ok());
        // An explicit scenario composes with --replay too.
        assert!(run(&argv(&[
            "sim", "--scenario", "capacity", "--replay", &csv_s, "--nodes", "3", "--steps",
            "60", "--policy", "always", "--json"
        ]))
        .is_ok());
        // Missing file fails loudly, as does a metric without a trace.
        assert!(run(&argv(&["sim", "--replay", "/no/such/file.csv"])).is_err());
        assert!(run(&argv(&["sim", "--replay-metric", "jobs"])).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
