# Enable 64-bit mode for the test session: dtype-sweep tests need f64 to
# stay f64. Artifacts are lowered by aot.py in a separate process (f32).
import jax

jax.config.update("jax_enable_x64", True)
