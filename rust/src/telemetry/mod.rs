//! Telemetry substrate.
//!
//! The paper evaluates against a proprietary 1 TB VMware vSphere trace
//! (100 clusters × ~14 ESX hosts × 250–350 VMs, one 52-metric VM vector
//! every 20 s, four weeks). That dataset is not available, so this module
//! provides the substitution documented in DESIGN.md §5:
//!
//! * [`catalog`] — the metric vocabulary (52 VM metrics / 134 host metrics,
//!   named after the real vSphere counters);
//! * [`generator`] — a synthetic trace generator that reproduces the causal
//!   structure PRONTO exploits: telemetry is low-rank (a few latent workload
//!   factors drive many correlated counters), CPU Ready is near zero except
//!   for *contention episodes*, and episodes are preceded by precursor drift
//!   in the latent factors a few samples ahead;
//! * [`trace`] — in-memory trace containers with CSV round-trip;
//! * [`source`] — fleet-level [`TraceSource`]: the engine's telemetry
//!   input, either fully materialized traces or windowed per-node
//!   streaming with O(nodes + window) memory.

pub mod catalog;
pub mod generator;
pub mod source;
pub mod trace;

pub use catalog::{host_metric_names, vm_metric_names, CPU_READY_IDX, VM_DIM};
pub use generator::{ClusterTrace, GeneratorConfig, TraceGenerator, VmTraceStream};
pub use source::{fleet_members, NodeView, StreamNodeView, StreamingFleet, TraceSource};
pub use trace::VmTrace;
