//! Algorithm 1: Reject-Job.
//!
//! Inputs per timestep: the node's current subspace iterate `(U, Σ)` and the
//! observed metric vector `y ∈ ℝ^d`. The routine projects `p = yᵀU ∈ ℝ^r`,
//! classifies each projection lane as +1/−1/0 via the streaming z-score
//! detector (lag 10, α 3.5, β 0.5 — the paper's constants), computes the
//! weighted sum `R_s = Σ_i b_i σ_i`, and raises the rejection signal when
//! `R_s ≥ tr` (the paper uses tr = 1 throughout).

use crate::detect::{MultiDetector, ZScoreConfig};
use crate::fpca::Subspace;

/// Reject-Job parameters (defaults = Algorithm 1's init block).
#[derive(Debug, Clone, Copy)]
pub struct RejectConfig {
    /// z-score filter parameters (lag = 10, α = 3.5, β = 0.5).
    pub zscore: ZScoreConfig,
    /// Rejection threshold `tr` on the weighted spike sum.
    pub threshold: f64,
    /// Maximum number of projection lanes tracked (r_max).
    pub max_rank: usize,
    /// Normalize singular values to sum 1 before weighting. The paper
    /// weights by raw σ_i; raw spectra grow with stream length under λ = 1,
    /// which makes a fixed `tr` scale-dependent — normalization keeps the
    /// threshold meaningful for all methods (and reduces to the paper's
    /// behaviour for the σ_r = 1/r fallback up to a constant).
    pub normalize_sigma: bool,
    /// Use the signed spike flags in the weighted sum (Algorithm 1
    /// verbatim). An SVD basis has arbitrary column signs, so simultaneous
    /// spikes on different lanes can cancel under the signed sum; the
    /// default uses |b_i| (any abrupt projection change signals a load
    /// shift), which strictly dominates on our traces — see the
    /// `signed_vs_abs` ablation in the fig6 bench.
    pub signed_flags: bool,
}

impl Default for RejectConfig {
    fn default() -> Self {
        Self {
            zscore: ZScoreConfig::default(),
            threshold: 1.0,
            max_rank: 8,
            normalize_sigma: true,
            signed_flags: false,
        }
    }
}

/// Streaming Reject-Job evaluator for one node.
#[derive(Debug, Clone)]
pub struct RejectJob {
    cfg: RejectConfig,
    detector: MultiDetector,
    /// Scratch: projections (len max_rank).
    proj: Vec<f64>,
    /// Scratch: per-lane ternary spike flags.
    flags: Vec<i8>,
    /// Timesteps processed.
    steps: usize,
    /// Timesteps with the signal raised.
    raised_count: usize,
}

impl RejectJob {
    pub fn new(cfg: RejectConfig) -> Self {
        Self {
            detector: MultiDetector::new(cfg.max_rank, cfg.zscore),
            proj: vec![0.0; cfg.max_rank],
            flags: vec![0; cfg.max_rank],
            cfg,
            steps: 0,
            raised_count: 0,
        }
    }

    pub fn config(&self) -> &RejectConfig {
        &self.cfg
    }

    /// Timesteps processed so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Fraction of timesteps with the rejection signal raised (downtime).
    pub fn downtime(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.raised_count as f64 / self.steps as f64
        }
    }

    /// Last computed projections (valid for the lanes of the last estimate).
    pub fn projections(&self) -> &[f64] {
        &self.proj
    }

    /// Last per-lane spike flags.
    pub fn spike_flags(&self) -> &[i8] {
        &self.flags
    }

    /// Algorithm 1 body. Returns `true` when a job arriving now must be
    /// REJECTED. Allocation-free after construction (hot path).
    pub fn observe(&mut self, estimate: &Subspace, y: &[f64]) -> bool {
        self.steps += 1;
        let r = estimate.rank().min(self.cfg.max_rank);
        if r == 0 {
            // No iterate yet (first block still filling): accept.
            return false;
        }
        // p = yᵀU
        estimate.project_into(y, &mut self.proj[..r]);
        // Lag buffer not filled → "return false" (Algorithm 1).
        let warmed = self.detector.warmed_up();
        self.detector.observe_into(&self.proj[..r], &mut self.flags[..r]);
        if !warmed {
            return false;
        }
        // Weighted spike sum R_s = Σ b_i σ_i.
        let mut denom = 1.0;
        if self.cfg.normalize_sigma {
            let s: f64 = estimate.sigma[..r].iter().sum();
            if s > 0.0 {
                denom = s;
            }
        }
        let mut rs = 0.0;
        for i in 0..r {
            let b = if self.cfg.signed_flags {
                self.flags[i] as f64
            } else {
                (self.flags[i] as f64).abs()
            };
            rs += b * estimate.sigma[i] / denom;
        }
        // Normalized threshold: tr is interpreted against the normalized
        // spectrum (tr = 1 ⇒ all weight spiking positive). We scale tr by
        // the top normalized weight so single-dominant-lane spikes can
        // trigger, matching the paper's raw-σ behaviour where σ₁ ≥ tr.
        let tr = if self.cfg.normalize_sigma {
            self.cfg.threshold * (estimate.sigma[0] / denom)
        } else {
            self.cfg.threshold
        };
        let reject = rs >= tr;
        if reject {
            self.raised_count += 1;
        }
        reject
    }

    /// Reset all filter state (subspace replaced wholesale).
    pub fn reset(&mut self) {
        self.detector.reset();
        self.steps = 0;
        self.raised_count = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    /// A fixed rank-2 estimate over d = 4: lanes pick coordinates 0 and 1.
    fn fixed_estimate() -> Subspace {
        let u = Mat::from_rows(
            4,
            2,
            &[1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0],
        );
        Subspace::new(u, vec![2.0, 1.0])
    }

    fn steady(v0: f64, v1: f64, t: usize) -> [f64; 4] {
        // Small jitter so the z-filter has nonzero std.
        let j = 0.01 * ((t % 3) as f64 - 1.0);
        [v0 + j, v1 + j, 0.0, 0.0]
    }

    #[test]
    fn accepts_before_warmup_and_on_steady_state() {
        let est = fixed_estimate();
        let mut rj = RejectJob::new(RejectConfig::default());
        for t in 0..40 {
            let y = steady(1.0, -1.0, t);
            assert!(!rj.observe(&est, &y), "t={t}");
        }
        assert_eq!(rj.downtime(), 0.0);
    }

    #[test]
    fn rejects_on_dominant_lane_spike() {
        let est = fixed_estimate();
        let mut rj = RejectJob::new(RejectConfig::default());
        for t in 0..30 {
            rj.observe(&est, &steady(1.0, -1.0, t));
        }
        // Large spike on lane 0 (σ = 2 → weight 2/3 ≥ tr·(2/3)).
        let reject = rj.observe(&est, &[50.0, -1.0, 0.0, 0.0]);
        assert!(reject);
        assert!(rj.downtime() > 0.0);
    }

    #[test]
    fn weak_lane_spike_alone_does_not_reject() {
        let est = fixed_estimate();
        let mut rj = RejectJob::new(RejectConfig::default());
        for t in 0..30 {
            rj.observe(&est, &steady(1.0, -1.0, t));
        }
        // Spike only on lane 1 (σ = 1 → weight 1/3 < tr·2/3).
        let reject = rj.observe(&est, &[1.0, 40.0, 0.0, 0.0]);
        assert!(!reject);
    }

    #[test]
    fn negative_spike_on_dominant_lane_lowers_sum() {
        // Signed (Algorithm 1 verbatim) mode: opposite-sign spikes cancel.
        let est = fixed_estimate();
        let mut rj = RejectJob::new(RejectConfig { signed_flags: true, ..Default::default() });
        for t in 0..30 {
            rj.observe(&est, &steady(1.0, -1.0, t));
        }
        // Negative spike on lane 0 and positive on lane 1:
        // R_s = (−1)(2/3) + (1)(1/3) < 0 → accept.
        let reject = rj.observe(&est, &[-40.0, 40.0, 0.0, 0.0]);
        assert!(!reject);
    }

    #[test]
    fn empty_estimate_always_accepts() {
        let est = Subspace::empty(4);
        let mut rj = RejectJob::new(RejectConfig::default());
        for _ in 0..20 {
            assert!(!rj.observe(&est, &[9.0, 9.0, 9.0, 9.0]));
        }
    }

    #[test]
    fn raw_sigma_mode_uses_absolute_threshold() {
        let est = fixed_estimate();
        let mut rj = RejectJob::new(RejectConfig {
            normalize_sigma: false,
            threshold: 1.0,
            ..Default::default()
        });
        for t in 0..30 {
            rj.observe(&est, &steady(1.0, -1.0, t));
        }
        // Lane-1 spike alone: R_s = σ₂ = 1.0 ≥ tr = 1.0 → reject in raw mode.
        assert!(rj.observe(&est, &[1.0, 40.0, 0.0, 0.0]));
    }

    #[test]
    fn reset_clears_downtime() {
        let est = fixed_estimate();
        let mut rj = RejectJob::new(RejectConfig::default());
        for t in 0..30 {
            rj.observe(&est, &steady(1.0, -1.0, t));
        }
        rj.observe(&est, &[50.0, -1.0, 0.0, 0.0]);
        assert!(rj.downtime() > 0.0);
        rj.reset();
        assert_eq!(rj.downtime(), 0.0);
        assert_eq!(rj.steps(), 0);
    }
}
