//! Admission policies for the simulator.
//!
//! The paper's evaluation compares rejection-signal scheduling against the
//! implicit alternatives: accepting everything, rejecting at random (what FD
//! degenerates to, §7.1), and an oracle that sees the true CPU Ready value.
//! All are expressed through the [`Admission`] trait so the simulator can
//! sweep policies uniformly.

use crate::fpca::Subspace;
use crate::rng::Xoshiro256;

/// A per-node admission policy: consumes the node's telemetry each timestep
/// and answers "can this node take a job right now?".
///
/// Policies that track a subspace also participate in federation: the
/// engine pushes [`Admission::iterate`] snapshots up the tree (delivery
/// may be delayed, so aggregators merge **stale** iterates) and feeds the
/// merged global view back through [`Admission::absorb`] when a node
/// (re)joins the pool. Memoryless policies keep the no-op defaults and
/// simply sit out the federation.
///
/// `Send` is a supertrait so the engine can shard the per-tick observe
/// loop across worker threads (`--threads N`): policies hold only
/// per-node state, each node lives in exactly one shard, and the merge
/// is by node id — no `Sync` needed, no shared mutation allowed.
pub trait Admission: Send {
    /// Observe the metric vector for the current timestep; returns `true`
    /// when a job arriving now would be ACCEPTED.
    fn observe(&mut self, y: &[f64]) -> bool;

    /// Policy tag for tables.
    fn name(&self) -> &'static str;

    /// Current local subspace iterate for federation pushes, if any.
    fn iterate(&self) -> Option<Subspace> {
        None
    }

    /// Pull a (possibly stale) merged global view into local state (§5.2
    /// transient-node seeding). `forget` down-weights the global side.
    fn absorb(&mut self, _global: &Subspace, _forget: f64) {}
}

/// PRONTO (or any embedding-backed node) as an [`Admission`] policy.
pub struct ProntoPolicy<E: crate::baselines::StreamingEmbedding> {
    node: super::NodeScheduler<E>,
}

impl<E: crate::baselines::StreamingEmbedding> ProntoPolicy<E> {
    pub fn new(node: super::NodeScheduler<E>) -> Self {
        Self { node }
    }

    pub fn node(&self) -> &super::NodeScheduler<E> {
        &self.node
    }
}

impl<E: crate::baselines::StreamingEmbedding + Send> Admission for ProntoPolicy<E> {
    fn observe(&mut self, y: &[f64]) -> bool {
        self.node.observe(y)
    }

    fn name(&self) -> &'static str {
        self.node.method()
    }

    fn iterate(&self) -> Option<Subspace> {
        let est = self.node.estimate();
        if est.is_empty() {
            None
        } else {
            Some(est)
        }
    }

    fn absorb(&mut self, global: &Subspace, forget: f64) {
        self.node.embedding_mut().absorb_estimate(global, forget);
    }
}

/// Accept always / reject with fixed probability (the "random scheduler"
/// the paper likens FD's behaviour to).
pub struct RandomPolicy {
    rng: Xoshiro256,
    reject_prob: f64,
}

impl RandomPolicy {
    pub fn new(reject_prob: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&reject_prob));
        Self { rng: Xoshiro256::seed_from_u64(seed), reject_prob }
    }

    /// Always-accept variant.
    pub fn always_accept(seed: u64) -> Self {
        Self::new(0.0, seed)
    }
}

impl Admission for RandomPolicy {
    fn observe(&mut self, _y: &[f64]) -> bool {
        !self.rng.bernoulli(self.reject_prob)
    }

    fn name(&self) -> &'static str {
        "RANDOM"
    }
}

/// Oracle that rejects exactly when the *current* CPU Ready value exceeds
/// the spike threshold — the information-upper-bound comparator (it reacts
/// instantly but cannot see the future either).
pub struct CpuReadyOracle {
    /// Index of cpu.ready in the feature vector.
    ready_idx: usize,
    threshold: f64,
}

impl CpuReadyOracle {
    pub fn new(ready_idx: usize, threshold: f64) -> Self {
        Self { ready_idx, threshold }
    }
}

impl Admission for CpuReadyOracle {
    fn observe(&mut self, y: &[f64]) -> bool {
        y[self.ready_idx] < self.threshold
    }

    fn name(&self) -> &'static str {
        "ORACLE"
    }
}

/// Static utilization-threshold policy (what CPU-utilization-based
/// schedulers reduce to on a single node): reject when a chosen metric
/// exceeds a fixed level.
pub struct ThresholdPolicy {
    metric_idx: usize,
    threshold: f64,
}

impl ThresholdPolicy {
    pub fn new(metric_idx: usize, threshold: f64) -> Self {
        Self { metric_idx, threshold }
    }
}

impl Admission for ThresholdPolicy {
    fn observe(&mut self, y: &[f64]) -> bool {
        y[self.metric_idx] < self.threshold
    }

    fn name(&self) -> &'static str {
        "UTIL-THRESH"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_policy_rates() {
        let mut p = RandomPolicy::new(0.3, 1);
        let n = 10_000;
        let accepts = (0..n).filter(|_| p.observe(&[0.0])).count();
        let rate = accepts as f64 / n as f64;
        assert!((rate - 0.7).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn always_accept_never_rejects() {
        let mut p = RandomPolicy::always_accept(2);
        assert!((0..100).all(|_| p.observe(&[1.0])));
    }

    #[test]
    fn oracle_tracks_threshold() {
        let mut o = CpuReadyOracle::new(0, 500.0);
        assert!(o.observe(&[499.0, 1.0]));
        assert!(!o.observe(&[500.0, 1.0]));
    }

    #[test]
    fn memoryless_policies_sit_out_federation() {
        let mut p = RandomPolicy::always_accept(3);
        assert!(p.iterate().is_none());
        // absorb is a no-op and must not panic.
        p.absorb(&Subspace::empty(8), 0.5);
        let mut o = CpuReadyOracle::new(0, 500.0);
        assert!(o.iterate().is_none());
    }

    #[test]
    fn pronto_policy_exposes_iterate_and_absorbs_global() {
        use crate::scheduler::{NodeScheduler, RejectConfig};
        use crate::telemetry::{GeneratorConfig, TraceGenerator};

        let gen = TraceGenerator::new(GeneratorConfig::default(), 17);
        let trace = gen.generate_vm(0, 256);
        let d = trace.dim();
        let mut warm = ProntoPolicy::new(NodeScheduler::new(d, RejectConfig::default()));
        assert!(warm.iterate().is_none(), "cold node has no iterate");
        for t in 0..trace.len() {
            warm.observe(trace.features(t));
        }
        let iterate = warm.iterate().expect("warm node has an iterate");
        assert_eq!(iterate.dim(), d);

        // A cold node absorbing the warm iterate is seeded immediately —
        // the §5.2 transient-node path, here under a *stale* iterate.
        let mut cold = ProntoPolicy::new(NodeScheduler::new(d, RejectConfig::default()));
        cold.absorb(&iterate, 0.5);
        let seeded = cold.iterate().expect("absorb seeded the estimate");
        assert_eq!(seeded.dim(), d);
        assert!(seeded.rank() > 0);
    }

    #[test]
    fn threshold_policy() {
        let mut p = ThresholdPolicy::new(1, 80.0);
        assert!(p.observe(&[0.0, 79.9]));
        assert!(!p.observe(&[0.0, 85.0]));
        assert_eq!(p.name(), "UTIL-THRESH");
    }
}
