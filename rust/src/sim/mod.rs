//! Data-center simulation and the §7.1 evaluation harness.
//!
//! * [`eval`] — trace-driven evaluation of a rejection-signal method
//!   against the CPU Ready ground truth: left/right-sided spike counts per
//!   CPU Ready spike (Figure 6), downtime and contained-spike percentages
//!   (Figure 7), and per-method aggregation over a fleet of VMs.
//! * [`datacenter`] — a job-level discrete-event simulator: Poisson
//!   arrivals, dispatcher probing, per-node admission by any
//!   [`crate::scheduler::Admission`] policy; used by the end-to-end
//!   example and the scalability bench.

pub mod datacenter;
pub mod eval;

pub use datacenter::{DataCenterSim, DispatchPolicy, SimConfig, SimReport};
pub use eval::{evaluate_method, EvalConfig, FleetEvaluation, NodeEvaluation};
