// Fixture: unregistered env key and environment mutation.
pub fn unregistered() -> bool {
    std::env::var("PRONTO_SECRET_KNOB").is_ok()
}

pub fn mutate() {
    std::env::set_var("PRONTO_BENCH_QUICK", "1");
}
