//! Horizontal scalability (§1, §9): aggregate federation throughput vs
//! node count under the thread-per-leaf runtime.
//!
//! Claim: "in the absence of communication latency, it exhibits
//! attractive horizontal scalability" — throughput grows near-linearly
//! until physical cores saturate.

use pronto::bench::Table;
use pronto::federation::{ConcurrentFederation, TreeTopology};
use pronto::telemetry::{GeneratorConfig, TraceGenerator};

fn main() {
    let quick = std::env::var("PRONTO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let steps = if quick { 512 } else { 2_048 };
    let sizes: &[usize] = if quick { &[1, 4, 8, 16] } else { &[1, 2, 4, 8, 16, 32, 64, 128] };

    let mut t = Table::new(
        "Scalability: federation throughput vs leaves (fanout 16)",
        &["leaves", "wall (s)", "obs/s", "speedup", "pushes"],
    );
    let mut base = 0.0f64;
    for &n in sizes {
        let gen = TraceGenerator::new(GeneratorConfig::default(), 1);
        let traces: Vec<_> = (0..n)
            .map(|v| gen.generate_vm_in_cluster(v / 16, v, steps))
            .collect();
        let fed = ConcurrentFederation::new(TreeTopology::new(n, 16), 4, 0.5)
            .with_push_every(64);
        // `run()` is wall-clock-free (determinism invariant); time it here.
        let started = std::time::Instant::now();
        let report = fed.run(traces).with_wall(started.elapsed());
        let thr = report.throughput();
        if n == 1 {
            base = thr;
        }
        t.row(&[
            format!("{n}"),
            format!("{:.3}", report.wall.as_secs_f64()),
            format!("{:.0}", thr),
            format!("{:.2}x", thr / base),
            format!("{}", report.pushes),
        ]);
    }
    t.print();
    t.maybe_write_csv("scalability");
    println!("\nshape: near-linear speedup until core count; flat wall time per leaf.");
}
