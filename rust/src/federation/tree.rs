//! The DASM federation tree (single-threaded engine, shardable fan-in).
//!
//! Since PR 9 the fan-in is organized for sharding. Level-0 aggregators
//! (the level directly above the leaves) accumulate leaf iterates
//! *incrementally*, exactly as the historical per-push path did: each
//! accepted iterate is merged into its group summary in arrival order.
//! Every level above is **derived** — recomputed on demand as a fixed
//! left-to-right fold over its non-empty children, and skipped entirely
//! when no child changed since the last reduction (dirty flag).
//!
//! Because the upper levels are a pure function of level-0 state, the
//! batched [`FederationTree::push_from_leaves`] entry point — which
//! shards *disjoint* level-0 groups across a [`minipool::WorkerPool`] —
//! lands in bit-for-bit the state the equivalent sequence of
//! [`FederationTree::push_from_leaf`] calls produces, at every pool
//! width. Determinism comes from the structure, not from scheduling:
//! each group's pending iterates are merged in batch order by exactly
//! one worker, groups never share state, and the upward reduction is a
//! single-threaded fixed-order fold.

use crate::fpca::{merge_subspaces, MergeOptions, Subspace};
use minipool::{Task, WorkerPool};

/// Identifier of a tree node (leaves and aggregators share the space).
pub type NodeId = usize;

/// Result of a leaf push.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// The iterate moved less than ε since the last push; nothing sent.
    Suppressed,
    /// The iterate was merged upward through `levels` aggregators.
    Propagated { levels: usize },
}

/// Shape of the federation tree: `q` levels with the given fanout at each
/// internal level. The paper expects "shallow yet very large fan-out".
#[derive(Debug, Clone)]
pub struct TreeTopology {
    /// Number of leaves (compute nodes).
    pub leaves: usize,
    /// Aggregator fanout (children per aggregator).
    pub fanout: usize,
}

impl TreeTopology {
    pub fn new(leaves: usize, fanout: usize) -> Self {
        assert!(leaves >= 1 && fanout >= 2);
        Self { leaves, fanout }
    }

    /// Number of levels above the leaves (root included).
    pub fn levels(&self) -> usize {
        let mut n = self.leaves;
        let mut levels = 0;
        while n > 1 {
            n = n.div_ceil(self.fanout);
            levels += 1;
        }
        levels.max(1)
    }
}

/// One aggregator's state: the merged summary of its subtree, plus a
/// dirty flag meaning "changed since my parent last reduced over me".
#[derive(Debug, Clone)]
struct Aggregator {
    summary: Subspace,
    merges: usize,
    dirty: bool,
}

/// The federation tree engine.
///
/// Leaves are external ([`crate::scheduler::NodeScheduler`]s, or anything
/// producing a [`Subspace`]); the tree stores per-leaf "last pushed"
/// snapshots for the ε gate plus one [`Aggregator`] per internal node.
pub struct FederationTree {
    topo: TreeTopology,
    d: usize,
    /// Merge rank used at aggregators.
    rank: usize,
    /// ε threshold of the push gate.
    epsilon: f64,
    /// Last pushed iterate per leaf (None = never pushed).
    last_push: Vec<Option<Subspace>>,
    /// Aggregators per level: `aggs[0]` is the level directly above the
    /// leaves, the last level has a single root.
    aggs: Vec<Vec<Aggregator>>,
    pushes: usize,
    suppressed: usize,
}

impl FederationTree {
    pub fn new(topo: TreeTopology, d: usize, rank: usize, epsilon: f64) -> Self {
        let mut aggs = Vec::new();
        let mut width = topo.leaves;
        loop {
            width = width.div_ceil(topo.fanout);
            aggs.push(vec![
                Aggregator { summary: Subspace::empty(d), merges: 0, dirty: false };
                width.max(1)
            ]);
            if width <= 1 {
                break;
            }
        }
        Self {
            last_push: vec![None; topo.leaves],
            topo,
            d,
            rank,
            epsilon,
            aggs,
            pushes: 0,
            suppressed: 0,
        }
    }

    pub fn topology(&self) -> &TreeTopology {
        &self.topo
    }

    /// Total pushes that actually propagated.
    pub fn pushes(&self) -> usize {
        self.pushes
    }

    /// Pushes suppressed by the ε gate.
    pub fn suppressed(&self) -> usize {
        self.suppressed
    }

    /// Merge count of the aggregator at `(level, index)` — level 0 is the
    /// level directly above the leaves. Level-0 counters tick once per
    /// accepted leaf iterate; upper-level counters tick once per pairwise
    /// merge performed while re-deriving a parent summary, so a parent
    /// whose subtree didn't change contributes nothing (the dirty-flag
    /// skip this exposes is pinned by a regression test).
    pub fn merges_at(&self, level: usize, index: usize) -> usize {
        self.aggs[level][index].merges
    }

    /// Forget the ε-gate baseline for `leaf` (call when the node behind
    /// the leaf restarts: its first post-rejoin push must not be
    /// suppressed just because the re-learned iterate resembles the
    /// pre-restart one).
    pub fn reset_leaf_gate(&mut self, leaf: NodeId) {
        assert!(leaf < self.topo.leaves);
        self.last_push[leaf] = None;
    }

    /// Leaf `leaf` offers its current iterate. Applies the ε gate, merges
    /// into the leaf's level-0 group, then re-derives the dirty ancestors
    /// up to the root (DASM: summaries travel up once).
    pub fn push_from_leaf(&mut self, leaf: NodeId, iterate: &Subspace) -> PushOutcome {
        assert!(leaf < self.topo.leaves);
        assert_eq!(iterate.dim(), self.d);
        if iterate.is_empty() {
            return PushOutcome::Suppressed;
        }
        if let Some(prev) = &self.last_push[leaf] {
            if prev.abs_diff(iterate) <= self.epsilon {
                self.suppressed += 1;
                return PushOutcome::Suppressed;
            }
        }
        self.last_push[leaf] = Some(iterate.clone());

        let group = leaf / self.topo.fanout;
        let agg = &mut self.aggs[0][group];
        agg.summary = merge_subspaces(
            &agg.summary,
            iterate,
            MergeOptions::rank(self.rank),
        );
        agg.merges += 1;
        agg.dirty = true;
        self.pushes += 1;
        self.reduce_upward();
        PushOutcome::Propagated { levels: self.aggs.len() }
    }

    /// Batched fan-in: apply every `(leaf, iterate)` pair, sharding the
    /// per-group ε-gating and level-0 merges across `pool`, then reduce
    /// upward once. Ends in **bit-for-bit** the state the same pairs
    /// pushed one-by-one through [`FederationTree::push_from_leaf`] would
    /// produce, at every pool width:
    ///
    /// * pairs are bucketed by level-0 group with a stable counting sort,
    ///   so each group sees its iterates in batch order;
    /// * a group's aggregator and its leaves' ε-gate snapshots are owned
    ///   by exactly one worker (groups cover disjoint contiguous leaf
    ///   ranges, so `last_push` shards along group boundaries);
    /// * the upward reduction is a single-threaded left-to-right fold
    ///   that skips parents whose children are all clean.
    pub fn push_from_leaves(&mut self, items: &[(NodeId, &Subspace)], pool: &WorkerPool) {
        if items.is_empty() {
            return;
        }
        let fanout = self.topo.fanout;
        let leaves = self.topo.leaves;
        let groups = self.aggs[0].len();
        let epsilon = self.epsilon;
        let rank = self.rank;

        // Stable counting sort of item indices by level-0 group.
        let mut counts = vec![0usize; groups];
        for &(leaf, iterate) in items {
            assert!(leaf < leaves);
            assert_eq!(iterate.dim(), self.d);
            counts[leaf / fanout] += 1;
        }
        let mut offsets = vec![0usize; groups + 1];
        for g in 0..groups {
            offsets[g + 1] = offsets[g] + counts[g];
        }
        let mut order = vec![0usize; items.len()];
        let mut cursor = offsets.clone();
        for (ix, &(leaf, _)) in items.iter().enumerate() {
            let g = leaf / fanout;
            order[cursor[g]] = ix;
            cursor[g] += 1;
        }

        // Contiguous group ranges, one per worker chunk. Level-0
        // aggregators and the leaf gate snapshots shard along the same
        // boundaries (group g owns leaves [g·fanout, (g+1)·fanout)).
        let per = groups.div_ceil(pool.threads()).max(1);
        let mut ranges: Vec<(usize, usize)> = Vec::new();
        let mut g = 0;
        while g < groups {
            let hi = (g + per).min(groups);
            ranges.push((g, hi));
            g = hi;
        }

        let mut counters = vec![(0usize, 0usize); ranges.len()];
        let order_ref: &[usize] = &order;
        let offsets_ref: &[usize] = &offsets;
        let (level0, _upper) = self.aggs.split_at_mut(1);
        let mut agg_rest: &mut [Aggregator] = &mut level0[0];
        let mut lp_rest: &mut [Option<Subspace>] = &mut self.last_push;
        let mut lp_consumed = 0;
        let mut tasks: Vec<Task<'_>> = Vec::with_capacity(ranges.len());
        for (&(g_lo, g_hi), cnt) in ranges.iter().zip(counters.iter_mut()) {
            let leaf_lo = g_lo * fanout;
            let leaf_hi = (g_hi * fanout).min(leaves);
            let (agg_chunk, agg_tail) =
                std::mem::take(&mut agg_rest).split_at_mut(g_hi - g_lo);
            agg_rest = agg_tail;
            debug_assert_eq!(leaf_lo, lp_consumed); // ranges are contiguous from 0
            let (lp_chunk, lp_tail) =
                std::mem::take(&mut lp_rest).split_at_mut(leaf_hi - lp_consumed);
            lp_rest = lp_tail;
            lp_consumed = leaf_hi;
            if offsets_ref[g_hi] == offsets_ref[g_lo] {
                continue; // no pending iterates in this chunk
            }
            tasks.push(Box::new(move || {
                for g in g_lo..g_hi {
                    let agg = &mut agg_chunk[g - g_lo];
                    for &ix in &order_ref[offsets_ref[g]..offsets_ref[g + 1]] {
                        let (leaf, iterate) = items[ix];
                        if iterate.is_empty() {
                            continue;
                        }
                        let slot = &mut lp_chunk[leaf - leaf_lo];
                        if let Some(prev) = slot {
                            if prev.abs_diff(iterate) <= epsilon {
                                cnt.1 += 1;
                                continue;
                            }
                        }
                        *slot = Some(iterate.clone());
                        agg.summary = merge_subspaces(
                            &agg.summary,
                            iterate,
                            MergeOptions::rank(rank),
                        );
                        agg.merges += 1;
                        agg.dirty = true;
                        cnt.0 += 1;
                    }
                }
            }));
        }
        pool.run(tasks);

        for (pushed, suppressed) in counters {
            self.pushes += pushed;
            self.suppressed += suppressed;
        }
        self.reduce_upward();
    }

    /// Re-derive every level above 0: a parent with at least one dirty
    /// child is recomputed as a left-to-right fold over its *non-empty*
    /// children (the first contributes `truncate(rank)` — bit-equal to
    /// merging it into an empty summary — each further one a pairwise
    /// [`merge_subspaces`]); a parent whose children are all clean keeps
    /// its summary and merge counter untouched.
    fn reduce_upward(&mut self) {
        let fanout = self.topo.fanout;
        let rank = self.rank;
        let d = self.d;
        for level in 1..self.aggs.len() {
            let (below, above) = self.aggs.split_at_mut(level);
            let children = &mut below[level - 1];
            let parents = &mut above[0];
            for (p, parent) in parents.iter_mut().enumerate() {
                let lo = p * fanout;
                let hi = (lo + fanout).min(children.len());
                if !children[lo..hi].iter().any(|c| c.dirty) {
                    continue;
                }
                let mut acc: Option<Subspace> = None;
                let mut merges = 0usize;
                for child in &children[lo..hi] {
                    if child.summary.is_empty() {
                        continue;
                    }
                    acc = Some(match acc {
                        None => child.summary.truncate(rank),
                        Some(folded) => {
                            merges += 1;
                            merge_subspaces(
                                &folded,
                                &child.summary,
                                MergeOptions::rank(rank),
                            )
                        }
                    });
                }
                parent.summary = acc.unwrap_or_else(|| Subspace::empty(d));
                parent.merges += merges;
                parent.dirty = true;
            }
            for child in children.iter_mut() {
                child.dirty = false;
            }
        }
        if let Some(top) = self.aggs.last_mut() {
            for agg in top.iter_mut() {
                agg.dirty = false;
            }
        }
    }

    /// The merged global view at the root (empty until any push).
    pub fn global_view(&self) -> &Subspace {
        &self.aggs.last().unwrap()[0].summary
    }

    /// The merged view of the level-0 aggregator covering `leaf` — what a
    /// node would pull to seed/refresh its local estimate (§5.2).
    pub fn local_group_view(&self, leaf: NodeId) -> &Subspace {
        &self.aggs[0][leaf / self.topo.fanout].summary
    }

    /// Merge the global view *into* a leaf estimate (the "pull" direction),
    /// returning the refreshed estimate. `forget` down-weights the global
    /// side so a node's own history dominates.
    pub fn pull_global(&self, local: &Subspace, forget: f64) -> Subspace {
        merge_subspaces(
            self.global_view(),
            local,
            MergeOptions { rank: self.rank, forget, enhance: 1.0 },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::subspace_distance;
    use crate::proptest::{gen_low_rank, gen_orthonormal, gen_spectrum};
    use crate::rng::Xoshiro256;

    fn subspace(rng: &mut Xoshiro256, d: usize, r: usize) -> Subspace {
        Subspace::new(gen_orthonormal(rng, d, r), gen_spectrum(rng, r))
    }

    /// Bitwise state equality: counters, every aggregator summary at every
    /// level, and the per-leaf ε-gate snapshots. Merge counters compare at
    /// level 0 only — level-0 counts tick once per accepted iterate and are
    /// therefore flush-invariant, while upper-level counts price the
    /// re-derivations actually performed, which legitimately depend on how
    /// the same pushes were grouped into flushes (per-push sequential calls
    /// re-derive ancestors once per push; a batch re-derives them once).
    fn assert_trees_equal(a: &FederationTree, b: &FederationTree) {
        assert_eq!(a.pushes, b.pushes);
        assert_eq!(a.suppressed, b.suppressed);
        assert_eq!(a.aggs.len(), b.aggs.len());
        for (level, (la, lb)) in a.aggs.iter().zip(b.aggs.iter()).enumerate() {
            assert_eq!(la.len(), lb.len());
            for (idx, (x, y)) in la.iter().zip(lb.iter()).enumerate() {
                if level == 0 {
                    assert_eq!(x.merges, y.merges, "merges at level 0 agg {idx}");
                }
                assert_eq!(
                    x.summary.u.data(),
                    y.summary.u.data(),
                    "basis at level {level} agg {idx}"
                );
                assert_eq!(x.summary.sigma, y.summary.sigma);
            }
        }
        for (leaf, (x, y)) in a.last_push.iter().zip(b.last_push.iter()).enumerate() {
            match (x, y) {
                (None, None) => {}
                (Some(sx), Some(sy)) => {
                    assert_eq!(sx.u.data(), sy.u.data(), "gate snapshot leaf {leaf}");
                    assert_eq!(sx.sigma, sy.sigma);
                }
                _ => panic!("gate snapshot presence differs at leaf {leaf}"),
            }
        }
    }

    #[test]
    fn topology_levels() {
        assert_eq!(TreeTopology::new(1, 4).levels(), 1);
        assert_eq!(TreeTopology::new(4, 4).levels(), 1);
        assert_eq!(TreeTopology::new(16, 4).levels(), 2);
        assert_eq!(TreeTopology::new(100, 10).levels(), 2);
        assert_eq!(TreeTopology::new(101, 10).levels(), 3);
    }

    #[test]
    fn push_reaches_root() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut tree = FederationTree::new(TreeTopology::new(16, 4), 10, 4, 0.0);
        let s = subspace(&mut rng, 10, 3);
        let out = tree.push_from_leaf(5, &s);
        assert_eq!(out, PushOutcome::Propagated { levels: 2 });
        assert!(!tree.global_view().is_empty());
        assert!(subspace_distance(&tree.global_view().u, &s.u) < 1e-6);
    }

    #[test]
    fn epsilon_gate_suppresses_unchanged_iterates() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut tree = FederationTree::new(TreeTopology::new(8, 4), 10, 4, 0.05);
        let s = subspace(&mut rng, 10, 3);
        assert!(matches!(tree.push_from_leaf(0, &s), PushOutcome::Propagated { .. }));
        // Identical iterate → suppressed.
        assert_eq!(tree.push_from_leaf(0, &s), PushOutcome::Suppressed);
        assert_eq!(tree.suppressed(), 1);
        // A different leaf still propagates.
        assert!(matches!(tree.push_from_leaf(1, &s), PushOutcome::Propagated { .. }));
    }

    #[test]
    fn empty_iterate_never_pushes() {
        let mut tree = FederationTree::new(TreeTopology::new(4, 2), 6, 2, 0.0);
        assert_eq!(
            tree.push_from_leaf(0, &Subspace::empty(6)),
            PushOutcome::Suppressed
        );
    }

    #[test]
    fn global_view_aggregates_shared_structure() {
        // All leaves observe streams drawn from the same rank-2 subspace;
        // the root view should recover that subspace.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let d = 16;
        let shared = gen_low_rank(&mut rng, d, 400, 2, 0.01);
        let truth = crate::linalg::svd_truncated(&shared, 2);

        let mut tree = FederationTree::new(TreeTopology::new(8, 4), d, 4, 0.0);
        for leaf in 0..8 {
            // Each leaf sees a disjoint chunk of the stream.
            let lo = leaf * 50;
            let mut chunk = crate::linalg::Mat::zeros(d, 50);
            for t in 0..50 {
                chunk.col_mut(t).copy_from_slice(shared.col(lo + t));
            }
            let svd = crate::linalg::svd_truncated(&chunk, 2);
            tree.push_from_leaf(leaf, &Subspace::new(svd.u, svd.sigma));
        }
        let dist = subspace_distance(&tree.global_view().truncate(2).u, &truth.u);
        assert!(dist < 0.05, "global view off: {dist}");
    }

    #[test]
    fn local_group_view_scopes_to_subtree() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut tree = FederationTree::new(TreeTopology::new(8, 4), 10, 4, 0.0);
        let s0 = subspace(&mut rng, 10, 2);
        tree.push_from_leaf(0, &s0); // group 0 (leaves 0–3)
        assert!(!tree.local_group_view(1).is_empty());
        assert!(tree.local_group_view(5).is_empty()); // group 1 untouched
    }

    #[test]
    fn pull_global_merges_views() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut tree = FederationTree::new(TreeTopology::new(4, 4), 12, 4, 0.0);
        let remote = subspace(&mut rng, 12, 3);
        tree.push_from_leaf(2, &remote);
        let local = subspace(&mut rng, 12, 3);
        let refreshed = tree.pull_global(&local, 0.5);
        assert_eq!(refreshed.dim(), 12);
        assert!(refreshed.rank() <= 4);
        // Refreshed view is not identical to local: global info arrived.
        assert!(refreshed.abs_diff(&local) > 1e-6);
    }

    #[test]
    fn clean_ancestors_skip_re_merging() {
        // 8 leaves, fanout 2 → level 0 has 4 groups, level 1 has 2
        // aggregators, level 2 is the root. Level-1 aggregator 0 covers
        // groups {0, 1} (leaves 0–3); aggregator 1 covers groups {2, 3}.
        let mut rng = Xoshiro256::seed_from_u64(6);
        let mut tree = FederationTree::new(TreeTopology::new(8, 2), 10, 4, 0.0);
        let (a, b, c) = (
            subspace(&mut rng, 10, 2),
            subspace(&mut rng, 10, 2),
            subspace(&mut rng, 10, 2),
        );

        tree.push_from_leaf(0, &a); // group 0 only → no pairwise fold yet
        assert_eq!(tree.merges_at(1, 0), 0);
        tree.push_from_leaf(2, &b); // groups 0 and 2 non-empty → one fold
        assert_eq!(tree.merges_at(1, 0), 1);
        assert_eq!(tree.merges_at(1, 1), 0); // right subtree untouched

        // A push in the *right* subtree must not re-derive the clean left
        // level-1 aggregator: its counter stays at 1.
        tree.push_from_leaf(4, &c);
        assert_eq!(tree.merges_at(1, 0), 1, "clean ancestor was re-merged");
        assert_eq!(tree.merges_at(1, 1), 0); // single non-empty child
        assert_eq!(tree.merges_at(2, 0), 1); // root folded both halves

        // And a push back in the left subtree re-derives only the left.
        tree.push_from_leaf(1, &c);
        assert_eq!(tree.merges_at(1, 0), 2);
        assert_eq!(tree.merges_at(1, 1), 0);
    }

    #[test]
    fn batched_push_matches_sequential_at_every_width() {
        // A batch exercising every gate path: normal pushes, a duplicate
        // leaf whose second iterate is ε-suppressed, an empty iterate,
        // and leaves spread across groups of a 3-level tree.
        let mut rng = Xoshiro256::seed_from_u64(7);
        let d = 12;
        let topo = || TreeTopology::new(23, 3); // 23 → 8 → 3 → 1
        let subs: Vec<Subspace> = (0..8).map(|_| subspace(&mut rng, d, 3)).collect();
        let empty = Subspace::empty(d);
        let items: Vec<(NodeId, &Subspace)> = vec![
            (0, &subs[0]),
            (22, &subs[1]),
            (7, &subs[2]),
            (7, &subs[2]), // ε-suppressed (identical to previous push)
            (11, &empty),  // never counted
            (3, &subs[3]),
            (15, &subs[4]),
            (7, &subs[5]), // moved again → propagates
            (4, &subs[6]),
            (16, &subs[7]),
        ];

        let mut seq = FederationTree::new(topo(), d, 4, 0.05);
        for &(leaf, s) in &items {
            seq.push_from_leaf(leaf, s);
        }
        assert!(seq.pushes() > 0 && seq.suppressed() > 0);

        for width in [1, 2, 4, 7] {
            let pool = WorkerPool::new(width);
            let mut batched = FederationTree::new(topo(), d, 4, 0.05);
            batched.push_from_leaves(&items, &pool);
            assert_trees_equal(&seq, &batched);

            // Split into two flushes (dirty flags must carry across calls).
            let mut split = FederationTree::new(topo(), d, 4, 0.05);
            split.push_from_leaves(&items[..4], &pool);
            split.push_from_leaves(&items[4..], &pool);
            assert_trees_equal(&seq, &split);
        }
    }
}
