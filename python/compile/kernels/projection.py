"""L1 Pallas kernels: the tiled matmuls on PRONTO's hot path.

Two kernels, both thin wrappers over an MXU-shaped tiled matmul:

* ``project_block`` — P = Y·U, projecting a block of b observations
  (b × d) onto the embedding (d × r): the per-timestep hot operation of
  Reject-Job, batched per block.
* ``gram`` — G = MᵀM for the tall-skinny update matrix M (d × k): the
  expensive input of the FPCA block update.

TPU adaptation (DESIGN.md §6): the paper's prototype is numpy on CPU; on a
TPU the natural formulation tiles the operands into VMEM-resident blocks
and feeds the MXU. BlockSpecs below express that HBM→VMEM schedule. All
``pallas_call``s use ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls, and interpret-mode lowers to plain HLO so the AOT
artifact stays executable everywhere (numerics validated against
``ref.py`` in pytest).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm × bk) @ (bk × bn) tile product, accumulated over the k grid.

    The k dimension is the innermost grid axis, so the output tile stays
    resident (in VMEM on a real TPU) while partial products accumulate —
    the classic MXU-friendly schedule.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def _pad_to(x, rows, cols):
    """Zero-pad a 2-D array up to (rows, cols)."""
    r, c = x.shape
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def matmul_tiled(x, y, *, bm=32, bk=64, bn=32):
    """Tiled matmul ``x @ y`` via a Pallas grid, padding to tile multiples.

    Tile defaults are sized for PRONTO's shapes (d ≈ 52 → one 64-wide k
    tile; b, r ≤ 32 → single m/n tiles), keeping the whole working set a
    few KB — far under VMEM budgets.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul dim mismatch {x.shape} @ {y.shape}"
    mp = -(-m // bm) * bm
    kp = -(-k // bk) * bk
    np_ = -(-n // bn) * bn
    xp = _pad_to(x, mp, kp)
    yp = _pad_to(y, kp, np_)

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(xp, yp)
    return out[:m, :n]


def project_block(y_block, u):
    """P = Y·U — project b stacked observations onto the embedding.

    Args:
      y_block: (b, d) block of telemetry vectors (rows = timesteps).
      u: (d, r) orthonormal embedding.

    Returns:
      (b, r) projections.
    """
    return matmul_tiled(y_block, u)


def gram(m):
    """G = MᵀM for tall-skinny M (d × k): the FPCA update's Gram product."""
    return matmul_tiled(m.T, m)
