//! Quickstart: one node, one telemetry stream, live admission decisions.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Generates a synthetic VM trace (52 VMware metrics at 20 s cadence),
//! streams it through a PRONTO node (FPCA-Edge + Reject-Job), and prints
//! the admission timeline plus summary statistics.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::scheduler::{NodeScheduler, RejectConfig};
use pronto::telemetry::{GeneratorConfig, TraceGenerator};

fn main() {
    let steps = 4_000;
    let gen = TraceGenerator::new(GeneratorConfig::default(), 42);
    let trace = gen.generate_vm(0, steps);
    println!(
        "trace: {} timesteps x {} metrics (VM 0, 20s cadence, ~{:.1} h)",
        trace.len(),
        trace.dim(),
        trace.len() as f64 * 20.0 / 3600.0
    );

    let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());
    let mut rejected_at = Vec::new();
    for t in 0..trace.len() {
        let accept = node.observe(trace.features(t));
        if !accept {
            rejected_at.push(t);
        }
    }

    let stats = node.stats();
    println!("\nadmission summary");
    println!("  steps observed        : {}", stats.steps);
    println!("  rejection raised      : {} steps", stats.rejected_steps);
    println!("  downtime              : {:.2}%", 100.0 * stats.downtime());
    println!("  current rank          : {}", node.estimate().rank());
    println!(
        "  leading singular value: {:.3}",
        node.estimate().sigma.first().copied().unwrap_or(0.0)
    );

    // Cross-check the signal against the CPU Ready ground truth.
    let threshold = 1000.0;
    let spikes: Vec<usize> = (0..trace.len())
        .filter(|&t| trace.cpu_ready(t) >= threshold)
        .collect();
    let predicted = spikes
        .iter()
        .filter(|&&t| {
            let lo = t.saturating_sub(5);
            rejected_at.iter().any(|&r| r >= lo && r <= t)
        })
        .count();
    println!("\nvs CPU Ready ground truth (spike = ready >= {threshold} ms)");
    println!("  CPU Ready spikes      : {}", spikes.len());
    println!(
        "  predicted (<=5 steps early): {} ({:.0}%)",
        predicted,
        100.0 * predicted as f64 / spikes.len().max(1) as f64
    );
}
