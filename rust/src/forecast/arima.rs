//! ARIMA(p, d, q) forecasting (§3.1 method 3).
//!
//! Fitting follows the Hannan–Rissanen two-stage scheme: (1) fit a long AR
//! by ordinary least squares to estimate innovations, (2) regress the
//! differenced series on its own lags *and* the lagged innovation estimates
//! to get the AR + MA coefficients. Order (p, d, q) is selected per fit by
//! minimum AIC over a small grid, exactly as the paper tunes "locally for
//! each forecast according to the smallest AIC criteria". The "average VM"
//! cluster variant fits on the mean series of the pool.

use super::{with_normalization, Forecaster};

/// An ARIMA order triple.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArimaOrder {
    pub p: usize,
    pub d: usize,
    pub q: usize,
}

/// ARIMA forecaster with AIC-selected order.
#[derive(Debug, Clone)]
pub struct Arima {
    /// Candidate p values.
    pub p_grid: Vec<usize>,
    /// Candidate d values.
    pub d_grid: Vec<usize>,
    /// Candidate q values.
    pub q_grid: Vec<usize>,
    /// Fit on the pool's average series ("average VM", §3.1) when a pool
    /// is supplied.
    pub use_pool_average: bool,
}

impl Default for Arima {
    fn default() -> Self {
        Self {
            p_grid: vec![1, 2, 3],
            d_grid: vec![0, 1],
            q_grid: vec![0, 1],
            use_pool_average: true,
        }
    }
}

/// Difference a series `d` times.
fn difference(xs: &[f64], d: usize) -> Vec<f64> {
    let mut cur = xs.to_vec();
    for _ in 0..d {
        cur = cur.windows(2).map(|w| w[1] - w[0]).collect();
    }
    cur
}

/// Invert differencing for a forecast sequence given the history tail.
fn undifference(history: &[f64], diffed_forecast: &[f64], d: usize) -> Vec<f64> {
    if d == 0 {
        return diffed_forecast.to_vec();
    }
    // Recursive reconstruction: for d=1, x_{t+1} = x_t + Δx_{t+1}; higher d
    // applies the same one level down.
    let lower_history = difference(history, d - 1);
    let mut last = *lower_history.last().expect("history too short for d");
    let mut lower_forecast = Vec::with_capacity(diffed_forecast.len());
    for &dx in diffed_forecast {
        last += dx;
        lower_forecast.push(last);
    }
    undifference(history, &lower_forecast, d - 1)
}

/// OLS solve for small systems via normal equations + Gaussian elimination.
fn ols(x_rows: &[Vec<f64>], y: &[f64]) -> Option<Vec<f64>> {
    let n = x_rows.len();
    if n == 0 {
        return None;
    }
    let k = x_rows[0].len();
    if n < k + 1 {
        return None;
    }
    // Normal equations A = XᵀX (k×k), b = Xᵀy.
    let mut a = vec![vec![0.0; k]; k];
    let mut b = vec![0.0; k];
    for (row, &yi) in x_rows.iter().zip(y) {
        for i in 0..k {
            b[i] += row[i] * yi;
            for j in 0..k {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    // Ridge jitter for stability.
    for (i, row) in a.iter_mut().enumerate() {
        row[i] += 1e-8;
    }
    // Gaussian elimination with partial pivoting.
    let mut aug: Vec<Vec<f64>> = a
        .into_iter()
        .zip(b)
        .map(|(mut row, bi)| {
            row.push(bi);
            row
        })
        .collect();
    for col in 0..k {
        let pivot = (col..k).max_by(|&i, &j| {
            aug[i][col].abs().partial_cmp(&aug[j][col].abs()).unwrap()
        })?;
        if aug[pivot][col].abs() < 1e-12 {
            return None;
        }
        aug.swap(col, pivot);
        let pv = aug[col][col];
        for i in 0..k {
            if i == col {
                continue;
            }
            let f = aug[i][col] / pv;
            for j in col..=k {
                aug[i][j] -= f * aug[col][j];
            }
        }
    }
    Some((0..k).map(|i| aug[i][k] / aug[i][i]).collect())
}

/// A fitted ARMA(p, q) model on a (differenced, normalized) series.
#[derive(Debug, Clone)]
struct ArmaFit {
    intercept: f64,
    ar: Vec<f64>,
    ma: Vec<f64>,
    /// Residual variance.
    sigma2: f64,
    /// Innovation estimates aligned with the tail of the series.
    residuals: Vec<f64>,
    aic: f64,
}

/// Hannan–Rissanen ARMA fit. Returns None when the series is too short.
fn fit_arma(xs: &[f64], p: usize, q: usize) -> Option<ArmaFit> {
    let n = xs.len();
    let long_ar = (p + q + 3).min(n / 3).max(1);
    if n < long_ar + p.max(q) + 8 {
        return None;
    }

    // Stage 1: long AR for innovation estimates.
    let mut rows = Vec::new();
    let mut ys = Vec::new();
    for t in long_ar..n {
        let mut row = Vec::with_capacity(long_ar + 1);
        row.push(1.0);
        for l in 1..=long_ar {
            row.push(xs[t - l]);
        }
        rows.push(row);
        ys.push(xs[t]);
    }
    let coef = ols(&rows, &ys)?;
    let mut eps = vec![0.0; n];
    for t in long_ar..n {
        let mut pred = coef[0];
        for l in 1..=long_ar {
            pred += coef[l] * xs[t - l];
        }
        eps[t] = xs[t] - pred;
    }

    // Stage 2: regress on p AR lags + q innovation lags.
    let start = long_ar + q.max(1);
    let mut rows2 = Vec::new();
    let mut ys2 = Vec::new();
    for t in start.max(p)..n {
        let mut row = Vec::with_capacity(1 + p + q);
        row.push(1.0);
        for l in 1..=p {
            row.push(xs[t - l]);
        }
        for l in 1..=q {
            row.push(eps[t - l]);
        }
        rows2.push(row);
        ys2.push(xs[t]);
    }
    let coef2 = ols(&rows2, &ys2)?;
    let intercept = coef2[0];
    let ar = coef2[1..1 + p].to_vec();
    let ma = coef2[1 + p..].to_vec();

    // Residuals + AIC.
    let mut sse = 0.0;
    let m = rows2.len();
    for (row, &yt) in rows2.iter().zip(&ys2) {
        let pred: f64 = row.iter().zip(&coef2).map(|(a, b)| a * b).sum();
        sse += (yt - pred) * (yt - pred);
    }
    let sigma2 = (sse / m as f64).max(1e-12);
    let kparams = (1 + p + q) as f64;
    let aic = m as f64 * sigma2.ln() + 2.0 * kparams;

    Some(ArmaFit { intercept, ar, ma, sigma2, residuals: eps, aic })
}

impl Arima {
    /// Fit all grid orders on the differenced series; lowest AIC wins.
    fn best_fit(&self, xs: &[f64]) -> Option<(ArimaOrder, ArmaFit)> {
        let mut best: Option<(ArimaOrder, ArmaFit)> = None;
        for &d in &self.d_grid {
            if xs.len() <= d + 10 {
                continue;
            }
            let diffed = difference(xs, d);
            for &p in &self.p_grid {
                for &q in &self.q_grid {
                    if let Some(fit) = fit_arma(&diffed, p, q) {
                        let order = ArimaOrder { p, d, q };
                        if best.as_ref().map(|(_, b)| fit.aic < b.aic).unwrap_or(true) {
                            best = Some((order, fit));
                        }
                    }
                }
            }
        }
        best
    }

    /// Multi-step forecast on the differenced scale, then un-difference.
    fn forecast_scaled(&self, xs: &[f64], horizon: usize) -> Vec<f64> {
        let Some((order, fit)) = self.best_fit(xs) else {
            // Degenerate fallback: persistence.
            return vec![*xs.last().unwrap(); horizon];
        };
        let diffed = difference(xs, order.d);
        let mut series = diffed.clone();
        let mut eps = fit.residuals.clone();
        let mut out = Vec::with_capacity(horizon);
        for _ in 0..horizon {
            let t = series.len();
            let mut pred = fit.intercept;
            for (l, phi) in fit.ar.iter().enumerate() {
                if t > l {
                    pred += phi * series[t - 1 - l];
                }
            }
            for (l, theta) in fit.ma.iter().enumerate() {
                if eps.len() > l {
                    pred += theta * eps[eps.len() - 1 - l];
                }
            }
            series.push(pred);
            eps.push(0.0); // future innovations have zero expectation
            out.push(pred);
        }
        let _ = fit.sigma2;
        undifference(xs, &out, order.d)
    }
}

impl Arima {
    /// One-step rolling predictions on the (normalized) scale: fit once on
    /// the history, then predict each future step from the actual values
    /// revealed so far, updating the innovation estimates as we go.
    fn rolling_scaled(&self, hist: &[f64], future: &[f64]) -> Vec<f64> {
        let Some((order, fit)) = self.best_fit(hist) else {
            // Persistence fallback.
            let mut prev = *hist.last().unwrap();
            return future
                .iter()
                .map(|&a| {
                    let p = prev;
                    prev = a;
                    p
                })
                .collect();
        };
        // Work on the differenced joint series.
        let mut joint = hist.to_vec();
        let mut diffed = difference(hist, order.d);
        let mut eps = fit.residuals.clone();
        let mut out = Vec::with_capacity(future.len());
        for &actual in future {
            let t = diffed.len();
            let mut pred_d = fit.intercept;
            for (l, phi) in fit.ar.iter().enumerate() {
                if t > l {
                    pred_d += phi * diffed[t - 1 - l];
                }
            }
            for (l, theta) in fit.ma.iter().enumerate() {
                if eps.len() > l {
                    pred_d += theta * eps[eps.len() - 1 - l];
                }
            }
            // Un-difference the one-step prediction against the actual tail.
            let pred = if order.d == 0 {
                pred_d
            } else {
                // For d >= 1 the one-step reconstruction only needs the
                // last actual level(s).
                let lower = difference(&joint, order.d - 1);
                lower.last().unwrap() + pred_d
            };
            out.push(pred);
            // Reveal the actual: extend the joint + differenced series and
            // update the innovation with the realized error.
            joint.push(actual);
            let new_d = {
                let lower = difference(&joint, order.d);
                *lower.last().unwrap()
            };
            eps.push(new_d - pred_d);
            diffed.push(new_d);
        }
        out
    }
}

impl Forecaster for Arima {
    fn name(&self) -> &'static str {
        "ARIMA"
    }

    fn forecast(&self, history: &[f64], pool: &[&[f64]], horizon: usize) -> Vec<f64> {
        // "Average VM": build the model on the cluster mean when available.
        if self.use_pool_average && !pool.is_empty() {
            let n = history.len();
            let mut avg = history.to_vec();
            let mut count = 1.0;
            for series in pool {
                if series.len() == n {
                    for (a, &x) in avg.iter_mut().zip(series.iter()) {
                        *a += x;
                    }
                    count += 1.0;
                }
            }
            for a in &mut avg {
                *a /= count;
            }
            return with_normalization(&avg, |scaled| self.forecast_scaled(scaled, horizon));
        }
        with_normalization(history, |scaled| self.forecast_scaled(scaled, horizon))
    }

    fn forecast_rolling(&self, history: &[f64], pool: &[&[f64]], future: &[f64]) -> Vec<f64> {
        // Build the (possibly pool-averaged) history, then normalize the
        // history and future jointly on the history's scale.
        let hist: Vec<f64> = if self.use_pool_average && !pool.is_empty() {
            let n = history.len();
            let mut avg = history.to_vec();
            let mut count = 1.0;
            for series in pool {
                if series.len() == n {
                    for (a, &x) in avg.iter_mut().zip(series.iter()) {
                        *a += x;
                    }
                    count += 1.0;
                }
            }
            for a in &mut avg {
                *a /= count;
            }
            avg
        } else {
            history.to_vec()
        };
        let (scaled, lo, span) = crate::metrics::normalize(&hist);
        let fut_scaled: Vec<f64> = future.iter().map(|x| (x - lo) / span).collect();
        let out = self.rolling_scaled(&scaled, &fut_scaled);
        crate::metrics::denormalize(&out, lo, span)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn difference_and_undifference_roundtrip() {
        let xs = [1.0, 3.0, 6.0, 10.0, 15.0];
        let d1 = difference(&xs, 1);
        assert_eq!(d1, vec![2.0, 3.0, 4.0, 5.0]);
        let rec = undifference(&xs, &[6.0, 7.0], 1);
        assert_eq!(rec, vec![21.0, 28.0]);
    }

    #[test]
    fn ols_recovers_exact_linear_system() {
        // y = 2 + 3a - b
        let rows = vec![
            vec![1.0, 1.0, 0.0],
            vec![1.0, 2.0, 1.0],
            vec![1.0, 0.0, 2.0],
            vec![1.0, 3.0, 3.0],
            vec![1.0, 1.5, 0.5],
        ];
        let y: Vec<f64> = rows.iter().map(|r| 2.0 + 3.0 * r[1] - r[2]).collect();
        let c = ols(&rows, &y).unwrap();
        assert!((c[0] - 2.0).abs() < 1e-6 && (c[1] - 3.0).abs() < 1e-6 && (c[2] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn recovers_ar1_dynamics() {
        // x_t = 0.8 x_{t-1} + ε: multi-step forecast must decay toward 0.
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut xs = vec![0.0];
        for _ in 0..500 {
            let prev = *xs.last().unwrap();
            xs.push(0.8 * prev + 0.1 * rng.normal());
        }
        // Put the series well away from zero so normalization is benign.
        let shifted: Vec<f64> = xs.iter().map(|x| x + 10.0).collect();
        let arima = Arima { d_grid: vec![0], ..Default::default() };
        let fc = arima.forecast(&shifted, &[], 20);
        assert_eq!(fc.len(), 20);
        // Forecast stays within the data range and trends to the mean.
        let mean = shifted.iter().sum::<f64>() / shifted.len() as f64;
        assert!((fc[19] - mean).abs() < 0.5, "fc={} mean={mean}", fc[19]);
    }

    #[test]
    fn handles_trend_via_differencing() {
        // Linear trend: ARIMA with d=1 should extrapolate roughly linearly.
        let xs: Vec<f64> = (0..200).map(|i| 2.0 * i as f64 + 5.0).collect();
        let arima = Arima::default();
        let fc = arima.forecast(&xs, &[], 5);
        for (i, v) in fc.iter().enumerate() {
            let expected = 2.0 * (200 + i) as f64 + 5.0;
            assert!((v - expected).abs() < 10.0, "step {i}: {v} vs {expected}");
        }
    }

    #[test]
    fn short_series_fallback_is_persistence() {
        let arima = Arima::default();
        let fc = arima.forecast(&[1.0, 2.0, 3.0], &[], 2);
        assert_eq!(fc, vec![3.0, 3.0]);
    }

    #[test]
    fn pool_average_changes_forecast() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let a: Vec<f64> = (0..300).map(|_| 10.0 + rng.normal()).collect();
        let b: Vec<f64> = (0..300).map(|_| 50.0 + rng.normal()).collect();
        let arima = Arima::default();
        let pool: Vec<&[f64]> = vec![&b];
        let with_pool = arima.forecast(&a, &pool, 3);
        let without = arima.forecast(&a, &[], 3);
        // The averaged series sits near 30, pulling the forecast up.
        assert!(with_pool[0] > without[0] + 5.0);
    }
}
