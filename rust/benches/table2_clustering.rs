//! Table 2: RMSE with KMeans pre-clustering of "similar VMs" under five
//! distance metrics (forecaster: SVM).
//!
//! Paper shape: clustering-based pooling is competitive with plain
//! cluster pooling; "Ordered" and ACF among the best.

use pronto::bench::experiments::{table2_clustering, ExperimentScale};
use pronto::bench::Table;

fn main() {
    let scale = ExperimentScale::from_env();
    let rows = table2_clustering(&scale);
    let mut t = Table::new(
        "Table 2: avg RMSE, SVM over KMeans-similar VMs",
        &["method", "14 days", "21 days"],
    );
    for (name, c) in rows {
        t.row(&[name, format!("{:.2}", c[0]), format!("{:.2}", c[1])]);
    }
    t.print();
    t.maybe_write_csv("table2");
    println!("\npaper reference: Ordered 102.62/98.88 | KM Euclidean 106.33/102.42 | KM Acf 104.31/102.02");
}
