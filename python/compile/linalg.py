"""Hand-written jnp decompositions for the L2 graphs.

``jnp.linalg.{svd,qr}`` lower to LAPACK custom-calls registered by jaxlib;
the bare ``xla``-crate PJRT client cannot resolve those, so everything that
must live inside an HLO artifact is written here from scratch:

* ``householder_qr`` — thin QR, unrolled over the (small, static) column
  count; mirrors ``rust/src/linalg/qr.rs`` including the non-negative-
  diagonal sign convention.
* ``svd_topk`` — truncated SVD of a tall matrix via Gram + warm-started
  orthogonal (block power) iteration with a fixed sweep count. For
  PRONTO's shapes (d ≲ 64, k = r + b ≲ 40, target rank ≤ 8) a couple of
  dozen iterations reach float32 accuracy; pytest validates against
  ``numpy.linalg.svd``.

All loops are Python-level over *static* bounds, so the traced graph is
small and fully unrolled — no dynamic shapes, no custom-calls.
"""

import jax
import jax.numpy as jnp

from .kernels.projection import gram, matmul_tiled


def householder_qr(a):
    """Thin QR of a (m × n, m ≥ n) with diag(R) ≥ 0.

    Returns (q, r) with q: (m, n) orthonormal columns, r: (n, n) upper
    triangular. Matches rust/src/linalg/qr.rs column for column.
    """
    m, n = a.shape
    assert m >= n, "householder_qr requires tall input"
    r = a
    vs = []
    for k in range(n):
        x = r[:, k]
        # Mask rows above the diagonal: the reflector acts on rows k..m.
        mask = (jnp.arange(m) >= k).astype(a.dtype)
        xk = x * mask
        norm_x = jnp.sqrt(jnp.sum(xk * xk))
        pivot = xk[k]
        alpha = jnp.where(pivot >= 0, -norm_x, norm_x)
        v = xk - alpha * (jnp.arange(m) == k).astype(a.dtype)
        norm_v = jnp.sqrt(jnp.sum(v * v))
        v = jnp.where(norm_v > 0, v / jnp.where(norm_v > 0, norm_v, 1.0), 0.0)
        # R ← (I − 2vvᵀ) R
        r = r - 2.0 * jnp.outer(v, v @ r)
        vs.append(v)

    # Q = H₀ … H_{n−1} applied to the first n columns of I.
    q = jnp.eye(m, n, dtype=a.dtype)
    for v in reversed(vs):
        q = q - 2.0 * jnp.outer(v, v @ q)

    # Zero the (numerically tiny) subdiagonal of R and fix signs so the
    # factorization is unique (diag(R) ≥ 0), matching the Rust oracle.
    rn = r[:n, :n] * (jnp.arange(n)[:, None] <= jnp.arange(n)[None, :])
    sign = jnp.where(jnp.diag(rn) < 0, -1.0, 1.0).astype(a.dtype)
    rn = rn * sign[:, None]
    q = q * sign[None, :]
    return q, rn


def jacobi_eigh_small(h, *, sweeps=8):
    """Eigendecomposition of a small symmetric matrix via cyclic Jacobi.

    Fully unrolled over static (k ≤ ~8) sizes: `sweeps` passes over all
    (p, q) pairs, each rotation zeroing one off-diagonal entry. Returns
    (eigenvalues (k,), eigenvectors (k, k) columns), unsorted.
    """
    k = h.shape[0]
    assert h.shape == (k, k)
    w0 = jnp.eye(k, dtype=h.dtype)

    def sweep(_, hw):
        h, w = hw
        for p in range(k):
            for q in range(p + 1, k):
                hpq = h[p, q]
                hpp = h[p, p]
                hqq = h[q, q]
                # Stable rotation angle; guard the hpq == 0 case.
                tau = (hqq - hpp) / (2.0 * jnp.where(hpq == 0, 1.0, hpq))
                t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
                t = jnp.where(hpq == 0, 0.0, t)
                c = 1.0 / jnp.sqrt(1.0 + t * t)
                s = c * t
                # Givens rotation G(p, q, θ): H ← GᵀHG, W ← WG.
                rot_p = c * h[:, p] - s * h[:, q]
                rot_q = s * h[:, p] + c * h[:, q]
                h = h.at[:, p].set(rot_p).at[:, q].set(rot_q)
                row_p = c * h[p, :] - s * h[q, :]
                row_q = s * h[p, :] + c * h[q, :]
                h = h.at[p, :].set(row_p).at[q, :].set(row_q)
                wp = c * w[:, p] - s * w[:, q]
                wq = s * w[:, p] + c * w[:, q]
                w = w.at[:, p].set(wp).at[:, q].set(wq)
        return h, w

    # fori_loop keeps the HLO graph one-sweep-sized: the unrolled variant
    # made XLA CPU compile times pathological (minutes for ~5k ops).
    h, w = jax.lax.fori_loop(0, sweeps, sweep, (h, w0))
    return jnp.diag(h), w


def svd_topk(m_mat, k, *, iters=24, use_pallas=True):
    """Top-k singular triplets of a tall matrix M (d × c), c small.

    Method: G = MᵀM (c × c, via the Pallas gram kernel), then orthogonal
    iteration V ← orth(G·V) for a fixed number of sweeps (warm-started at
    the leading canonical vectors), eigenvalues from the Rayleigh quotient,
    σ = sqrt(λ), U = M·V·diag(1/σ).

    Returns (u, sigma, v): u (d × k), sigma (k,) descending, v (c × k).
    """
    d, c = m_mat.shape
    assert k <= c, "rank exceeds column count"
    g = gram(m_mat) if use_pallas else jnp.dot(m_mat.T, m_mat)

    # Oversampling: iterate a slightly wider subspace so the k-th Ritz
    # value converges even for clustered spectra (randomized-SVD practice);
    # only the top k triplets are returned.
    ko = min(c, k + 4)

    # Deterministic quasi-random start (shader-style hash): canonical
    # starts can lie exactly in G's null space (e.g. the first FPCA block,
    # whose leading r columns are the zero "empty estimate"), stalling the
    # iteration. A dense pseudo-random start avoids that with prob. 1 and
    # keeps the graph free of RNG ops.
    ij = jnp.arange(c)[:, None] * 12.9898 + jnp.arange(ko)[None, :] * 78.233 + 1.0
    v0 = jnp.sin(ij) * 43758.5453
    v = (v0 - jnp.floor(v0) - 0.5).astype(m_mat.dtype)
    v, _ = householder_qr(v)

    def power_step(_, v):
        w = jnp.dot(g, v)
        v, _ = householder_qr(w)
        return v

    # Same fori_loop trick: one QR body instead of `iters` unrolled copies.
    v = jax.lax.fori_loop(0, iters, power_step, v)

    # Rayleigh–Ritz: diagonalize the small projected matrix H = VᵀGV with
    # an unrolled Jacobi eigensolver. For clustered spectra the orthogonal
    # iteration leaves H visibly non-diagonal; the Ritz rotation recovers
    # optimal eigenvalue estimates within the subspace.
    h = jnp.dot(v.T, jnp.dot(g, v))
    lam, w = jacobi_eigh_small(h)
    v = jnp.dot(v, w)
    lam = jnp.clip(lam, 0.0, None)
    order = jnp.argsort(-lam)[:k]
    lam = lam[order]
    v = v[:, order]
    sigma = jnp.sqrt(lam)

    safe = jnp.where(sigma > 0, sigma, 1.0)
    if use_pallas:
        u = matmul_tiled(m_mat, v) / safe[None, :]
    else:
        u = jnp.dot(m_mat, v) / safe[None, :]
    # Trailing directions with tiny σ are ill-conditioned under M·v/σ;
    # re-orthonormalize (QR of an ≈orthonormal d×k matrix: Q ≈ U, cheap).
    u, _ = householder_qr(u)
    # Null directions (σ ≈ 0 relative to the spectrum head) get zero
    # columns rather than garbage, matching the Rust/Jacobi oracle.
    tiny = sigma <= 1e-7 * jnp.maximum(sigma[0], 1e-30)
    u = u * (~tiny)[None, :]
    return u, sigma, v
