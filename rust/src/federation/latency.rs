//! Federation push-latency models.
//!
//! The paper scopes communication latency out ("in the absence of
//! communication latency, it exhibits attractive horizontal scalability");
//! production federations do not get that luxury. A [`LatencyModel`]
//! describes how long a leaf's `(U, Σ)` push takes to reach its
//! aggregator, in telemetry steps (20 s units). Both federation runtimes
//! consume it: the discrete-event engine schedules delayed
//! `FederationPush` events against [`super::FederationTree`], and
//! [`super::ConcurrentFederation`] holds pushes in a per-leaf pending
//! queue until their delivery step. Sampling is deterministic given the
//! seed, so latency never perturbs the arrival/churn RNG streams.

use crate::rng::Xoshiro256;

/// Distribution of the push latency, in telemetry steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LatencyModel {
    /// Instant delivery (the paper's setting).
    None,
    /// Fixed delay.
    Constant { steps: f64 },
    /// Exponential delay with the given mean (heavy WAN tail).
    Exponential { mean_steps: f64 },
    /// Uniform delay in `[lo, hi]`.
    Uniform { lo: f64, hi: f64 },
}

impl LatencyModel {
    /// Sample one delay in steps (≥ 0).
    pub fn sample(&self, rng: &mut Xoshiro256) -> f64 {
        match *self {
            LatencyModel::None => 0.0,
            LatencyModel::Constant { steps } => steps.max(0.0),
            LatencyModel::Exponential { mean_steps } => {
                if mean_steps <= 0.0 {
                    0.0
                } else {
                    rng.exponential(1.0 / mean_steps)
                }
            }
            LatencyModel::Uniform { lo, hi } => {
                let (lo, hi) = (lo.max(0.0), hi.max(0.0));
                if hi <= lo {
                    lo
                } else {
                    rng.uniform(lo, hi)
                }
            }
        }
    }

    /// Whether delivery is instantaneous for every sample.
    pub fn is_instant(&self) -> bool {
        match *self {
            LatencyModel::None => true,
            LatencyModel::Constant { steps } => steps <= 0.0,
            LatencyModel::Exponential { mean_steps } => mean_steps <= 0.0,
            LatencyModel::Uniform { lo, hi } => lo <= 0.0 && hi <= 0.0,
        }
    }

    /// Mean delay in steps (for reports and sizing heuristics).
    pub fn mean(&self) -> f64 {
        match *self {
            LatencyModel::None => 0.0,
            LatencyModel::Constant { steps } => steps.max(0.0),
            LatencyModel::Exponential { mean_steps } => mean_steps.max(0.0),
            LatencyModel::Uniform { lo, hi } => 0.5 * (lo.max(0.0) + hi.max(0.0)),
        }
    }
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_instant_and_zero() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        assert!(LatencyModel::None.is_instant());
        assert_eq!(LatencyModel::None.sample(&mut rng), 0.0);
        assert_eq!(LatencyModel::None.mean(), 0.0);
    }

    #[test]
    fn constant_returns_value() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let m = LatencyModel::Constant { steps: 3.5 };
        for _ in 0..10 {
            assert_eq!(m.sample(&mut rng), 3.5);
        }
        assert!(!m.is_instant());
    }

    #[test]
    fn exponential_mean_close() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let m = LatencyModel::Exponential { mean_steps: 4.0 };
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| m.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn uniform_bounds_hold() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let m = LatencyModel::Uniform { lo: 1.0, hi: 2.0 };
        for _ in 0..1000 {
            let x = m.sample(&mut rng);
            assert!((1.0..=2.0).contains(&x));
        }
        assert!((m.mean() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_uniform_and_negative_inputs_clamp() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        assert_eq!(LatencyModel::Uniform { lo: 2.0, hi: 1.0 }.sample(&mut rng), 2.0);
        assert_eq!(LatencyModel::Constant { steps: -1.0 }.sample(&mut rng), 0.0);
        assert!(LatencyModel::Constant { steps: -1.0 }.is_instant());
    }

    #[test]
    fn sampling_is_deterministic_per_seed() {
        let m = LatencyModel::Exponential { mean_steps: 2.0 };
        let mut a = Xoshiro256::seed_from_u64(9);
        let mut b = Xoshiro256::seed_from_u64(9);
        for _ in 0..100 {
            assert_eq!(m.sample(&mut a).to_bits(), m.sample(&mut b).to_bits());
        }
    }
}
