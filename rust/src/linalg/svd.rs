//! One-sided Jacobi SVD.
//!
//! The merge step (Algorithm 4) needs the SVD of a small ((r₁+r₂) square)
//! matrix and the block update (Algorithm 5 / SSVD) the SVD of a tall
//! d × (r+b) matrix. One-sided Jacobi is simple, numerically robust, and —
//! crucially — expressible with the exact same sweep structure in jnp for
//! the L2 artifacts (no LAPACK custom-calls). For tall inputs we first
//! reduce via QR so Jacobi runs on the small square factor.

use super::{householder_qr, Mat};

/// Result of a singular value decomposition `A = U diag(sigma) Vᵀ`.
#[derive(Debug, Clone)]
pub struct Svd {
    /// Left singular vectors, m × k (k = min(m, n) or the requested rank).
    pub u: Mat,
    /// Singular values, descending, length k.
    pub sigma: Vec<f64>,
    /// Right singular vectors, n × k (columns).
    pub v: Mat,
}

/// One-sided Jacobi SVD of a square-or-tall matrix.
///
/// Rotates column pairs of a working copy of `A` until all pairs are
/// mutually orthogonal; then column norms are the singular values and the
/// accumulated rotations give V. Converges quadratically; `MAX_SWEEPS` is
/// generous for the ≤ 32-column problems PRONTO produces.
pub fn jacobi_svd(a: &Mat) -> Svd {
    let (m, n) = (a.rows(), a.cols());
    if m < n {
        // Work on the transpose and swap U/V.
        let t = jacobi_svd(&a.transpose());
        return Svd { u: t.v, sigma: t.sigma, v: t.u };
    }
    // Tall case: QR first so Jacobi operates on the n×n factor R.
    if m > n {
        let (q, r) = householder_qr(a);
        let inner = jacobi_svd(&r);
        return Svd { u: q.matmul(&inner.u), sigma: inner.sigma, v: inner.v };
    }

    const MAX_SWEEPS: usize = 60;
    // Relative off-diagonal tolerance.
    const TOL: f64 = 1e-14;

    let mut w = a.clone(); // becomes U * diag(sigma)
    let mut v = Mat::eye(n);

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for the (p, q) column pair.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..n {
                    let wp = w.get(i, p);
                    let wq = w.get(i, q);
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                let denom = (app * aqq).sqrt();
                if denom > 0.0 {
                    off = off.max(apq.abs() / denom);
                }
                if apq.abs() <= TOL * denom {
                    continue;
                }
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let wp = w.get(i, p);
                    let wq = w.get(i, q);
                    w.set(i, p, c * wp - s * wq);
                    w.set(i, q, s * wp + c * wq);
                }
                for i in 0..n {
                    let vp = v.get(i, p);
                    let vq = v.get(i, q);
                    v.set(i, p, c * vp - s * vq);
                    v.set(i, q, s * vp + c * vq);
                }
            }
        }
        if off < TOL {
            break;
        }
    }

    // Column norms -> singular values; normalize to get U.
    let mut sigma: Vec<f64> = (0..n)
        .map(|j| w.col(j).iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    let mut u = w;
    for j in 0..n {
        let s = sigma[j];
        if s > 0.0 {
            for x in u.col_mut(j) {
                *x /= s;
            }
        }
    }

    // Sort descending by sigma (stable permutation applied to U, V).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| sigma[j].partial_cmp(&sigma[i]).unwrap());
    let (mut su, mut sv) = (Mat::zeros(u.rows(), n), Mat::zeros(v.rows(), n));
    let mut ss = vec![0.0; n];
    for (newj, &oldj) in order.iter().enumerate() {
        ss[newj] = sigma[oldj];
        su.col_mut(newj).copy_from_slice(u.col(oldj));
        sv.col_mut(newj).copy_from_slice(v.col(oldj));
    }
    sigma = ss;
    u = su;
    v = sv;

    Svd { u, sigma, v }
}

/// Rank-r truncated SVD: the leading r singular triplets of `a`.
pub fn svd_truncated(a: &Mat, r: usize) -> Svd {
    let full = jacobi_svd(a);
    let k = r.min(full.sigma.len());
    Svd {
        u: full.u.take_cols(k),
        sigma: full.sigma[..k].to_vec(),
        v: full.v.take_cols(k),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{frob_diff, orthonormality_error};
    use crate::rng::Xoshiro256;

    fn random_mat(rng: &mut Xoshiro256, m: usize, n: usize) -> Mat {
        let data: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
        Mat::from_col_major(m, n, data)
    }

    fn reconstruct(svd: &Svd) -> Mat {
        svd.u.mul_diag(&svd.sigma).matmul(&svd.v.transpose())
    }

    #[test]
    fn svd_reconstructs_square() {
        let mut rng = Xoshiro256::seed_from_u64(10);
        for &n in &[1usize, 2, 3, 5, 8, 16] {
            let a = random_mat(&mut rng, n, n);
            let svd = jacobi_svd(&a);
            assert!(frob_diff(&reconstruct(&svd), &a) < 1e-8, "n={n}");
        }
    }

    #[test]
    fn svd_reconstructs_tall_and_wide() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        for &(m, n) in &[(20, 4), (64, 12), (4, 20), (3, 64)] {
            let a = random_mat(&mut rng, m, n);
            let svd = jacobi_svd(&a);
            assert!(frob_diff(&reconstruct(&svd), &a) < 1e-8, "m={m} n={n}");
        }
    }

    #[test]
    fn factors_orthonormal_and_sigma_sorted() {
        let mut rng = Xoshiro256::seed_from_u64(12);
        let a = random_mat(&mut rng, 30, 6);
        let svd = jacobi_svd(&a);
        assert!(orthonormality_error(&svd.u) < 1e-9);
        assert!(orthonormality_error(&svd.v) < 1e-9);
        for w in svd.sigma.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(svd.sigma.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn svd_matches_known_diagonal() {
        let a = Mat::diag(&[3.0, 1.0, 2.0]);
        let svd = jacobi_svd(&a);
        let s = &svd.sigma;
        assert!((s[0] - 3.0).abs() < 1e-12 && (s[1] - 2.0).abs() < 1e-12
            && (s[2] - 1.0).abs() < 1e-12, "{s:?}");
    }

    #[test]
    fn truncated_svd_is_best_rank_r() {
        // Build a matrix with a known spectrum and check the rank-2
        // truncation error equals the tail energy.
        let mut rng = Xoshiro256::seed_from_u64(13);
        let u = {
            let (q, _) = crate::linalg::householder_qr(&random_mat(&mut rng, 10, 4));
            q
        };
        let v = {
            let (q, _) = crate::linalg::householder_qr(&random_mat(&mut rng, 8, 4));
            q
        };
        let sig = [5.0, 3.0, 1.0, 0.5];
        let a = u.mul_diag(&sig).matmul(&v.transpose());
        let t = svd_truncated(&a, 2);
        let err = frob_diff(&reconstruct(&t), &a);
        let expected = (1.0f64 + 0.25).sqrt(); // sqrt(1^2 + 0.5^2)
        assert!((err - expected).abs() < 1e-8, "err={err} expected={expected}");
    }

    #[test]
    fn svd_of_zero_matrix() {
        let a = Mat::zeros(5, 3);
        let svd = jacobi_svd(&a);
        assert!(svd.sigma.iter().all(|&s| s == 0.0));
        assert!(svd.u.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn rank_one_matrix() {
        let u = Mat::col_vec(&[1.0, 2.0, 2.0]); // norm 3
        let v = Mat::col_vec(&[3.0, 4.0]); // norm 5
        let a = u.matmul(&v.transpose());
        let svd = jacobi_svd(&a);
        assert!((svd.sigma[0] - 15.0).abs() < 1e-10);
        assert!(svd.sigma[1].abs() < 1e-10);
    }
}

/// Fast truncated SVD for tall matrices via Gram + orthogonal iteration
/// with Rayleigh–Ritz refinement — the same algorithm the L2 HLO artifact
/// uses (python/compile/linalg.py), making it both the performance path
/// and a parity twin. For PRONTO's shapes (d ≈ 52, c = r+b ≈ 36, k ≤ 8)
/// it is ~20× faster than full Jacobi; accuracy is validated against
/// [`jacobi_svd`] in tests.
pub fn svd_gram_topk(a: &Mat, k: usize, iters: usize) -> Svd {
    svd_gram_topk_warm(a, k, iters, 0)
}

/// [`svd_gram_topk`] with a warm start: the first `warm_cols` iteration
/// vectors are the leading canonical directions e₁…e_w of the column
/// space. In FPCA's update M = [U·diag(Σ) | B] those positions hold the
/// previous principal directions, so the iteration starts next to the
/// answer and converges in a fraction of the sweeps (§Perf).
pub fn svd_gram_topk_warm(a: &Mat, k: usize, iters: usize, warm_cols: usize) -> Svd {
    let (d, c) = (a.rows(), a.cols());
    let k = k.min(c);
    // Oversample so the k-th Ritz value converges on clustered spectra.
    let ko = (k + 4).min(c);
    let warm = warm_cols.min(ko);

    // Gram matrix G = AᵀA (c × c), symmetric fast path.
    let g = a.gram();

    // Leading canonical directions for the warm columns; deterministic
    // quasi-random fill (same hash as the artifact) for the rest.
    let mut v = Mat::zeros(c, ko);
    for j in 0..warm {
        v.set(j, j, 1.0);
    }
    for i in 0..c {
        for j in warm..ko {
            let x = ((i as f64) * 12.9898 + (j as f64) * 78.233 + 1.0).sin() * 43758.5453;
            v.set(i, j, x - x.floor() - 0.5);
        }
    }
    let (mut v, _) = householder_qr(&v);
    for _ in 0..iters {
        let w = g.matmul(&v);
        let (q, _) = householder_qr(&w);
        v = q;
    }

    // Rayleigh–Ritz: diagonalize H = VᵀGV (ko × ko, tiny) with Jacobi.
    let h = v.transpose_mul(&g.matmul(&v));
    let ritz = jacobi_svd(&h); // symmetric PSD: singular ≡ eigen decomposition
    let vr = v.matmul(&ritz.u);

    // σ = sqrt(λ); U = A·v/σ, re-orthonormalized.
    let mut sigma: Vec<f64> = ritz.sigma.iter().take(k).map(|&l| l.max(0.0).sqrt()).collect();
    let v_top = vr.take_cols(k);
    let av = a.matmul(&v_top);
    let mut u = Mat::zeros(d, k);
    for j in 0..k {
        let s = sigma[j];
        if s > 1e-12 * sigma[0].max(1e-300) {
            let col = av.col(j);
            let out = u.col_mut(j);
            for i in 0..d {
                out[i] = col[i] / s;
            }
        } else {
            sigma[j] = 0.0;
        }
    }
    let (q, _) = householder_qr(&u);
    // Zero the null columns after re-orthonormalization (QR fills them
    // with arbitrary directions).
    let mut u = q;
    for j in 0..k {
        if sigma[j] == 0.0 {
            for x in u.col_mut(j) {
                *x = 0.0;
            }
        }
    }
    Svd { u, sigma, v: v_top }
}

#[cfg(test)]
mod gram_tests {
    use super::*;
    use crate::linalg::{orthonormality_error, subspace_distance};
    use crate::proptest::{forall, gen_low_rank, gen_mat};

    #[test]
    fn gram_topk_matches_jacobi_on_low_rank() {
        forall("svd_gram_topk == jacobi (low rank)", |rng| {
            let d = 16 + rng.gen_range(48);
            let c = 8 + rng.gen_range(28);
            let a = gen_low_rank(rng, d, c, 4, 0.01);
            let fast = svd_gram_topk(&a, 4, 24);
            let slow = svd_truncated(&a, 4);
            for (x, y) in fast.sigma.iter().zip(slow.sigma.iter()) {
                let rel = (x - y).abs() / y.max(1e-9);
                if rel > 2e-2 {
                    return Err(format!("sigma {x} vs {y}"));
                }
            }
            let dist = subspace_distance(&fast.u.take_cols(2), &slow.u.take_cols(2));
            if dist > 0.05 {
                return Err(format!("span distance {dist}"));
            }
            Ok(())
        });
    }

    #[test]
    fn gram_topk_on_gaussian_spectra() {
        forall("svd_gram_topk sigma on gaussian", |rng| {
            let a = gen_mat(rng, 52, 36);
            let fast = svd_gram_topk(&a, 4, 32);
            let slow = svd_truncated(&a, 4);
            for (x, y) in fast.sigma.iter().zip(slow.sigma.iter()) {
                let rel = (x - y).abs() / y.max(1e-9);
                if rel > 5e-2 {
                    return Err(format!("sigma {x} vs {y} (rel {rel})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gram_topk_orthonormal_u() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(4);
        let a = gen_low_rank(&mut rng, 52, 36, 4, 0.05);
        let svd = svd_gram_topk(&a, 4, 24);
        assert!(orthonormality_error(&svd.u) < 1e-9);
        assert!(svd.sigma.windows(2).all(|w| w[0] >= w[1] - 1e-12));
    }

    #[test]
    fn gram_topk_zero_matrix() {
        let a = Mat::zeros(10, 6);
        let svd = svd_gram_topk(&a, 3, 10);
        assert!(svd.sigma.iter().all(|&s| s == 0.0));
        assert!(svd.u.data().iter().all(|x| x.is_finite()));
    }
}
