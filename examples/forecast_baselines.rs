//! Offline forecasting baselines (§3): reproduce the motivation study —
//! classical methods cannot predict CPU Ready well.
//!
//! ```bash
//! PRONTO_BENCH_QUICK=1 cargo run --release --example forecast_baselines
//! ```
//!
//! Runs a compact version of Tables 1 and 4 and prints the comparison.

use pronto::bench::experiments::{spike_tables, table1_rmse, ExperimentScale};
use pronto::bench::Table;
use pronto::forecast::SpikeThreshold;

fn main() {
    let scale = ExperimentScale::quick();
    println!("(quick scale: {} clusters x {} VMs)", scale.clusters, scale.vms_per_cluster);

    let rows = table1_rmse(&scale);
    let mut t1 = Table::new(
        "Table 1 (compact): avg RMSE, CPU Ready daily medians",
        &["method", "sameVM/14d", "sameVM/21d", "cluster/14d", "cluster/21d"],
    );
    for (name, cells) in rows {
        t1.row(&[
            name,
            format!("{:.2}", cells[0]),
            format!("{:.2}", cells[1]),
            format!("{:.2}", cells[2]),
            format!("{:.2}", cells[3]),
        ]);
    }
    t1.print();

    let (rows, pct) = spike_tables(
        &scale,
        &[
            SpikeThreshold::Fixed(500.0),
            SpikeThreshold::Fixed(800.0),
            SpikeThreshold::Fixed(1000.0),
        ],
    );
    let mut t4 = Table::new(
        "Table 4 (compact): spike-alarm accuracy, fixed thresholds",
        &["method", "500", "800", "1000"],
    );
    for (name, cells) in rows {
        t4.row(&[
            name,
            format!("{:.4}", cells[0]),
            format!("{:.4}", cells[1]),
            format!("{:.4}", cells[2]),
        ]);
    }
    t4.row(&[
        "% of spikes".to_string(),
        format!("{:.2}", pct[0]),
        format!("{:.2}", pct[1]),
        format!("{:.2}", pct[2]),
    ]);
    t4.print();

    println!("\nTakeaway (paper §3): even the best offline method leaves");
    println!("large errors on short horizons — motivating PRONTO's online,");
    println!("unsupervised projection-tracking approach.");
}
