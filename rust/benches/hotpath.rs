//! Hot-path microbenches: the L3 operations on the per-observation path,
//! plus the XLA artifact execution costs. Drives the §Perf optimization
//! loop (EXPERIMENTS.md).

use pronto::bench::{Bencher, Sample, Table};
use pronto::fpca::{merge_subspaces, FpcaEdge, FpcaEdgeConfig, MergeOptions, Subspace};
use pronto::proptest::{gen_low_rank, gen_orthonormal};
use pronto::rng::Xoshiro256;
use pronto::scheduler::{NodeScheduler, RejectConfig, RejectJob};
use pronto::telemetry::{GeneratorConfig, TraceGenerator};

fn main() {
    let d = 52;
    let r = 4;
    let bencher = Bencher::from_env();
    let mut rng = Xoshiro256::seed_from_u64(3);
    let mut t = Table::new("hot path microbenchmarks", &["op", "median", "p90"]);
    let mut row = |s: Sample| {
        t.row(&[s.name.clone(), Sample::human(s.median_ns), Sample::human(s.p90_ns)]);
    };

    // Reject-Job single observation (the admission decision).
    let est = Subspace::new(gen_orthonormal(&mut rng, d, r), vec![4.0, 3.0, 2.0, 1.0]);
    let mut rj = RejectJob::new(RejectConfig::default());
    let y: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
    row(bencher.bench("reject_job_observe", || rj.observe(&est, &y)));

    // Full node pipeline per observation (standardize + project + detect +
    // buffered embedding update, amortized).
    let gen = TraceGenerator::new(GeneratorConfig::default(), 5);
    let trace = gen.generate_vm(0, 4096);
    let mut node = NodeScheduler::new(d, RejectConfig::default());
    let mut cursor = 0usize;
    row(bencher.bench("node_observe (amortized)", || {
        let t_ = cursor % trace.len();
        cursor += 1;
        node.observe(trace.features(t_))
    }));

    // FPCA block update (the per-block cost behind the amortization).
    let block = gen_low_rank(&mut rng, d, 32, 4, 0.1);
    let mut edge = FpcaEdge::new(d, FpcaEdgeConfig::default());
    edge.update_block(&block);
    row(bencher.bench("fpca_update_block (native)", || {
        edge.update_block(&block);
        edge.rank()
    }));

    // Subspace merge (aggregator cost).
    let s1 = Subspace::new(gen_orthonormal(&mut rng, d, r), vec![4.0, 3.0, 2.0, 1.0]);
    let s2 = Subspace::new(gen_orthonormal(&mut rng, d, r), vec![2.0, 1.5, 1.0, 0.5]);
    row(bencher.bench("merge_subspaces (native)", || {
        merge_subspaces(&s1, &s2, MergeOptions::rank(r))
    }));

    // XLA artifact executions (when built).
    if let Some(rt) = pronto::runtime::shared_runtime() {
        let cfg = rt.manifest().config;
        use pronto::runtime::XlaFpca;
        use pronto::baselines::StreamingEmbedding;
        let mut xf = XlaFpca::new(rt.clone(), cfg.dim).unwrap();
        let ys: Vec<Vec<f64>> = (0..cfg.block)
            .map(|_| (0..cfg.dim).map(|_| rng.normal()).collect())
            .collect();
        let mut i = 0usize;
        row(bencher.bench("xla fpca_update (per block)", || {
            // Feed exactly one block per iteration.
            for y in &ys {
                xf.observe(y);
            }
            i += 1;
            i
        }));

        let mut pd = pronto::runtime::XlaProjectDetect::new(rt.clone());
        let est_x = Subspace::new(
            gen_orthonormal(&mut rng, cfg.dim, cfg.rank),
            vec![4.0, 3.0, 2.0, 1.0],
        );
        let block_f32: Vec<f32> = (0..cfg.block * cfg.dim).map(|_| rng.normal() as f32).collect();
        row(bencher.bench("xla project_detect (per block)", || {
            pd.run_block(&est_x, &block_f32).unwrap().1.len()
        }));
    } else {
        eprintln!("(artifacts not built; skipping XLA rows)");
    }

    t.print();
    t.maybe_write_csv("hotpath");
}
