// Fixture: pinned keys (and a registered dynamic-key prefix) only.
use std::collections::BTreeMap;

pub fn render(m: &mut BTreeMap<String, u64>, p: usize) {
    m.insert("scenario".into(), 1);
    m.insert(format!("queue_delay_p{p}"), 2);
}
