// Fixture: an explained pragma suppresses the finding it covers.
pub fn timed_ms() -> u128 {
    // pronto-lint: allow(wall-clock) — fixture demonstrating an explained waiver
    std::time::Instant::now().elapsed().as_millis()
}
