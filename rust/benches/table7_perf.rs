//! Table 7: per-vector update time and memory per method.
//!
//! The paper reports (Python prototype): PRONTO 15 ms / PM 22 ms / FD 25 ms
//! / SP 9 ms, ~123–155 MB. Absolute numbers are not comparable (Rust vs
//! numpy); the *ordering* is the reproducible claim: SP fastest, PRONTO
//! second, PM and FD slowest. Block-method costs are amortized per vector
//! exactly as §7.2 prescribes. Memory is the resident state the method
//! owns (reported analytically — Rust has no interpreter slack).

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::baselines::*;
use pronto::bench::{Bencher, Sample, Table};
use pronto::fpca::{FpcaEdge, FpcaEdgeConfig};
use pronto::telemetry::{GeneratorConfig, TraceGenerator};

fn state_bytes(method: &str, d: usize, r: usize, b: usize) -> usize {
    let f = std::mem::size_of::<f64>();
    match method {
        // U (d×r) + Σ (r) + block buffer (d×b)
        "PRONTO" => f * (d * r + r + d * b),
        // W (d×r_max) + energies
        "SP" => f * (d * 8 + 8 + 2),
        // sketch (2r × d)
        "FD" => f * (2 * r * d),
        // Q (d×r) + accumulator (d×r) + block implicit
        "PM" => f * (2 * d * r),
        _ => 0,
    }
}

fn main() {
    let d = 52;
    let r = 4;
    let steps = 4_096;
    let gen = TraceGenerator::new(GeneratorConfig::default(), 7);
    let trace = gen.generate_vm(0, steps);
    let bencher = Bencher::from_env();

    let mut t = Table::new(
        "Table 7: per-vector update cost (amortized) + method state",
        &["method", "time/vector", "state (KB)", "paper (ms, MB)"],
    );

    // Each closure streams the whole trace once; cost reported per vector.
    let mut bench_method = |name: &str,
                            paper: &str,
                            mut run: Box<dyn FnMut() -> usize>| {
        let s = bencher.bench(name, &mut *run);
        let per_vec = s.median_ns / steps as f64;
        t.row(&[
            name.to_string(),
            Sample::human(per_vec),
            format!("{:.1}", state_bytes(name, d, r, 32) as f64 / 1024.0),
            paper.to_string(),
        ]);
    };

    let tr = trace.clone();
    bench_method(
        "PRONTO",
        "15 ms, ~148 MB",
        Box::new(move || {
            let mut e = FpcaEdge::new(d, FpcaEdgeConfig::default());
            for t in 0..tr.len() {
                StreamingEmbedding::observe(&mut e, tr.features(t));
            }
            e.rank()
        }),
    );
    let tr = trace.clone();
    bench_method(
        "PM",
        "22 ms, ~155 MB",
        Box::new(move || {
            let mut e = BlockPowerMethod::new(d, r, d, 3);
            for t in 0..tr.len() {
                e.observe(tr.features(t));
            }
            e.rank()
        }),
    );
    let tr = trace.clone();
    bench_method(
        "FD",
        "25 ms, ~151 MB",
        Box::new(move || {
            let mut e = FrequentDirections::new(d, r);
            for t in 0..tr.len() {
                e.observe(tr.features(t));
            }
            e.rank()
        }),
    );
    let tr = trace.clone();
    bench_method(
        "SP",
        "9 ms, ~123 MB",
        Box::new(move || {
            let mut e = Spirit::new(d, SpiritConfig::default());
            for t in 0..tr.len() {
                e.observe(tr.features(t));
            }
            e.rank()
        }),
    );

    t.print();
    t.maybe_write_csv("table7");
    println!("\nshape check: SP fastest; PRONTO amortized-block cost between SP and FD/PM.");
}
