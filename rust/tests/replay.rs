//! Trace-driven arrival replay: a `VmTrace`-format CSV written to disk
//! must drive the engine's arrival sequence *exactly* — same steps, same
//! counts — closing the loop between `telemetry/trace.rs` CSVs and
//! `ArrivalProcess::Replay` scenarios.

use pronto::linalg::Mat;
use pronto::scheduler::{Admission, JobOutcome, RandomPolicy};
use pronto::sim::{ArrivalPattern, DiscreteEventEngine, ReplaySchedule, Scenario};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};
use std::sync::Arc;

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(0, v, steps)).collect()
}

fn always_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
    tr.iter()
        .enumerate()
        .map(|(i, _)| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
        .collect()
}

/// Build a one-metric arrival trace (`timestep,arrivals` CSV shape).
fn arrival_trace(counts: &[u32]) -> VmTrace {
    let mut m = Mat::zeros(1, counts.len());
    for (t, &c) in counts.iter().enumerate() {
        m.set(0, t, c as f64);
    }
    VmTrace::new(0, 0, 0, m, vec!["arrivals".to_string()])
}

#[test]
fn replay_arrivals_match_trace_timestamps_exactly() {
    // A lumpy, gap-heavy schedule: batches, silence, singletons.
    let mut counts = vec![0u32; 60];
    for (t, c) in [(0, 2), (3, 1), (4, 4), (17, 1), (18, 1), (40, 3), (59, 2)] {
        counts[t] = c;
    }
    let dir = std::env::temp_dir().join("pronto_replay_roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("vm0.csv");
    arrival_trace(&counts).write_csv(&csv).unwrap();

    // CSV → schedule: per-step counts survive the round-trip.
    let sched = ReplaySchedule::from_path(&csv, None).unwrap();
    assert_eq!(sched.len(), counts.len());
    for (t, &c) in counts.iter().enumerate() {
        assert_eq!(sched.count_at(t), c, "count mutated at step {t}");
    }
    assert_eq!(sched.total(), counts.iter().map(|&c| c as usize).sum::<usize>());

    // Schedule → engine: with always-accept policies every arrival shows
    // up as an outcome stamped with its arrival step; the histogram over
    // steps must equal the trace exactly.
    let scenario = Scenario {
        arrivals: ArrivalPattern::Replay { schedule: Arc::new(sched) },
        ..Scenario::default()
    }
    .with_nodes(3)
    .with_steps(counts.len());
    let tr = fleet(3, counts.len(), 77);
    let report = DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run();
    assert_eq!(report.jobs_arrived, counts.iter().map(|&c| c as usize).sum::<usize>());

    let mut got = vec![0u32; counts.len()];
    for o in &report.outcomes {
        let at = match *o {
            JobOutcome::Accepted { at, .. } => at,
            JobOutcome::Rejected { at } => at,
        };
        got[at] += 1;
    }
    assert_eq!(got, counts, "engine arrival sequence diverged from the trace");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn replay_runs_are_deterministic_and_independent_of_seed_streams() {
    // Replay consumes no arrival randomness: two different seeds still
    // produce the identical arrival histogram (dispatch may differ).
    let counts: Vec<u32> = (0..80).map(|t| if t % 9 == 0 { 2 } else { 0 }).collect();
    let mk = |seed: u64| {
        let scenario = Scenario {
            arrivals: ArrivalPattern::Replay {
                schedule: Arc::new(ReplaySchedule::from_counts(counts.clone(), "inline")),
            },
            ..Scenario::default()
        }
        .with_nodes(3)
        .with_steps(80)
        .with_seed(seed);
        let tr = fleet(3, 80, 5);
        DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run()
    };
    let a = mk(1);
    let b = mk(1);
    assert_eq!(a.to_json_string(), b.to_json_string(), "same-seed replay diverged");
    let c = mk(2);
    assert_eq!(a.jobs_arrived, c.jobs_arrived, "arrival count depends on seed");
    let at_steps = |r: &pronto::sim::SimReport| {
        r.outcomes
            .iter()
            .map(|o| match *o {
                JobOutcome::Accepted { at, .. } => at,
                JobOutcome::Rejected { at } => at,
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(at_steps(&a), at_steps(&c), "arrival timestamps depend on seed");
}

#[test]
fn named_replay_scenario_matches_its_demo_schedule() {
    let scenario = Scenario::named("replay").unwrap().with_nodes(4);
    let steps = 400;
    let scenario = scenario.with_steps(steps);
    let demo = ReplaySchedule::demo(2_000); // catalog schedule length
    let tr = fleet(4, steps, 13);
    let report =
        DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run();
    let expected: usize = (0..steps).map(|t| demo.count_at(t) as usize).sum();
    assert_eq!(report.jobs_arrived, expected);
}
