//! Streaming trace-source regressions: the windowed streaming path must
//! be byte-indistinguishable from materialized replay on every catalog
//! scenario, and report documents must never contain non-finite values —
//! even on degenerate runs (everything rejected, nothing arriving, no
//! federation pushes).

use pronto::scheduler::{Admission, NodeScheduler, ProntoPolicy, RandomPolicy, RejectConfig};
use pronto::ser::JsonValue;
use pronto::sim::{ArrivalPattern, DiscreteEventEngine, Scenario, CATALOG};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, TraceSource, VmTrace};

fn members(n: usize) -> Vec<(usize, usize)> {
    (0..n).map(|v| (v / 4, v)).collect()
}

fn fleet(gen: &TraceGenerator, n: usize, steps: usize) -> Vec<VmTrace> {
    members(n)
        .iter()
        .map(|&(c, v)| gen.generate_vm_in_cluster(c, v, steps))
        .collect()
}

fn always_policies(n: usize) -> Vec<Box<dyn Admission>> {
    (0..n)
        .map(|i| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
        .collect()
}

fn pronto_policies(n: usize, d: usize) -> Vec<Box<dyn Admission>> {
    (0..n)
        .map(|_| {
            Box::new(ProntoPolicy::new(NodeScheduler::new(d, RejectConfig::default())))
                as Box<dyn Admission>
        })
        .collect()
}

#[test]
fn streaming_reports_match_materialized_on_every_catalog_scenario() {
    // The acceptance criterion of the streaming work: same scenario, same
    // seed, same generator → byte-identical `--json` documents whether
    // telemetry is materialized up front or streamed through the window.
    let n = 6;
    let steps = 600;
    for name in CATALOG {
        let scenario = Scenario::named(name)
            .unwrap()
            .with_nodes(n)
            .with_steps(steps)
            .with_seed(0xFEED);
        let gen = TraceGenerator::new(GeneratorConfig::default(), 1717);
        let mat = DiscreteEventEngine::try_from_source(
            scenario.clone(),
            TraceSource::materialized(fleet(&gen, n, steps)),
            always_policies(n),
        )
        .unwrap()
        .run();
        let stream = DiscreteEventEngine::try_from_source(
            scenario.clone(),
            TraceSource::streaming(&gen, &members(n), steps, scenario.score_window),
            always_policies(n),
        )
        .unwrap()
        .run();
        assert_eq!(
            mat.to_json_string(),
            stream.to_json_string(),
            "scenario '{name}': streaming diverged from materialized"
        );
        assert_eq!(mat.outcomes, stream.outcomes, "scenario '{name}': outcome drift");
        assert_eq!(mat.events_processed, stream.events_processed);
    }
}

#[test]
fn streaming_parity_holds_with_pronto_policies_under_churn() {
    // `churn` is the hard case for a sliding window: dead nodes stop
    // consuming telemetry, then must resume on the exact column when they
    // rejoin (plus federation pulls through the policy factory).
    let n = 6;
    let steps = 800;
    let d = GeneratorConfig::default().dim;
    let scenario = Scenario::named("churn")
        .unwrap()
        .with_nodes(n)
        .with_steps(steps)
        .with_seed(42);
    let gen = TraceGenerator::new(GeneratorConfig::default(), 55);
    let run = |source: TraceSource| {
        DiscreteEventEngine::try_from_source(scenario.clone(), source, pronto_policies(n, d))
            .unwrap()
            .with_policy_factory(Box::new(move |_| {
                Box::new(ProntoPolicy::new(NodeScheduler::new(d, RejectConfig::default())))
                    as Box<dyn Admission>
            }))
            .run()
    };
    let mat = run(TraceSource::materialized(fleet(&gen, n, steps)));
    let stream = run(TraceSource::streaming(
        &gen,
        &members(n),
        steps,
        scenario.score_window,
    ));
    assert!(mat.node_leaves > 0, "churn never fired");
    assert_eq!(
        mat.to_json_string(),
        stream.to_json_string(),
        "streaming diverged under churn + pronto policies"
    );
}

/// Every float-valued report field must parse back as a finite number;
/// the named keys must be exactly zero.
fn assert_zeroed_and_finite(text: &str, zero_keys: &[&str]) {
    let lower = text.to_ascii_lowercase();
    assert!(
        !lower.contains("nan") && !lower.contains("inf"),
        "non-finite value leaked into JSON: {text}"
    );
    let doc = pronto::ser::parse_json(text).expect("report must stay valid JSON");
    for key in zero_keys {
        let v = doc
            .get(key)
            .and_then(JsonValue::as_f64)
            .unwrap_or_else(|| panic!("missing or non-numeric key '{key}': {text}"));
        assert_eq!(v, 0.0, "'{key}' must be 0.0, got {v}");
    }
}

#[test]
fn all_rejected_run_reports_zeros_not_nans() {
    // RandomPolicy with reject probability 1.0 refuses every offer. With
    // the SLO-bearing `priority` scenario, every mean_*/attainment field
    // divides by a count that is now zero — the report must emit 0.0.
    let n = 4;
    let steps = 400;
    let scenario = Scenario::named("priority").unwrap().with_nodes(n).with_steps(steps);
    let gen = TraceGenerator::new(GeneratorConfig::default(), 7);
    let reject_all: Vec<Box<dyn Admission>> = (0..n)
        .map(|i| Box::new(RandomPolicy::new(1.0, i as u64)) as Box<dyn Admission>)
        .collect();
    let report = DiscreteEventEngine::try_from_source(
        scenario,
        TraceSource::materialized(fleet(&gen, n, steps)),
        reject_all,
    )
    .unwrap()
    .run();
    assert!(report.jobs_arrived > 0, "load too thin to mean anything");
    assert_eq!(report.jobs_accepted, 0);
    assert_eq!(report.jobs_rejected, report.jobs_arrived);
    assert_eq!(report.slo_total, report.jobs_arrived);
    assert_eq!(report.slo_attained, 0);
    assert_zeroed_and_finite(
        &report.to_json_string(),
        &[
            "mean_push_latency_steps",
            "mean_queue_delay_steps",
            "mean_utilization",
            "slo_attainment",
            "queue_delay_p0",
            "queue_delay_p1",
            "queue_delay_p2",
            "acceptance_rate",
        ],
    );
}

#[test]
fn zero_arrival_zero_push_run_reports_zeros_not_nans() {
    // No arrivals and no federation: every rate/mean denominator is zero.
    let scenario = Scenario {
        arrivals: ArrivalPattern::Poisson { rate: 0.0 },
        ..Scenario::named("capacity").unwrap()
    }
    .with_nodes(3)
    .with_steps(300);
    let gen = TraceGenerator::new(GeneratorConfig::default(), 9);
    let report = DiscreteEventEngine::try_from_source(
        scenario,
        TraceSource::materialized(fleet(&gen, 3, 300)),
        always_policies(3),
    )
    .unwrap()
    .run();
    assert_eq!(report.jobs_arrived, 0);
    assert_eq!(report.federation_pushes, 0);
    assert_zeroed_and_finite(
        &report.to_json_string(),
        &[
            "mean_push_latency_steps",
            "mean_queue_delay_steps",
            "mean_utilization",
            "jobs_arrived",
        ],
    );
}
