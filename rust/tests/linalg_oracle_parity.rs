//! Blocked-vs-scalar linalg kernel parity: the panel-blocked kernels
//! behind `Mat::matmul_into`, `matvec_into`, and `transpose_matvec_into`
//! must be **bit-identical** to the scalar oracle that
//! `PRONTO_LINALG=scalar` selects.
//!
//! Two layers of evidence, mirroring `tests/queue_wheel_parity.rs`:
//!
//! * kernel-level property tests — randomized shapes (panel remainders
//!   included) and data (exact zeros injected to exercise the matvec
//!   skip gate) produce bitwise-equal outputs from both backings via the
//!   explicit `_with` entry points;
//! * an env-plumbing test pinning `LinalgBacking::from_env()`. The
//!   cached `LinalgBacking::current()` cannot flip mid-process (it is a
//!   `OnceLock`), so engine-level byte identity under
//!   `PRONTO_LINALG=scalar` runs cross-process in CI, diffing full
//!   scenario reports against the default blocked run.
//!
//! Seeded and replayable via `PRONTO_PROP_SEED` / `PRONTO_PROP_CASES`.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::linalg::{LinalgBacking, Mat};
use pronto::proptest::forall;
use pronto::rng::Xoshiro256;

/// Random matrix with exact zeros sprinkled in: the matvec kernels gate
/// on `x == 0.0`, so parity must hold across the skip/no-skip boundary.
fn random_mat(rng: &mut Xoshiro256, rows: usize, cols: usize, zero_prob: f64) -> Mat {
    let data: Vec<f64> = (0..rows * cols)
        .map(|_| if rng.bernoulli(zero_prob) { 0.0 } else { rng.normal() })
        .collect();
    Mat::from_col_major(rows, cols, data)
}

/// Bitwise comparison: `f64::==` would let `-0.0` impersonate `0.0`.
fn bits_equal(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn matmul_backings_are_bit_identical() {
    forall("blocked ≡ scalar: matmul_into over random shapes", |rng| {
        // Shapes straddle the 4-wide panel boundary on every side.
        let m = 1 + rng.gen_range(13);
        let k = 1 + rng.gen_range(10);
        let n = 1 + rng.gen_range(13);
        let a = random_mat(rng, m, k, 0.15);
        let b = random_mat(rng, k, n, 0.15);
        let mut blocked = Mat::zeros(m, n);
        let mut scalar = Mat::zeros(m, n);
        a.matmul_into_with(&b, &mut blocked, LinalgBacking::Blocked);
        a.matmul_into_with(&b, &mut scalar, LinalgBacking::Scalar);
        if bits_equal(blocked.data(), scalar.data()) {
            Ok(())
        } else {
            Err(format!("matmul {m}x{k} · {k}x{n}: backings disagree bitwise"))
        }
    });
}

#[test]
fn batch_matvec_backings_are_bit_identical() {
    forall("blocked ≡ scalar: batch_matvec_into", |rng| {
        let d = 1 + rng.gen_range(16);
        let r = 1 + rng.gen_range(9);
        let cols = 1 + rng.gen_range(9);
        let u = random_mat(rng, d, r, 0.1);
        let xs = random_mat(rng, r, cols, 0.1);
        let mut blocked = Mat::zeros(d, cols);
        let mut scalar = Mat::zeros(d, cols);
        u.batch_matvec_into_with(&xs, &mut blocked, LinalgBacking::Blocked);
        u.batch_matvec_into_with(&xs, &mut scalar, LinalgBacking::Scalar);
        if bits_equal(blocked.data(), scalar.data()) {
            Ok(())
        } else {
            Err(format!("batch_matvec {d}x{r} · {r}x{cols}: backings disagree bitwise"))
        }
    });
}

#[test]
fn matvec_backings_are_bit_identical_across_zero_gates() {
    forall("blocked ≡ scalar: matvec_into / transpose_matvec_into", |rng| {
        let rows = 1 + rng.gen_range(14);
        let cols = 1 + rng.gen_range(14);
        let a = random_mat(rng, rows, cols, 0.1);
        // Heavy zero density in the vector: every panel shape (all-zero,
        // mixed, zero-free) shows up across cases, exercising both the
        // jammed fast path and the per-column skip fallback.
        let v: Vec<f64> = (0..cols)
            .map(|_| if rng.bernoulli(0.4) { 0.0 } else { rng.normal() })
            .collect();
        let mut blocked = vec![0.0; rows];
        let mut scalar = vec![0.0; rows];
        a.matvec_into_with(&v, &mut blocked, LinalgBacking::Blocked);
        a.matvec_into_with(&v, &mut scalar, LinalgBacking::Scalar);
        if !bits_equal(&blocked, &scalar) {
            return Err(format!("matvec {rows}x{cols}: backings disagree bitwise"));
        }
        let w: Vec<f64> = (0..rows)
            .map(|_| if rng.bernoulli(0.4) { 0.0 } else { rng.normal() })
            .collect();
        let mut tb = vec![0.0; cols];
        let mut ts = vec![0.0; cols];
        a.transpose_matvec_into_with(&w, &mut tb, LinalgBacking::Blocked);
        a.transpose_matvec_into_with(&w, &mut ts, LinalgBacking::Scalar);
        if !bits_equal(&tb, &ts) {
            return Err(format!("transpose_matvec {rows}x{cols}: backings disagree bitwise"));
        }
        Ok(())
    });
}

#[test]
fn env_var_selects_the_scalar_oracle() {
    // `from_env()` is the uncached read behind the `OnceLock`; this is
    // the only test in this binary touching the variable (the kernel
    // tests above pass backings explicitly), so the process-global
    // mutation cannot race them. The cached `current()` is pinned at
    // whatever the environment held at first use — flipping it requires
    // a fresh process, which is exactly what the CI scalar-vs-blocked
    // report diff does.
    std::env::remove_var("PRONTO_LINALG");
    assert_eq!(LinalgBacking::from_env(), LinalgBacking::Blocked);
    std::env::set_var("PRONTO_LINALG", "scalar");
    assert_eq!(LinalgBacking::from_env(), LinalgBacking::Scalar);
    // Unknown values fall back to the default blocked kernels.
    std::env::set_var("PRONTO_LINALG", "simd");
    assert_eq!(LinalgBacking::from_env(), LinalgBacking::Blocked);
    std::env::remove_var("PRONTO_LINALG");
    assert_eq!(LinalgBacking::from_env(), LinalgBacking::Blocked);
}
