"""L2 graph semantics: FPCA update, merge, and Reject-Job block vs numpy
oracles (ports of the Rust reference implementations)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def svd_r(a, r):
    u, s, vt = np.linalg.svd(a, full_matrices=False)
    return u[:, :r], s[:r]


def rand_orth(rng, d, r):
    q, _ = np.linalg.qr(rng.standard_normal((d, r)))
    return q.astype(np.float32)


# ---------------------------------------------------------------- fpca


@given(seed=st.integers(0, 2**31 - 1))
def test_fpca_update_first_block_is_block_svd(seed):
    rng = np.random.default_rng(seed)
    d, r, b = 20, 4, 16
    block = rng.standard_normal((d, b)).astype(np.float32)
    u0 = np.zeros((d, r), dtype=np.float32)
    s0 = np.zeros(r, dtype=np.float32)
    u, s = model.fpca_update(jnp.asarray(u0), jnp.asarray(s0), jnp.asarray(block), 1.0)
    _, s_true = svd_r(block, r)
    np.testing.assert_allclose(np.asarray(s), s_true, rtol=2e-2, atol=1e-3)


@given(seed=st.integers(0, 2**31 - 1))
def test_fpca_update_matches_direct_svd_of_concatenation(seed):
    rng = np.random.default_rng(seed)
    d, r, b = 24, 4, 16
    # Previous estimate = exact SVD of some earlier data.
    prev = rng.standard_normal((d, 40)).astype(np.float32)
    u0, s0 = svd_r(prev, r)
    block = rng.standard_normal((d, b)).astype(np.float32)
    u, s = model.fpca_update(
        jnp.asarray(u0.astype(np.float32)),
        jnp.asarray(s0.astype(np.float32)),
        jnp.asarray(block),
        1.0,
    )
    m = np.concatenate([u0 * s0[None, :], block], axis=1)
    _, s_true = svd_r(m, r)
    np.testing.assert_allclose(np.asarray(s), s_true, rtol=2e-2, atol=2e-3)


def test_fpca_update_forget_shrinks_history():
    rng = np.random.default_rng(5)
    d, r, b = 16, 4, 16
    u0 = rand_orth(rng, d, r)
    s0 = np.array([10.0, 5.0, 2.0, 1.0], dtype=np.float32)
    block = 0.01 * rng.standard_normal((d, b)).astype(np.float32)
    _, s_keep = model.fpca_update(jnp.asarray(u0), jnp.asarray(s0), jnp.asarray(block), 1.0)
    _, s_forget = model.fpca_update(jnp.asarray(u0), jnp.asarray(s0), jnp.asarray(block), 0.5)
    assert np.asarray(s_forget)[0] < np.asarray(s_keep)[0]
    np.testing.assert_allclose(np.asarray(s_forget)[0], 5.0, rtol=5e-2)


# ---------------------------------------------------------------- merge


@given(seed=st.integers(0, 2**31 - 1))
def test_merge_matches_algorithm3_oracle(seed):
    rng = np.random.default_rng(seed)
    d, r = 20, 4
    u1, s1 = rand_orth(rng, d, r), np.sort(rng.uniform(1, 10, r))[::-1].astype(np.float32)
    u2, s2 = rand_orth(rng, d, r), np.sort(rng.uniform(1, 10, r))[::-1].astype(np.float32)
    lam = 0.9
    um, sm = model.merge_subspaces(
        jnp.asarray(u1), jnp.asarray(s1), jnp.asarray(u2), jnp.asarray(s2), lam
    )
    cat = np.concatenate([lam * u1 * s1[None, :], u2 * s2[None, :]], axis=1)
    _, s_true = svd_r(cat, r)
    np.testing.assert_allclose(np.asarray(sm), s_true, rtol=2e-2, atol=2e-3)
    # Merged basis orthonormal.
    um = np.asarray(um)
    np.testing.assert_allclose(um.T @ um, np.eye(r), atol=5e-3)


# ---------------------------------------------------------- project_detect


def zscore_oracle(p_seq, lag=10, alpha=3.5, beta=0.5):
    """Numpy port of rust/src/detect/zscore.rs MultiDetector."""
    b, r = p_seq.shape
    buf = np.zeros((r, lag))
    seen = 0
    flags = np.zeros((b, r))
    for t in range(b):
        warmed = seen >= lag
        mean = buf.mean(axis=1)
        std = buf.std(axis=1)
        dev = p_seq[t] - mean
        spike = warmed & (np.abs(dev) > alpha * std) & (std > 0)
        flags[t] = np.where(spike, np.sign(dev), 0.0)
        last = buf[:, -1]
        entering = np.where(spike, beta * p_seq[t] + (1 - beta) * last, p_seq[t])
        buf = np.concatenate([buf[:, 1:], entering[:, None]], axis=1)
        seen += 1
    return flags, buf, seen


@given(seed=st.integers(0, 2**31 - 1))
def test_project_detect_flags_match_oracle(seed):
    rng = np.random.default_rng(seed)
    d, r, b, lag = 12, 4, 32, 10
    u = rand_orth(rng, d, r)
    s = np.array([4.0, 3.0, 2.0, 1.0], dtype=np.float32)
    # Steady stream with one injected spike after warmup.
    y = 0.05 * rng.standard_normal((b, d)).astype(np.float32) + 1.0
    y[20] += 30.0 * u[:, 0]  # aligned with lane 0
    buf0 = np.zeros((r, lag), dtype=np.float32)
    flags, reject, buf, seen = model.project_detect(
        jnp.asarray(u), jnp.asarray(s), jnp.asarray(y),
        jnp.asarray(buf0), jnp.int32(0),
    )
    p = y @ u
    want_flags, want_buf, want_seen = zscore_oracle(p.astype(np.float64))
    np.testing.assert_array_equal(np.asarray(flags), want_flags)
    np.testing.assert_allclose(np.asarray(buf), want_buf, rtol=1e-4, atol=1e-4)
    assert int(seen) == want_seen


def test_project_detect_rejects_on_dominant_spike():
    rng = np.random.default_rng(11)
    d, r, b, lag = 12, 4, 32, 10
    u = rand_orth(rng, d, r)
    s = np.array([4.0, 1.0, 0.5, 0.25], dtype=np.float32)
    y = 0.05 * rng.standard_normal((b, d)).astype(np.float32)
    y[25] += 50.0 * u[:, 0]
    flags, reject, _, _ = model.project_detect(
        jnp.asarray(u), jnp.asarray(s), jnp.asarray(y),
        jnp.zeros((r, lag), dtype=jnp.float32), jnp.int32(0),
    )
    reject = np.asarray(reject)
    assert reject[25] == 1.0, "dominant-lane spike must raise rejection"
    assert reject[:lag].sum() == 0.0, "no rejections during warmup"


def test_project_detect_state_threads_across_blocks():
    # Two consecutive blocks must equal one double-length block.
    rng = np.random.default_rng(13)
    d, r, b, lag = 8, 4, 16, 10
    u = rand_orth(rng, d, r)
    s = np.ones(r, dtype=np.float32)
    y = rng.standard_normal((2 * b, d)).astype(np.float32)
    buf = jnp.zeros((r, lag), dtype=jnp.float32)
    seen = jnp.int32(0)
    f1, _, buf, seen = model.project_detect(
        jnp.asarray(u), jnp.asarray(s), jnp.asarray(y[:b]), buf, seen
    )
    f2, _, buf, seen = model.project_detect(
        jnp.asarray(u), jnp.asarray(s), jnp.asarray(y[b:]), buf, seen
    )
    p = y @ u
    want, _, _ = zscore_oracle(p.astype(np.float64))
    got = np.concatenate([np.asarray(f1), np.asarray(f2)])
    np.testing.assert_array_equal(got, want)
    assert int(seen) == 2 * b
