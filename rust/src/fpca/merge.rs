//! Subspace merging (paper Algorithms 3 and 4).
//!
//! Merging combines two `(U, Σ)` estimates into one describing the union of
//! the workloads they summarize. Algorithm 3 is the direct SVD of the
//! concatenated scaled bases; Algorithm 4 avoids materializing Vᵀ by
//! reducing to a small ((r₁+r₂) × (r₁+r₂)) SVD via a Gram product and one
//! QR — the variant both the aggregator tree and FPCA-Edge use.

use super::Subspace;
use crate::linalg::{householder_qr, svd_truncated, Mat};

/// Merge parameters.
#[derive(Debug, Clone, Copy)]
pub struct MergeOptions {
    /// Target rank r of the merged estimate.
    pub rank: usize,
    /// Forgetting factor λ₁ ∈ (0, 1] applied to the *first* (older)
    /// subspace (Algorithm 3).
    pub forget: f64,
    /// Enhancing factor λ₂ ≥ 1 applied to the second (newer) subspace.
    pub enhance: f64,
}

impl MergeOptions {
    pub fn rank(rank: usize) -> Self {
        Self { rank, forget: 1.0, enhance: 1.0 }
    }
}

/// Algorithm 3: `[U', Σ', ~] ← SVD_r([λ₁ U₁Σ₁, λ₂ U₂Σ₂])`.
///
/// Direct and simple; costs an SVD of a d × (r₁+r₂) matrix. Used as the
/// reference implementation and in tests against [`merge_subspaces`].
pub fn merge_svd_basic(s1: &Subspace, s2: &Subspace, opts: MergeOptions) -> Subspace {
    assert_eq!(s1.dim(), s2.dim(), "merge dimension mismatch");
    if s1.is_empty() {
        return s2.truncate(opts.rank);
    }
    if s2.is_empty() {
        return s1.truncate(opts.rank);
    }
    let left = s1.scaled_basis().scaled(opts.forget);
    let right = s2.scaled_basis().scaled(opts.enhance);
    let cat = left.hcat(&right);
    let svd = svd_truncated(&cat, opts.rank.min(cat.cols()));
    Subspace::new(svd.u, svd.sigma)
}

/// Algorithm 4: the optimized, Vᵀ-free merge.
///
/// ```text
/// Z ← U₁ᵀ U₂
/// [Q, R] ← QR(U₂ − U₁ Z)
/// [U', Σ', ~] ← SVD_r([[λ₁Σ₁, Z Σ₂], [0, R Σ₂]])
/// U'' ← [U₁, Q] U'
/// ```
///
/// Requires both bases orthonormal (they are, by construction, everywhere in
/// PRONTO). The expensive inputs are the two d × r Gram/QR products; the SVD
/// itself is on an (r₁+r₂) square matrix.
pub fn merge_subspaces(s1: &Subspace, s2: &Subspace, opts: MergeOptions) -> Subspace {
    assert_eq!(s1.dim(), s2.dim(), "merge dimension mismatch");
    if s1.is_empty() {
        return s2.truncate(opts.rank);
    }
    if s2.is_empty() {
        return s1.truncate(opts.rank);
    }
    let (r1, r2) = (s1.rank(), s2.rank());

    // Z = U1ᵀ U2  (r1 × r2)
    let z = s1.u.transpose_mul(&s2.u);
    // QR of the component of U2 orthogonal to U1.
    let u1z = s1.u.matmul(&z);
    let (q, r) = householder_qr(&s2.u.sub(&u1z));

    // Small block matrix  [[λ₁Σ₁, ZΣ₂], [0, RΣ₂]]  of size (r1+r2)².
    let mut x = Mat::zeros(r1 + r2, r1 + r2);
    for i in 0..r1 {
        x.set(i, i, opts.forget * s1.sigma[i]);
    }
    let zs2 = z.mul_diag(&s2.sigma.iter().map(|s| s * opts.enhance).collect::<Vec<_>>());
    for i in 0..r1 {
        for j in 0..r2 {
            x.set(i, r1 + j, zs2.get(i, j));
        }
    }
    let rs2 = r.mul_diag(&s2.sigma.iter().map(|s| s * opts.enhance).collect::<Vec<_>>());
    for i in 0..r2 {
        for j in 0..r2 {
            x.set(r1 + i, r1 + j, rs2.get(i, j));
        }
    }

    let svd = svd_truncated(&x, opts.rank.min(r1 + r2));
    // U'' = [U1, Q] U'
    let basis = s1.u.hcat(&q);
    let u2 = basis.matmul(&svd.u);
    Subspace::new(u2, svd.sigma)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{orthonormality_error, subspace_distance};
    use crate::proptest::{forall, gen_low_rank, gen_orthonormal, gen_spectrum};

    fn random_subspace(rng: &mut crate::rng::Xoshiro256, d: usize, r: usize) -> Subspace {
        let u = gen_orthonormal(rng, d, r);
        let sigma = gen_spectrum(rng, r);
        Subspace::new(u, sigma)
    }

    #[test]
    fn merge_with_empty_is_truncation() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(1);
        let s = random_subspace(&mut rng, 12, 4);
        let e = Subspace::empty(12);
        let m = merge_subspaces(&e, &s, MergeOptions::rank(3));
        assert_eq!(m.rank(), 3);
        assert_eq!(m.sigma, s.sigma[..3]);
        let m2 = merge_subspaces(&s, &e, MergeOptions::rank(2));
        assert_eq!(m2.rank(), 2);
    }

    #[test]
    fn optimized_merge_matches_basic_svd_merge() {
        forall("Alg4 == Alg3", |rng| {
            let d = 8 + rng.gen_range(24);
            let r1 = 1 + rng.gen_range(4);
            let r2 = 1 + rng.gen_range(4);
            let s1 = random_subspace(rng, d, r1);
            let s2 = random_subspace(rng, d, r2);
            let opts = MergeOptions { rank: (r1 + r2).min(4), forget: 0.9, enhance: 1.0 };
            let a = merge_svd_basic(&s1, &s2, opts);
            let b = merge_subspaces(&s1, &s2, opts);
            // Same singular values…
            for (x, y) in a.sigma.iter().zip(b.sigma.iter()) {
                if (x - y).abs() > 1e-8 * (1.0 + x.abs()) {
                    return Err(format!("sigma mismatch: {:?} vs {:?}", a.sigma, b.sigma));
                }
            }
            // …and same span (bases may differ by rotation within equal
            // singular-value groups; compare the subspaces).
            let dist = subspace_distance(&a.u, &b.u);
            if dist > 1e-6 {
                return Err(format!("span mismatch: dist={dist}"));
            }
            Ok(())
        });
    }

    #[test]
    fn merged_basis_orthonormal() {
        forall("merge orthonormality", |rng| {
            let d = 8 + rng.gen_range(40);
            let r1 = 1 + rng.gen_range(5);
            let r2 = 1 + rng.gen_range(5);
            let s1 = random_subspace(rng, d, r1);
            let s2 = random_subspace(rng, d, r2);
            let m = merge_subspaces(&s1, &s2, MergeOptions::rank(4));
            let err = orthonormality_error(&m.u);
            if err < 1e-8 {
                Ok(())
            } else {
                Err(format!("orthonormality err {err}"))
            }
        });
    }

    #[test]
    fn merge_sigma_descending_nonnegative() {
        forall("merge spectrum ordered", |rng| {
            let d = 10 + rng.gen_range(20);
            let s1 = random_subspace(rng, d, 3);
            let s2 = random_subspace(rng, d, 3);
            let m = merge_subspaces(&s1, &s2, MergeOptions::rank(6));
            if m.sigma.windows(2).all(|w| w[0] >= w[1] - 1e-12)
                && m.sigma.iter().all(|&s| s >= 0.0)
            {
                Ok(())
            } else {
                Err(format!("bad spectrum {:?}", m.sigma))
            }
        });
    }

    #[test]
    fn merging_identical_subspace_preserves_span() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(8);
        let s = random_subspace(&mut rng, 16, 3);
        let m = merge_subspaces(&s, &s, MergeOptions::rank(3));
        assert!(subspace_distance(&m.u, &s.u) < 1e-6);
        // Energy doubles in quadrature: sqrt(2)·σ.
        for (ms, ss) in m.sigma.iter().zip(s.sigma.iter()) {
            assert!((ms - ss * 2f64.sqrt()).abs() < 1e-8);
        }
    }

    #[test]
    fn merge_recovers_true_subspace_of_split_data() {
        // SVD of [A | B] computed directly vs merging SVD(A) with SVD(B):
        // for exact-rank inputs the merge is lossless.
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(9);
        let d = 20;
        let a = gen_low_rank(&mut rng, d, 15, 3, 0.0);
        let b = gen_low_rank(&mut rng, d, 15, 3, 0.0);
        let svd_a = crate::linalg::svd_truncated(&a, 3);
        let svd_b = crate::linalg::svd_truncated(&b, 3);
        let sa = Subspace::new(svd_a.u, svd_a.sigma);
        let sb = Subspace::new(svd_b.u, svd_b.sigma);
        let merged = merge_subspaces(&sa, &sb, MergeOptions::rank(6));

        let cat = a.hcat(&b);
        let direct = crate::linalg::svd_truncated(&cat, 6);
        for (m, d_) in merged.sigma.iter().zip(direct.sigma.iter()) {
            assert!((m - d_).abs() < 1e-7 * (1.0 + d_), "{:?} vs {:?}", merged.sigma, direct.sigma);
        }
        assert!(subspace_distance(&merged.u, &direct.u) < 1e-6);
    }

    #[test]
    fn forgetting_factor_downweights_old_subspace() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(10);
        let old = random_subspace(&mut rng, 12, 2);
        let new = random_subspace(&mut rng, 12, 2);
        let no_forget = merge_subspaces(&old, &new, MergeOptions { rank: 2, forget: 1.0, enhance: 1.0 });
        let forget = merge_subspaces(&old, &new, MergeOptions { rank: 2, forget: 0.1, enhance: 1.0 });
        // With heavy forgetting the merged span should be closer to `new`.
        let d_no = subspace_distance(&no_forget.u, &new.u);
        let d_yes = subspace_distance(&forget.u, &new.u);
        assert!(d_yes <= d_no + 1e-9, "d_yes={d_yes} d_no={d_no}");
    }
}
