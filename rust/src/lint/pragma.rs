//! `pronto-lint` suppression pragmas.
//!
//! A finding is suppressed by a line comment of the form
//!
//! ```text
//! // pronto-lint: allow(<rule>[, <rule>...]) — <reason>
//! ```
//!
//! placed either on the offending line (trailing) or on the line
//! directly above it. The em-dash separator may also be written `--`.
//! The reason is mandatory: a pragma without one never suppresses and
//! is itself reported, as are pragmas naming unknown rules and pragmas
//! that suppress nothing (so stale exemptions cannot linger).

use super::lexer::{Token, TokenKind};

/// One parsed pragma comment.
#[derive(Debug, Clone)]
pub struct Pragma {
    /// 1-based line of the pragma comment.
    pub line: usize,
    /// Rules it names (empty when malformed).
    pub rules: Vec<String>,
    /// Whether a non-empty reason follows the `—` / `--` separator.
    pub has_reason: bool,
    /// `pronto-lint:` marker present but the `allow(...)` clause is not
    /// parseable.
    pub malformed: bool,
}

impl Pragma {
    /// Does this pragma (when well-formed, with a reason) cover a
    /// finding of `rule` on `line`?
    pub fn covers(&self, rule: &str, line: usize) -> bool {
        !self.malformed
            && self.has_reason
            && (self.line == line || self.line + 1 == line)
            && self.rules.iter().any(|r| r == rule)
    }
}

/// Extract every pragma from a token stream (only `//` line comments are
/// considered; doc comments `///` and `//!` are prose, not directives).
pub fn parse_pragmas(tokens: &[Token]) -> Vec<Pragma> {
    let mut out = Vec::new();
    for t in tokens {
        if t.kind != TokenKind::LineComment {
            continue;
        }
        let body = t.text.trim_start_matches('/');
        // Exactly `//`: three or more slashes make it a doc comment.
        if t.text.len() - body.len() != 2 {
            continue;
        }
        let body = body.trim_start();
        let Some(rest) = body.strip_prefix("pronto-lint:") else {
            continue;
        };
        let rest = rest.trim_start();
        let Some(args) = rest.strip_prefix("allow") else {
            out.push(Pragma { line: t.line, rules: Vec::new(), has_reason: false, malformed: true });
            continue;
        };
        let args = args.trim_start();
        let (inner, tail) = match args.strip_prefix('(').and_then(|a| a.split_once(')')) {
            Some(pair) => pair,
            None => {
                out.push(Pragma {
                    line: t.line,
                    rules: Vec::new(),
                    has_reason: false,
                    malformed: true,
                });
                continue;
            }
        };
        let rules: Vec<String> = inner
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        let tail = tail.trim_start();
        let reason = tail
            .strip_prefix('\u{2014}')
            .or_else(|| tail.strip_prefix("--"))
            .map(str::trim)
            .unwrap_or("");
        out.push(Pragma {
            line: t.line,
            rules,
            has_reason: !reason.is_empty(),
            malformed: false,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::lex;

    #[test]
    fn parses_rule_and_reason() {
        let toks = lex("// pronto-lint: allow(wall-clock) — bench timing is the product\nlet x = 1;");
        let p = parse_pragmas(&toks);
        assert_eq!(p.len(), 1);
        assert!(!p[0].malformed);
        assert_eq!(p[0].rules, vec!["wall-clock".to_string()]);
        assert!(p[0].has_reason);
        assert!(p[0].covers("wall-clock", 1));
        assert!(p[0].covers("wall-clock", 2));
        assert!(!p[0].covers("wall-clock", 3));
        assert!(!p[0].covers("rng-discipline", 1));
    }

    #[test]
    fn ascii_double_dash_separator() {
        let toks = lex("// pronto-lint: allow(env-registry, schema-pin) -- two rules at once");
        let p = parse_pragmas(&toks);
        assert_eq!(p[0].rules.len(), 2);
        assert!(p[0].has_reason);
    }

    #[test]
    fn missing_reason_never_covers() {
        let toks = lex("// pronto-lint: allow(wall-clock)");
        let p = parse_pragmas(&toks);
        assert!(!p[0].malformed);
        assert!(!p[0].has_reason);
        assert!(!p[0].covers("wall-clock", 1));
    }

    #[test]
    fn malformed_pragma_flagged() {
        let toks = lex("// pronto-lint: please ignore this");
        let p = parse_pragmas(&toks);
        assert!(p[0].malformed);
    }

    #[test]
    fn doc_comments_are_not_pragmas() {
        let toks = lex("/// pronto-lint: allow(wall-clock) — prose about pragmas");
        assert!(parse_pragmas(&toks).is_empty());
    }
}
