//! Table 4: spike-alarm accuracy with fixed thresholds 500/800/1000 ms.
//!
//! Paper shape: accuracy rises as the threshold rises (rarer, better-
//! defined spikes); % of spikes falls from ~9.5 to ~0.85.

use pronto::bench::experiments::{spike_tables, ExperimentScale};
use pronto::bench::Table;
use pronto::forecast::SpikeThreshold;

fn main() {
    let scale = ExperimentScale::from_env();
    let (rows, pct) = spike_tables(
        &scale,
        &[
            SpikeThreshold::Fixed(500.0),
            SpikeThreshold::Fixed(800.0),
            SpikeThreshold::Fixed(1000.0),
        ],
    );
    let mut t = Table::new(
        "Table 4: alarm accuracy, fixed spike thresholds",
        &["method", "500", "800", "1000"],
    );
    for (name, c) in rows {
        t.row(&[name, format!("{:.4}", c[0]), format!("{:.4}", c[1]), format!("{:.4}", c[2])]);
    }
    t.row(&[
        "% of spikes".into(),
        format!("{:.2}", pct[0]),
        format!("{:.2}", pct[1]),
        format!("{:.2}", pct[2]),
    ]);
    t.print();
    t.maybe_write_csv("table4");
    println!("\npaper reference: best accuracies 0.9071/0.9417/0.9763; spikes 9.54/2.63/0.85%");
}
