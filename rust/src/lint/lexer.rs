//! A lightweight Rust tokenizer for the lint pass.
//!
//! This is not a full Rust lexer — it only needs to be precise about the
//! things the rules care about: identifiers, integer literals, string
//! literals (including raw and byte strings), and comments (line, block,
//! doc), each stamped with its 1-based source line. Everything else is
//! emitted as single-character [`TokenKind::Punct`] tokens, which is
//! enough to pattern-match call shapes like `insert("key"` or
//! `stream_seed(seed, 3)` without building an AST. Crucially it never
//! confuses the *contents* of strings or comments with code, so a comment
//! mentioning `Instant::now()` does not trip the wall-clock rule.

/// Token classes the rule engine distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokenKind {
    /// Identifier or keyword (`Instant`, `unsafe`, `insert`, ...).
    Ident,
    /// Integer literal, raw text preserved (`10`, `0x9E37_79B9`, `1u64`).
    IntLit,
    /// Float literal (`1.5`, `1e-9`, `2.0f64`).
    FloatLit,
    /// String literal; `text` holds the *unquoted* content with escape
    /// sequences left as written (`\n` stays two characters).
    StrLit,
    /// Character or byte literal (`'a'`, `b'\n'`).
    CharLit,
    /// Lifetime (`'a` in `&'a str`).
    Lifetime,
    /// `// ...` comment (including `///` and `//!` doc comments);
    /// `text` holds the full lexeme including the slashes.
    LineComment,
    /// `/* ... */` comment (nesting handled); `line` is the start line.
    BlockComment,
    /// Any other single character (`{`, `#`, `:`, ...).
    Punct,
}

/// One lexed token with its 1-based start line.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokenKind,
    pub text: String,
    pub line: usize,
}

impl Token {
    /// Comments carry pragmas and `SAFETY:` notes but are never code.
    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokenKind::LineComment | TokenKind::BlockComment)
    }

    pub fn is_ident(&self, name: &str) -> bool {
        self.kind == TokenKind::Ident && self.text == name
    }

    pub fn is_punct(&self, ch: char) -> bool {
        self.kind == TokenKind::Punct && self.text.len() == 1 && self.text.starts_with(ch)
    }
}

/// Tokenize a source file. Never fails: unexpected bytes degrade to
/// `Punct` tokens rather than aborting the lint of the whole file.
pub fn lex(source: &str) -> Vec<Token> {
    Lexer {
        chars: source.chars().collect(),
        pos: 0,
        line: 1,
        out: Vec::new(),
    }
    .run()
}

/// Parse an integer literal's numeric value: strips `_` separators,
/// handles `0x`/`0o`/`0b` radix prefixes, and ignores a trailing type
/// suffix (`u64`, `usize`, ...). Returns `None` for malformed text.
pub fn int_value(text: &str) -> Option<u64> {
    let t: String = text.chars().filter(|&c| c != '_').collect();
    let lower = t.to_ascii_lowercase();
    let (radix, digits) = if let Some(rest) = lower.strip_prefix("0x") {
        (16, rest.to_string())
    } else if let Some(rest) = lower.strip_prefix("0o") {
        (8, rest.to_string())
    } else if let Some(rest) = lower.strip_prefix("0b") {
        (2, rest.to_string())
    } else {
        (10, lower)
    };
    let end = digits
        .find(|c: char| !c.is_digit(radix))
        .unwrap_or(digits.len());
    let num = &digits[..end];
    if num.is_empty() {
        return None;
    }
    u64::from_str_radix(num, radix).ok()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: usize,
    out: Vec<Token>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, kind: TokenKind, text: String, line: usize) {
        self.out.push(Token { kind, text, line });
    }

    fn run(mut self) -> Vec<Token> {
        while let Some(c) = self.peek(0) {
            if c.is_whitespace() {
                self.bump();
            } else if c == '/' && self.peek(1) == Some('/') {
                self.line_comment();
            } else if c == '/' && self.peek(1) == Some('*') {
                self.block_comment();
            } else if (c == 'r' || c == 'b') && self.string_prefix() {
                // consumed a raw/byte string, raw ident, or byte char
            } else if c == '"' {
                self.string();
            } else if c == '\'' {
                self.quote();
            } else if c.is_ascii_digit() {
                self.number();
            } else if c.is_alphabetic() || c == '_' {
                self.ident();
            } else {
                let line = self.line;
                self.bump();
                self.push(TokenKind::Punct, c.to_string(), line);
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.push(TokenKind::LineComment, text, line);
    }

    fn block_comment(&mut self) {
        let line = self.line;
        let mut text = String::new();
        let mut depth = 0usize;
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                text.push_str("/*");
                self.bump();
                self.bump();
            } else if c == '*' && self.peek(1) == Some('/') {
                depth = depth.saturating_sub(1);
                text.push_str("*/");
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.push(TokenKind::BlockComment, text, line);
    }

    /// Handle the `r`/`b` prefixes: raw strings `r"…"` / `r#"…"#`, byte
    /// strings `b"…"` / `br#"…"#`, byte chars `b'…'`, and raw idents
    /// `r#ident`. Returns false when the `r`/`b` is just the start of an
    /// ordinary identifier, leaving the cursor untouched.
    fn string_prefix(&mut self) -> bool {
        let first = self.peek(0).unwrap_or(' ');
        let mut k = 1;
        if first == 'b' {
            match self.peek(1) {
                Some('"') => {
                    self.bump(); // 'b'
                    self.string();
                    return true;
                }
                Some('\'') => {
                    self.bump(); // 'b'
                    self.char_literal();
                    return true;
                }
                Some('r') => k = 2,
                _ => return false,
            }
        }
        // At `r` (possibly after `b`): count hashes, expect a quote.
        let mut hashes = 0;
        while self.peek(k) == Some('#') {
            hashes += 1;
            k += 1;
        }
        match self.peek(k) {
            Some('"') => {
                self.raw_string(k, hashes);
                true
            }
            Some(c) if first == 'r' && hashes == 1 && (c.is_alphabetic() || c == '_') => {
                // Raw identifier `r#ident`: strip the prefix, lex the rest.
                self.bump();
                self.bump();
                self.ident();
                true
            }
            _ => false,
        }
    }

    fn raw_string(&mut self, quote_at: usize, hashes: usize) {
        let line = self.line;
        for _ in 0..=quote_at {
            self.bump(); // prefix chars + opening quote
        }
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '"' {
                let mut all = true;
                for h in 0..hashes {
                    if self.peek(1 + h) != Some('#') {
                        all = false;
                        break;
                    }
                }
                if all {
                    self.bump(); // closing quote
                    for _ in 0..hashes {
                        self.bump();
                    }
                    break;
                }
            }
            text.push(c);
            self.bump();
        }
        self.push(TokenKind::StrLit, text, line);
    }

    fn string(&mut self) {
        let line = self.line;
        self.bump(); // opening quote
        let mut text = String::new();
        while let Some(c) = self.bump() {
            if c == '\\' {
                text.push(c);
                if let Some(esc) = self.bump() {
                    text.push(esc);
                }
            } else if c == '"' {
                break;
            } else {
                text.push(c);
            }
        }
        self.push(TokenKind::StrLit, text, line);
    }

    /// At a `'`: disambiguate lifetimes (`'a`) from char literals (`'x'`).
    fn quote(&mut self) {
        let next = self.peek(1);
        let after = self.peek(2);
        let is_lifetime = matches!(next, Some(c) if c.is_alphabetic() || c == '_')
            && after != Some('\'');
        if is_lifetime {
            let line = self.line;
            let mut text = String::from("'");
            self.bump();
            while let Some(c) = self.peek(0) {
                if c.is_alphanumeric() || c == '_' {
                    text.push(c);
                    self.bump();
                } else {
                    break;
                }
            }
            self.push(TokenKind::Lifetime, text, line);
        } else {
            self.char_literal();
        }
    }

    fn char_literal(&mut self) {
        let line = self.line;
        let mut text = String::from("'");
        self.bump(); // opening quote
        // Bounded scan to the closing quote; escapes skip one char (and
        // `\u{…}` skips to the brace close).
        let mut guard = 0;
        while let Some(c) = self.bump() {
            guard += 1;
            if guard > 16 {
                break; // malformed; don't eat the file
            }
            if c == '\\' {
                text.push(c);
                match self.bump() {
                    Some('u') => {
                        text.push('u');
                        if self.peek(0) == Some('{') {
                            while let Some(u) = self.bump() {
                                text.push(u);
                                if u == '}' {
                                    break;
                                }
                            }
                        }
                    }
                    Some(esc) => text.push(esc),
                    None => break,
                }
            } else if c == '\'' {
                text.push(c);
                break;
            } else {
                text.push(c);
            }
        }
        self.push(TokenKind::CharLit, text, line);
    }

    fn number(&mut self) {
        let line = self.line;
        let mut text = String::new();
        let radix_prefix = self.peek(0) == Some('0')
            && matches!(self.peek(1), Some('x' | 'X' | 'o' | 'b'));
        text.push(self.bump().unwrap());
        if radix_prefix {
            text.push(self.bump().unwrap());
        }
        let mut is_float = false;
        while let Some(c) = self.peek(0) {
            if c.is_ascii_alphanumeric() || c == '_' {
                if !radix_prefix && (c == 'e' || c == 'E') {
                    // Exponent only when digits (or a signed digit) follow;
                    // otherwise it's a suffix/ident boundary.
                    let d1 = self.peek(1);
                    let d2 = self.peek(2);
                    let exp = matches!(d1, Some(d) if d.is_ascii_digit())
                        || (matches!(d1, Some('+' | '-'))
                            && matches!(d2, Some(d) if d.is_ascii_digit()));
                    if exp {
                        is_float = true;
                        text.push(self.bump().unwrap());
                        if matches!(self.peek(0), Some('+' | '-')) {
                            text.push(self.bump().unwrap());
                        }
                        continue;
                    }
                }
                text.push(self.bump().unwrap());
            } else if c == '.'
                && !radix_prefix
                && !is_float
                && matches!(self.peek(1), Some(d) if d.is_ascii_digit())
            {
                is_float = true;
                text.push(self.bump().unwrap());
            } else {
                break;
            }
        }
        let lower = text.to_ascii_lowercase();
        let float = is_float || (!radix_prefix && (lower.ends_with("f32") || lower.ends_with("f64")));
        let kind = if float { TokenKind::FloatLit } else { TokenKind::IntLit };
        self.push(kind, text, line);
    }

    fn ident(&mut self) {
        let line = self.line;
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        self.push(TokenKind::Ident, text, line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokenKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_numbers_and_puncts() {
        let toks = kinds("let x = 0x9E37_79B9u64 + 10;");
        assert!(toks.contains(&(TokenKind::Ident, "let".into())));
        assert!(toks.contains(&(TokenKind::IntLit, "0x9E37_79B9u64".into())));
        assert!(toks.contains(&(TokenKind::IntLit, "10".into())));
        assert!(toks.contains(&(TokenKind::Punct, ";".into())));
    }

    #[test]
    fn int_values_parse_radix_and_suffix() {
        assert_eq!(int_value("10"), Some(10));
        assert_eq!(int_value("0x9E37_79B9"), Some(0x9E37_79B9));
        assert_eq!(int_value("0x9E37_79B9_7F4A_7C15"), Some(0x9E37_79B9_7F4A_7C15));
        assert_eq!(int_value("42u64"), Some(42));
        assert_eq!(int_value("0b101"), Some(5));
        assert_eq!(int_value("_"), None);
    }

    #[test]
    fn strings_and_comments_do_not_leak_code() {
        let toks = lex("// Instant::now() in a comment\nlet s = \"SystemTime\";");
        let idents: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokenKind::Ident)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(idents, vec!["let", "s"]);
        assert!(toks.iter().any(|t| t.kind == TokenKind::StrLit && t.text == "SystemTime"));
    }

    #[test]
    fn raw_and_byte_strings() {
        let toks = kinds(r####"let a = r#"raw "quoted" text"#; let b = b"bytes";"####);
        assert!(toks.contains(&(TokenKind::StrLit, "raw \"quoted\" text".into())));
        assert!(toks.contains(&(TokenKind::StrLit, "bytes".into())));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        assert!(toks.contains(&(TokenKind::Lifetime, "'a".into())));
        assert!(toks.iter().any(|(k, t)| *k == TokenKind::CharLit && t.contains('x')));
        assert!(!toks.iter().any(|(k, t)| *k == TokenKind::Lifetime && t == "'x"));
        let _ = toks.iter().any(|(k, _)| *k == TokenKind::CharLit);
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* outer /* inner */ still comment */ fn f() {}");
        assert_eq!(toks[0].kind, TokenKind::BlockComment);
        assert!(toks[0].text.contains("inner"));
        assert!(toks.iter().any(|t| t.is_ident("fn")));
    }

    #[test]
    fn line_numbers_track_newlines() {
        let toks = lex("a\nb\n\nc");
        let lines: Vec<usize> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }

    #[test]
    fn floats_are_not_int_lits() {
        let toks = kinds("let x = 1.5 + 1e-9 + 2.0f64; let r = 0..10;");
        assert!(toks.contains(&(TokenKind::FloatLit, "1.5".into())));
        assert!(toks.contains(&(TokenKind::FloatLit, "1e-9".into())));
        assert!(toks.contains(&(TokenKind::FloatLit, "2.0f64".into())));
        assert!(toks.contains(&(TokenKind::IntLit, "0".into())));
        assert!(toks.contains(&(TokenKind::IntLit, "10".into())));
    }
}
