//! Sliding-window spike bookkeeping (Figure 5).
//!
//! PRONTO classifies detected spikes relative to a *reference point* placed
//! at the middle of a window of size `w`: events in the half *after* the
//! reference point ("left-sided" in the paper's time-flows-right rendering —
//! i.e. in the future relative to the reference) are treated as **incoming
//! predictions**; events in the half before it are in the past
//! ("right-sided": consecutive/delayed detections). A prediction counts as
//! successful when a CPU Ready spike is preceded by ≥ 1 rejection-signal
//! raise within the current window.

/// Which half of the window an event falls in, relative to the reference
/// point at w/2 (see Figure 5, third row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpikeSide {
    /// Between the reference point and the window head: imminent/incoming
    /// (the important kind — rejection raises here *precede* CPU Ready spikes).
    Left,
    /// Behind the reference point: already happened (consecutive spikes or
    /// delayed detection).
    Right,
}

/// Counts of events by side within one window evaluation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SideCounts {
    pub left: usize,
    pub right: usize,
}

impl SideCounts {
    pub fn total(&self) -> usize {
        self.left + self.right
    }
}

/// Fixed-size boolean ring buffer over the last `w` timesteps with
/// reference-point queries. One instance tracks one binary event stream
/// (e.g. "rejection raised at t" or "CPU Ready spiked at t").
#[derive(Debug, Clone)]
pub struct SlidingWindow {
    w: usize,
    buf: Vec<bool>,
    head: usize,
    seen: usize,
}

impl SlidingWindow {
    pub fn new(w: usize) -> Self {
        assert!(w >= 2, "window must hold at least two timesteps");
        Self { w, buf: vec![false; w], head: 0, seen: 0 }
    }

    /// Window size.
    pub fn len(&self) -> usize {
        self.w
    }

    pub fn is_empty(&self) -> bool {
        self.seen == 0
    }

    /// Observations pushed so far.
    pub fn seen(&self) -> usize {
        self.seen
    }

    /// True once a full window of observations is available — the minimum
    /// before any prediction can be made (Figure 5, second row).
    pub fn full(&self) -> bool {
        self.seen >= self.w
    }

    /// Push the event flag for the newest timestep.
    pub fn push(&mut self, event: bool) {
        self.buf[self.head] = event;
        self.head = (self.head + 1) % self.w;
        self.seen += 1;
    }

    /// Event flag `age` steps back from the newest observation
    /// (`age = 0` is the newest). Panics if `age ≥ min(seen, w)`.
    pub fn get_back(&self, age: usize) -> bool {
        assert!(age < self.w.min(self.seen), "age out of range");
        let idx = (self.head + self.w - 1 - age) % self.w;
        self.buf[idx]
    }

    /// Index (in steps-back form) of the reference point: w/2.
    pub fn reference_age(&self) -> usize {
        self.w / 2
    }

    /// Classify a step-back age into a window side relative to the
    /// reference point. Ages newer than the reference are `Left`
    /// (incoming relative to the reference time), older are `Right`.
    pub fn side_of(&self, age: usize) -> SpikeSide {
        if age < self.reference_age() {
            SpikeSide::Left
        } else {
            SpikeSide::Right
        }
    }

    /// Count events in the current window by side. Requires a full window.
    pub fn side_counts(&self) -> SideCounts {
        assert!(self.full(), "side_counts needs a full window");
        let mut c = SideCounts::default();
        for age in 0..self.w {
            if self.get_back(age) {
                match self.side_of(age) {
                    SpikeSide::Left => c.left += 1,
                    SpikeSide::Right => c.right += 1,
                }
            }
        }
        c
    }

    /// Any event anywhere in the window?
    pub fn any(&self) -> bool {
        let n = self.w.min(self.seen);
        (0..n).any(|age| self.get_back(age))
    }

    /// Any event within the last `k` observations?
    pub fn any_within(&self, k: usize) -> bool {
        let n = self.w.min(self.seen).min(k);
        (0..n).any(|age| self.get_back(age))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_and_wraps() {
        let mut w = SlidingWindow::new(4);
        assert!(!w.full());
        for i in 0..6 {
            w.push(i % 2 == 0);
        }
        assert!(w.full());
        // Last four pushes were for i = 2,3,4,5 → events at ages 1 (i=4) and 3 (i=2).
        assert!(!w.get_back(0)); // i=5
        assert!(w.get_back(1)); // i=4
        assert!(!w.get_back(2)); // i=3
        assert!(w.get_back(3)); // i=2
    }

    #[test]
    fn reference_point_is_half_window() {
        let w = SlidingWindow::new(10);
        assert_eq!(w.reference_age(), 5);
        assert_eq!(w.side_of(0), SpikeSide::Left);
        assert_eq!(w.side_of(4), SpikeSide::Left);
        assert_eq!(w.side_of(5), SpikeSide::Right);
        assert_eq!(w.side_of(9), SpikeSide::Right);
    }

    #[test]
    fn side_counts_split() {
        let mut w = SlidingWindow::new(6);
        // Push pattern oldest→newest: T F F T F T
        for &e in &[true, false, false, true, false, true] {
            w.push(e);
        }
        // ages: 0=T(newest) 1=F 2=T 3=F 4=F 5=T ; reference_age = 3
        let c = w.side_counts();
        assert_eq!(c, SideCounts { left: 2, right: 1 });
        assert_eq!(c.total(), 3);
    }

    #[test]
    fn any_within_respects_horizon() {
        let mut w = SlidingWindow::new(8);
        for _ in 0..7 {
            w.push(false);
        }
        w.push(true); // newest
        assert!(w.any_within(1));
        for _ in 0..3 {
            w.push(false);
        }
        assert!(!w.any_within(3));
        assert!(w.any_within(4));
    }

    #[test]
    #[should_panic]
    fn side_counts_requires_full_window() {
        let mut w = SlidingWindow::new(4);
        w.push(true);
        let _ = w.side_counts();
    }

    #[test]
    fn get_back_before_full_window() {
        let mut w = SlidingWindow::new(5);
        w.push(true);
        w.push(false);
        assert!(!w.get_back(0));
        assert!(w.get_back(1));
    }
}
