//! Spike definitions and the §3.2 "alarm method".
//!
//! §3.2 transforms the CPU Ready series into a binary spike series under a
//! threshold definition, forecasts the *binary* series with the §3.1
//! methods, and scores with the balanced accuracy metric
//! ([`crate::metrics::spike_accuracy`]). Threshold families: fixed values
//! (500/800/1000 ms, Table 4), per-VM percentiles (90/95/99, Table 5), and
//! statistical rules (μ+3σ, xbar-chart upper control limit, median —
//! Table 6).

use super::Forecaster;
use crate::metrics::spike_accuracy;

/// A spike-threshold definition.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SpikeThreshold {
    /// Fixed absolute value in ms (Table 4: 500, 800, 1000).
    Fixed(f64),
    /// Per-VM percentile in (0, 100) (Table 5: 90, 95, 99).
    Percentile(f64),
    /// μ + 3σ, assuming normality (Table 6 "statistical normal").
    MeanPlus3Std,
    /// Simplified xbar chart: UCL = mean + D4-corrected mean moving range
    /// (Table 6 "statistical xbar"; D4 = 3.267 for subgroup size 2).
    XBar,
    /// Per-VM median (Table 6 "median").
    Median,
}

impl SpikeThreshold {
    pub fn name(&self) -> String {
        match self {
            SpikeThreshold::Fixed(v) => format!("{v:.0}"),
            SpikeThreshold::Percentile(p) => format!("{p:.0}th"),
            SpikeThreshold::MeanPlus3Std => "mu+3sigma".to_string(),
            SpikeThreshold::XBar => "xbar".to_string(),
            SpikeThreshold::Median => "median".to_string(),
        }
    }

    /// Resolve the numeric threshold for a VM's series.
    pub fn resolve(&self, xs: &[f64]) -> f64 {
        assert!(!xs.is_empty());
        match *self {
            SpikeThreshold::Fixed(v) => v,
            SpikeThreshold::Percentile(p) => {
                let mut sorted = xs.to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let pos = (p / 100.0) * (sorted.len() - 1) as f64;
                let lo = pos.floor() as usize;
                let hi = pos.ceil() as usize;
                let frac = pos - lo as f64;
                sorted[lo] * (1.0 - frac) + sorted[hi] * frac
            }
            SpikeThreshold::MeanPlus3Std => {
                let n = xs.len() as f64;
                let mean = xs.iter().sum::<f64>() / n;
                let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
                mean + 3.0 * var.sqrt()
            }
            SpikeThreshold::XBar => {
                let n = xs.len() as f64;
                let mean = xs.iter().sum::<f64>() / n;
                let mr: f64 = xs.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>()
                    / (xs.len() - 1).max(1) as f64;
                // UCL of the individuals chart via the D4 correction on MR.
                const D4: f64 = 3.267;
                mean + (D4 - 1.0) * mr
            }
            SpikeThreshold::Median => {
                let mut sorted = xs.to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let m = sorted.len();
                if m % 2 == 1 {
                    sorted[m / 2]
                } else {
                    0.5 * (sorted[m / 2 - 1] + sorted[m / 2])
                }
            }
        }
    }
}

/// Binary spike mask of a series under a resolved threshold: a spike is a
/// value **at or above** the threshold (§3.2).
pub fn spike_mask(xs: &[f64], threshold: f64) -> Vec<bool> {
    xs.iter().map(|&x| x >= threshold).collect()
}

/// The §3.2 alarm method: transform history to a binary series under
/// `threshold` (resolved on the history), forecast the binary series with
/// `method`, threshold the forecast at 0.5, and score against the true
/// future spikes. Returns (accuracy, % of values that are spikes in the
/// forecast window) — the two numbers each Table 4–6 cell needs.
pub fn alarm_forecast_accuracy(
    method: &dyn Forecaster,
    history: &[f64],
    pool: &[&[f64]],
    future: &[f64],
    threshold: SpikeThreshold,
) -> (f64, f64) {
    let thr = threshold.resolve(history);
    let alarm_history: Vec<f64> = history
        .iter()
        .map(|&x| if x >= thr { 1.0 } else { 0.0 })
        .collect();
    // Pool series get their own thresholds (per-VM definitions).
    let pool_alarms: Vec<Vec<f64>> = pool
        .iter()
        .map(|s| {
            let t = threshold.resolve(s);
            s.iter().map(|&x| if x >= t { 1.0 } else { 0.0 }).collect()
        })
        .collect();
    let pool_refs: Vec<&[f64]> = pool_alarms.iter().map(|v| v.as_slice()).collect();

    let alarm_future: Vec<f64> = future
        .iter()
        .map(|&x| if x >= thr { 1.0 } else { 0.0 })
        .collect();
    let fc = method.forecast_rolling(&alarm_history, &pool_refs, &alarm_future);
    let pred: Vec<bool> = fc.iter().map(|&x| x >= 0.5).collect();
    let truth = spike_mask(future, thr);
    let spike_pct = 100.0 * truth.iter().filter(|&&s| s).count() as f64 / truth.len() as f64;
    (spike_accuracy(&pred, &truth), spike_pct)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forecast::Naive;

    #[test]
    fn fixed_threshold_resolution() {
        assert_eq!(SpikeThreshold::Fixed(500.0).resolve(&[1.0, 2.0]), 500.0);
    }

    #[test]
    fn percentile_thresholds_are_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let p90 = SpikeThreshold::Percentile(90.0).resolve(&xs);
        let p99 = SpikeThreshold::Percentile(99.0).resolve(&xs);
        assert!(p90 < p99);
        assert!((p90 - 89.1).abs() < 1e-9);
    }

    #[test]
    fn mean_plus_3std_above_mean() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        let t = SpikeThreshold::MeanPlus3Std.resolve(&xs);
        let mean = xs.iter().sum::<f64>() / 5.0;
        assert!(t > mean);
    }

    #[test]
    fn xbar_ucl_above_mean_for_varying_series() {
        let xs = [10.0, 12.0, 9.0, 14.0, 11.0, 10.0];
        let t = SpikeThreshold::XBar.resolve(&xs);
        assert!(t > 11.0);
    }

    #[test]
    fn median_splits_half() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(SpikeThreshold::Median.resolve(&xs), 3.0);
        let even = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(SpikeThreshold::Median.resolve(&even), 2.5);
    }

    #[test]
    fn spike_mask_inclusive() {
        assert_eq!(spike_mask(&[1.0, 2.0, 3.0], 2.0), vec![false, true, true]);
    }

    #[test]
    fn alarm_accuracy_on_trivially_predictable_series() {
        // History ends in a non-spike run; future is all non-spikes: naive
        // alarm forecasting is perfect.
        let history: Vec<f64> = (0..50).map(|i| if i == 10 { 900.0 } else { 100.0 }).collect();
        let future = vec![100.0; 20];
        let (acc, pct) = alarm_forecast_accuracy(
            &Naive,
            &history,
            &[],
            &future,
            SpikeThreshold::Fixed(500.0),
        );
        assert_eq!(acc, 1.0);
        assert_eq!(pct, 0.0);
    }

    #[test]
    fn alarm_accuracy_detects_rare_spike_rate() {
        let mut history = vec![100.0; 100];
        history.extend(vec![900.0; 2]);
        history.extend(vec![100.0; 50]);
        let mut future = vec![100.0; 45];
        future.extend(vec![900.0; 5]);
        let (_, pct) = alarm_forecast_accuracy(
            &Naive,
            &history,
            &[],
            &future,
            SpikeThreshold::Fixed(500.0),
        );
        assert!((pct - 10.0).abs() < 1e-9);
    }
}
