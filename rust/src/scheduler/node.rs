//! Per-node admission pipeline: streaming embedding + Reject-Job.
//!
//! A [`NodeScheduler`] is the complete local decision stack the paper
//! describes (Figure 3): each incoming telemetry vector updates the
//! embedding tracker (block-wise) and flows through Reject-Job to produce
//! the admission decision for that timestep — no communication involved.

use super::{JobId, OnlineStandardizer, Priority, RejectConfig, RejectJob};
use crate::baselines::StreamingEmbedding;
use crate::fpca::{FpcaEdge, FpcaEdgeConfig, Subspace};
use std::collections::VecDeque;

/// Smoothing factor of the per-host queue-delay EWMA exposed through
/// [`AdmissionProbe`].
const QUEUE_DELAY_EWMA_ALPHA: f64 = 0.2;

/// Rolling statistics of one node's admission behaviour.
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeStats {
    /// Timesteps observed.
    pub steps: usize,
    /// Timesteps with the rejection signal raised.
    pub rejected_steps: usize,
    /// Jobs offered to this node.
    pub jobs_offered: usize,
    /// Jobs accepted.
    pub jobs_accepted: usize,
}

impl NodeStats {
    /// Fraction of time the node refused work (paper: "downtime").
    pub fn downtime(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.rejected_steps as f64 / self.steps as f64
        }
    }

    /// Fraction of offered jobs accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.jobs_offered == 0 {
            1.0
        } else {
            self.jobs_accepted as f64 / self.jobs_offered as f64
        }
    }
}

/// How a host picks the next waiting job when slots free up.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueuePolicy {
    /// Strict arrival order; an oversized head blocks everything behind it.
    Fifo,
    /// Smallest slot demand that fits first (trades fairness for less
    /// head-of-line blocking).
    SmallestFirst,
}

/// A job parked in a host's wait queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueuedJob {
    pub job_id: JobId,
    /// Slot demand.
    pub demand: u32,
    /// Scheduling class: higher pops first; order within a class follows
    /// the queue policy (FIFO / smallest-first).
    pub priority: Priority,
    /// Simulation tick the job entered the queue (for queue-delay metrics).
    pub enqueued_at: u64,
}

/// What an admission offer to a host reports back: the scalar rejection
/// signal the paper dispatches on, plus the host-local congestion state a
/// queue-aware dispatcher scores. A node with a clear signal and a deep
/// queue is *not* equivalent to an idle one — this is the structured view
/// that lets `DispatchPolicy::QueueAware` / `LeastLoaded` tell them apart.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionProbe {
    /// The node's rejection signal at the latest telemetry step.
    pub signal_raised: bool,
    /// Slots free right now (0 when the budget is fully committed —
    /// saturating, so a shrunk budget never reports phantom capacity).
    pub free_slots: u32,
    /// Jobs parked in the wait queue.
    pub queue_depth: usize,
    /// Exponentially weighted average of observed queue delays, in ticks
    /// (0 until the first queued job starts).
    pub queue_delay_ewma: f64,
}

/// Host-level capacity: a slot budget, the set of running jobs, and a
/// bounded wait queue. Purely mechanical bookkeeping — admission (should
/// the host take work at all?) stays with the [`super::Admission`] policy;
/// this type answers the orthogonal question "does the work *fit* right
/// now, and if not, may it wait?".
#[derive(Debug, Clone)]
pub struct HostCapacity {
    slots: u32,
    used: u32,
    queue_cap: usize,
    policy: QueuePolicy,
    queue: VecDeque<QueuedJob>,
    /// Running jobs in start order (newest last) with their slot demands.
    running: Vec<(JobId, u32)>,
    /// EWMA of observed queue delays in ticks (see [`AdmissionProbe`]).
    delay_ewma: f64,
    delay_samples: u64,
}

impl HostCapacity {
    pub fn new(slots: u32, queue_cap: usize, policy: QueuePolicy) -> Self {
        assert!(slots >= 1);
        Self {
            slots,
            used: 0,
            queue_cap,
            policy,
            queue: VecDeque::new(),
            running: Vec::new(),
            delay_ewma: 0.0,
            delay_samples: 0,
        }
    }

    /// Effectively infinite capacity with no queue — the legacy
    /// "admission-only" host for scenarios without a capacity model.
    pub fn unbounded() -> Self {
        Self::new(u32::MAX, 0, QueuePolicy::Fifo)
    }

    pub fn slots(&self) -> u32 {
        self.slots
    }

    pub fn used(&self) -> u32 {
        self.used
    }

    /// Slots free right now. Saturating: a budget that shrank below
    /// current usage (heterogeneous re-targeting, pressure budgets)
    /// reports 0 free, not a wrapped-around near-2³² figure.
    pub fn free(&self) -> u32 {
        self.slots.saturating_sub(self.used)
    }

    /// Can `demand` slots start immediately against the full budget?
    pub fn can_start(&self, demand: u32) -> bool {
        demand <= self.slots.saturating_sub(self.used)
    }

    /// Can `demand` slots start against an externally shrunk budget
    /// (pressure preemption uses a tighter budget while contended)?
    /// Saturating for the same reason as [`HostCapacity::free`].
    pub fn fits_budget(&self, demand: u32, budget: u32) -> bool {
        demand <= budget.saturating_sub(self.used)
    }

    /// Re-target the slot budget. The new budget may be *below* current
    /// usage: running jobs keep their slots and finish normally, while
    /// `free()`/`can_start()` saturate at zero until usage drains back
    /// under the new budget.
    pub fn set_slots(&mut self, slots: u32) {
        assert!(slots >= 1);
        self.slots = slots;
    }

    /// Consume slots for a starting job.
    pub fn start(&mut self, job_id: JobId, demand: u32) {
        debug_assert!(self.can_start(demand), "over-committed start");
        self.used += demand;
        self.running.push((job_id, demand));
    }

    /// Release a finished (or displaced) job's slots; returns its demand.
    pub fn finish(&mut self, job_id: JobId) -> Option<u32> {
        let pos = self.running.iter().position(|&(id, _)| id == job_id)?;
        let (_, demand) = self.running.remove(pos);
        self.used -= demand;
        Some(demand)
    }

    /// Running jobs in start order (newest last).
    pub fn running(&self) -> &[(JobId, u32)] {
        &self.running
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn queue_has_room(&self) -> bool {
        self.queue.len() < self.queue_cap
    }

    /// Park a job; `false` when the bounded queue is full. The queue is
    /// kept ordered by (priority desc, arrival order): the insertion
    /// point is found from the back, which is O(1) on single-class
    /// fleets and keeps within-class order stable by construction.
    pub fn try_enqueue(
        &mut self,
        job_id: JobId,
        demand: u32,
        priority: Priority,
        now: u64,
    ) -> bool {
        if !self.queue_has_room() {
            return false;
        }
        let mut i = self.queue.len();
        while i > 0 && self.queue[i - 1].priority < priority {
            i -= 1;
        }
        self.queue.insert(i, QueuedJob { job_id, demand, priority, enqueued_at: now });
        true
    }

    /// Remove and return the next waiting job that fits within `budget`
    /// slots. Priorities are strict: only the highest priority class with
    /// a waiting job is considered. Within that class the queue policy
    /// applies — FIFO offers the class's earliest job (an oversized one
    /// blocks the class), smallest-first scans for the least demanding fit
    /// (earliest wins ties). Single-class queues behave exactly as the
    /// pre-priority implementation did, including the O(1) FIFO head pop
    /// (the queue is priority-ordered at enqueue).
    pub fn pop_startable(&mut self, budget: u32) -> Option<QueuedJob> {
        match self.policy {
            QueuePolicy::Fifo => {
                // The front is the earliest job of the highest waiting
                // class, by the enqueue ordering invariant.
                let head = *self.queue.front()?;
                if self.fits_budget(head.demand, budget) {
                    self.queue.pop_front()
                } else {
                    None
                }
            }
            QueuePolicy::SmallestFirst => {
                let mut best: Option<(usize, Priority, u32)> = None;
                for (i, qj) in self.queue.iter().enumerate() {
                    // Priority-ordered queue: once a fit exists, nothing
                    // in a lower class can beat it — stop scanning there.
                    if let Some((_, bp, bd)) = best {
                        if qj.priority < bp {
                            break;
                        }
                        if qj.demand < bd && self.fits_budget(qj.demand, budget) {
                            best = Some((i, qj.priority, qj.demand));
                        }
                    } else if self.fits_budget(qj.demand, budget) {
                        best = Some((i, qj.priority, qj.demand));
                    }
                }
                best.and_then(|(i, _, _)| self.queue.remove(i))
            }
        }
    }

    /// Fold an observed queue delay (ticks between enqueue and start)
    /// into the host's EWMA. The first sample seeds the average.
    pub fn note_queue_delay(&mut self, delay_ticks: u64) {
        self.delay_ewma = if self.delay_samples == 0 {
            delay_ticks as f64
        } else {
            QUEUE_DELAY_EWMA_ALPHA * delay_ticks as f64
                + (1.0 - QUEUE_DELAY_EWMA_ALPHA) * self.delay_ewma
        };
        self.delay_samples += 1;
    }

    /// Current queue-delay EWMA in ticks (0 before any sample).
    pub fn queue_delay_ewma(&self) -> f64 {
        self.delay_ewma
    }

    /// Answer an admission offer with the structured congestion view
    /// (`signal_raised` is the admission policy's verdict — this type
    /// only knows the mechanical side).
    pub fn probe(&self, signal_raised: bool) -> AdmissionProbe {
        AdmissionProbe {
            signal_raised,
            free_slots: self.free(),
            queue_depth: self.queue.len(),
            queue_delay_ewma: self.delay_ewma,
        }
    }

    /// Evacuate the host (node departure): returns the running set (start
    /// order) and the flushed wait queue, leaving the host empty.
    pub fn evacuate(&mut self) -> (Vec<(JobId, u32)>, Vec<QueuedJob>) {
        self.used = 0;
        (
            std::mem::take(&mut self.running),
            self.queue.drain(..).collect(),
        )
    }

    /// Forget the host's queue-delay telemetry (EWMA + sample count).
    /// Called when a node rejoins after an outage: the pre-outage
    /// congestion history describes a host that no longer exists, and a
    /// stale EWMA would keep steering queue-aware dispatch away from (or
    /// toward) the fresh host for thousands of ticks.
    pub fn reset_telemetry(&mut self) {
        self.delay_ewma = 0.0;
        self.delay_samples = 0;
    }
}

/// One node's full local scheduling stack, generic over the embedding
/// method (PRONTO's FPCA-Edge or any §7 baseline).
pub struct NodeScheduler<E: StreamingEmbedding = FpcaEdge> {
    embedding: E,
    reject: RejectJob,
    /// Online per-feature z-scaling ahead of the embedding (None = feed
    /// raw vectors; see [`OnlineStandardizer`] for why the default is on).
    standardizer: Option<OnlineStandardizer>,
    /// Cached copy of the embedding's estimate, refreshed only when
    /// [`StreamingEmbedding::version`] changes (block methods refresh once
    /// per block — cloning the subspace every timestep dominated the hot
    /// path before this cache; see EXPERIMENTS.md §Perf).
    cached_estimate: Subspace,
    cached_version: Option<u64>,
    /// Rejection signal at the latest observed timestep.
    raised: bool,
    stats: NodeStats,
}

impl NodeScheduler<FpcaEdge> {
    /// Standard PRONTO node: FPCA-Edge embedding with default parameters.
    pub fn new(dim: usize, cfg: RejectConfig) -> Self {
        let fpca = FpcaEdge::new(dim, FpcaEdgeConfig::default());
        Self::with_embedding(fpca, cfg)
    }
}

impl<E: StreamingEmbedding> NodeScheduler<E> {
    /// Node with an explicit embedding engine (used for the §7 baselines).
    pub fn with_embedding(embedding: E, cfg: RejectConfig) -> Self {
        let dim = embedding.dim();
        Self {
            cached_estimate: Subspace::empty(dim),
            cached_version: None,
            embedding,
            reject: RejectJob::new(cfg),
            standardizer: Some(OnlineStandardizer::new(dim)),
            raised: false,
            stats: NodeStats::default(),
        }
    }

    /// Disable the input standardizer (feed raw metric vectors).
    pub fn without_standardizer(mut self) -> Self {
        self.standardizer = None;
        self
    }

    /// Feed the telemetry vector for the current timestep; returns `true`
    /// when the node can ACCEPT a job arriving now (i.e. signal not raised).
    pub fn observe(&mut self, y: &[f64]) -> bool {
        // Refresh the cached estimate only when the embedding advanced
        // (block methods: once per block). Methods reporting version None
        // refresh every step.
        let version = self.embedding.version();
        if version.is_none() || version != self.cached_version {
            self.cached_estimate = self.embedding.estimate();
            self.cached_version = version;
        }
        // Standardize (borrowed scratch, no allocation), then Reject-Job
        // (uses the estimate as of *before* this sample — the iterate only
        // refreshes at block boundaries anyway).
        let raised = match &mut self.standardizer {
            Some(st) => {
                let z = st.transform(y);
                let raised = self.reject.observe(&self.cached_estimate, z);
                self.embedding.observe(z);
                raised
            }
            None => {
                let raised = self.reject.observe(&self.cached_estimate, y);
                self.embedding.observe(y);
                raised
            }
        };
        self.raised = raised;
        self.stats.steps += 1;
        if self.raised {
            self.stats.rejected_steps += 1;
        }
        !self.raised
    }

    /// Offer a job at the current timestep; bookkeeping + decision.
    pub fn offer_job(&mut self) -> bool {
        self.stats.jobs_offered += 1;
        if !self.raised {
            self.stats.jobs_accepted += 1;
            true
        } else {
            false
        }
    }

    /// Rejection signal at the latest timestep.
    pub fn rejection_raised(&self) -> bool {
        self.raised
    }

    /// Latest projections (diagnostics; Figure 4a).
    pub fn projections(&self) -> &[f64] {
        self.reject.projections()
    }

    /// Current subspace estimate.
    pub fn estimate(&self) -> Subspace {
        self.embedding.estimate()
    }

    /// Embedding engine (for federation pulls/pushes).
    pub fn embedding(&self) -> &E {
        &self.embedding
    }

    pub fn embedding_mut(&mut self) -> &mut E {
        &mut self.embedding
    }

    pub fn stats(&self) -> NodeStats {
        self.stats
    }

    /// Method tag ("PRONTO", "SP", "FD", "PM").
    pub fn method(&self) -> &'static str {
        self.embedding.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Spirit;
    use crate::telemetry::{GeneratorConfig, TraceGenerator};

    #[test]
    fn node_accepts_during_calm_trace() {
        let gen = TraceGenerator::new(
            GeneratorConfig { episode_hazard: 0.0, ..Default::default() },
            7,
        );
        let trace = gen.generate_vm(0, 400);
        let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());
        let mut accepts = 0;
        for t in 0..trace.len() {
            if node.observe(trace.features(t)) {
                accepts += 1;
            }
        }
        // Calm trace: vast majority of steps acceptable.
        assert!(accepts as f64 / trace.len() as f64 > 0.85, "accepts={accepts}");
    }

    #[test]
    fn node_raises_signal_sometimes_on_contended_trace() {
        let gen = TraceGenerator::new(
            GeneratorConfig { episode_hazard: 0.03, ..Default::default() },
            11,
        );
        let trace = gen.generate_vm(0, 2000);
        let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());
        for t in 0..trace.len() {
            node.observe(trace.features(t));
        }
        let down = node.stats().downtime();
        assert!(down > 0.0, "rejection signal never raised");
        assert!(down < 0.5, "downtime too high: {down}");
    }

    #[test]
    fn offer_job_respects_signal_and_counts() {
        let gen = TraceGenerator::new(GeneratorConfig::default(), 3);
        let trace = gen.generate_vm(2, 600);
        let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());
        let mut offered = 0;
        for t in 0..trace.len() {
            node.observe(trace.features(t));
            if t % 10 == 0 {
                let ok = node.offer_job();
                offered += 1;
                assert_eq!(ok, !node.rejection_raised());
            }
        }
        assert_eq!(node.stats().jobs_offered, offered);
        assert!(node.stats().jobs_accepted <= offered);
    }

    #[test]
    fn host_capacity_tracks_slots_and_queue() {
        let mut h = HostCapacity::new(4, 2, QueuePolicy::Fifo);
        assert!(h.can_start(4));
        h.start(1, 3);
        assert_eq!(h.used(), 3);
        assert_eq!(h.free(), 1);
        assert!(!h.can_start(2));
        assert!(h.try_enqueue(2, 2, 0, 10));
        assert!(h.try_enqueue(3, 1, 0, 11));
        assert!(!h.try_enqueue(4, 1, 0, 12), "queue bound ignored");
        // FIFO head needs 2 slots; only 1 free → head-of-line blocks.
        assert!(h.pop_startable(h.slots()).is_none());
        assert_eq!(h.finish(1), Some(3));
        let qj = h.pop_startable(h.slots()).unwrap();
        assert_eq!((qj.job_id, qj.demand, qj.enqueued_at), (2, 2, 10));
        h.start(qj.job_id, qj.demand);
        assert_eq!(h.running().len(), 1);
    }

    #[test]
    fn host_capacity_smallest_first_skips_blocked_head() {
        let mut h = HostCapacity::new(4, 4, QueuePolicy::SmallestFirst);
        h.start(1, 3);
        assert!(h.try_enqueue(2, 3, 0, 0));
        assert!(h.try_enqueue(3, 1, 0, 1));
        assert!(h.try_enqueue(4, 1, 0, 2));
        // 1 slot free: the 3-slot head is skipped, earliest 1-slot job wins.
        let qj = h.pop_startable(h.slots()).unwrap();
        assert_eq!(qj.job_id, 3);
        // Shrunk budget (pressure): nothing fits below current usage.
        assert!(h.pop_startable(2).is_none());
    }

    #[test]
    fn host_capacity_evacuates_cleanly() {
        let mut h = HostCapacity::new(4, 2, QueuePolicy::Fifo);
        h.start(7, 2);
        h.start(8, 1);
        assert!(h.try_enqueue(9, 1, 0, 5));
        let (running, queued) = h.evacuate();
        assert_eq!(running, vec![(7, 2), (8, 1)]);
        assert_eq!(queued.len(), 1);
        assert_eq!(queued[0].job_id, 9);
        assert_eq!(h.used(), 0);
        assert_eq!(h.queue_len(), 0);
        assert!(h.running().is_empty());
    }

    #[test]
    fn shrunk_budget_saturates_instead_of_underflowing() {
        // Regression: free()/can_start() computed `slots - used`, which
        // underflowed in debug builds once a budget dropped below current
        // usage (heterogeneous re-targeting / pressure budgets).
        let mut h = HostCapacity::new(4, 2, QueuePolicy::Fifo);
        h.start(1, 4);
        h.set_slots(2); // budget now below usage
        assert_eq!(h.free(), 0);
        assert!(!h.can_start(1));
        assert!(!h.fits_budget(1, 2));
        // Draining below the new budget restores capacity.
        assert_eq!(h.finish(1), Some(4));
        assert_eq!(h.free(), 2);
        assert!(h.can_start(2));
        assert!(!h.can_start(3));
    }

    #[test]
    fn fifo_queue_is_priority_strict_within_class_fifo() {
        let mut h = HostCapacity::new(2, 8, QueuePolicy::Fifo);
        h.start(0, 2); // fill the host so everything parks
        assert!(h.try_enqueue(1, 1, 0, 10));
        assert!(h.try_enqueue(2, 1, 2, 11));
        assert!(h.try_enqueue(3, 1, 2, 12));
        assert!(h.try_enqueue(4, 1, 1, 13));
        h.finish(0);
        // Highest class first, FIFO within the class, lowest class last.
        let order: Vec<JobId> = std::iter::from_fn(|| h.pop_startable(h.slots()))
            .map(|qj| qj.job_id)
            .collect();
        assert_eq!(order, vec![2, 3, 4, 1]);
    }

    #[test]
    fn fifo_priority_head_blocks_only_its_class_pop() {
        // The highest class's earliest job is the only candidate; if it
        // does not fit, the pop blocks (no silent skip to lower classes).
        let mut h = HostCapacity::new(4, 8, QueuePolicy::Fifo);
        h.start(0, 3);
        assert!(h.try_enqueue(1, 2, 1, 0)); // high class, needs 2 (blocked)
        assert!(h.try_enqueue(2, 1, 0, 1)); // low class, would fit
        assert!(h.pop_startable(h.slots()).is_none());
    }

    #[test]
    fn smallest_first_orders_by_priority_then_demand() {
        let mut h = HostCapacity::new(4, 8, QueuePolicy::SmallestFirst);
        h.start(0, 4);
        assert!(h.try_enqueue(1, 3, 0, 0));
        assert!(h.try_enqueue(2, 1, 0, 1));
        assert!(h.try_enqueue(3, 2, 1, 2));
        assert!(h.try_enqueue(4, 1, 1, 3));
        h.finish(0);
        let order: Vec<JobId> = std::iter::from_fn(|| h.pop_startable(h.slots()))
            .map(|qj| qj.job_id)
            .collect();
        // Class 1 by demand (4 then 3), then class 0 by demand (2 then 1).
        assert_eq!(order, vec![4, 3, 2, 1]);
    }

    #[test]
    fn probe_reports_congestion_and_delay_ewma() {
        let mut h = HostCapacity::new(4, 4, QueuePolicy::Fifo);
        let p = h.probe(false);
        assert_eq!((p.signal_raised, p.free_slots, p.queue_depth), (false, 4, 0));
        assert_eq!(p.queue_delay_ewma, 0.0);
        h.start(1, 3);
        assert!(h.try_enqueue(2, 2, 0, 5));
        let p = h.probe(true);
        assert!(p.signal_raised);
        assert_eq!(p.free_slots, 1);
        assert_eq!(p.queue_depth, 1);
        // First delay sample seeds the EWMA; later samples smooth it.
        h.note_queue_delay(100);
        assert_eq!(h.queue_delay_ewma(), 100.0);
        h.note_queue_delay(0);
        assert!(h.queue_delay_ewma() < 100.0 && h.queue_delay_ewma() > 0.0);
    }

    #[test]
    fn evacuate_keeps_but_reset_clears_delay_telemetry() {
        // Regression (leave → join → probe): evacuation alone must not
        // touch the EWMA — a mid-run pressure probe may still read it —
        // but a rejoining node resets it, so post-heal probes never score
        // the fresh host on pre-outage congestion.
        let mut h = HostCapacity::new(2, 4, QueuePolicy::Fifo);
        h.start(1, 2);
        assert!(h.try_enqueue(2, 1, 0, 10));
        h.note_queue_delay(400);
        h.note_queue_delay(600);
        assert!(h.queue_delay_ewma() > 0.0);
        // The node leaves: jobs evacuate, telemetry survives the drain.
        let (running, queued) = h.evacuate();
        assert_eq!((running.len(), queued.len()), (1, 1));
        assert!(h.queue_delay_ewma() > 0.0, "evacuate must not clear the EWMA");
        // The node rejoins: telemetry resets, probes read a fresh host.
        h.reset_telemetry();
        assert_eq!(h.queue_delay_ewma(), 0.0);
        assert_eq!(h.probe(false).queue_delay_ewma, 0.0);
        // The next delay sample seeds the EWMA exactly (sample count was
        // reset too — a stale count would have smoothed against zero).
        h.note_queue_delay(250);
        assert_eq!(h.queue_delay_ewma(), 250.0);
    }

    #[test]
    fn unbounded_host_never_blocks() {
        let mut h = HostCapacity::unbounded();
        for id in 0..1_000u64 {
            assert!(h.can_start(5));
            h.start(id, 5);
        }
        assert!(!h.queue_has_room(), "legacy host has no queue");
        assert_eq!(h.finish(500), Some(5));
        assert_eq!(h.finish(500), None);
    }

    #[test]
    fn works_with_baseline_embedding() {
        let gen = TraceGenerator::new(GeneratorConfig::default(), 5);
        let trace = gen.generate_vm(1, 300);
        let spirit = Spirit::new(trace.dim(), crate::baselines::SpiritConfig::default());
        let mut node = NodeScheduler::with_embedding(spirit, RejectConfig::default());
        for t in 0..trace.len() {
            node.observe(trace.features(t));
        }
        assert_eq!(node.method(), "SP");
        assert_eq!(node.stats().steps, 300);
    }
}
