//! Integration tests for the scenario layer: churn keeps decision quality,
//! push latency degrades gracefully, and every catalog entry runs clean.

use pronto::scheduler::{Admission, NodeScheduler, ProntoPolicy, RandomPolicy, RejectConfig};
use pronto::sim::{ChurnModel, DiscreteEventEngine, PolicyFactory, Scenario, CATALOG};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 4, v, steps)).collect()
}

fn pronto_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
    tr.iter()
        .map(|t| {
            Box::new(ProntoPolicy::new(NodeScheduler::new(
                t.dim(),
                RejectConfig::default(),
            ))) as Box<dyn Admission>
        })
        .collect()
}

fn always_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
    tr.iter()
        .enumerate()
        .map(|(i, _)| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
        .collect()
}

fn pronto_factory(d: usize) -> PolicyFactory {
    Box::new(move |_node| {
        Box::new(ProntoPolicy::new(NodeScheduler::new(d, RejectConfig::default())))
            as Box<dyn Admission>
    })
}

fn assert_conservation(report: &pronto::sim::SimReport) {
    assert_eq!(
        report.jobs_arrived,
        report.jobs_accepted + report.jobs_rejected
    );
    assert_eq!(
        report.jobs_accepted,
        report.good_accepts + report.bad_accepts
    );
    assert_eq!(report.outcomes.len(), report.jobs_arrived);
    // Full ledger: every arrival lands in exactly one bucket — rejected
    // at admission, completed, dropped at a full queue, lost to a
    // departure/failed migration, or still waiting/running (in flight) at
    // the horizon. Nothing leaks, nothing double-counts.
    assert_eq!(
        report.jobs_arrived,
        report.jobs_rejected
            + report.jobs_completed
            + report.jobs_dropped
            + report.jobs_displaced
            + report.jobs_still_queued
            + report.jobs_still_running,
        "job ledger leaked in scenario '{}'",
        report.scenario
    );
    assert!(report.jobs_completed + report.jobs_displaced <= report.jobs_accepted);
    // Migrations re-place displaced jobs; each needs a preemption or a
    // queue flush first, so they never exceed total displacement events.
    assert!(report.jobs_migrated <= report.jobs_preempted + report.jobs_queued);
    assert!(report.mean_push_latency_steps.is_finite());
    assert!(report.mean_queue_delay_steps.is_finite());
    // The event-driven capacity integral can never report phantom usage:
    // utilization is a true time average and stays within [0, 1].
    assert!((0.0..=1.0).contains(&report.mean_utilization));
    assert!(report.slo_attained <= report.slo_total);
    for d in &report.mean_queue_delay_by_priority {
        assert!(d.is_finite() && *d >= 0.0);
    }
}

#[test]
fn every_named_scenario_runs_clean() {
    for name in CATALOG {
        let scenario = Scenario::named(name)
            .unwrap()
            .with_nodes(6)
            .with_steps(1_000);
        let tr = fleet(6, 1_000, 31);
        let report =
            DiscreteEventEngine::new(scenario, tr.clone(), pronto_policies(&tr)).run();
        assert_conservation(&report);
        assert!(report.jobs_arrived > 0, "{name}: no jobs arrived");
    }
}

#[test]
fn churn_scenario_pronto_keeps_placement_edge() {
    // Under churn, PRONTO's informed rejections must not fall behind
    // blind always-accept placement; churn machinery itself must engage.
    let steps = 4_000;
    let nodes = 8;
    let mk_scenario = || {
        Scenario {
            churn: Some(ChurnModel {
                leave_hazard: 0.002,
                rejoin_delay_mean: 80.0,
                min_alive: 3,
            }),
            ..Scenario::named("churn").unwrap()
        }
        .with_nodes(nodes)
        .with_steps(steps)
        .with_seed(77)
    };
    let tr = fleet(nodes, steps, 41);
    let d = tr[0].dim();

    let r_pronto = DiscreteEventEngine::new(mk_scenario(), tr.clone(), pronto_policies(&tr))
        .with_policy_factory(pronto_factory(d))
        .run();
    let r_always =
        DiscreteEventEngine::new(mk_scenario(), tr.clone(), always_policies(&tr)).run();

    assert_conservation(&r_pronto);
    assert!(r_pronto.node_leaves > 0, "churn never fired");
    assert!(r_pronto.node_joins > 0, "no node ever rejoined");
    // Same arrival stream (separate RNG streams ⇒ identical arrivals).
    assert_eq!(r_pronto.jobs_arrived, r_always.jobs_arrived);
    assert!(
        r_pronto.placement_quality() + 0.02 >= r_always.placement_quality(),
        "pronto {:.3} fell behind always-accept {:.3} under churn",
        r_pronto.placement_quality(),
        r_always.placement_quality()
    );
}

#[test]
fn latency_scenario_degrades_gracefully() {
    // Nonzero push latency: stale merges, but the cluster keeps making
    // decisions — no panic, sane rates, pushes delivered late.
    let steps = 3_000;
    let nodes = 8;
    let tr = fleet(nodes, steps, 51);

    let instant = Scenario::named("baseline-poisson")
        .unwrap()
        .with_nodes(nodes)
        .with_steps(steps)
        .with_seed(9);
    let mut delayed = Scenario::named("latency")
        .unwrap()
        .with_nodes(nodes)
        .with_steps(steps)
        .with_seed(9);
    delayed.federation.latency =
        pronto::federation::LatencyModel::Exponential { mean_steps: 20.0 };

    let r_instant =
        DiscreteEventEngine::new(instant, tr.clone(), pronto_policies(&tr)).run();
    let r_delayed =
        DiscreteEventEngine::new(delayed, tr.clone(), pronto_policies(&tr)).run();

    assert_conservation(&r_instant);
    assert_conservation(&r_delayed);
    assert!(r_delayed.mean_push_latency_steps > 5.0, "latency not applied");
    assert!(
        r_delayed.federation_pushes + r_delayed.federation_suppressed > 0,
        "no pushes offered under latency"
    );
    // Local admission decisions are unchanged by federation staleness
    // (decisions are local in PRONTO) — acceptance must stay in family.
    assert!(
        (r_delayed.acceptance_rate() - r_instant.acceptance_rate()).abs() < 0.2,
        "latency warped acceptance: {:.3} vs {:.3}",
        r_delayed.acceptance_rate(),
        r_instant.acceptance_rate()
    );
    assert!(r_delayed.acceptance_rate() > 0.3);
}

#[test]
fn capacity_scenario_reports_nonzero_queueing() {
    // The catalog `capacity` entry oversubscribes the fleet (~1.1× with
    // admission always open): queues must build, delay jobs, and drop
    // the excess once the bounded queues fill.
    let scenario = Scenario::named("capacity").unwrap().with_nodes(8).with_steps(2_000);
    let tr = fleet(8, 2_000, 91);
    let report =
        DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run();
    assert_conservation(&report);
    assert!(report.jobs_queued > 0, "no job ever waited");
    assert!(report.mean_queue_delay_steps > 0.0, "queueing delay is zero");
    assert!(report.peak_queue_len > 0);
    assert!(report.jobs_dropped > 0, "bounded queues never overflowed");
    assert!(report.mean_utilization > 0.5, "oversubscribed fleet mostly idle");
    // Capacity does not bend admission accounting.
    assert_eq!(report.jobs_accepted, report.good_accepts + report.bad_accepts);
}

#[test]
fn preemption_scenario_preempts_and_migrates() {
    // Churn evacuates hosts and contended nodes shed load; with a
    // migration budget, displaced jobs find peers via their admission
    // signals.
    let nodes = 8;
    let steps = 3_000;
    let scenario = Scenario::named("preemption").unwrap().with_nodes(nodes).with_steps(steps);
    let tr = fleet(nodes, steps, 93);
    let d = tr[0].dim();
    let report = DiscreteEventEngine::new(scenario, tr.clone(), pronto_policies(&tr))
        .with_policy_factory(pronto_factory(d))
        .run();
    assert_conservation(&report);
    assert!(report.node_leaves > 0, "churn never fired");
    assert!(report.jobs_preempted > 0, "nothing was ever preempted");
    assert!(report.jobs_migrated > 0, "no displaced job was re-placed");
    // Migration keeps most displaced work alive: outright losses stay
    // below preemption events.
    assert!(report.jobs_displaced <= report.jobs_preempted + report.jobs_queued);
}

#[test]
fn queue_aware_priority_and_hetero_catalog_entries_run_clean() {
    // The three new entries exercise probe-scored dispatch, scheduling
    // classes with SLOs, and per-node heterogeneous budgets end to end.
    for (name, nodes) in [("queue-aware", 8), ("priority", 8), ("hetero", 12)] {
        let scenario = Scenario::named(name).unwrap().with_nodes(nodes).with_steps(1_500);
        let tr = fleet(nodes, 1_500, 97);
        let report =
            DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run();
        assert_conservation(&report);
        assert!(report.jobs_queued > 0, "{name}: nothing ever queued");
        assert!(report.jobs_completed > 0, "{name}: nothing completed");
    }
    let scenario = Scenario::named("priority").unwrap().with_nodes(8).with_steps(1_500);
    let tr = fleet(8, 1_500, 97);
    let report = DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run();
    assert!(report.slo_total > 0, "priority scenario set no deadlines");
    assert_eq!(report.mean_queue_delay_by_priority.len(), 3);
}

#[test]
fn custom_toml_hetero_priority_scenario_runs() {
    let text = r#"
[scenario]
name = "it-hetero"
nodes = 9
steps = 1200
seed = 23
dispatch = "least-loaded"

[arrivals]
pattern = "poisson"
rate = 1.0

[capacity]
slots_per_node = 2
queue_capacity = 4
max_job_slots = 2
queue_policy = "smallest-first"
priority_levels = 2
slo_steps = 40
host_class_slots = [1, 2, 4]
host_class_weights = [1, 2, 1]
"#;
    let scenario = Scenario::from_toml(text).unwrap();
    let tr = fleet(9, 1_200, 99);
    let report =
        DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run();
    assert_conservation(&report);
    assert_eq!(report.scenario, "it-hetero");
    assert!(report.slo_total > 0);
    assert_eq!(report.mean_queue_delay_by_priority.len(), 2);
}

#[test]
fn custom_toml_capacity_scenario_runs() {
    let text = r#"
[scenario]
name = "it-capacity"
nodes = 6
steps = 1000
seed = 19

[arrivals]
pattern = "poisson"
rate = 1.0

[capacity]
slots_per_node = 2
queue_capacity = 3
max_job_slots = 1
queue_policy = "smallest-first"
migration_limit = 1
"#;
    let scenario = Scenario::from_toml(text).unwrap();
    let tr = fleet(6, 1_000, 95);
    let report =
        DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run();
    assert_conservation(&report);
    assert_eq!(report.scenario, "it-capacity");
    assert!(report.jobs_queued > 0);
}

#[test]
fn custom_toml_scenario_runs() {
    let text = r#"
[scenario]
name = "it-custom"
nodes = 5
steps = 800
seed = 13

[arrivals]
pattern = "bursty"
rate = 0.1
burst_rate = 1.0
mean_burst_len = 20
mean_gap_len = 100

[federation]
enabled = true
push_every = 32
latency = "constant"
latency_mean_steps = 4.0
"#;
    let scenario = Scenario::from_toml(text).unwrap();
    assert_eq!(scenario.name, "it-custom");
    let tr = fleet(5, 800, 61);
    let report = DiscreteEventEngine::new(scenario, tr.clone(), pronto_policies(&tr)).run();
    assert_conservation(&report);
    assert_eq!(report.scenario, "it-custom");
    assert!(report.mean_push_latency_steps > 3.0);
}

#[test]
fn unplaceable_jobs_counted_when_pool_drains() {
    // Hazard 1.0, never rejoin, floor 0: the pool empties almost
    // immediately and later arrivals must be counted, not crash.
    let scenario = Scenario {
        churn: Some(ChurnModel {
            leave_hazard: 1.0,
            rejoin_delay_mean: 0.0,
            min_alive: 0,
        }),
        ..Scenario::default()
    }
    .with_nodes(3)
    .with_steps(600);
    let tr = fleet(3, 600, 71);
    let report = DiscreteEventEngine::new(scenario, tr.clone(), always_policies(&tr)).run();
    assert_conservation(&report);
    assert_eq!(report.node_leaves, 3);
    assert!(report.jobs_unplaceable > 0, "expected orphaned arrivals");
    assert!(report.jobs_rejected >= report.jobs_unplaceable);
}
