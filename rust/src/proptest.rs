//! Minimal property-testing support.
//!
//! The environment has no `proptest`/`quickcheck`, so invariant tests use
//! this thin layer: seeded random generators over the domain types plus a
//! [`forall`] driver that reports the failing case index and seed so any
//! failure is reproducible with `PRONTO_PROP_SEED=<seed>`.

use crate::linalg::Mat;
use crate::rng::Xoshiro256;

/// Number of cases per property (override with `PRONTO_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PRONTO_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Base seed (override with `PRONTO_PROP_SEED` to replay a failure).
pub fn base_seed() -> u64 {
    std::env::var("PRONTO_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` over `cases` RNGs derived from the base seed. Panics with the
/// case seed on first failure so it can be replayed in isolation.
pub fn forall(name: &str, prop: impl Fn(&mut Xoshiro256) -> Result<(), String>) {
    let cases = default_cases();
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with PRONTO_PROP_SEED={base} PRONTO_PROP_CASES={c}): {msg}",
                c = case + 1
            );
        }
    }
}

/// Random matrix with standard-normal entries.
pub fn gen_mat(rng: &mut Xoshiro256, rows: usize, cols: usize) -> Mat {
    let data = (0..rows * cols).map(|_| rng.normal()).collect();
    Mat::from_col_major(rows, cols, data)
}

/// Random matrix with orthonormal columns (QR of a Gaussian draw).
pub fn gen_orthonormal(rng: &mut Xoshiro256, rows: usize, cols: usize) -> Mat {
    assert!(rows >= cols);
    let (q, _) = crate::linalg::householder_qr(&gen_mat(rng, rows, cols));
    q
}

/// Random low-rank-plus-noise matrix: rank `r` signal with singular values
/// decaying as 1/k plus `noise`-scaled Gaussian perturbation. This mimics
/// the telemetry structure PRONTO assumes.
pub fn gen_low_rank(rng: &mut Xoshiro256, rows: usize, cols: usize, r: usize, noise: f64) -> Mat {
    let r = r.min(rows.min(cols));
    let u = gen_orthonormal(rng, rows, r);
    let v = gen_orthonormal(rng, cols, r);
    let sig: Vec<f64> = (1..=r).map(|k| 10.0 / k as f64).collect();
    let mut m = u.mul_diag(&sig).matmul(&v.transpose());
    if noise > 0.0 {
        for x in m.data_mut() {
            *x += noise * rng.normal();
        }
    }
    m
}

/// Random descending non-negative spectrum of length `r` (σ₁ ≥ … ≥ σ_r ≥ 0).
pub fn gen_spectrum(rng: &mut Xoshiro256, r: usize) -> Vec<f64> {
    let mut s: Vec<f64> = (0..r).map(|_| rng.next_f64() * 10.0).collect();
    s.sort_by(|a, b| b.partial_cmp(a).unwrap());
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormality_error;

    #[test]
    fn forall_reports_failures() {
        let res = std::panic::catch_unwind(|| {
            forall("always-fails", |_| Err("nope".into()));
        });
        assert!(res.is_err());
    }

    #[test]
    fn gen_orthonormal_is_orthonormal() {
        forall("orthonormal generator", |rng| {
            let m = 4 + rng.gen_range(30);
            let n = 1 + rng.gen_range(m.min(8));
            let q = gen_orthonormal(rng, m, n);
            let err = orthonormality_error(&q);
            if err < 1e-9 {
                Ok(())
            } else {
                Err(format!("orthonormality error {err}"))
            }
        });
    }

    #[test]
    fn gen_spectrum_descending() {
        forall("spectrum generator", |rng| {
            let r = 1 + rng.gen_range(10);
            let s = gen_spectrum(rng, r);
            if s.windows(2).all(|w| w[0] >= w[1]) {
                Ok(())
            } else {
                Err(format!("not descending: {s:?}"))
            }
        });
    }
}
