//! Table 3: RMSE per forecasting-window duration (1 day … 15 min).
//!
//! Paper shape: SVM wins at long windows (≥3 h); naive/ExpSmo win at short
//! windows; RMSE grows sharply as the window shrinks.

use pronto::bench::experiments::{table3_windows, ExperimentScale};
use pronto::bench::Table;

fn main() {
    let scale = ExperimentScale::from_env();
    let (labels, rows) = table3_windows(&scale);
    let mut header: Vec<&str> = vec!["method"];
    header.extend(labels.iter());
    let mut t = Table::new("Table 3: avg RMSE per forecasting window", &header);
    for (name, cells) in &rows {
        let mut row = vec![name.clone()];
        row.extend(cells.iter().map(|c| format!("{c:.2}")));
        t.row(&row);
    }
    t.print();
    t.maybe_write_csv("table3");

    let short_idx = labels.len() - 3; // 1 hour column
    let svm = &rows[3].1;
    let naive = &rows[0].1;
    println!(
        "\nshape: SVM at 1day {:.1} vs naive {:.1} (SVM should win) | naive at 1h {:.1} vs SVM {:.1}",
        svm[0], naive[0], naive[short_idx], svm[short_idx]
    );
    println!("paper reference: SVM 96.15 (1d) -> 1155.12 (15min); naive 122.39 -> 876.16");
}
