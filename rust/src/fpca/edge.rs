//! FPCA-Edge (paper Algorithm 5): streaming, block-wise, rank-adaptive
//! principal subspace tracking.
//!
//! Per block `B ∈ ℝ^{d×b}`:
//!
//! 1. `SSVD_r(B, U, Σ)` — SVD of the block alone if the estimate is empty,
//!    otherwise merge the block (as a subspace with unit spectrum, per the
//!    paper's `Merge_r(U, Σ, D, I)`) into the estimate;
//! 2. merge with the previous estimate (`Merge`);
//! 3. `Rank_r^{α,β}` — adjust the rank by ±1 when the energy ratio (Eq. 7)
//!    leaves `[α, β]`.
//!
//! Memory is O(d·r + d·b); each update costs two Gram/QR passes and one
//! small SVD. The rank is capped by `r_max` so state stays bounded (and so
//! the masked fixed-shape HLO artifact can mirror the algorithm exactly).

use super::{merge_subspaces, MergeOptions, Subspace};
use crate::linalg::{svd_gram_topk_warm, svd_truncated, Mat};

/// Bounds `[α, β]` on the energy ratio E_r (Eq. 7).
#[derive(Debug, Clone, Copy)]
pub struct EnergyBounds {
    pub alpha: f64,
    pub beta: f64,
}

impl Default for EnergyBounds {
    /// Loose defaults that keep r stable on stationary workloads and grow
    /// it under distributional shift.
    fn default() -> Self {
        Self { alpha: 0.01, beta: 0.4 }
    }
}

/// FPCA-Edge configuration.
#[derive(Debug, Clone, Copy)]
pub struct FpcaEdgeConfig {
    /// Initial rank estimate r.
    pub initial_rank: usize,
    /// Hard cap on the adaptive rank (bounded state; also the artifact's
    /// compiled width).
    pub max_rank: usize,
    /// Minimum rank (never adapt below this).
    pub min_rank: usize,
    /// Block size b: number of observations buffered per update.
    pub block_size: usize,
    /// Energy bounds (α, β) driving rank adaptation.
    pub energy: EnergyBounds,
    /// Forgetting factor λ applied to the previous estimate at each block
    /// merge (1.0 = no forgetting).
    pub forget: f64,
    /// Enable/disable rank adaptation (paper's eval fixes r = 4; the
    /// adaptive path is exercised separately).
    pub adaptive_rank: bool,
}

impl Default for FpcaEdgeConfig {
    fn default() -> Self {
        Self {
            initial_rank: 4,
            max_rank: 8,
            min_rank: 1,
            block_size: 32,
            energy: EnergyBounds::default(),
            forget: 1.0,
            adaptive_rank: false,
        }
    }
}

/// Streaming FPCA-Edge tracker for one node.
#[derive(Debug, Clone)]
pub struct FpcaEdge {
    cfg: FpcaEdgeConfig,
    d: usize,
    /// Current rank estimate r (≤ cfg.max_rank).
    rank: usize,
    /// Current subspace estimate.
    estimate: Subspace,
    /// Observation buffer `B` (filled column by column).
    buffer: Mat,
    buffered: usize,
    /// Reusable scratch for the update panel `[λ·U·diag(Σ) | B]` —
    /// reallocated only when the estimate rank or block width changes,
    /// so steady-state block updates assemble in place instead of paying
    /// the historical scaled-basis/scale/hcat allocation chain.
    panel: Mat,
    /// Blocks processed so far.
    blocks: usize,
    /// External estimate refreshes (federation pulls) absorbed so far;
    /// counted into the version so schedulers drop their cached estimate.
    pulls: usize,
}

impl FpcaEdge {
    pub fn new(d: usize, cfg: FpcaEdgeConfig) -> Self {
        assert!(cfg.initial_rank >= 1 && cfg.initial_rank <= cfg.max_rank);
        assert!(cfg.min_rank >= 1 && cfg.min_rank <= cfg.max_rank);
        assert!(cfg.block_size >= cfg.max_rank, "block must be at least r_max wide");
        assert!(cfg.energy.alpha < cfg.energy.beta);
        Self {
            cfg,
            d,
            rank: cfg.initial_rank,
            estimate: Subspace::empty(d),
            buffer: Mat::zeros(d, cfg.block_size),
            buffered: 0,
            panel: Mat::zeros(0, 0),
            blocks: 0,
            pulls: 0,
        }
    }

    pub fn config(&self) -> &FpcaEdgeConfig {
        &self.cfg
    }

    /// Ambient dimension d.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Current adaptive rank r.
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Blocks processed so far.
    pub fn blocks_processed(&self) -> usize {
        self.blocks
    }

    /// Current subspace estimate (empty until the first full block).
    pub fn estimate(&self) -> &Subspace {
        &self.estimate
    }

    /// Replace the local estimate (used when a node pulls the merged global
    /// view from its aggregator).
    pub fn set_estimate(&mut self, s: Subspace) {
        assert_eq!(s.dim(), self.d);
        self.rank = s.rank().clamp(self.cfg.min_rank, self.cfg.max_rank);
        self.estimate = s.truncate(self.rank);
    }

    /// External estimate refreshes absorbed so far (see
    /// [`FpcaEdge::pull_global_estimate`]).
    pub fn external_pulls(&self) -> usize {
        self.pulls
    }

    /// Absorb a (possibly stale) merged global view pulled from the
    /// federation (§5.2). An empty local estimate is simply seeded; an
    /// established one is merged with `forget` down-weighting the global
    /// side so local history dominates. Bumps the version so schedulers
    /// refresh their cached estimate.
    pub fn pull_global_estimate(&mut self, global: &Subspace, forget: f64) {
        assert_eq!(global.dim(), self.d);
        if global.is_empty() {
            return;
        }
        let merged = if self.estimate.is_empty() {
            global.clone()
        } else {
            merge_subspaces(
                global,
                &self.estimate,
                MergeOptions { rank: self.cfg.max_rank, forget, enhance: 1.0 },
            )
        };
        self.set_estimate(merged);
        self.pulls += 1;
    }

    /// Feed one observation. Returns `true` when this observation completed
    /// a block (i.e. the estimate was just refreshed).
    pub fn observe(&mut self, y: &[f64]) -> bool {
        assert_eq!(y.len(), self.d, "feature dim mismatch");
        self.buffer.col_mut(self.buffered).copy_from_slice(y);
        self.buffered += 1;
        if self.buffered < self.cfg.block_size {
            return false;
        }
        // Lend the full buffer to the update without the historical
        // per-block clone: swap it out for a zero-capacity placeholder
        // (`update_block` never touches the buffer) and put it back.
        let block = std::mem::replace(&mut self.buffer, Mat::zeros(0, 0));
        self.buffered = 0;
        self.update_block(&block);
        self.buffer = block;
        true
    }

    /// Algorithm 5 body for one full block.
    ///
    /// Computes SVD_r([λ·U·diag(Σ) | B]) — the paper's Eq. (2)/(3)
    /// iteration — via the Gram + orthogonal-iteration fast path
    /// ([`svd_gram_topk`]), which is the same algorithm the L2 HLO
    /// artifact runs. (The Algorithm-3/4 merge formulation is equivalent —
    /// see `fpca::merge` tests — but pays two extra QR passes; this direct
    /// form is ~15× faster per block. §Perf in EXPERIMENTS.md.)
    pub fn update_block(&mut self, block: &Mat) {
        assert_eq!(block.rows(), self.d);
        let r = self.rank;

        // Assemble M = [λ·U·diag(Σ) | B] into the reusable panel scratch.
        // Column j of the leading part is u_j · σ_j · λ — the exact
        // per-element product order of the historical
        // `scaled_basis().scaled(forget).hcat(block)` chain, so results
        // are bit-identical without its three per-block allocations.
        let r_e = self.estimate.rank();
        let want = r_e + block.cols();
        if self.panel.rows() != self.d || self.panel.cols() != want {
            self.panel = Mat::zeros(self.d, want);
        }
        let forget = self.cfg.forget;
        for j in 0..r_e {
            let sj = self.estimate.sigma[j];
            let src = self.estimate.u.col(j);
            let dst = self.panel.col_mut(j);
            for i in 0..src.len() {
                dst[i] = src[i] * sj * forget;
            }
        }
        for j in 0..block.cols() {
            self.panel.col_mut(r_e + j).copy_from_slice(block.col(j));
        }
        // Warm start on the previous PCs (the leading columns of M):
        // 6 warm sweeps reach the same accuracy 24 cold sweeps do.
        let (warm, iters) = if r_e == 0 { (0, 24) } else { (r_e, 6) };
        let svd = svd_gram_topk_warm(&self.panel, r, iters, warm);
        self.estimate = Subspace::new(svd.u, svd.sigma);
        self.blocks += 1;

        if self.cfg.adaptive_rank {
            self.adapt_rank();
        }
    }

    /// Reference (slow) Algorithm 5 body via the explicit SSVD + merge
    /// composition; retained as the oracle the fast path is tested
    /// against and for the ablation bench.
    pub fn update_block_reference(&mut self, block: &Mat) {
        assert_eq!(block.rows(), self.d);
        let r = self.rank;
        let merged = if self.estimate.is_empty() {
            let svd = svd_truncated(block, r);
            Subspace::new(svd.u, svd.sigma)
        } else {
            let bsvd = svd_truncated(block, (r + self.cfg.block_size).min(block.cols()));
            let bsub = Subspace::new(bsvd.u, bsvd.sigma);
            merge_subspaces(
                &self.estimate,
                &bsub,
                MergeOptions { rank: r, forget: self.cfg.forget, enhance: 1.0 },
            )
        };
        self.estimate = merged.truncate(r);
        self.blocks += 1;
        if self.cfg.adaptive_rank {
            self.adapt_rank();
        }
    }

    /// `Rank_r^{α,β}` (Eq. 7): grow r when the tail component still carries
    /// more than β of the captured energy; shrink when below α.
    fn adapt_rank(&mut self) {
        let e = self.estimate.energy_ratio();
        if e > self.cfg.energy.beta && self.rank < self.cfg.max_rank {
            self.rank += 1;
            // Paper appends the canonical vector e_{r+1} with zero energy;
            // the next block merge fills it in. We mirror that.
            let mut u = Mat::zeros(self.d, self.rank);
            for j in 0..self.estimate.rank() {
                u.col_mut(j).copy_from_slice(self.estimate.u.col(j));
            }
            // Choose the canonical vector least represented in the basis to
            // keep columns independent.
            let pivot = self.least_covered_axis();
            u.set(pivot, self.rank - 1, 1.0);
            let mut sigma = self.estimate.sigma.clone();
            sigma.push(0.0);
            self.estimate = Subspace::new(u, sigma);
        } else if e < self.cfg.energy.alpha && self.rank > self.cfg.min_rank {
            self.rank -= 1;
            self.estimate = self.estimate.truncate(self.rank);
        }
    }

    fn least_covered_axis(&self) -> usize {
        let mut best = 0usize;
        let mut best_cov = f64::INFINITY;
        for i in 0..self.d {
            let cov: f64 = (0..self.estimate.rank())
                .map(|j| self.estimate.u.get(i, j).powi(2))
                .sum();
            if cov < best_cov {
                best_cov = cov;
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{orthonormality_error, subspace_distance, svd_truncated};
    use crate::proptest::{forall, gen_low_rank};
    use crate::rng::Xoshiro256;

    fn feed_matrix(edge: &mut FpcaEdge, m: &Mat) {
        for t in 0..m.cols() {
            edge.observe(m.col(t));
        }
    }

    #[test]
    fn estimate_empty_until_first_block() {
        let mut edge = FpcaEdge::new(8, FpcaEdgeConfig { block_size: 16, ..Default::default() });
        let mut rng = Xoshiro256::seed_from_u64(1);
        for i in 0..15 {
            let y: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
            assert!(!edge.observe(&y), "i={i}");
            assert!(edge.estimate().is_empty());
        }
        let y: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
        assert!(edge.observe(&y));
        assert_eq!(edge.estimate().rank(), 4);
    }

    #[test]
    fn recovers_subspace_of_low_rank_stream() {
        forall("fpca recovers low-rank subspace", |rng| {
            let d = 16 + rng.gen_range(32);
            let n = 512;
            let data = gen_low_rank(rng, d, n, 3, 0.01);
            let mut edge = FpcaEdge::new(
                d,
                FpcaEdgeConfig { initial_rank: 3, block_size: 32, ..Default::default() },
            );
            feed_matrix(&mut edge, &data);
            let truth = svd_truncated(&data, 3);
            let dist = subspace_distance(&edge.estimate().u, &truth.u);
            if dist < 0.15 {
                Ok(())
            } else {
                Err(format!("subspace distance {dist}"))
            }
        });
    }

    #[test]
    fn estimate_stays_orthonormal_over_many_blocks() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let d = 24;
        let mut edge = FpcaEdge::new(d, FpcaEdgeConfig::default());
        for _ in 0..20 {
            let block = gen_low_rank(&mut rng, d, 32, 4, 0.1);
            edge.update_block(&block);
            assert!(orthonormality_error(&edge.estimate().u) < 1e-8);
        }
        assert_eq!(edge.blocks_processed(), 20);
    }

    #[test]
    fn sigma_grows_with_stream_energy() {
        // Singular values accumulate energy across blocks (no forgetting).
        let mut rng = Xoshiro256::seed_from_u64(3);
        let d = 12;
        let mut edge = FpcaEdge::new(d, FpcaEdgeConfig::default());
        let b1 = gen_low_rank(&mut rng, d, 32, 2, 0.0);
        edge.update_block(&b1);
        let s1 = edge.estimate().sigma[0];
        for _ in 0..5 {
            let b = gen_low_rank(&mut rng, d, 32, 2, 0.0);
            edge.update_block(&b);
        }
        assert!(edge.estimate().sigma[0] > s1);
    }

    #[test]
    fn forgetting_bounds_sigma() {
        // With λ < 1 the spectrum converges instead of growing unboundedly.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let d = 12;
        let mut edge = FpcaEdge::new(
            d,
            FpcaEdgeConfig { forget: 0.7, ..Default::default() },
        );
        let mut tops = Vec::new();
        for _ in 0..30 {
            let b = gen_low_rank(&mut rng, d, 32, 2, 0.0);
            edge.update_block(&b);
            tops.push(edge.estimate().sigma[0]);
        }
        let late_growth = tops[29] / tops[20];
        assert!(late_growth < 1.2, "sigma still growing: {late_growth}");
    }

    #[test]
    fn adaptive_rank_grows_under_rich_data() {
        // Feed data of true rank 6 with initial rank 2 and tight beta: the
        // tracker should raise its rank.
        let mut rng = Xoshiro256::seed_from_u64(5);
        let d = 20;
        let mut edge = FpcaEdge::new(
            d,
            FpcaEdgeConfig {
                initial_rank: 2,
                max_rank: 8,
                adaptive_rank: true,
                energy: EnergyBounds { alpha: 0.01, beta: 0.25 },
                ..Default::default()
            },
        );
        for _ in 0..12 {
            let b = gen_low_rank(&mut rng, d, 32, 6, 0.02);
            edge.update_block(&b);
        }
        assert!(edge.rank() > 2, "rank did not grow: {}", edge.rank());
    }

    #[test]
    fn adaptive_rank_shrinks_on_degenerate_data() {
        // Rank-1 data with generous initial rank: trailing energy ratio
        // collapses and the rank should drop.
        let mut rng = Xoshiro256::seed_from_u64(6);
        let d = 16;
        let mut edge = FpcaEdge::new(
            d,
            FpcaEdgeConfig {
                initial_rank: 6,
                max_rank: 8,
                adaptive_rank: true,
                energy: EnergyBounds { alpha: 0.02, beta: 0.9 },
                ..Default::default()
            },
        );
        for _ in 0..15 {
            let b = gen_low_rank(&mut rng, d, 32, 1, 0.001);
            edge.update_block(&b);
        }
        assert!(edge.rank() < 6, "rank did not shrink: {}", edge.rank());
    }

    #[test]
    fn set_estimate_respects_caps() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut edge = FpcaEdge::new(10, FpcaEdgeConfig { max_rank: 4, ..Default::default() });
        let big = crate::proptest::gen_orthonormal(&mut rng, 10, 6);
        edge.set_estimate(Subspace::new(big, vec![6.0, 5.0, 4.0, 3.0, 2.0, 1.0]));
        assert_eq!(edge.rank(), 4);
        assert_eq!(edge.estimate().rank(), 4);
    }
}

#[cfg(test)]
mod fastpath_tests {
    use super::*;
    use crate::linalg::subspace_distance;
    use crate::proptest::{forall, gen_low_rank};

    #[test]
    fn fast_block_update_matches_reference() {
        forall("fast update == reference update", |rng| {
            let d = 16 + rng.gen_range(48);
            let mut fast = FpcaEdge::new(d, FpcaEdgeConfig::default());
            let mut slow = FpcaEdge::new(d, FpcaEdgeConfig::default());
            for _ in 0..6 {
                let block = gen_low_rank(rng, d, 32, 4, 0.05);
                fast.update_block(&block);
                slow.update_block_reference(&block);
            }
            let ef = fast.estimate();
            let es = slow.estimate();
            for (a, b) in ef.sigma.iter().zip(es.sigma.iter()) {
                let rel = (a - b).abs() / b.max(1e-9);
                if rel > 0.03 {
                    return Err(format!("sigma {a} vs {b}"));
                }
            }
            let dist = subspace_distance(&ef.truncate(2).u, &es.truncate(2).u);
            if dist > 0.05 {
                return Err(format!("span {dist}"));
            }
            Ok(())
        });
    }
}
