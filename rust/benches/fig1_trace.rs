//! Figure 1: CPU Ready real values + offline predictions for one VM, 1 h.
//!
//! Emits the (time, real, ExpSmo, SVR, naive) series — set
//! `PRONTO_BENCH_CSV_DIR` to capture the CSV for plotting. The paper's
//! point: none of the offline methods track the spikes.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::bench::Table;
use pronto::forecast::{ExpSmoothing, Forecaster, LinearSvr, Naive};
use pronto::metrics::rmse;
use pronto::telemetry::{GeneratorConfig, TraceGenerator};

fn main() {
    // One hour at 20 s cadence = 180 samples, preceded by 1 h of history
    // per 20 s forecasting step (forecast window 20 s as in Figure 1).
    let horizon = 180usize;
    let history_len = 180usize;
    let steps = history_len + horizon;
    let gen = TraceGenerator::new(GeneratorConfig::default(), 17);
    let trace = gen.generate_vm(3, steps);
    let ready = trace.cpu_ready_series();

    let methods: Vec<Box<dyn Forecaster>> = vec![
        Box::new(Naive),
        Box::new(ExpSmoothing::default()),
        Box::new(LinearSvr { use_pool: false, tag: "SVR", ..Default::default() }),
    ];

    // Rolling one-step-ahead forecasts over the final hour.
    let mut series: Vec<Vec<f64>> = vec![Vec::new(); methods.len()];
    for t in history_len..steps {
        let hist = &ready[t - history_len..t];
        for (mi, m) in methods.iter().enumerate() {
            series[mi].push(m.forecast(hist, &[], 1)[0]);
        }
    }
    let real = &ready[history_len..];

    let mut t = Table::new(
        "Figure 1: one-step CPU Ready predictions, single VM, 1 hour",
        &["t", "real", "naive", "ExpSmo", "SVR"],
    );
    for i in 0..horizon {
        t.row(&[
            format!("{i}"),
            format!("{:.1}", real[i]),
            format!("{:.1}", series[0][i]),
            format!("{:.1}", series[1][i]),
            format!("{:.1}", series[2][i]),
        ]);
    }
    // Print only the summary to stdout; full series goes to CSV.
    t.maybe_write_csv("fig1_series");
    let mut summary = Table::new(
        "Figure 1 summary: per-method RMSE over the hour",
        &["method", "RMSE (ms)"],
    );
    for (mi, m) in methods.iter().enumerate() {
        summary.row(&[m.name().to_string(), format!("{:.1}", rmse(&series[mi], real))]);
    }
    summary.print();
    println!("\nshape: all methods miss the spikes (large RMSE vs spike magnitudes ~1000+ ms).");
}
