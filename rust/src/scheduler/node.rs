//! Per-node admission pipeline: streaming embedding + Reject-Job.
//!
//! A [`NodeScheduler`] is the complete local decision stack the paper
//! describes (Figure 3): each incoming telemetry vector updates the
//! embedding tracker (block-wise) and flows through Reject-Job to produce
//! the admission decision for that timestep — no communication involved.

use super::{OnlineStandardizer, RejectConfig, RejectJob};
use crate::baselines::StreamingEmbedding;
use crate::fpca::{FpcaEdge, FpcaEdgeConfig, Subspace};

/// Rolling statistics of one node's admission behaviour.
#[derive(Debug, Clone, Copy, Default)]
pub struct NodeStats {
    /// Timesteps observed.
    pub steps: usize,
    /// Timesteps with the rejection signal raised.
    pub rejected_steps: usize,
    /// Jobs offered to this node.
    pub jobs_offered: usize,
    /// Jobs accepted.
    pub jobs_accepted: usize,
}

impl NodeStats {
    /// Fraction of time the node refused work (paper: "downtime").
    pub fn downtime(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.rejected_steps as f64 / self.steps as f64
        }
    }

    /// Fraction of offered jobs accepted.
    pub fn acceptance_rate(&self) -> f64 {
        if self.jobs_offered == 0 {
            1.0
        } else {
            self.jobs_accepted as f64 / self.jobs_offered as f64
        }
    }
}

/// One node's full local scheduling stack, generic over the embedding
/// method (PRONTO's FPCA-Edge or any §7 baseline).
pub struct NodeScheduler<E: StreamingEmbedding = FpcaEdge> {
    embedding: E,
    reject: RejectJob,
    /// Online per-feature z-scaling ahead of the embedding (None = feed
    /// raw vectors; see [`OnlineStandardizer`] for why the default is on).
    standardizer: Option<OnlineStandardizer>,
    /// Cached copy of the embedding's estimate, refreshed only when
    /// [`StreamingEmbedding::version`] changes (block methods refresh once
    /// per block — cloning the subspace every timestep dominated the hot
    /// path before this cache; see EXPERIMENTS.md §Perf).
    cached_estimate: Subspace,
    cached_version: Option<u64>,
    /// Rejection signal at the latest observed timestep.
    raised: bool,
    stats: NodeStats,
}

impl NodeScheduler<FpcaEdge> {
    /// Standard PRONTO node: FPCA-Edge embedding with default parameters.
    pub fn new(dim: usize, cfg: RejectConfig) -> Self {
        let fpca = FpcaEdge::new(dim, FpcaEdgeConfig::default());
        Self::with_embedding(fpca, cfg)
    }
}

impl<E: StreamingEmbedding> NodeScheduler<E> {
    /// Node with an explicit embedding engine (used for the §7 baselines).
    pub fn with_embedding(embedding: E, cfg: RejectConfig) -> Self {
        let dim = embedding.dim();
        Self {
            cached_estimate: Subspace::empty(dim),
            cached_version: None,
            embedding,
            reject: RejectJob::new(cfg),
            standardizer: Some(OnlineStandardizer::new(dim)),
            raised: false,
            stats: NodeStats::default(),
        }
    }

    /// Disable the input standardizer (feed raw metric vectors).
    pub fn without_standardizer(mut self) -> Self {
        self.standardizer = None;
        self
    }

    /// Feed the telemetry vector for the current timestep; returns `true`
    /// when the node can ACCEPT a job arriving now (i.e. signal not raised).
    pub fn observe(&mut self, y: &[f64]) -> bool {
        // Refresh the cached estimate only when the embedding advanced
        // (block methods: once per block). Methods reporting version None
        // refresh every step.
        let version = self.embedding.version();
        if version.is_none() || version != self.cached_version {
            self.cached_estimate = self.embedding.estimate();
            self.cached_version = version;
        }
        // Standardize (borrowed scratch, no allocation), then Reject-Job
        // (uses the estimate as of *before* this sample — the iterate only
        // refreshes at block boundaries anyway).
        let raised = match &mut self.standardizer {
            Some(st) => {
                let z = st.transform(y);
                let raised = self.reject.observe(&self.cached_estimate, z);
                self.embedding.observe(z);
                raised
            }
            None => {
                let raised = self.reject.observe(&self.cached_estimate, y);
                self.embedding.observe(y);
                raised
            }
        };
        self.raised = raised;
        self.stats.steps += 1;
        if self.raised {
            self.stats.rejected_steps += 1;
        }
        !self.raised
    }

    /// Offer a job at the current timestep; bookkeeping + decision.
    pub fn offer_job(&mut self) -> bool {
        self.stats.jobs_offered += 1;
        if !self.raised {
            self.stats.jobs_accepted += 1;
            true
        } else {
            false
        }
    }

    /// Rejection signal at the latest timestep.
    pub fn rejection_raised(&self) -> bool {
        self.raised
    }

    /// Latest projections (diagnostics; Figure 4a).
    pub fn projections(&self) -> &[f64] {
        self.reject.projections()
    }

    /// Current subspace estimate.
    pub fn estimate(&self) -> Subspace {
        self.embedding.estimate()
    }

    /// Embedding engine (for federation pulls/pushes).
    pub fn embedding(&self) -> &E {
        &self.embedding
    }

    pub fn embedding_mut(&mut self) -> &mut E {
        &mut self.embedding
    }

    pub fn stats(&self) -> NodeStats {
        self.stats
    }

    /// Method tag ("PRONTO", "SP", "FD", "PM").
    pub fn method(&self) -> &'static str {
        self.embedding.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Spirit;
    use crate::telemetry::{GeneratorConfig, TraceGenerator};

    #[test]
    fn node_accepts_during_calm_trace() {
        let gen = TraceGenerator::new(
            GeneratorConfig { episode_hazard: 0.0, ..Default::default() },
            7,
        );
        let trace = gen.generate_vm(0, 400);
        let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());
        let mut accepts = 0;
        for t in 0..trace.len() {
            if node.observe(trace.features(t)) {
                accepts += 1;
            }
        }
        // Calm trace: vast majority of steps acceptable.
        assert!(accepts as f64 / trace.len() as f64 > 0.85, "accepts={accepts}");
    }

    #[test]
    fn node_raises_signal_sometimes_on_contended_trace() {
        let gen = TraceGenerator::new(
            GeneratorConfig { episode_hazard: 0.03, ..Default::default() },
            11,
        );
        let trace = gen.generate_vm(0, 2000);
        let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());
        for t in 0..trace.len() {
            node.observe(trace.features(t));
        }
        let down = node.stats().downtime();
        assert!(down > 0.0, "rejection signal never raised");
        assert!(down < 0.5, "downtime too high: {down}");
    }

    #[test]
    fn offer_job_respects_signal_and_counts() {
        let gen = TraceGenerator::new(GeneratorConfig::default(), 3);
        let trace = gen.generate_vm(2, 600);
        let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());
        let mut offered = 0;
        for t in 0..trace.len() {
            node.observe(trace.features(t));
            if t % 10 == 0 {
                let ok = node.offer_job();
                offered += 1;
                assert_eq!(ok, !node.rejection_raised());
            }
        }
        assert_eq!(node.stats().jobs_offered, offered);
        assert!(node.stats().jobs_accepted <= offered);
    }

    #[test]
    fn works_with_baseline_embedding() {
        let gen = TraceGenerator::new(GeneratorConfig::default(), 5);
        let trace = gen.generate_vm(1, 300);
        let spirit = Spirit::new(trace.dim(), crate::baselines::SpiritConfig::default());
        let mut node = NodeScheduler::with_embedding(spirit, RejectConfig::default());
        for t in 0..trace.len() {
            node.observe(trace.features(t));
        }
        assert_eq!(node.method(), "SP");
        assert_eq!(node.stats().steps, 300);
    }
}
