//! Linear ε-SVR over autoregressive features (§3.1 method 4).
//!
//! The paper applies "an autoregressive transformation of the time series"
//! and trains an SVM regressor on data from the target VM, the cluster's
//! VMs ("SVM cluster"), or all VMs ("SVM full"). We implement a linear
//! ε-insensitive SVR trained by averaged subgradient descent — exact
//! solver choice is irrelevant at these scales, and the paper's claim
//! being reproduced is *relative* accuracy across methods.

use super::{with_normalization, Forecaster};

/// Linear ε-SVR forecaster over lag features.
#[derive(Debug, Clone)]
pub struct LinearSvr {
    /// Number of autoregressive lags used as features.
    pub lags: usize,
    /// ε-insensitive tube half-width.
    pub epsilon: f64,
    /// L2 regularization strength.
    pub lambda: f64,
    /// SGD epochs.
    pub epochs: usize,
    /// Learning rate.
    pub lr: f64,
    /// Pool usage: include pool series in training ("SVM cluster"/"full").
    pub use_pool: bool,
    /// Table tag; the paper distinguishes "SVM Cluster" vs "SVM Full".
    pub tag: &'static str,
}

impl Default for LinearSvr {
    fn default() -> Self {
        Self {
            lags: 8,
            epsilon: 0.01,
            lambda: 1e-4,
            epochs: 60,
            lr: 0.05,
            use_pool: true,
            tag: "SVM cluster",
        }
    }
}

impl LinearSvr {
    /// Build (features, target) pairs from one scaled series.
    fn training_pairs(&self, xs: &[f64], rows: &mut Vec<Vec<f64>>, ys: &mut Vec<f64>) {
        if xs.len() <= self.lags {
            return;
        }
        for t in self.lags..xs.len() {
            let mut row = Vec::with_capacity(self.lags + 1);
            row.push(1.0);
            for l in 1..=self.lags {
                row.push(xs[t - l]);
            }
            rows.push(row);
            ys.push(xs[t]);
        }
    }

    /// Averaged subgradient descent on the ε-insensitive loss.
    fn train(&self, rows: &[Vec<f64>], ys: &[f64]) -> Vec<f64> {
        let k = self.lags + 1;
        let mut w = vec![0.0; k];
        let mut w_avg = vec![0.0; k];
        let n = rows.len().max(1);
        for epoch in 0..self.epochs {
            let lr = self.lr / (1.0 + epoch as f64 * 0.1);
            for (row, &y) in rows.iter().zip(ys) {
                let pred: f64 = row.iter().zip(&w).map(|(a, b)| a * b).sum();
                let err = pred - y;
                // Subgradient of ε-insensitive loss + L2.
                let g = if err > self.epsilon {
                    1.0
                } else if err < -self.epsilon {
                    -1.0
                } else {
                    0.0
                };
                for i in 0..k {
                    w[i] -= lr * (g * row[i] + self.lambda * w[i]);
                }
            }
            for i in 0..k {
                w_avg[i] += w[i];
            }
        }
        let _ = n;
        for wi in &mut w_avg {
            *wi /= self.epochs as f64;
        }
        w_avg
    }

    fn forecast_scaled(&self, xs: &[f64], pool_scaled: &[Vec<f64>], horizon: usize) -> Vec<f64> {
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        self.training_pairs(xs, &mut rows, &mut ys);
        if self.use_pool {
            for series in pool_scaled {
                self.training_pairs(series, &mut rows, &mut ys);
            }
        }
        if rows.is_empty() {
            return vec![*xs.last().unwrap(); horizon];
        }
        let w = self.train(&rows, &ys);

        // Recursive multi-step forecast.
        let mut series = xs.to_vec();
        let mut out = Vec::with_capacity(horizon);
        for _ in 0..horizon {
            let t = series.len();
            let mut feats = Vec::with_capacity(self.lags + 1);
            feats.push(1.0);
            for l in 1..=self.lags {
                feats.push(if t >= l { series[t - l] } else { series[0] });
            }
            let pred: f64 = feats.iter().zip(&w).map(|(a, b)| a * b).sum();
            series.push(pred);
            out.push(pred);
        }
        out
    }
}

impl LinearSvr {
    /// Train once on the scaled history (+pool), then one-step predict each
    /// future value from the actual lags revealed so far.
    fn rolling_scaled(&self, xs: &[f64], pool_scaled: &[Vec<f64>], future: &[f64]) -> Vec<f64> {
        let mut rows = Vec::new();
        let mut ys = Vec::new();
        self.training_pairs(xs, &mut rows, &mut ys);
        if self.use_pool {
            for series in pool_scaled {
                self.training_pairs(series, &mut rows, &mut ys);
            }
        }
        if rows.is_empty() {
            let mut prev = *xs.last().unwrap();
            return future
                .iter()
                .map(|&a| {
                    let p = prev;
                    prev = a;
                    p
                })
                .collect();
        }
        let w = self.train(&rows, &ys);
        let mut series = xs.to_vec();
        let mut out = Vec::with_capacity(future.len());
        for &actual in future {
            let t = series.len();
            let mut feats = Vec::with_capacity(self.lags + 1);
            feats.push(1.0);
            for l in 1..=self.lags {
                feats.push(if t >= l { series[t - l] } else { series[0] });
            }
            out.push(feats.iter().zip(&w).map(|(a, b)| a * b).sum());
            series.push(actual);
        }
        out
    }
}

impl Forecaster for LinearSvr {
    fn name(&self) -> &'static str {
        self.tag
    }

    fn forecast(&self, history: &[f64], pool: &[&[f64]], horizon: usize) -> Vec<f64> {
        // Normalize the target; pool series are normalized independently
        // (each VM has its own scale, per the per-VM protocol of §3.1).
        let pool_scaled: Vec<Vec<f64>> = if self.use_pool {
            pool.iter().map(|s| crate::metrics::normalize(s).0).collect()
        } else {
            Vec::new()
        };
        with_normalization(history, |scaled| {
            self.forecast_scaled(scaled, &pool_scaled, horizon)
        })
    }

    fn forecast_rolling(&self, history: &[f64], pool: &[&[f64]], future: &[f64]) -> Vec<f64> {
        let pool_scaled: Vec<Vec<f64>> = if self.use_pool {
            pool.iter().map(|s| crate::metrics::normalize(s).0).collect()
        } else {
            Vec::new()
        };
        let (scaled, lo, span) = crate::metrics::normalize(history);
        let fut_scaled: Vec<f64> = future.iter().map(|x| (x - lo) / span).collect();
        let out = self.rolling_scaled(&scaled, &pool_scaled, &fut_scaled);
        crate::metrics::denormalize(&out, lo, span)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    #[test]
    fn learns_ar1_structure_better_than_mean() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut xs = vec![10.0];
        for _ in 0..600 {
            let prev = *xs.last().unwrap();
            xs.push(10.0 + 0.9 * (prev - 10.0) + 0.1 * rng.normal());
        }
        // Hold out the last 10 points.
        let (train, test) = xs.split_at(xs.len() - 10);
        let svr = LinearSvr { use_pool: false, ..Default::default() };
        let fc = svr.forecast(train, &[], 10);
        let rmse_svr = crate::metrics::rmse(&fc, test);
        let mean = train.iter().sum::<f64>() / train.len() as f64;
        let rmse_mean = crate::metrics::rmse(&vec![mean; 10], test);
        assert!(
            rmse_svr < rmse_mean * 1.5,
            "svr={rmse_svr:.4} vs mean={rmse_mean:.4}"
        );
        assert!(fc.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn constant_series_predicts_constant() {
        let svr = LinearSvr::default();
        let fc = svr.forecast(&[4.0; 100], &[], 5);
        for v in fc {
            assert!((v - 4.0).abs() < 1.0, "v={v}");
        }
    }

    #[test]
    fn pool_data_expands_training_set() {
        // Pool with strong AR structure helps when target history is short.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let gen_series = |rng: &mut Xoshiro256, n: usize| -> Vec<f64> {
            let mut xs = vec![5.0];
            for _ in 0..n {
                let prev = *xs.last().unwrap();
                xs.push(5.0 + 0.8 * (prev - 5.0) + 0.05 * rng.normal());
            }
            xs
        };
        let target = gen_series(&mut rng, 30);
        let p1 = gen_series(&mut rng, 500);
        let p2 = gen_series(&mut rng, 500);
        let pool: Vec<&[f64]> = vec![&p1, &p2];
        let svr = LinearSvr::default();
        let fc = svr.forecast(&target, &pool, 5);
        assert_eq!(fc.len(), 5);
        assert!(fc.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn horizon_zero_is_empty() {
        let svr = LinearSvr::default();
        assert!(svr.forecast(&[1.0; 50], &[], 0).is_empty());
    }
}
