"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is the
core correctness signal for the kernel layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.projection import gram, matmul_tiled, project_block

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(rng, *shape, dtype=np.float32):
    return rng.standard_normal(shape).astype(dtype)


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tiled_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k)
    y = rand(rng, k, n)
    got = matmul_tiled(jnp.asarray(x), jnp.asarray(y))
    want = ref.matmul_ref(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
def test_matmul_tiled_dtypes(dtype):
    rng = np.random.default_rng(0)
    x = rand(rng, 33, 52, dtype=dtype)
    y = rand(rng, 52, 4, dtype=dtype)
    got = matmul_tiled(jnp.asarray(x), jnp.asarray(y))
    assert np.asarray(got).dtype == dtype
    np.testing.assert_allclose(np.asarray(got), x @ y, rtol=1e-5, atol=1e-5)


@given(
    b=st.integers(1, 64),
    d=st.integers(1, 64),
    r=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_project_block_matches_ref(b, d, r, seed):
    rng = np.random.default_rng(seed)
    y = rand(rng, b, d)
    u = rand(rng, d, r)
    got = project_block(jnp.asarray(y), jnp.asarray(u))
    want = ref.project_block_ref(jnp.asarray(y), jnp.asarray(u))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@given(d=st.integers(1, 64), c=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_gram_matches_ref(d, c, seed):
    rng = np.random.default_rng(seed)
    m = rand(rng, d, c)
    got = gram(jnp.asarray(m))
    want = ref.gram_ref(jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_matmul_exact_tile_boundaries():
    # Shapes exactly at tile multiples exercise the no-padding path.
    rng = np.random.default_rng(7)
    x = rand(rng, 32, 64)
    y = rand(rng, 64, 32)
    got = matmul_tiled(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), x @ y, rtol=1e-5, atol=1e-5)


def test_matmul_multi_tile_k_accumulation():
    # k spanning several tiles exercises the accumulate-over-k grid axis.
    rng = np.random.default_rng(8)
    x = rand(rng, 16, 200)
    y = rand(rng, 200, 8)
    got = matmul_tiled(jnp.asarray(x), jnp.asarray(y))
    np.testing.assert_allclose(np.asarray(got), x @ y, rtol=1e-4, atol=1e-4)


def test_kernel_is_jittable_and_stable():
    # Calling through jit twice must give identical results (purity).
    rng = np.random.default_rng(9)
    x = jnp.asarray(rand(rng, 10, 52))
    u = jnp.asarray(rand(rng, 52, 4))
    a = project_block(x, u)
    b = project_block(x, u)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
