# Build-time-only package: authors the L2 JAX graphs (calling the L1 Pallas
# kernels) and AOT-lowers them to HLO text artifacts the Rust runtime loads.
# Never imported on the request path.
