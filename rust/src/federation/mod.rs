//! Federation layer: the DASM aggregation tree (paper §4–5, Figure 2).
//!
//! Compute nodes sit at the leaves of a shallow, high-fanout tree;
//! aggregator nodes merge the `(U, Σ)` iterates that leaves push upward.
//! Summaries travel upward **once** per propagation (the distributed
//! agglomerative summary model), so no synchronization is modelled — the
//! paper explicitly scopes synchronization issues out. Leaves push only
//! when their iterate moved by more than ε since the last push
//! (Algorithm 2's `absdiff` gate), and may pull the merged global view to
//! seed or refresh their local estimate (§5.2, including new/transient
//! nodes joining the pool).
//!
//! Two runtimes are provided:
//! * [`tree`] — the single-threaded federation engine (deterministic, used
//!   by the evaluation benches);
//! * [`concurrent`] — a thread-per-leaf actor runtime exercising the same
//!   merge logic under real parallelism (scalability bench).

//! A third concern joined in the scenario work: [`latency`] models the
//! push delivery delay the paper scopes out, and both runtimes accept it —
//! the discrete-event engine schedules delayed merges into
//! [`FederationTree`], and [`ConcurrentFederation`] holds pushes in
//! per-leaf pending queues until their delivery step (dropping pushes that
//! would land after the run — "arrived too late").

mod concurrent;
mod latency;
mod tree;

pub use concurrent::{ConcurrentFederation, FederationReport};
pub use latency::LatencyModel;
pub use tree::{FederationTree, NodeId, PushOutcome, TreeTopology};
