//! Table 5: spike-alarm accuracy with per-VM percentile thresholds.
//!
//! Paper shape: accuracy rises from the 90th to the 99th percentile;
//! percentile spikes are more frequent and harder than fixed ones.

use pronto::bench::experiments::{spike_tables, ExperimentScale};
use pronto::bench::Table;
use pronto::forecast::SpikeThreshold;

fn main() {
    let scale = ExperimentScale::from_env();
    let (rows, pct) = spike_tables(
        &scale,
        &[
            SpikeThreshold::Percentile(90.0),
            SpikeThreshold::Percentile(95.0),
            SpikeThreshold::Percentile(99.0),
        ],
    );
    let mut t = Table::new(
        "Table 5: alarm accuracy, percentile spike thresholds",
        &["method", "90th", "95th", "99th"],
    );
    for (name, c) in rows {
        t.row(&[name, format!("{:.4}", c[0]), format!("{:.4}", c[1]), format!("{:.4}", c[2])]);
    }
    t.row(&[
        "% of spikes".into(),
        format!("{:.2}", pct[0]),
        format!("{:.2}", pct[1]),
        format!("{:.2}", pct[2]),
    ]);
    t.print();
    t.maybe_write_csv("table5");
    println!("\npaper reference: best 0.7472/0.7942/0.8534; spikes 13.28/10.18/7.3%");
}
