//! Offline CPU Ready forecasting baselines (paper §3, Tables 1–6).
//!
//! These are the methods the paper evaluates *before* proposing PRONTO, to
//! establish that classical forecasting cannot predict CPU Ready well:
//!
//! * [`naive`] / [`expsmo`] — last-value and exponential smoothing (α=0.2);
//! * [`arima`] — ARIMA(p,d,q) with AIC order selection (CSS/Hannan–Rissanen
//!   style fitting), optionally on the "average VM" of a cluster;
//! * [`svr`] — linear ε-SVR over autoregressive features, trained on the
//!   target VM or pooled over cluster VMs ("SVM cluster"/"SVM full");
//! * [`kmeans`] — VM pre-clustering with the five distance metrics of
//!   Table 2 (Euclidean, correlation, STS, CORT, ACF);
//! * [`spikes`] — the §3.2 alarm method: spike-threshold definitions
//!   (fixed / percentile / μ+3σ / xbar / median) and the accuracy metric.
//!
//! All forecasters implement [`Forecaster`]; inputs are min-max normalized
//! and de-normalized around fitting, per §3.1.

mod arima;
mod expsmo;
mod kmeans;
mod naive;
mod spikes;
mod svr;

pub use arima::{Arima, ArimaOrder};
pub use expsmo::ExpSmoothing;
pub use kmeans::{acf_distance, cort_distance, sts_distance, DistanceKind, KMeansSeries};
pub use naive::Naive;
pub use spikes::{alarm_forecast_accuracy, spike_mask, SpikeThreshold};
pub use svr::LinearSvr;

use crate::metrics::{denormalize, normalize};

/// A forecasting method for a scalar series, optionally informed by a pool
/// of aligned series from related VMs (same cluster / similar VMs).
pub trait Forecaster {
    /// Method tag used in the tables ("naive", "ExpSmo", "ARIMA", "SVM …").
    fn name(&self) -> &'static str;

    /// Forecast `horizon` future values of `history`, given `pool`
    /// (other VMs' aligned histories; may be empty). Inputs are raw-scale;
    /// implementations normalize internally per the paper.
    fn forecast(&self, history: &[f64], pool: &[&[f64]], horizon: usize) -> Vec<f64>;

    /// Rolling one-step-ahead forecasts over a revealed future: the model
    /// is fit on `history` (+pool) once, then for each step t the method
    /// predicts `future[t]` from the *actual* values up to t−1 — the §3
    /// protocol for per-timestep next-day prediction. The default
    /// re-invokes `forecast` with the extended history (correct but
    /// O(n·fit)); methods with cheap recursive predictors override it.
    fn forecast_rolling(&self, history: &[f64], pool: &[&[f64]], future: &[f64]) -> Vec<f64> {
        let mut ext = history.to_vec();
        let mut out = Vec::with_capacity(future.len());
        for &actual in future {
            out.push(self.forecast(&ext, pool, 1)[0]);
            ext.push(actual);
        }
        out
    }
}

/// Normalize history + pool jointly, run `f` on the scaled series, and
/// de-normalize the output — the §3.1 protocol shared by every method.
pub(crate) fn with_normalization(
    history: &[f64],
    f: impl FnOnce(&[f64]) -> Vec<f64>,
) -> Vec<f64> {
    let (scaled, lo, span) = normalize(history);
    let mut out = f(&scaled);
    // Clamp to a modest extrapolation band around the observed range:
    // recursive multi-step forecasts (ARIMA/SVR) can diverge on very short
    // histories, and the paper's normalize-then-denormalize protocol is
    // explicitly about solver stability.
    for x in &mut out {
        *x = x.clamp(-0.5, 1.5);
    }
    denormalize(&out, lo, span)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_wrapper_roundtrips_scale() {
        let hist = [100.0, 200.0, 300.0];
        // Identity forecast of the last scaled value.
        let out = with_normalization(&hist, |s| vec![s[s.len() - 1]; 2]);
        assert_eq!(out, vec![300.0, 300.0]);
    }
}
