//! Struct-of-arrays fleet state for the engine's hot loops.
//!
//! At 100k nodes the per-tick scans — observe, capacity drain, churn
//! hazard, pressure preemption — dominate the engine's wall time, and
//! the historical layout (an array-of-structs [`HostCapacity`] per node,
//! flags scattered across parallel `Vec<bool>`s owned by `run()`'s stack
//! frame) made every scan a pointer-chase over ~100-byte strides. This
//! module keeps the same *logical* state but pivots the hot fields into
//! dense parallel arrays:
//!
//! * [`FleetState`] — liveness flags, the merged `can_accept` rejection
//!   signal, the **sorted** alive-id list the observe shards and probe
//!   samplers iterate, a dense id→position index map (O(1) membership
//!   and rank lookups, maintained incrementally on churn), and the
//!   round-robin probe cursor.
//! * [`HostTable`] — the [`HostCapacity`] hosts plus struct-of-arrays
//!   mirrors of their hot scalar fields (slot budget, slots used, queue
//!   depth, queue-delay EWMA). Mutations delegate to the host (the
//!   single source of truth for queue contents and the running set) and
//!   re-sync that node's mirror; reads on the per-tick scan paths and
//!   the probe fast path come straight from the contiguous arrays.
//!
//! Both types are pure layout changes: every method reproduces the exact
//! value the scattered representation produced, so reports stay
//! byte-identical (the catalog determinism suite is the witness).

use crate::scheduler::{AdmissionProbe, HostCapacity, JobId, Priority, QueuedJob};

/// Sentinel in the id→position map for nodes that are not alive.
const NOT_ALIVE: u32 = u32::MAX;

/// Dense per-node liveness/signal state plus the sorted alive-id list.
///
/// Invariants: `alive_ids` is strictly sorted; `alive[i]` ⇔ `alive_ids`
/// contains `i` ⇔ `pos[i] != NOT_ALIVE`; and for every alive `i`,
/// `alive_ids[pos[i] as usize] == i`. Leave/join maintain all three in
/// one O(shift) pass (no binary search, no re-sort).
#[derive(Debug)]
pub struct FleetState {
    alive: Vec<bool>,
    can_accept: Vec<bool>,
    alive_ids: Vec<usize>,
    /// id → rank in `alive_ids` (`NOT_ALIVE` when down).
    pos: Vec<u32>,
    /// Round-robin probe cursor, tracked by node *identity* (the next
    /// node id to probe), not by index into the alive list — an index
    /// cursor re-aliases every later probe after churn.
    rr_next: usize,
}

impl FleetState {
    /// A fleet of `n` nodes, all alive and accepting.
    pub fn new(n: usize) -> Self {
        Self {
            alive: vec![true; n],
            can_accept: vec![true; n],
            alive_ids: (0..n).collect(),
            pos: (0..n).map(|i| i as u32).collect(),
            rr_next: 0,
        }
    }

    /// Total fleet size (alive or not).
    pub fn len(&self) -> usize {
        self.alive.len()
    }

    pub fn is_empty(&self) -> bool {
        self.alive.is_empty()
    }

    pub fn alive_count(&self) -> usize {
        self.alive_ids.len()
    }

    #[inline]
    pub fn is_alive(&self, node: usize) -> bool {
        self.alive[node]
    }

    #[inline]
    pub fn can_accept(&self, node: usize) -> bool {
        self.can_accept[node]
    }

    #[inline]
    pub fn set_can_accept(&mut self, node: usize, v: bool) {
        self.can_accept[node] = v;
    }

    /// The sorted alive-id list (the iteration order of every per-tick
    /// scan and the pool of every probe sampler).
    pub fn alive_ids(&self) -> &[usize] {
        &self.alive_ids
    }

    /// The merged rejection-signal array (`can_accept[node]`), for
    /// read-only scoring paths that index by candidate id.
    pub fn can_accept_slice(&self) -> &[bool] {
        &self.can_accept
    }

    /// Split borrow for the observe loop: the alive ids to iterate and
    /// the `can_accept` output array the per-node verdicts write into
    /// (the parallel shards carve the latter into disjoint chunks).
    pub fn observe_split(&mut self) -> (&[usize], &mut [bool]) {
        (&self.alive_ids, &mut self.can_accept)
    }

    /// Mark `node` dead. Returns `false` (and changes nothing) when it
    /// already was. O(shift) on the dense arrays.
    pub fn leave(&mut self, node: usize) -> bool {
        if !self.alive[node] {
            return false;
        }
        self.alive[node] = false;
        let p = self.pos[node] as usize;
        debug_assert_eq!(self.alive_ids[p], node);
        self.pos[node] = NOT_ALIVE;
        self.alive_ids.remove(p);
        for &id in &self.alive_ids[p..] {
            self.pos[id] -= 1;
        }
        true
    }

    /// Mark `node` alive. Returns `false` (and changes nothing) when it
    /// already was. O(shift); the sorted order is restored by inserting
    /// at the id's rank, exactly where the historical binary-search
    /// insert put it.
    pub fn join(&mut self, node: usize) -> bool {
        if self.alive[node] {
            return false;
        }
        self.alive[node] = true;
        // Rank of `node` among the alive ids = first position whose id
        // exceeds it. Ids below `node` keep their rank; ids above shift
        // up by one — the same walk updates the index map.
        let p = self.alive_ids.partition_point(|&id| id < node);
        self.alive_ids.insert(p, node);
        self.pos[node] = p as u32;
        for &id in &self.alive_ids[p + 1..] {
            self.pos[id] += 1;
        }
        true
    }

    /// Round-robin probe: the first alive node with id `>= rr_next`
    /// (wrapping), advancing the cursor past it. `None` on an empty
    /// alive set. Identity-tracked (see the field docs), so churn never
    /// re-aliases or starves the rotation.
    pub fn rr_probe(&mut self) -> Option<usize> {
        let m = self.alive_ids.len();
        if m == 0 {
            return None;
        }
        let pos = self.alive_ids.partition_point(|&id| id < self.rr_next);
        let c = self.alive_ids[if pos == m { 0 } else { pos }];
        self.rr_next = c + 1;
        Some(c)
    }
}

/// The fleet's hosts plus struct-of-arrays mirrors of their hot scalars.
///
/// Every mutation goes through a delegating method that re-syncs the
/// touched node's mirror row, so `slots`/`used`/`queue_depth`/
/// `delay_ewma` always equal the host's own accessors — probes and the
/// per-tick capacity/pressure scans read the contiguous arrays, queue
/// contents and the running set stay inside [`HostCapacity`].
#[derive(Debug)]
pub struct HostTable {
    hosts: Vec<HostCapacity>,
    slots: Vec<u32>,
    used: Vec<u32>,
    queue_depth: Vec<u32>,
    delay_ewma: Vec<f64>,
}

impl HostTable {
    pub fn new(hosts: Vec<HostCapacity>) -> Self {
        let slots = hosts.iter().map(|h| h.slots()).collect();
        let used = hosts.iter().map(|h| h.used()).collect();
        let queue_depth = hosts.iter().map(|h| h.queue_len() as u32).collect();
        let delay_ewma = hosts.iter().map(|h| h.queue_delay_ewma()).collect();
        Self { hosts, slots, used, queue_depth, delay_ewma }
    }

    pub fn len(&self) -> usize {
        self.hosts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.hosts.is_empty()
    }

    /// Re-read node `i`'s hot scalars from its host.
    #[inline]
    fn sync(&mut self, i: usize) {
        let h = &self.hosts[i];
        self.slots[i] = h.slots();
        self.used[i] = h.used();
        self.queue_depth[i] = h.queue_len() as u32;
        self.delay_ewma[i] = h.queue_delay_ewma();
    }

    /// Read-only escape hatch (diagnostics/tests).
    pub fn host(&self, i: usize) -> &HostCapacity {
        &self.hosts[i]
    }

    #[inline]
    pub fn slots(&self, i: usize) -> u32 {
        self.slots[i]
    }

    #[inline]
    pub fn used(&self, i: usize) -> u32 {
        self.used[i]
    }

    /// Slots free right now (saturating, like [`HostCapacity::free`]).
    #[inline]
    pub fn free(&self, i: usize) -> u32 {
        self.slots[i].saturating_sub(self.used[i])
    }

    #[inline]
    pub fn can_start(&self, i: usize, demand: u32) -> bool {
        demand <= self.free(i)
    }

    #[inline]
    pub fn queue_len(&self, i: usize) -> usize {
        self.queue_depth[i] as usize
    }

    pub fn queue_has_room(&self, i: usize) -> bool {
        self.hosts[i].queue_has_room()
    }

    /// Running jobs on `i` in start order (newest last).
    pub fn running(&self, i: usize) -> &[(JobId, u32)] {
        self.hosts[i].running()
    }

    /// The structured probe answer, served entirely from the mirror
    /// arrays — field-for-field identical to `host.probe(signal_raised)`.
    #[inline]
    pub fn probe(&self, i: usize, signal_raised: bool) -> AdmissionProbe {
        AdmissionProbe {
            signal_raised,
            free_slots: self.free(i),
            queue_depth: self.queue_depth[i] as usize,
            queue_delay_ewma: self.delay_ewma[i],
        }
    }

    pub fn start(&mut self, i: usize, job_id: JobId, demand: u32) {
        self.hosts[i].start(job_id, demand);
        self.sync(i);
    }

    pub fn finish(&mut self, i: usize, job_id: JobId) -> Option<u32> {
        let freed = self.hosts[i].finish(job_id);
        self.sync(i);
        freed
    }

    pub fn try_enqueue(
        &mut self,
        i: usize,
        job_id: JobId,
        demand: u32,
        priority: Priority,
        now: u64,
    ) -> bool {
        let ok = self.hosts[i].try_enqueue(job_id, demand, priority, now);
        self.sync(i);
        ok
    }

    pub fn pop_startable(&mut self, i: usize, budget: u32) -> Option<QueuedJob> {
        let qj = self.hosts[i].pop_startable(budget);
        self.sync(i);
        qj
    }

    pub fn note_queue_delay(&mut self, i: usize, delay_ticks: u64) {
        self.hosts[i].note_queue_delay(delay_ticks);
        self.sync(i);
    }

    pub fn evacuate(&mut self, i: usize) -> (Vec<(JobId, u32)>, Vec<QueuedJob>) {
        let out = self.hosts[i].evacuate();
        self.sync(i);
        out
    }

    /// Reset node `i`'s queue-delay telemetry (rejoin after an outage);
    /// see [`HostCapacity::reset_telemetry`].
    pub fn reset_telemetry(&mut self, i: usize) {
        self.hosts[i].reset_telemetry();
        self.sync(i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::QueuePolicy;

    fn assert_invariants(f: &FleetState) {
        let mut expect: Vec<usize> =
            (0..f.len()).filter(|&i| f.is_alive(i)).collect();
        expect.sort_unstable();
        assert_eq!(f.alive_ids(), expect.as_slice(), "alive_ids out of sync");
        for (rank, &id) in f.alive_ids().iter().enumerate() {
            assert_eq!(f.pos[id] as usize, rank, "pos map wrong for id {id}");
        }
        for i in 0..f.len() {
            if !f.is_alive(i) {
                assert_eq!(f.pos[i], NOT_ALIVE, "dead id {i} still ranked");
            }
        }
        assert_eq!(f.alive_count(), expect.len());
    }

    #[test]
    fn leave_join_keep_the_index_map_dense_and_sorted() {
        let mut f = FleetState::new(8);
        assert_invariants(&f);
        assert!(f.leave(3));
        assert!(!f.leave(3), "double leave must be a no-op");
        assert_invariants(&f);
        assert!(f.leave(0));
        assert!(f.leave(7));
        assert_invariants(&f);
        assert!(f.join(3));
        assert!(!f.join(3), "double join must be a no-op");
        assert_invariants(&f);
        assert!(f.join(0));
        assert!(f.join(7));
        assert_invariants(&f);
        assert_eq!(f.alive_ids(), (0..8).collect::<Vec<_>>().as_slice());
    }

    #[test]
    fn mass_churn_stress_keeps_invariants() {
        // Deterministic pseudo-random churn over a mid-sized fleet: the
        // dense map must survive arbitrary interleavings.
        let n = 257;
        let mut f = FleetState::new(n);
        let mut x = 0x9E3779B97F4A7C15u64;
        for _ in 0..4_000 {
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let node = (x >> 16) as usize % n;
            if x & 1 == 0 {
                f.leave(node);
            } else {
                f.join(node);
            }
        }
        assert_invariants(&f);
        for i in 0..n {
            f.join(i);
        }
        assert_invariants(&f);
        assert_eq!(f.alive_count(), n);
    }

    #[test]
    fn rr_probe_rotates_identity_order_and_survives_churn() {
        let mut f = FleetState::new(4);
        let first: Vec<usize> = (0..8).map(|_| f.rr_probe().unwrap()).collect();
        assert_eq!(first, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        f.leave(1);
        f.leave(2);
        let after: Vec<usize> = (0..4).map(|_| f.rr_probe().unwrap()).collect();
        assert_eq!(after, vec![0, 3, 0, 3], "rotation re-aliased under churn");
        f.join(2);
        let back: Vec<usize> = (0..3).map(|_| f.rr_probe().unwrap()).collect();
        // Cursor sat past 3 (wrap): next is 0, then 2, then 3.
        assert_eq!(back, vec![0, 2, 3]);
        f.leave(0);
        f.leave(2);
        f.leave(3);
        assert_eq!(f.alive_count(), 0);
        assert_eq!(f.rr_probe(), None);
    }

    #[test]
    fn host_table_mirrors_track_every_mutation() {
        let hosts: Vec<HostCapacity> =
            (0..3).map(|_| HostCapacity::new(4, 2, QueuePolicy::Fifo)).collect();
        let mut t = HostTable::new(hosts);
        let check = |t: &HostTable| {
            for i in 0..t.len() {
                let h = t.host(i);
                assert_eq!(t.slots(i), h.slots());
                assert_eq!(t.used(i), h.used());
                assert_eq!(t.free(i), h.free());
                assert_eq!(t.queue_len(i), h.queue_len());
                let (a, b) = (t.probe(i, false), h.probe(false));
                assert_eq!(a.free_slots, b.free_slots);
                assert_eq!(a.queue_depth, b.queue_depth);
                assert_eq!(a.queue_delay_ewma, b.queue_delay_ewma);
            }
        };
        check(&t);
        t.start(0, 1, 3);
        assert!(t.can_start(0, 1) && !t.can_start(0, 2));
        check(&t);
        assert!(t.try_enqueue(0, 2, 2, 0, 10));
        assert!(t.try_enqueue(0, 3, 1, 0, 11));
        assert!(!t.try_enqueue(0, 4, 1, 0, 12), "bounded queue overflowed");
        check(&t);
        assert_eq!(t.finish(0, 1), Some(3));
        check(&t);
        let qj = t.pop_startable(0, 4).expect("queued job fits now");
        assert_eq!(qj.job_id, 2);
        t.start(0, qj.job_id, qj.demand);
        t.note_queue_delay(0, 250);
        check(&t);
        let (running, queued) = t.evacuate(0);
        assert_eq!(running.len(), 1);
        assert_eq!(queued.len(), 1);
        check(&t);
        assert_eq!(t.used(0), 0);
        assert_eq!(t.queue_len(0), 0);
        // The delay mirror tracks the rejoin telemetry reset too.
        assert!(t.probe(0, false).queue_delay_ewma > 0.0);
        t.reset_telemetry(0);
        check(&t);
        assert_eq!(t.probe(0, false).queue_delay_ewma, 0.0);
    }
}
