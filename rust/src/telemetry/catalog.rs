//! VMware vSphere metric catalog.
//!
//! The paper's dataset has "134 different resource metrics for a typical ESX
//! host … and 52 metrics for a VM", emitted every 20 seconds. We reproduce
//! the vocabulary with the standard vSphere counter names (group.counter
//! convention) so traces read like real exports. Index 0 is always
//! `cpu.ready` — the headline metric.

/// Number of per-VM metrics (paper §3).
pub const VM_DIM: usize = 52;

/// Number of per-host metrics (paper §3).
pub const HOST_DIM: usize = 134;

/// Sampling cadence of the trace, seconds (paper §3: every 20 s).
pub const SAMPLE_PERIOD_SECS: u64 = 20;

/// CPU Ready is reported in milliseconds of ready-but-unscheduled time per
/// 20 000 ms sampling period (paper Figure 1 caption).
pub const SAMPLE_PERIOD_MS: f64 = 20_000.0;

/// Index of `cpu.ready` within the VM metric vector.
pub const CPU_READY_IDX: usize = 0;

/// The 52 per-VM counters. Order is the feature order of every VM vector.
pub fn vm_metric_names() -> Vec<&'static str> {
    vec![
        // CPU (13)
        "cpu.ready",
        "cpu.usage.average",
        "cpu.usagemhz.average",
        "cpu.wait",
        "cpu.idle",
        "cpu.used",
        "cpu.system",
        "cpu.costop",
        "cpu.demand",
        "cpu.entitlement",
        "cpu.latency",
        "cpu.maxlimited",
        "cpu.overlap",
        // Memory (15)
        "mem.usage.average",
        "mem.granted",
        "mem.active",
        "mem.shared",
        "mem.zero",
        "mem.swapped",
        "mem.swaptarget",
        "mem.swapin",
        "mem.swapout",
        "mem.vmmemctl",
        "mem.consumed",
        "mem.overhead",
        "mem.compressed",
        "mem.compressionRate",
        "mem.latency",
        // Disk (12)
        "disk.usage.average",
        "disk.read",
        "disk.write",
        "disk.numberRead",
        "disk.numberWrite",
        "disk.commandsAborted",
        "disk.busResets",
        "disk.totalLatency",
        "disk.maxTotalLatency",
        "disk.queueLatency",
        "disk.kernelLatency",
        "disk.deviceLatency",
        // Network (8)
        "net.usage.average",
        "net.received",
        "net.transmitted",
        "net.packetsRx",
        "net.packetsTx",
        "net.droppedRx",
        "net.droppedTx",
        "net.errorsRx",
        // System / power (4)
        "sys.uptime",
        "sys.heartbeat",
        "power.power",
        "rescpu.actav1",
    ]
}

/// The 134 per-host counters: the VM set plus host-only groups
/// (datastore, storageAdapter, storagePath, hbr, vflash, per-core cpu).
pub fn host_metric_names() -> Vec<String> {
    let mut names: Vec<String> = vm_metric_names().iter().map(|s| s.to_string()).collect();
    for g in [
        "datastore.read",
        "datastore.write",
        "datastore.numberReadAveraged",
        "datastore.numberWriteAveraged",
        "datastore.totalReadLatency",
        "datastore.totalWriteLatency",
        "datastore.maxQueueDepth",
        "storageAdapter.read",
        "storageAdapter.write",
        "storageAdapter.commandsAveraged",
        "storagePath.read",
        "storagePath.write",
        "storagePath.commandsAveraged",
        "hbr.hbrNumVms",
        "hbr.hbrNetRx",
        "hbr.hbrNetTx",
        "vflash.numActiveVMDKs",
        "mem.heap",
        "mem.heapfree",
        "mem.reservedCapacity",
        "mem.totalCapacity",
        "mem.state",
        "mem.unreserved",
        "mem.sysUsage",
        "cpu.coreUtilization",
        "cpu.utilization",
        "cpu.reservedCapacity",
        "cpu.totalCapacity",
        "net.bytesRx",
        "net.bytesTx",
        "net.broadcastRx",
        "net.broadcastTx",
        "net.multicastRx",
        "net.multicastTx",
        "disk.maxQueueDepth",
        "disk.commands",
        "sys.resourceCpuUsage",
        "sys.resourceMemConsumed",
        "power.powerCap",
        "power.energy",
    ] {
        names.push(g.to_string());
    }
    // Per-core utilization counters to reach the documented 134.
    let mut core = 0usize;
    while names.len() < HOST_DIM {
        names.push(format!("cpu.coreUtilization.{core}"));
        core += 1;
    }
    names.truncate(HOST_DIM);
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vm_catalog_has_52_unique_metrics() {
        let names = vm_metric_names();
        assert_eq!(names.len(), VM_DIM);
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), VM_DIM, "duplicate metric names");
    }

    #[test]
    fn host_catalog_has_134_unique_metrics() {
        let names = host_metric_names();
        assert_eq!(names.len(), HOST_DIM);
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), HOST_DIM, "duplicate metric names");
    }

    #[test]
    fn cpu_ready_is_index_zero() {
        assert_eq!(vm_metric_names()[CPU_READY_IDX], "cpu.ready");
    }
}
