//! SPIRIT (Streaming Pattern dIscoveRy in multIple Time-series),
//! Papadimitriou, Sun & Faloutsos, VLDB 2005.
//!
//! Tracks k "hidden variables" (principal directions) with a PAST-style
//! recursive least-squares update per observation and adapts k from the
//! ratio of captured to total energy. SPIRIT maintains per-direction energy
//! estimates `d_i` from which approximate singular values can be derived —
//! the paper notes SPIRIT is the only baseline that produces a (guarantee-
//! free) spectrum, which is why it partially supports PRONTO's weighting.

use super::StreamingEmbedding;
use crate::fpca::Subspace;
use crate::linalg::Mat;

/// SPIRIT configuration.
#[derive(Debug, Clone, Copy)]
pub struct SpiritConfig {
    /// Initial number of hidden variables.
    pub initial_rank: usize,
    /// Maximum tracked rank.
    pub max_rank: usize,
    /// Exponential forgetting factor λ ∈ (0, 1]; the paper's recommended
    /// default is 0.96.
    pub lambda: f64,
    /// Energy thresholds (f_E, F_E): grow k when captured/total energy
    /// drops below `low`, shrink when above `high`.
    pub energy_low: f64,
    pub energy_high: f64,
}

impl Default for SpiritConfig {
    fn default() -> Self {
        Self {
            initial_rank: 4,
            max_rank: 8,
            lambda: 0.96,
            energy_low: 0.95,
            energy_high: 0.98,
        }
    }
}

/// Streaming SPIRIT tracker.
#[derive(Debug, Clone)]
pub struct Spirit {
    cfg: SpiritConfig,
    d: usize,
    k: usize,
    /// Hidden-variable directions (columns, approximately orthonormal).
    w: Mat,
    /// Per-direction energy estimates d_i (RLS gain denominators).
    di: Vec<f64>,
    /// Exponentially weighted total input energy.
    total_energy: f64,
    /// Exponentially weighted captured energy.
    captured_energy: f64,
    /// Observations seen.
    seen: usize,
}

impl Spirit {
    pub fn new(d: usize, cfg: SpiritConfig) -> Self {
        assert!(cfg.initial_rank >= 1 && cfg.initial_rank <= cfg.max_rank);
        assert!(cfg.max_rank <= d);
        assert!(cfg.lambda > 0.0 && cfg.lambda <= 1.0);
        assert!(cfg.energy_low < cfg.energy_high);
        let mut w = Mat::zeros(d, cfg.max_rank);
        // Canonical initialization, as in the reference implementation.
        for j in 0..cfg.max_rank {
            w.set(j % d, j, 1.0);
        }
        Self {
            cfg,
            d,
            k: cfg.initial_rank,
            w,
            di: vec![1e-3; cfg.max_rank],
            total_energy: 0.0,
            captured_energy: 0.0,
            seen: 0,
        }
    }

    /// The TrackW update: deflate the observation through each hidden
    /// variable in turn, updating direction and energy.
    fn track_w(&mut self, y: &[f64]) {
        let lambda = self.cfg.lambda;
        let mut x: Vec<f64> = y.to_vec();
        let mut captured = 0.0;
        for j in 0..self.k {
            // Projection onto current direction.
            let mut yj = 0.0;
            for i in 0..self.d {
                yj += self.w.get(i, j) * x[i];
            }
            self.di[j] = lambda * self.di[j] + yj * yj;
            // Per-coordinate error and gradient-style direction update.
            let gain = yj / self.di[j].max(1e-12);
            for i in 0..self.d {
                let e = x[i] - yj * self.w.get(i, j);
                let wij = self.w.get(i, j) + gain * e;
                self.w.set(i, j, wij);
            }
            // Normalize immediately: deflation must use a unit direction or
            // the captured energy (and the residual) blows up.
            let n: f64 = (0..self.d).map(|i| self.w.get(i, j).powi(2)).sum::<f64>().sqrt();
            if n > 0.0 {
                for i in 0..self.d {
                    self.w.set(i, j, self.w.get(i, j) / n);
                }
            }
            // Re-project with the *updated, normalized* direction; deflate.
            let mut yj2 = 0.0;
            for i in 0..self.d {
                yj2 += self.w.get(i, j) * x[i];
            }
            for i in 0..self.d {
                x[i] -= yj2 * self.w.get(i, j);
            }
            captured += yj2 * yj2;
        }

        let input_energy: f64 = y.iter().map(|v| v * v).sum();
        self.total_energy = lambda * self.total_energy + input_energy;
        self.captured_energy = lambda * self.captured_energy + captured;
    }

    /// Energy-ratio rank adaptation (the paper's f_E/F_E rule).
    fn adapt_rank(&mut self) {
        if self.total_energy <= 0.0 || self.seen < 2 * self.d {
            return;
        }
        let ratio = self.captured_energy / self.total_energy;
        if ratio < self.cfg.energy_low && self.k < self.cfg.max_rank {
            self.k += 1;
            self.di[self.k - 1] = 1e-3;
            // Fresh canonical direction, orthogonalized against current W.
            let pivot = (self.seen + self.k) % self.d;
            let mut v = vec![0.0; self.d];
            v[pivot] = 1.0;
            for j in 0..self.k - 1 {
                let dot: f64 = (0..self.d).map(|i| v[i] * self.w.get(i, j)).sum();
                for i in 0..self.d {
                    v[i] -= dot * self.w.get(i, j);
                }
            }
            let n: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
            for (i, vi) in v.iter().enumerate() {
                self.w.set(i, self.k - 1, if n > 1e-9 { vi / n } else { 0.0 });
            }
        } else if ratio > self.cfg.energy_high && self.k > 1 {
            self.k -= 1;
        }
    }

    /// Current captured/total energy ratio (diagnostics + tests).
    pub fn energy_ratio(&self) -> f64 {
        if self.total_energy <= 0.0 {
            return 0.0;
        }
        self.captured_energy / self.total_energy
    }

    /// Approximate singular values from the RLS energies: d_i accumulates
    /// λ-discounted squared projections, so σ_i ≈ sqrt(d_i).
    fn sigma(&self) -> Vec<f64> {
        let mut s: Vec<f64> = self.di[..self.k].iter().map(|&d| d.max(0.0).sqrt()).collect();
        s.sort_by(|a, b| b.partial_cmp(a).unwrap());
        s
    }
}

impl StreamingEmbedding for Spirit {
    fn observe(&mut self, y: &[f64]) {
        assert_eq!(y.len(), self.d);
        self.track_w(y);
        self.seen += 1;
        self.adapt_rank();
    }

    fn estimate(&self) -> Subspace {
        if self.seen < self.cfg.initial_rank {
            return Subspace::empty(self.d);
        }
        Subspace::new(self.w.take_cols(self.k), self.sigma())
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn rank(&self) -> usize {
        self.k
    }

    fn name(&self) -> &'static str {
        "SP"
    }

    fn has_spectrum(&self) -> bool {
        true // approximate, without quality guarantees (paper §7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest::{forall, gen_low_rank};
    use crate::rng::Xoshiro256;

    #[test]
    fn directions_stay_normalized() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut sp = Spirit::new(10, SpiritConfig::default());
        for _ in 0..500 {
            let y: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
            sp.observe(&y);
        }
        let est = sp.estimate();
        for j in 0..est.rank() {
            let n: f64 = est.u.col(j).iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((n - 1.0).abs() < 1e-6, "col {j} norm {n}");
        }
    }

    #[test]
    fn recovers_dominant_direction() {
        forall("spirit finds top PC", |rng| {
            let d = 8 + rng.gen_range(16);
            let data = gen_low_rank(rng, d, 800, 1, 0.05);
            let mut sp = Spirit::new(d, SpiritConfig { initial_rank: 2, ..Default::default() });
            for t in 0..data.cols() {
                sp.observe(data.col(t));
            }
            let truth = crate::linalg::svd_truncated(&data, 1);
            let w0 = sp.estimate();
            // |cos| between tracked direction 0 and true PC1.
            let dot: f64 = (0..d).map(|i| w0.u.get(i, 0) * truth.u.get(i, 0)).sum();
            if dot.abs() > 0.9 {
                Ok(())
            } else {
                Err(format!("|cos|={}", dot.abs()))
            }
        });
    }

    #[test]
    fn rank_grows_for_rich_signal() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let d = 16;
        let data = gen_low_rank(&mut rng, d, 1500, 6, 0.02);
        let mut sp = Spirit::new(
            d,
            SpiritConfig { initial_rank: 1, max_rank: 8, ..Default::default() },
        );
        for t in 0..data.cols() {
            sp.observe(data.col(t));
        }
        assert!(sp.rank() > 1, "rank stayed {}", sp.rank());
    }

    #[test]
    fn sigma_is_descending() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut sp = Spirit::new(12, SpiritConfig::default());
        for _ in 0..300 {
            let y: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
            sp.observe(&y);
        }
        let s = sp.estimate().sigma;
        assert!(s.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn empty_before_warmup() {
        let sp = Spirit::new(12, SpiritConfig::default());
        assert!(sp.estimate().is_empty());
    }
}
