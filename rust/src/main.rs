//! `pronto` CLI entrypoint (subcommands filled in by cli module).
fn main() {
    pronto::cli::main();
}
