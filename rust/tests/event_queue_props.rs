//! Property-based tests for the deterministic event queue, in the style
//! of `linalg_props.rs`: seeded, replayable via `PRONTO_PROP_SEED` /
//! `PRONTO_PROP_CASES`.
//!
//! The invariants under test are exactly what the engine's
//! bit-reproducibility rests on: pops are globally ordered by
//! `(time, seq)`, same-time events preserve schedule order (FIFO), and
//! the step/tick conversions round-trip.

use pronto::proptest::forall;
use pronto::sim::{
    latency_to_ticks, step_to_ticks, ticks_to_step, Event, EventQueue, SimTime, TICKS_PER_STEP,
};

/// Tag each scheduled event with its insertion index so the pop sequence
/// can be compared against a reference model.
fn tagged(node: usize) -> Event {
    Event::NodeJoin { node }
}

fn untag(e: Event) -> usize {
    match e {
        Event::NodeJoin { node } => node,
        other => panic!("unexpected event {other:?}"),
    }
}

#[test]
fn pops_match_a_stable_sort_by_time_then_schedule_order() {
    forall("EventQueue ≡ stable sort by (time, insertion)", |rng| {
        let n = 1 + rng.gen_range(300);
        let mut q = EventQueue::with_capacity(n);
        let mut model: Vec<(SimTime, usize)> = Vec::with_capacity(n);
        for i in 0..n {
            // Small time range forces plenty of ties.
            let t = rng.gen_range(40) as SimTime;
            q.schedule(t, tagged(i));
            model.push((t, i));
        }
        // Reference: stable sort by time keeps insertion order on ties;
        // sorting the (time, index) pairs is the same thing.
        model.sort();
        let mut popped = Vec::with_capacity(n);
        while let Some(s) = q.pop() {
            let idx = untag(s.event);
            if s.time != model.iter().find(|&&(_, i)| i == idx).unwrap().0 {
                return Err(format!("event {idx} popped with a mutated time {}", s.time));
            }
            popped.push((s.time, idx));
        }
        if popped.len() != n {
            return Err(format!("popped {} of {n} events", popped.len()));
        }
        if popped != model {
            return Err("pop order diverged from stable (time, seq) sort".into());
        }
        Ok(())
    });
}

#[test]
fn pops_are_globally_ordered_under_interleaved_scheduling() {
    forall("interleaved schedule/pop keeps (time, seq) order", |rng| {
        let mut q = EventQueue::with_capacity(64);
        let rounds = 1 + rng.gen_range(20);
        let mut next_tag = 0usize;
        let mut tag_time: Vec<SimTime> = Vec::new();
        let mut last: Option<(SimTime, usize)> = None;
        let mut popped = 0usize;
        // Clock floor: new events may never be scheduled before the last
        // pop (the engine only schedules at or after `now`), otherwise
        // global pop ordering is unachievable by construction.
        let mut floor: SimTime = 0;
        for _ in 0..rounds {
            for _ in 0..(1 + rng.gen_range(10)) {
                let t = floor + rng.gen_range(30) as SimTime;
                q.schedule(t, tagged(next_tag));
                tag_time.push(t);
                next_tag += 1;
            }
            for _ in 0..rng.gen_range(8) {
                let Some(s) = q.pop() else { break };
                popped += 1;
                let idx = untag(s.event);
                if s.time != tag_time[idx] {
                    return Err(format!("tag {idx}: time {} != scheduled {}", s.time, tag_time[idx]));
                }
                if let Some((lt, lidx)) = last {
                    if s.time < lt {
                        return Err(format!("time went backwards: {} after {lt}", s.time));
                    }
                    if s.time == lt && idx < lidx {
                        return Err(format!(
                            "same-time FIFO violated: tag {idx} after {lidx} at t={lt}"
                        ));
                    }
                }
                floor = s.time;
                last = Some((s.time, idx));
            }
        }
        // Drain the rest; the invariant must hold to the end.
        while let Some(s) = q.pop() {
            popped += 1;
            let idx = untag(s.event);
            if let Some((lt, lidx)) = last {
                if s.time < lt || (s.time == lt && idx < lidx) {
                    return Err(format!("drain violated order at tag {idx}"));
                }
            }
            last = Some((s.time, idx));
        }
        if popped != next_tag {
            return Err(format!("lost events: {popped} of {next_tag}"));
        }
        Ok(())
    });
}

#[test]
fn same_time_events_pop_in_schedule_order_exactly() {
    forall("equal timestamps drain FIFO", |rng| {
        let mut q = EventQueue::with_capacity(64);
        let t = rng.gen_range(1_000) as SimTime;
        let n = 2 + rng.gen_range(100);
        for i in 0..n {
            q.schedule(t, tagged(i));
        }
        for want in 0..n {
            let s = q.pop().ok_or("queue drained early")?;
            if s.time != t {
                return Err(format!("time changed: {}", s.time));
            }
            let got = untag(s.event);
            if got != want {
                return Err(format!("FIFO broken: got {got}, want {want}"));
            }
        }
        if !q.is_empty() {
            return Err("queue not empty after draining".into());
        }
        Ok(())
    });
}

#[test]
fn step_tick_conversions_roundtrip_for_arbitrary_steps() {
    forall("step↔tick round-trip", |rng| {
        // Any step a realistic run could reach (u64 ticks cap the step
        // space at 2^64 / TICKS_PER_STEP; stay well inside).
        let step = rng.gen_range(1 << 40);
        let base = step_to_ticks(step);
        if ticks_to_step(base) != step {
            return Err(format!("step {step}: base tick maps to {}", ticks_to_step(base)));
        }
        // Every tick within the step maps back to it…
        let off = rng.gen_range(TICKS_PER_STEP as usize) as SimTime;
        if ticks_to_step(base + off) != step {
            return Err(format!("step {step} + {off} ticks leaked to another step"));
        }
        // …and the first tick past it does not.
        if ticks_to_step(base + TICKS_PER_STEP) != step + 1 {
            return Err("step boundary off by one".into());
        }
        Ok(())
    });
}

#[test]
fn latency_to_ticks_is_monotone_and_never_zero() {
    forall("latency_to_ticks: floor 1, monotone, exact on whole steps", |rng| {
        let a = rng.next_f64() * 50.0;
        let b = rng.next_f64() * 50.0;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let (tl, th) = (latency_to_ticks(lo), latency_to_ticks(hi));
        if tl == 0 || th == 0 {
            return Err("a delayed event may never tie its cause (zero ticks)".into());
        }
        if tl > th {
            return Err(format!("monotonicity broken: {lo}->{tl}, {hi}->{th}"));
        }
        let k = 1 + rng.gen_range(100) as u64;
        if latency_to_ticks(k as f64) != k * TICKS_PER_STEP {
            return Err(format!("whole-step latency {k} not exact"));
        }
        if latency_to_ticks(-1.0) != 1 {
            return Err("negative latency must clamp to the 1-tick floor".into());
        }
        Ok(())
    });
}
