//! Determinism regressions: the engine must be bit-reproducible given a
//! seed, and the two federation runtimes must agree on the merged view.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::federation::{
    ConcurrentFederation, FederationTree, LatencyModel, TreeTopology,
};
use pronto::linalg::subspace_distance;
use pronto::scheduler::{Admission, NodeScheduler, ProntoPolicy, RejectConfig};
use pronto::sim::{DiscreteEventEngine, Scenario, CATALOG};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 4, v, steps)).collect()
}

fn pronto_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
    tr.iter()
        .map(|t| {
            Box::new(ProntoPolicy::new(NodeScheduler::new(
                t.dim(),
                RejectConfig::default(),
            ))) as Box<dyn Admission>
        })
        .collect()
}

#[test]
fn same_seed_same_scenario_identical_reports() {
    // Byte-identical JSON across two fresh engine runs, for every named
    // scenario — the acceptance criterion of the scenario work.
    for name in CATALOG {
        let scenario = Scenario::named(name)
            .unwrap()
            .with_nodes(6)
            .with_steps(1_200)
            .with_seed(0xDECAF);
        let tr = fleet(6, 1_200, 17);
        let a = DiscreteEventEngine::new(scenario.clone(), tr.clone(), pronto_policies(&tr))
            .run();
        let b = DiscreteEventEngine::new(scenario, tr.clone(), pronto_policies(&tr)).run();
        assert_eq!(
            a.to_json_string(),
            b.to_json_string(),
            "scenario '{name}' not reproducible"
        );
        assert_eq!(a.outcomes, b.outcomes, "scenario '{name}' outcome drift");
    }
}

#[test]
fn capacity_preemption_replay_are_byte_identical_per_seed() {
    // The new event machinery (enqueue/start/preempt/migrate, trace
    // replay) must be exactly as reproducible as the original engine:
    // same seed ⇒ identical outcome digest and byte-identical JSON.
    for name in ["capacity", "preemption", "replay"] {
        let scenario = Scenario::named(name)
            .unwrap()
            .with_nodes(6)
            .with_steps(1_500)
            .with_seed(0xBEEF);
        let tr = fleet(6, 1_500, 19);
        let d = tr[0].dim();
        let run = || {
            let mut engine = DiscreteEventEngine::new(
                scenario.clone(),
                tr.clone(),
                pronto_policies(&tr),
            );
            if scenario.churn.is_some() {
                engine = engine.with_policy_factory(Box::new(move |_| {
                    Box::new(ProntoPolicy::new(NodeScheduler::new(
                        d,
                        RejectConfig::default(),
                    ))) as Box<dyn Admission>
                }));
            }
            engine.run()
        };
        let a = run();
        let b = run();
        assert_eq!(
            a.outcomes_digest(),
            b.outcomes_digest(),
            "scenario '{name}' outcome digest drifted"
        );
        assert_eq!(
            a.to_json_string(),
            b.to_json_string(),
            "scenario '{name}' JSON not byte-identical"
        );
        assert_eq!(a.jobs_preempted, b.jobs_preempted);
        assert_eq!(a.jobs_migrated, b.jobs_migrated);
        assert_eq!(a.jobs_queued, b.jobs_queued);
    }
}

#[test]
fn round_robin_probe_on_churn_scenario_is_byte_identical() {
    // Satellite regression for the identity-tracked round-robin cursor:
    // the `churn` catalog entry with `probe = round-robin` must stay
    // bit-reproducible across runs (the cursor advances by node id, so
    // leaves/joins shift nothing that isn't supposed to shift).
    let mut scenario = Scenario::named("churn")
        .unwrap()
        .with_nodes(6)
        .with_steps(1_500)
        .with_seed(0xC0FFEE);
    scenario.probe = pronto::sim::ProbePolicy::RoundRobin;
    let tr = fleet(6, 1_500, 13);
    let d = tr[0].dim();
    let run = || {
        DiscreteEventEngine::new(scenario.clone(), tr.clone(), pronto_policies(&tr))
            .with_policy_factory(Box::new(move |_| {
                Box::new(ProntoPolicy::new(NodeScheduler::new(
                    d,
                    RejectConfig::default(),
                ))) as Box<dyn Admission>
            }))
            .run()
    };
    let a = run();
    let b = run();
    assert_eq!(a.to_json_string(), b.to_json_string());
    assert_eq!(a.outcomes, b.outcomes);
}

#[test]
fn seed_change_changes_outcomes() {
    let tr = fleet(4, 800, 23);
    let a = DiscreteEventEngine::new(
        Scenario::default().with_steps(800).with_seed(1),
        tr.clone(),
        pronto_policies(&tr),
    )
    .run();
    let b = DiscreteEventEngine::new(
        Scenario::default().with_steps(800).with_seed(2),
        tr.clone(),
        pronto_policies(&tr),
    )
    .run();
    assert_ne!(a.outcomes_digest(), b.outcomes_digest());
}

#[test]
fn tree_and_concurrent_federation_agree_within_tolerance() {
    // Same traces through the single-threaded tree (manual drive) and the
    // thread-per-leaf runtime: the merged global views must describe the
    // same dominant subspace, within merge-order tolerance.
    let n = 8;
    let steps = 1_024;
    let traces = fleet(n, steps, 29);
    let d = traces[0].dim();
    let rank = 4;

    let mut tree = FederationTree::new(TreeTopology::new(n, 4), d, rank, 0.0);
    for (leaf, tr) in traces.iter().enumerate() {
        let mut node = NodeScheduler::new(d, RejectConfig::default());
        for t in 0..steps {
            node.observe(tr.features(t));
        }
        tree.push_from_leaf(leaf, &node.estimate());
    }

    let report = ConcurrentFederation::new(TreeTopology::new(n, 4), rank, 0.0)
        .with_push_every(steps)
        .run(traces);

    let g_tree = tree.global_view();
    let g_conc = &report.global_view;
    assert_eq!(g_tree.rank(), rank);
    assert_eq!(g_conc.rank(), rank);
    // Dominant directions agree.
    let dist = subspace_distance(&g_tree.truncate(2).u, &g_conc.truncate(2).u);
    assert!(dist < 0.35, "federation runtimes diverged: distance {dist}");
    // Energy scales agree.
    let ratio = g_tree.sigma[0] / g_conc.sigma[0];
    assert!((0.5..2.0).contains(&ratio), "sigma ratio {ratio}");
}

#[test]
fn concurrent_federation_latency_is_deterministic_per_leaf() {
    // The latency stream must not depend on thread scheduling: two runs
    // with the same seed drop the same number of late pushes and deliver
    // the same number of pushes.
    let run = || {
        ConcurrentFederation::new(TreeTopology::new(4, 4), 4, 0.0)
            .with_push_every(32)
            .with_latency(LatencyModel::Exponential { mean_steps: 24.0 }, 99)
            .run(fleet(4, 512, 37))
    };
    let a = run();
    let b = run();
    assert_eq!(a.pushes, b.pushes);
    assert_eq!(a.suppressed, b.suppressed);
    assert_eq!(a.late_drops, b.late_drops);
}
