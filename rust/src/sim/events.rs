//! Typed simulation events and the deterministic event queue.
//!
//! The queue is a binary min-heap ordered by `(time, seq)`: `time` is a
//! fixed-point tick count ([`TICKS_PER_STEP`] ticks per 20 s telemetry
//! step, so sub-step latencies order correctly without floating-point
//! comparisons) and `seq` is a monotone insertion counter that breaks ties
//! deterministically — two runs that schedule the same events in the same
//! order pop them in the same order, which is what makes reports
//! bit-reproducible. Event payloads are small `Copy` data; anything large
//! (federation subspace snapshots) lives in a pooled slab on the engine
//! side and is referenced here by index, keeping the hot loop free of
//! per-event allocation.

use crate::scheduler::JobId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation clock: integer ticks.
pub type SimTime = u64;

/// Ticks per telemetry step (20 s of simulated wall time).
pub const TICKS_PER_STEP: u64 = 1_000;

/// Convert a step index to its tick timestamp.
#[inline]
pub fn step_to_ticks(step: usize) -> SimTime {
    step as u64 * TICKS_PER_STEP
}

/// Convert a tick timestamp to the telemetry step it falls in.
#[inline]
pub fn ticks_to_step(t: SimTime) -> usize {
    (t / TICKS_PER_STEP) as usize
}

/// Convert a latency in (possibly fractional) steps to whole ticks,
/// always at least one tick so a delayed event never ties its cause.
#[inline]
pub fn latency_to_ticks(steps: f64) -> u64 {
    ((steps.max(0.0) * TICKS_PER_STEP as f64).round() as u64).max(1)
}

/// Everything that can happen in the cluster.
///
/// Job lifecycle events carry `gen` — the job's *placement generation*,
/// bumped every time the job is displaced or re-placed. A handler ignores
/// an event whose generation no longer matches the job's, which makes
/// stale events (a completion for a job that was preempted in between, a
/// preemption for a job that already finished) safe no-ops instead of
/// double bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// All alive nodes consume their telemetry vector for `step`.
    TelemetryTick { step: usize },
    /// A job arrives at the dispatcher (demand/duration live in the
    /// engine's job table).
    JobArrival { job_id: JobId },
    /// A job admitted by `node` is handed to the host: it either starts,
    /// parks in the bounded wait queue, or is dropped when the queue is
    /// full.
    JobEnqueue { node: usize, job_id: JobId },
    /// A job begins service on `node` (slots were reserved when the start
    /// was scheduled).
    JobStart { node: usize, job_id: JobId, gen: u32 },
    /// A previously started job finishes on `node`.
    JobCompletion { node: usize, job_id: JobId, gen: u32 },
    /// An over-committed node sheds a running job (pressure preemption:
    /// the rejection signal is raised and usage exceeds the contended
    /// budget).
    JobPreempt { node: usize, job_id: JobId, gen: u32 },
    /// A displaced job is re-offered to peers; `from` (the node that shed
    /// it) is excluded from the probe.
    JobMigrate { job_id: JobId, from: usize },
    /// A leaf's iterate snapshot (pooled at `snapshot`) reaches its
    /// aggregator after the configured push latency.
    FederationPush { leaf: usize, snapshot: usize, sent_at: SimTime },
    /// A node joins (or rejoins) the pool.
    NodeJoin { node: usize },
    /// A node leaves the pool; its in-flight jobs are displaced.
    NodeLeave { node: usize },
}

/// An event bound to a point on the simulation clock.
#[derive(Debug, Clone, Copy)]
pub struct Scheduled {
    pub time: SimTime,
    seq: u64,
    pub event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    /// Reverse ordering so `BinaryHeap` (a max-heap) pops the earliest
    /// `(time, seq)` first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    next_seq: u64,
    scheduled_total: usize,
}

impl EventQueue {
    /// Queue with pre-reserved capacity (the engine sizes this from the
    /// scenario so steady-state operation never reallocates).
    pub fn with_capacity(cap: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(cap), next_seq: 0, scheduled_total: 0 }
    }

    /// Schedule `event` at `time`. Events at equal times fire in
    /// scheduling order (FIFO) — the insertion counter breaks the tie.
    pub fn schedule(&mut self, time: SimTime, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.scheduled_total += 1;
        self.heap.push(Scheduled { time, seq, event });
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events scheduled over the queue's lifetime.
    pub fn scheduled_total(&self) -> usize {
        self.scheduled_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::with_capacity(8);
        q.schedule(30, Event::TelemetryTick { step: 3 });
        q.schedule(10, Event::TelemetryTick { step: 1 });
        q.schedule(20, Event::TelemetryTick { step: 2 });
        let times: Vec<SimTime> = std::iter::from_fn(|| q.pop()).map(|s| s.time).collect();
        assert_eq!(times, vec![10, 20, 30]);
    }

    #[test]
    fn equal_times_pop_fifo() {
        let mut q = EventQueue::with_capacity(8);
        for node in 0..5 {
            q.schedule(42, Event::NodeJoin { node });
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|s| match s.event {
                Event::NodeJoin { node } => node,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn interleaved_schedule_pop_is_stable() {
        let mut q = EventQueue::with_capacity(8);
        q.schedule(5, Event::TelemetryTick { step: 0 });
        q.schedule(1, Event::NodeLeave { node: 9 });
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().time, 1);
        q.schedule(2, Event::NodeJoin { node: 9 });
        assert_eq!(q.pop().unwrap().time, 2);
        assert_eq!(q.pop().unwrap().time, 5);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        assert_eq!(q.scheduled_total(), 3);
    }

    #[test]
    fn tick_conversions_roundtrip() {
        assert_eq!(step_to_ticks(7), 7 * TICKS_PER_STEP);
        assert_eq!(ticks_to_step(step_to_ticks(7) + TICKS_PER_STEP - 1), 7);
        assert_eq!(latency_to_ticks(0.0), 1);
        assert_eq!(latency_to_ticks(2.0), 2 * TICKS_PER_STEP);
        assert_eq!(latency_to_ticks(0.5), TICKS_PER_STEP / 2);
    }
}
