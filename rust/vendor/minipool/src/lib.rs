//! Minimal, dependency-free worker pool (vendored, like `anyhow` /
//! `once_cell`): std scoped threads draining a shared mpsc channel work
//! queue. Built for deterministic data-parallel sharding — the caller
//! splits its state into disjoint chunks, boxes one task per chunk, and
//! [`WorkerPool::run`] executes them all before returning, so borrowed
//! (non-`'static`) state is fine and no synchronization beyond the queue
//! is needed.
//!
//! Design constraints, in order:
//!
//! 1. **Safety** — no `unsafe`. Scoped threads give borrowed tasks
//!    without lifetime transmutation; the price is one thread spawn per
//!    worker per [`WorkerPool::run`] call rather than persistent workers.
//!    For the intended workload (one fan-out per simulation tick, each
//!    task touching hundreds of nodes) the spawn cost is noise.
//! 2. **Exact sequential fallback** — width 1 (or a single task) runs
//!    inline on the caller's thread, in submission order, spawning
//!    nothing. A `--threads 1` caller therefore executes byte-for-byte
//!    the code it would have run without a pool in the picture.
//! 3. **Work stealing by queue** — tasks go through one channel that idle
//!    workers pull from, so an unbalanced split degrades throughput, not
//!    correctness.
//!
//! Panic semantics: a panicking task aborts the fan-out — remaining
//! queued tasks may be dropped unexecuted — and the panic propagates to
//! the caller when the scope joins, so a failed parallel section can
//! never be silently half-applied.

#![forbid(unsafe_code)]

use std::sync::mpsc;
use std::sync::Mutex;

/// A boxed unit of work. The lifetime lets tasks borrow from the caller's
/// stack frame; [`WorkerPool::run`] joins every task before returning, so
/// the borrows never outlive their owner.
pub type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

/// A fixed-width pool. Cheap to construct (no threads live between
/// [`WorkerPool::run`] calls) and cheap to clone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    /// A pool of `threads` workers; 0 is clamped to 1.
    pub fn new(threads: usize) -> Self {
        Self { threads: threads.max(1) }
    }

    /// Configured width.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Will [`WorkerPool::run`] ever spawn a thread?
    pub fn is_parallel(&self) -> bool {
        self.threads > 1
    }

    /// Run every task to completion, then return.
    ///
    /// Width 1 — or a single task — runs inline in submission order: the
    /// exact sequential path, no threads, no channel. Otherwise
    /// `min(threads, tasks)` scoped workers drain the shared queue in
    /// submission order (which worker gets which task is scheduling-
    /// dependent; callers get determinism by writing to disjoint state,
    /// not by relying on assignment).
    pub fn run(&self, tasks: Vec<Task<'_>>) {
        if self.threads == 1 || tasks.len() <= 1 {
            for task in tasks {
                task();
            }
            return;
        }
        let workers = self.threads.min(tasks.len());
        let (tx, rx) = mpsc::channel();
        for task in tasks {
            tx.send(task).expect("receiver alive until scope end");
        }
        drop(tx); // queue drained ⇒ recv errors ⇒ workers exit
        let rx = Mutex::new(rx);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    // Hold the queue lock only for the dequeue, never
                    // while a task runs.
                    let next = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        // A sibling panicked holding the lock: stop
                        // pulling work; the scope re-raises the panic.
                        Err(_poisoned) => return,
                    };
                    match next {
                        Ok(task) => task(),
                        Err(_empty) => return,
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn sequential_width_runs_inline_in_order() {
        let pool = WorkerPool::new(1);
        assert!(!pool.is_parallel());
        let mut order: Vec<usize> = Vec::new();
        let order_cell = std::sync::Mutex::new(&mut order);
        let oc = &order_cell;
        let tasks: Vec<Task> = (0..8)
            .map(|i| Box::new(move || oc.lock().unwrap().push(i)) as Task)
            .collect();
        pool.run(tasks);
        drop(order_cell);
        assert_eq!(order, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_run_completes_disjoint_chunk_writes() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let mut out = vec![0usize; 1000];
        {
            let tasks: Vec<Task> = out
                .chunks_mut(123)
                .enumerate()
                .map(|(c, chunk)| {
                    Box::new(move || {
                        for (k, slot) in chunk.iter_mut().enumerate() {
                            *slot = c * 123 + k + 1;
                        }
                    }) as Task
                })
                .collect();
            pool.run(tasks);
        }
        // Every slot written exactly once with its own value.
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i + 1, "slot {i} not written");
        }
    }

    #[test]
    fn pool_is_reusable_and_zero_clamps_to_one() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        for _ in 0..3 {
            let tasks: Vec<Task> = (0..5)
                .map(|_| {
                    Box::new(|| {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Task
                })
                .collect();
            pool.run(tasks);
        }
        assert_eq!(hits.load(Ordering::SeqCst), 15);
    }

    #[test]
    fn more_tasks_than_threads_all_run() {
        let pool = WorkerPool::new(3);
        let hits = AtomicUsize::new(0);
        let tasks: Vec<Task> = (0..64)
            .map(|_| {
                Box::new(|| {
                    hits.fetch_add(1, Ordering::SeqCst);
                }) as Task
            })
            .collect();
        pool.run(tasks);
        assert_eq!(hits.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn task_panic_propagates_to_caller() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(|| {
            let tasks: Vec<Task> = vec![
                Box::new(|| {}) as Task,
                Box::new(|| panic!("boom")) as Task,
            ];
            pool.run(tasks);
        });
        assert!(result.is_err(), "worker panic must not be swallowed");
    }
}
