//! Deterministic pseudo-random number generation.
//!
//! The offline build environment ships no `rand` crate, so PRONTO carries its
//! own small, reproducible RNG substrate: [`SplitMix64`] for seeding and
//! [`Xoshiro256`] (xoshiro256**) as the workhorse generator, plus the handful
//! of distributions the telemetry generator and simulator need (uniform,
//! normal, exponential, log-normal, Poisson, Zipf).
//!
//! Everything here is deterministic given a seed; every experiment in
//! `EXPERIMENTS.md` records the seed it ran with.

/// SplitMix64: tiny, high-quality 64-bit mixer. Used to expand a user seed
/// into the 256-bit state of [`Xoshiro256`] and for cheap one-off hashing.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new generator from an arbitrary seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Golden-ratio multiplier used to spread stream tags across the seed
/// space (the same constant SplitMix64 increments by).
const STREAM_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// The central RNG stream-tag registry.
///
/// Every dedicated RNG stream in the engine and its satellites is keyed
/// by one of these tags via [`stream_seed`] / [`node_stream_seed`].
/// Historically the tag assignments (1–10) lived only in comments; this
/// module is the single checked source of truth. `pronto lint`
/// (rng-discipline rule) rejects integer-literal tags in engine paths —
/// new streams must add a named constant here — and both a unit test
/// below and the lint run itself verify the registry stays collision-free.
pub mod streams {
    /// Job inter-arrival draws (`sim::engine`).
    pub const ARRIVALS: u64 = 1;
    /// Job service-time draws (`sim::engine`).
    pub const DURATION: u64 = 2;
    /// Candidate-probe / dispatch sampling (`sim::engine`).
    pub const DISPATCH: u64 = 3;
    /// Node churn (leave/join) schedule (`sim::engine`).
    pub const CHURN: u64 = 4;
    /// Federation push-latency sampling inside the engine (`sim::engine`).
    pub const FED_LATENCY: u64 = 5;
    /// Per-job slot-demand draws (`sim::engine`).
    pub const DEMAND: u64 = 6;
    /// Migration peer sampling (`sim::engine`).
    pub const MIGRATE: u64 = 7;
    /// Per-job priority-class draws (`sim::engine`).
    pub const PRIORITY: u64 = 8;
    /// Heterogeneous host-class slot-budget draws (`sim::engine`).
    pub const HETERO: u64 = 9;
    /// PM baseline per-node sketch seeding (`cli`, `sim::eval` callers).
    pub const PM_BASELINE: u64 = 10;
    /// Per-leaf push-latency sampling in the thread-per-leaf concurrent
    /// federation (`federation::concurrent`).
    pub const CONCURRENT_PUSH_LATENCY: u64 = 11;
    /// Correlated whole-rack outage hazard + outage durations
    /// (`sim::engine`, fault injection).
    pub const RACK_OUTAGE: u64 = 12;
    /// Federation-tree partition hazard, member selection, and heal
    /// times (`sim::engine`, fault injection).
    pub const PARTITION: u64 = 13;
    /// Straggler-node selection at engine init (`sim::engine`, fault
    /// injection).
    pub const STRAGGLER: u64 = 14;
    /// Antagonist-tenant arrival draws (`sim::engine`, fault injection).
    pub const ANTAGONIST: u64 = 15;

    /// Every registered stream, for uniqueness checks and docs.
    pub const ALL: &[(u64, &str)] = &[
        (ARRIVALS, "arrivals"),
        (DURATION, "duration"),
        (DISPATCH, "dispatch"),
        (CHURN, "churn"),
        (FED_LATENCY, "fed-latency"),
        (DEMAND, "demand"),
        (MIGRATE, "migrate"),
        (PRIORITY, "priority"),
        (HETERO, "hetero"),
        (PM_BASELINE, "pm-baseline"),
        (CONCURRENT_PUSH_LATENCY, "concurrent-push-latency"),
        (RACK_OUTAGE, "rack-outage"),
        (PARTITION, "partition"),
        (STRAGGLER, "straggler"),
        (ANTAGONIST, "antagonist"),
    ];
}

/// Seed for dedicated RNG stream `tag` of a run keyed by `seed` — the
/// convention behind the engine's independent, order-insensitive streams
/// (arrivals = 1, duration = 2, …, hetero = 9; see `sim::engine`). Two
/// tags map to well-separated SplitMix64 states, so adding a stream never
/// perturbs the draws of an existing one.
pub fn stream_seed(seed: u64, tag: u64) -> u64 {
    SplitMix64::new(seed ^ tag.wrapping_mul(STREAM_GAMMA)).next_u64()
}

/// One mixing hop of `seed` itself — `stream_seed(seed, 0)`, i.e. a plain
/// SplitMix64 expansion with no stream tag. This is the root of
/// hierarchical derivations (e.g. the telemetry generator folds a path of
/// stream components on top of it), kept as a named helper so engine code
/// never passes a literal tag.
pub fn seed_hash(seed: u64) -> u64 {
    SplitMix64::new(seed).next_u64()
}

/// Per-node substream of stream `tag`: one more SplitMix64 hop keyed by
/// the node id. A plain `seed ^ node` leaves adjacent nodes sharing most
/// of their RNG state (ids differ in a couple of low bits); hashing the
/// id through the mixer decorrelates neighbours completely.
pub fn node_stream_seed(seed: u64, tag: u64, node: usize) -> u64 {
    SplitMix64::new(stream_seed(seed, tag) ^ (node as u64).wrapping_mul(STREAM_GAMMA))
        .next_u64()
}

/// xoshiro256** — fast, 256-bit state, passes BigCrush. The default
/// generator for all stochastic components (trace generation, job arrivals,
/// property tests).
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 expansion (the construction recommended by the
    /// xoshiro authors; avoids all-zero states).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased for
    /// the n ≪ 2^64 values used here).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate λ (mean 1/λ).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // 1 - U avoids ln(0).
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Log-normal with the *underlying* normal's mu/sigma.
    pub fn log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Poisson-distributed count with mean `lambda` (Knuth for small λ,
    /// normal approximation above 64 where Knuth's product underflows).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        debug_assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let x = self.normal_with(lambda, lambda.sqrt());
            return x.max(0.0).round() as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.next_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf-distributed rank in [0, n) with exponent `s` (inverse-CDF over a
    /// precomputable harmonic sum would be faster; n is small here).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut u = self.next_f64() * h;
        for k in 1..=n {
            u -= (k as f64).powf(-s);
            if u <= 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values from the canonical splitmix64.c with seed 1234567.
        let mut sm = SplitMix64::new(1234567);
        let first = sm.next_u64();
        let second = sm.next_u64();
        assert_ne!(first, second);
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(first, sm2.next_u64());
    }

    #[test]
    fn xoshiro_uniform_bounds() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn xoshiro_uniform_mean() {
        let mut rng = Xoshiro256::seed_from_u64(99);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large_lambda() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        for &lambda in &[0.5, 4.0, 20.0, 100.0] {
            let n = 50_000;
            let mean: f64 =
                (0..n).map(|_| rng.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.gen_range(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Xoshiro256::seed_from_u64(19);
        let idx = rng.sample_indices(100, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn zipf_prefers_low_ranks() {
        let mut rng = Xoshiro256::seed_from_u64(23);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[rng.zipf(10, 1.2)] += 1;
        }
        assert!(counts[0] > counts[9] * 3, "counts={counts:?}");
    }

    #[test]
    fn stream_seed_matches_engine_convention() {
        // The engine has always derived its streams as
        // SplitMix64::new(seed ^ tag * gamma).next_u64(); the helper must
        // reproduce that byte-for-byte so the refactor shifts nothing.
        for (seed, tag) in [(2021u64, 1u64), (0, 9), (u64::MAX, 4), (0xFEED, 7)] {
            let mut sm = SplitMix64::new(seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            assert_eq!(stream_seed(seed, tag), sm.next_u64());
        }
    }

    #[test]
    fn seed_hash_is_the_tagless_stream_seed() {
        // `seed_hash` must stay the tag-0 hop so hierarchical derivations
        // (telemetry generator) are byte-identical to the historical
        // inline SplitMix64 expansion.
        for seed in [0u64, 1, 2021, 0xFEED, u64::MAX] {
            assert_eq!(seed_hash(seed), stream_seed(seed, 0));
            let mut sm = SplitMix64::new(seed);
            assert_eq!(seed_hash(seed), sm.next_u64());
        }
    }

    #[test]
    fn stream_registry_tags_are_unique_and_match_constants() {
        // The registry is the single source of truth for stream tags;
        // a collision would silently correlate two "independent" streams.
        let mut tags: Vec<u64> = streams::ALL.iter().map(|(t, _)| *t).collect();
        tags.sort_unstable();
        let n = tags.len();
        tags.dedup();
        assert_eq!(tags.len(), n, "duplicate stream tag in rng::streams::ALL");
        let mut names: Vec<&str> = streams::ALL.iter().map(|(_, n)| *n).collect();
        names.sort_unstable();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate stream name in rng::streams::ALL");
        // The named constants and the ALL table must agree.
        assert!(streams::ALL.contains(&(streams::ARRIVALS, "arrivals")));
        assert!(streams::ALL
            .contains(&(streams::CONCURRENT_PUSH_LATENCY, "concurrent-push-latency")));
        assert!(streams::ALL.contains(&(streams::RACK_OUTAGE, "rack-outage")));
        assert!(streams::ALL.contains(&(streams::ANTAGONIST, "antagonist")));
        assert_eq!(streams::ALL.len(), 15);
    }

    #[test]
    fn node_stream_seeds_decorrelate_adjacent_nodes() {
        // Adjacent node ids must not share RNG state: the derived Xoshiro
        // states should differ in every word, not just the low bits the
        // ids differ in.
        let a = Xoshiro256::seed_from_u64(node_stream_seed(2021, 10, 0));
        let b = Xoshiro256::seed_from_u64(node_stream_seed(2021, 10, 1));
        let (mut a, mut b) = (a, b);
        let da: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let db: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert!(da.iter().zip(&db).all(|(x, y)| x != y), "shared draws: {da:?} {db:?}");
        // Distinct tags give distinct per-node streams too.
        assert_ne!(node_stream_seed(2021, 10, 3), node_stream_seed(2021, 11, 3));
        assert_ne!(node_stream_seed(2021, 10, 3), stream_seed(2021, 10));
    }
}
