//! Queue-aware dispatch acceptance tests and the capacity-path bug-sweep
//! regressions.
//!
//! The headline claims: `signal-only` dispatch reproduces the pre-probe
//! engine behaviour byte-for-byte, and `queue-aware` dispatch — join the
//! least congested of the probed, signal-clear candidates — delivers a
//! lower mean queue delay than `signal-only` on the oversubscribed
//! `capacity` scenario at the same seed.

use pronto::scheduler::{Admission, RandomPolicy};
use pronto::sim::{
    ArrivalPattern, CapacityModel, ChurnModel, DiscreteEventEngine, DispatchPolicy,
    ProbePolicy, Scenario,
};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 4, v, steps)).collect()
}

fn always_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
    tr.iter()
        .enumerate()
        .map(|(i, _)| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
        .collect()
}

fn run(scenario: Scenario, tr: &[VmTrace]) -> pronto::sim::SimReport {
    DiscreteEventEngine::new(scenario, tr.to_vec(), always_policies(tr)).run()
}

#[test]
fn queue_aware_cuts_mean_queue_delay_on_the_capacity_scenario() {
    // Same seed, same arrival stream (probe candidates come from the same
    // dispatch RNG stream in both runs): power-of-two-choices over the
    // AdmissionProbe must beat blind first-clear placement on queue delay.
    // 20 nodes put the catalog's offered load at ~0.9 of the fleet's slots
    // — the classic high-but-stable regime where join-the-shorter-queue
    // separates decisively from random placement.
    let nodes = 20;
    let steps = 2_500;
    let tr = fleet(nodes, steps, 11);
    let base = Scenario::named("capacity").unwrap().with_nodes(nodes).with_steps(steps);
    assert_eq!(base.dispatch, DispatchPolicy::SignalOnly, "catalog default changed");

    let so = run(base.clone(), &tr);
    let mut qa_scenario = base.clone();
    qa_scenario.dispatch = DispatchPolicy::QueueAware;
    let qa = run(qa_scenario, &tr);

    // Dispatch scoring consumes no extra randomness: identical arrivals.
    assert_eq!(so.jobs_arrived, qa.jobs_arrived);
    assert!(so.jobs_queued > 0 && qa.jobs_queued > 0, "nothing queued — no contrast");
    assert!(
        qa.mean_queue_delay_steps < so.mean_queue_delay_steps,
        "queue-aware {:.3} steps not below signal-only {:.3} steps",
        qa.mean_queue_delay_steps,
        so.mean_queue_delay_steps
    );
}

#[test]
fn least_loaded_also_beats_signal_only_on_drops_or_delay() {
    // Weaker directional check for the third policy: balancing load must
    // not make the overloaded fleet strictly worse on both axes.
    let nodes = 16;
    let steps = 1_500;
    let tr = fleet(nodes, steps, 13);
    let base = Scenario::named("capacity").unwrap().with_nodes(nodes).with_steps(steps);
    let so = run(base.clone(), &tr);
    let mut ll_scenario = base;
    ll_scenario.dispatch = DispatchPolicy::LeastLoaded;
    let ll = run(ll_scenario, &tr);
    assert_eq!(so.jobs_arrived, ll.jobs_arrived);
    assert!(
        ll.mean_queue_delay_steps <= so.mean_queue_delay_steps
            || ll.jobs_dropped <= so.jobs_dropped,
        "least-loaded worse on every axis: delay {:.3} vs {:.3}, drops {} vs {}",
        ll.mean_queue_delay_steps,
        so.mean_queue_delay_steps,
        ll.jobs_dropped,
        so.jobs_dropped
    );
}

#[test]
fn single_probe_collapses_every_policy_to_the_same_report() {
    // With one candidate the scorer has no freedom: queue-aware and
    // least-loaded must match signal-only byte-for-byte. This pins the
    // "signal-only preserves today's behaviour" equivalence from the
    // other side — the scored path differs only by its choice among
    // multiple candidates, never in bookkeeping.
    let tr = fleet(8, 1_200, 17);
    let mk = |dispatch| {
        let mut s = Scenario::named("capacity").unwrap().with_nodes(8).with_steps(1_200);
        s.probe = ProbePolicy::RandomProbe;
        s.dispatch = dispatch;
        s
    };
    let so = run(mk(DispatchPolicy::SignalOnly), &tr).to_json_string();
    let qa = run(mk(DispatchPolicy::QueueAware), &tr).to_json_string();
    let ll = run(mk(DispatchPolicy::LeastLoaded), &tr).to_json_string();
    assert_eq!(so, qa, "queue-aware diverged on a single probe");
    assert_eq!(so, ll, "least-loaded diverged on a single probe");
}

#[test]
fn scored_dispatch_is_deterministic_per_seed() {
    for name in ["queue-aware", "priority", "hetero"] {
        let scenario = Scenario::named(name).unwrap().with_nodes(8).with_steps(1_000);
        let tr = fleet(8, 1_000, 23);
        let a = run(scenario.clone(), &tr);
        let b = run(scenario, &tr);
        assert_eq!(
            a.to_json_string(),
            b.to_json_string(),
            "scenario '{name}' not reproducible"
        );
    }
}

#[test]
fn priority_classes_wait_in_order() {
    // Strict-priority queues under sustained load: the top class must see
    // less queueing than the bottom class, and SLO accounting must close.
    // The arrival rate is eased to ~1.15× the 6-node fleet's slots so the
    // bottom class still starts from the queue often enough to measure.
    let mut scenario = Scenario::named("priority").unwrap().with_nodes(6).with_steps(2_500);
    scenario.arrivals = ArrivalPattern::Poisson { rate: 0.5 };
    let tr = fleet(6, 2_500, 29);
    let report = run(scenario, &tr);
    assert_eq!(report.mean_queue_delay_by_priority.len(), 3);
    let d = &report.mean_queue_delay_by_priority;
    assert!(
        d[2] < d[0],
        "top class waited {:.3} steps, bottom {:.3} — priorities ignored",
        d[2],
        d[0]
    );
    assert!(report.slo_total == report.jobs_arrived);
    assert!(report.slo_attained > 0 && report.slo_attained <= report.slo_total);
    assert!(report.slo_attainment() < 1.0, "overloaded fleet met every deadline?");
}

#[test]
fn utilization_is_a_true_time_average_under_churn() {
    // Regression: the tick-sampled denominator only saw the fleet at
    // telemetry boundaries, so mid-step churn over/under-counted capacity.
    // The event-driven integral is bounded by construction, churn or not.
    let scenario = Scenario {
        capacity: Some(CapacityModel {
            slots_per_node: 2,
            contended_slots: 2,
            queue_capacity: 4,
            max_job_slots: 1,
            queue_policy: pronto::scheduler::QueuePolicy::Fifo,
            migration_limit: 1,
            ..CapacityModel::default()
        }),
        churn: Some(ChurnModel {
            leave_hazard: 0.01, // aggressive: capacity swings constantly
            rejoin_delay_mean: 20.0,
            min_alive: 2,
        }),
        arrivals: ArrivalPattern::Poisson { rate: 1.3 },
        ..Scenario::default()
    }
    .with_nodes(6)
    .with_steps(2_000);
    let tr = fleet(6, 2_000, 31);
    let report = run(scenario, &tr);
    assert!(report.node_leaves > 0 && report.node_joins > 0, "churn never swung capacity");
    assert!(
        report.mean_utilization > 0.0 && report.mean_utilization <= 1.0,
        "utilization out of bounds: {}",
        report.mean_utilization
    );
    // Oversubscribed fleet: the busy figure must be meaningful, not
    // diluted by a miscounted denominator.
    assert!(report.mean_utilization > 0.5, "overloaded fleet reads mostly idle");
}
