//! Figure 4: tracked projections (4a) and the rejection signal vs CPU
//! Ready spikes (4b) for a single node.
//!
//! Emits the projection series and the (rejection, ready-spike) timeline;
//! the claim to verify: rejection raises precede CPU Ready spikes.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::bench::Table;
use pronto::scheduler::{NodeScheduler, RejectConfig};
use pronto::telemetry::{GeneratorConfig, TraceGenerator};

fn main() {
    let steps = 2_000;
    let gen = TraceGenerator::new(GeneratorConfig::default(), 67);
    let trace = gen.generate_vm(0, steps);
    let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());

    let mut proj_rows: Vec<(usize, Vec<f64>)> = Vec::new();
    let mut raised = Vec::with_capacity(steps);
    for t in 0..steps {
        node.observe(trace.features(t));
        raised.push(node.rejection_raised());
        if t % 4 == 0 {
            proj_rows.push((t, node.projections().to_vec()));
        }
    }

    let mut t4a = Table::new(
        "Figure 4a: tracked projections over time (sampled)",
        &["t", "p0", "p1", "p2", "p3"],
    );
    for (t, p) in &proj_rows {
        t4a.row(&[
            format!("{t}"),
            format!("{:.3}", p.first().copied().unwrap_or(0.0)),
            format!("{:.3}", p.get(1).copied().unwrap_or(0.0)),
            format!("{:.3}", p.get(2).copied().unwrap_or(0.0)),
            format!("{:.3}", p.get(3).copied().unwrap_or(0.0)),
        ]);
    }
    t4a.maybe_write_csv("fig4a_projections");

    let threshold = 1000.0;
    let mut t4b = Table::new(
        "Figure 4b: rejection signal vs CPU Ready spikes",
        &["t", "rejection", "ready_spike"],
    );
    let mut spikes = 0;
    let mut preceded = 0;
    for t in 0..steps {
        let spike = trace.cpu_ready(t) >= threshold;
        if spike {
            spikes += 1;
            let lo = t.saturating_sub(5);
            if raised[lo..=t].iter().any(|&r| r) {
                preceded += 1;
            }
        }
        t4b.row(&[
            format!("{t}"),
            format!("{}", raised[t] as u8),
            format!("{}", spike as u8),
        ]);
    }
    t4b.maybe_write_csv("fig4b_signals");

    println!("Figure 4 summary (node 0, {steps} steps):");
    println!("  CPU Ready spikes (>= {threshold} ms): {spikes}");
    println!(
        "  preceded by a rejection raise within 5 steps: {preceded} ({:.0}%)",
        100.0 * preceded as f64 / spikes.max(1) as f64
    );
    println!("  rejection raises total: {}", raised.iter().filter(|&&r| r).count());
    println!("  (full series in CSV when PRONTO_BENCH_CSV_DIR is set)");
}
