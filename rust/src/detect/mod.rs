//! Streaming spike detection.
//!
//! Implements the robust z-score peak detector of van Brakel (2014) that
//! Algorithm 1 (Reject-Job) embeds: a per-signal lag buffer of dampened
//! history, running mean/std filters, threshold `alpha` (z-scores) and
//! influence `beta` for detected peaks. [`ZScoreDetector`] tracks one scalar
//! signal; [`MultiDetector`] tracks the r projection signals of a node;
//! [`SlidingWindow`] provides the left/right-sided spike bookkeeping of
//! Figure 5 used by the evaluation.

mod window;
mod zscore;

pub use window::{SideCounts, SlidingWindow, SpikeSide};
pub use zscore::{MultiDetector, Spike, ZScoreConfig, ZScoreDetector};
