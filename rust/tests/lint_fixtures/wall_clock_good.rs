// Fixture: callers inject timestamps; the engine derives time logically.
pub fn stamp(now_steps: u64) -> u64 {
    now_steps + 1
}
