//! Artifact manifest (`artifacts/manifest.json`) parsing.

use crate::ser::{parse_json, JsonValue};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Tensor spec within an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" (default) or "s32".
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled artifact: file name plus typed signature.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Compile-time configuration the artifacts were lowered with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArtifactConfig {
    pub dim: usize,
    pub rank: usize,
    pub block: usize,
    pub lag: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub config: ArtifactConfig,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

fn tensor_specs(v: &JsonValue) -> Result<Vec<TensorSpec>> {
    v.as_array()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|t| {
            let name = t
                .get("name")
                .and_then(JsonValue::as_str)
                .ok_or_else(|| anyhow!("tensor spec missing name"))?
                .to_string();
            let shape = t
                .get("shape")
                .and_then(JsonValue::as_array)
                .ok_or_else(|| anyhow!("tensor spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<Vec<_>>>()?;
            let dtype = t
                .get("dtype")
                .and_then(JsonValue::as_str)
                .unwrap_or("f32")
                .to_string();
            Ok(TensorSpec { name, shape, dtype })
        })
        .collect()
}

impl Manifest {
    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = parse_json(text).map_err(|e| anyhow!("manifest json: {e}"))?;
        let cfg = v.get("config").ok_or_else(|| anyhow!("missing config"))?;
        let get = |k: &str| -> Result<usize> {
            cfg.get(k)
                .and_then(JsonValue::as_usize)
                .ok_or_else(|| anyhow!("config missing {k}"))
        };
        let config = ArtifactConfig {
            dim: get("dim")?,
            rank: get("rank")?,
            block: get("block")?,
            lag: get("lag")?,
        };
        let mut artifacts = BTreeMap::new();
        let arts = v
            .get("artifacts")
            .and_then(JsonValue::as_object)
            .ok_or_else(|| anyhow!("missing artifacts"))?;
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(JsonValue::as_str)
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                .to_string();
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file,
                    inputs: tensor_specs(
                        entry.get("inputs").ok_or_else(|| anyhow!("no inputs"))?,
                    )?,
                    outputs: tensor_specs(
                        entry.get("outputs").ok_or_else(|| anyhow!("no outputs"))?,
                    )?,
                },
            );
        }
        Ok(Manifest { config, artifacts })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"dim": 52, "rank": 4, "block": 32, "lag": 10, "dtype": "f32"},
      "artifacts": {
        "fpca_update": {
          "file": "fpca_update.hlo.txt",
          "inputs": [
            {"name": "u", "shape": [52, 4]},
            {"name": "s", "shape": [4]},
            {"name": "block", "shape": [52, 32]},
            {"name": "forget", "shape": []}
          ],
          "outputs": [
            {"name": "u_new", "shape": [52, 4]},
            {"name": "s_new", "shape": [4]}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config, ArtifactConfig { dim: 52, rank: 4, block: 32, lag: 10 });
        let a = m.artifact("fpca_update").unwrap();
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[0].shape, vec![52, 4]);
        assert_eq!(a.inputs[3].shape, Vec::<usize>::new());
        assert_eq!(a.inputs[3].elements(), 1);
        assert_eq!(a.outputs[1].name, "s_new");
        assert_eq!(a.inputs[0].dtype, "f32");
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_when_present() {
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built yet
        }
        let m = Manifest::load(&dir).unwrap();
        for name in ["fpca_update", "merge_subspaces", "project_detect"] {
            let a = m.artifact(name).unwrap();
            assert!(dir.join(&a.file).exists(), "{} missing", a.file);
        }
    }
}
