//! Federated PCA (FPCA-Edge) — the paper's local-update engine.
//!
//! Native Rust implementation of the constructions in the paper's appendix
//! (Grammenos et al. 2019):
//!
//! * [`merge`] — Algorithm 3 (basic SVD merge with forgetting factor) and
//!   Algorithm 4 (the V-free optimized merge via Gram + QR + small SVD);
//! * [`edge`] — Algorithm 5 (`FPCA-Edge`): per-block SSVD update, merge with
//!   the previous estimate, and energy-based adaptive rank (Eq. 7);
//! * [`subspace`] — the `(U, Σ)` estimate type shared across the crate.
//!
//! This implementation is the *numerical oracle* for the AOT-compiled HLO
//! artifacts (`python/compile/model.py` mirrors it with masked fixed-rank
//! shapes) and the engine the pure-native scheduler path uses.

mod edge;
mod merge;
mod subspace;

pub use edge::{EnergyBounds, FpcaEdge, FpcaEdgeConfig};
pub use merge::{merge_subspaces, merge_svd_basic, MergeOptions};
pub use subspace::Subspace;
