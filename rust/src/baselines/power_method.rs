//! Memory-limited streaming PCA via the block power method
//! (Mitliagkas, Caramanis & Jain, NeurIPS 2013).
//!
//! Buffers a block of observations, accumulates the empirical covariance
//! action `(Σ_t y_t y_tᵀ) Q` on the current iterate, and re-orthonormalizes
//! via QR once per block — one power iteration per block, O(d·r) state plus
//! the block buffer. Footnote 2 of the paper applies: PM needs a block at
//! least as large as the data dimensionality, which forces a larger window
//! than the other methods.
//!
//! PM produces no singular values; PRONTO's weighting falls back to
//! σ_r = 1/r (paper §7).

use super::{decay_spectrum, StreamingEmbedding};
use crate::fpca::Subspace;
use crate::linalg::{householder_qr, Mat};
use crate::rng::Xoshiro256;

/// Block power method tracker.
#[derive(Debug, Clone)]
pub struct BlockPowerMethod {
    d: usize,
    r: usize,
    /// Current orthonormal iterate Q ∈ ℝ^{d×r}.
    q: Mat,
    /// Accumulated covariance action on Q for the current block: (ΣyyᵀQ).
    acc: Mat,
    /// Scratch for the per-observation projection yᵀQ (allocation-free
    /// hot path).
    proj: Vec<f64>,
    /// Observations accumulated in the current block.
    in_block: usize,
    /// Block size (≥ d per the paper's requirement).
    block: usize,
    /// Completed power iterations.
    iterations: usize,
    seen: usize,
}

impl BlockPowerMethod {
    /// `block` defaults to `d` when 0 is passed (the paper's minimum).
    ///
    /// PM is the one randomized baseline: `seed` draws the Gaussian
    /// start. Callers instantiating a fleet should derive per-node seeds
    /// through [`crate::rng::node_stream_seed`] (the CLI uses stream
    /// tag 10) rather than `seed ^ node` — a plain XOR leaves adjacent
    /// nodes' SplitMix64 states nearly identical, correlating their
    /// sketches.
    pub fn new(d: usize, r: usize, block: usize, seed: u64) -> Self {
        assert!(r >= 1 && r <= d);
        let block = if block == 0 { d } else { block };
        assert!(block >= d, "power method needs block >= d (paper footnote 2)");
        // Random Gaussian start, orthonormalized.
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let g = Mat::from_col_major(d, r, (0..d * r).map(|_| rng.normal()).collect());
        let (q, _) = householder_qr(&g);
        Self {
            d,
            r,
            q,
            acc: Mat::zeros(d, r),
            proj: vec![0.0; r],
            in_block: 0,
            block,
            iterations: 0,
            seen: 0,
        }
    }

    /// Number of completed power iterations (blocks).
    pub fn iterations(&self) -> usize {
        self.iterations
    }
}

impl StreamingEmbedding for BlockPowerMethod {
    fn observe(&mut self, y: &[f64]) {
        assert_eq!(y.len(), self.d);
        // acc += y (yᵀ Q): rank-1 covariance action, O(d·r). The
        // projection lands in the owned scratch (no per-step Vec).
        self.q.transpose_matvec_into(y, &mut self.proj);
        for j in 0..self.r {
            let w = self.proj[j];
            if w == 0.0 {
                continue;
            }
            let col = self.acc.col_mut(j);
            for i in 0..self.d {
                col[i] += y[i] * w;
            }
        }
        self.in_block += 1;
        self.seen += 1;
        if self.in_block == self.block {
            let (q, _) = householder_qr(&self.acc);
            self.q = q;
            self.acc = Mat::zeros(self.d, self.r);
            self.in_block = 0;
            self.iterations += 1;
        }
    }

    fn estimate(&self) -> Subspace {
        if self.iterations == 0 {
            return Subspace::empty(self.d);
        }
        Subspace::new(self.q.clone(), decay_spectrum(self.r))
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn rank(&self) -> usize {
        self.r
    }

    fn name(&self) -> &'static str {
        "PM"
    }

    fn has_spectrum(&self) -> bool {
        false
    }

    fn version(&self) -> Option<u64> {
        Some(self.iterations as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{orthonormality_error, subspace_distance};
    use crate::proptest::{forall, gen_low_rank};

    #[test]
    fn requires_full_block_before_estimate() {
        let mut pm = BlockPowerMethod::new(6, 2, 0, 7);
        for i in 0..5 {
            pm.observe(&[1.0, 0.5, 0.0, 0.0, 0.0, 0.0]);
            assert!(pm.estimate().is_empty(), "i={i}");
        }
        pm.observe(&[1.0, 0.5, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(pm.iterations(), 1);
        assert!(!pm.estimate().is_empty());
    }

    #[test]
    fn iterate_is_orthonormal() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(1);
        let mut pm = BlockPowerMethod::new(8, 3, 8, 42);
        for _ in 0..64 {
            let y: Vec<f64> = (0..8).map(|_| rng.normal()).collect();
            pm.observe(&y);
        }
        assert!(orthonormality_error(&pm.estimate().u) < 1e-9);
    }

    #[test]
    fn converges_to_top_subspace() {
        forall("pm converges", |rng| {
            let d = 8 + rng.gen_range(12);
            let data = gen_low_rank(rng, d, d * 30, 2, 0.02);
            let mut pm = BlockPowerMethod::new(d, 2, d, 9);
            for t in 0..data.cols() {
                pm.observe(data.col(t));
            }
            let truth = crate::linalg::svd_truncated(&data, 2);
            let dist = subspace_distance(&pm.estimate().u, &truth.u);
            if dist < 0.25 {
                Ok(())
            } else {
                Err(format!("distance {dist}"))
            }
        });
    }

    #[test]
    #[should_panic]
    fn rejects_small_blocks() {
        let _ = BlockPowerMethod::new(10, 2, 5, 0);
    }

    #[test]
    fn no_spectrum_fallback() {
        let pm = BlockPowerMethod::new(6, 3, 0, 1);
        assert!(!pm.has_spectrum());
    }
}
