//! Data-center simulation and the §7.1 evaluation harness.
//!
//! * [`events`] — typed simulation events and the deterministic
//!   `(time, seq)`-ordered event queue: a hierarchical timing wheel by
//!   default, with the historical binary heap kept as a byte-identical
//!   debug oracle (`--features heap-oracle` / `PRONTO_EVENT_QUEUE=heap`).
//! * [`fleet`] — struct-of-arrays per-node state for the engine's hot
//!   loops: liveness/signal flags with a dense alive-id index, and the
//!   host table with contiguous mirrors of the hot capacity scalars.
//! * [`engine`] — the discrete-event cluster engine: telemetry ticks, job
//!   arrivals/starts/completions, host-level capacity (slot budgets,
//!   bounded wait queues, preemption and migration of displaced jobs),
//!   node churn (join/leave mid-run), and federation pushes with
//!   configurable delivery latency; bit-reproducible given a seed.
//! * [`scenario`] — composable run descriptions: arrival patterns
//!   (Poisson, bursty/MMPP, diurnal, trace replay), capacity models,
//!   churn schedules, federation latency; a named catalog plus TOML
//!   loading (`pronto sim --scenario …`).
//! * [`datacenter`] — the fixed-step façade ([`DataCenterSim`]) that maps
//!   a [`SimConfig`] onto the engine's steady-Poisson scenario.
//! * [`eval`] — trace-driven evaluation of a rejection-signal method
//!   against the CPU Ready ground truth: left/right-sided spike counts per
//!   CPU Ready spike (Figure 6), downtime and contained-spike percentages
//!   (Figure 7), and per-method aggregation over a fleet of VMs.
//! * [`quality`] — the ground-truth-labeled prediction-quality scorer
//!   (eval v2): per-spike lead time, precision/recall/F1,
//!   false-positive rate, and signal-to-decision latency over
//!   engine-captured raised/spike timelines, reduced to the
//!   schema-versioned `EVAL_quality.json` rows of `pronto eval
//!   --scenario`.

pub mod datacenter;
pub mod engine;
pub mod eval;
pub mod events;
pub mod fleet;
pub mod quality;
pub mod scenario;

pub use datacenter::{DataCenterSim, SimConfig};
pub use engine::{
    sample_distinct, DiscreteEventEngine, EngineError, PolicyFactory, SampleScratch,
    SignalCapture, SimReport,
};
pub use fleet::{FleetState, HostTable};
pub use eval::{evaluate_method, EvalConfig, FleetEvaluation, NodeEvaluation};
pub use quality::{
    decision_latencies, quality_report, score_report, score_timeline, QualityRow, TimelineScore,
};
pub use events::{
    latency_to_ticks, step_to_ticks, ticks_to_step, Event, EventQueue, QueueBacking, Scheduled,
    SimTime, TickBatch, TICKS_PER_STEP,
};
pub use scenario::{
    ArrivalPattern, CapacityModel, ChurnModel, DispatchPolicy, FailureModel, FederationSpec,
    HostClass, ProbePolicy, ReplaySchedule, Scenario, CATALOG,
};
