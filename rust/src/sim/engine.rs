//! The deterministic discrete-event cluster engine.
//!
//! Replaces the fixed-step simulator loop: the cluster is driven by a
//! binary-heap event queue ([`super::events`]) over typed events —
//! telemetry ticks, job arrivals/completions, federation pushes with
//! delivery latency, and node churn. Determinism guarantees:
//!
//! * events order by `(time, seq)` — no hash maps, no wall clock;
//! * every stochastic component draws from its **own** RNG stream derived
//!   from the scenario seed (arrivals, durations, dispatch, churn,
//!   latency), so enabling churn does not shift the arrival sequence;
//! * the same `(Scenario, traces, policies)` triple therefore produces a
//!   bit-identical [`SimReport`] — `SimReport::to_json_string` output is
//!   byte-comparable across runs, which the determinism regression tests
//!   rely on.
//!
//! The hot loop is allocation-free in steady state: events are small
//! `Copy` values, federation subspace snapshots live in a free-listed
//! slab referenced by index, probe candidates reuse one buffer, and
//! per-node state is indexed by dense node id.

use super::events::{
    latency_to_ticks, step_to_ticks, ticks_to_step, Event, EventQueue, SimTime, TICKS_PER_STEP,
};
use super::scenario::{ArrivalPattern, DispatchPolicy, Scenario};
use crate::federation::{FederationTree, TreeTopology};
use crate::fpca::Subspace;
use crate::rng::{SplitMix64, Xoshiro256};
use crate::scheduler::{Admission, JobOutcome};
use crate::ser::JsonValue;
use crate::telemetry::VmTrace;
use std::collections::BTreeMap;

/// Aggregate result of a simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// Scenario name the run was driven by.
    pub scenario: String,
    pub steps: usize,
    pub nodes: usize,
    pub seed: u64,
    pub jobs_arrived: usize,
    pub jobs_accepted: usize,
    pub jobs_rejected: usize,
    /// Jobs that ran to completion within the horizon.
    pub jobs_completed: usize,
    /// Jobs killed because their node left mid-run.
    pub jobs_displaced: usize,
    /// Arrivals that found zero alive nodes.
    pub jobs_unplaceable: usize,
    /// Accepted jobs whose node stayed calm over the score window.
    pub good_accepts: usize,
    /// Accepted jobs whose node hit a CPU Ready spike in the score window.
    pub bad_accepts: usize,
    /// Rejections where a probed node indeed spiked in the score window.
    pub justified_rejections: usize,
    /// Churn events that actually fired.
    pub node_joins: usize,
    pub node_leaves: usize,
    /// Federation pushes that propagated / were ε-suppressed.
    pub federation_pushes: usize,
    pub federation_suppressed: usize,
    /// Pushes still in flight when the run ended (delivery would have
    /// landed past the horizon) — parity with
    /// [`crate::federation::FederationReport::late_drops`].
    pub federation_late_drops: usize,
    /// Mean observed push delivery latency in steps (0 when instant or no
    /// pushes happened).
    pub mean_push_latency_steps: f64,
    /// Peak number of concurrently running jobs across the cluster.
    pub peak_inflight: usize,
    /// Per-job outcomes (ordered by arrival).
    pub outcomes: Vec<JobOutcome>,
}

impl SimReport {
    /// Fraction of accepted jobs placed on nodes that stayed healthy.
    pub fn placement_quality(&self) -> f64 {
        if self.jobs_accepted == 0 {
            return 1.0;
        }
        self.good_accepts as f64 / self.jobs_accepted as f64
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.jobs_arrived == 0 {
            return 1.0;
        }
        self.jobs_accepted as f64 / self.jobs_arrived as f64
    }

    /// Fraction of rejections that avoided a real spike.
    pub fn rejection_precision(&self) -> f64 {
        if self.jobs_rejected == 0 {
            return 1.0;
        }
        self.justified_rejections as f64 / self.jobs_rejected as f64
    }

    /// Order-sensitive FNV/SplitMix fold over the outcome sequence: two
    /// runs with identical per-job outcomes (and only those) agree.
    pub fn outcomes_digest(&self) -> u64 {
        fn mix(h: u64, v: u64) -> u64 {
            let mut s = SplitMix64::new(h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            s.next_u64()
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for o in &self.outcomes {
            h = match *o {
                JobOutcome::Accepted { node, at } => {
                    mix(mix(mix(h, 1), node as u64), at as u64)
                }
                JobOutcome::Rejected { at } => mix(mix(h, 2), at as u64),
            };
        }
        h
    }

    /// Canonical JSON rendering (BTreeMap ⇒ sorted keys ⇒ byte-stable for
    /// identical runs). The outcome list is folded into a digest so the
    /// document stays small while still witnessing per-job divergence.
    pub fn to_json(&self) -> JsonValue {
        let mut m = BTreeMap::new();
        let num = |x: usize| JsonValue::Number(x as f64);
        m.insert("scenario".into(), JsonValue::String(self.scenario.clone()));
        m.insert("steps".into(), num(self.steps));
        m.insert("nodes".into(), num(self.nodes));
        // String: a u64 seed above 2^53 would lose precision as a JSON
        // number, and the seed is the reproduction key.
        m.insert("seed".into(), JsonValue::String(self.seed.to_string()));
        m.insert("jobs_arrived".into(), num(self.jobs_arrived));
        m.insert("jobs_accepted".into(), num(self.jobs_accepted));
        m.insert("jobs_rejected".into(), num(self.jobs_rejected));
        m.insert("jobs_completed".into(), num(self.jobs_completed));
        m.insert("jobs_displaced".into(), num(self.jobs_displaced));
        m.insert("jobs_unplaceable".into(), num(self.jobs_unplaceable));
        m.insert("good_accepts".into(), num(self.good_accepts));
        m.insert("bad_accepts".into(), num(self.bad_accepts));
        m.insert("justified_rejections".into(), num(self.justified_rejections));
        m.insert("node_joins".into(), num(self.node_joins));
        m.insert("node_leaves".into(), num(self.node_leaves));
        m.insert("federation_pushes".into(), num(self.federation_pushes));
        m.insert(
            "federation_suppressed".into(),
            num(self.federation_suppressed),
        );
        m.insert(
            "federation_late_drops".into(),
            num(self.federation_late_drops),
        );
        m.insert(
            "mean_push_latency_steps".into(),
            JsonValue::Number(self.mean_push_latency_steps),
        );
        m.insert("peak_inflight".into(), num(self.peak_inflight));
        m.insert(
            "acceptance_rate".into(),
            JsonValue::Number(self.acceptance_rate()),
        );
        m.insert(
            "placement_quality".into(),
            JsonValue::Number(self.placement_quality()),
        );
        m.insert(
            "rejection_precision".into(),
            JsonValue::Number(self.rejection_precision()),
        );
        m.insert(
            "outcomes_digest".into(),
            JsonValue::String(format!("{:016x}", self.outcomes_digest())),
        );
        JsonValue::Object(m)
    }

    /// Canonical JSON string — the byte-comparable determinism artifact.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }
}

/// Builds a fresh admission policy for a node that rejoins after churn (a
/// restarted machine loses its in-memory subspace state).
pub type PolicyFactory = Box<dyn Fn(usize) -> Box<dyn Admission>>;

/// Pooled storage for in-flight federation snapshots: events carry a slab
/// index instead of the (heap-heavy) subspace itself.
#[derive(Default)]
struct SnapshotPool {
    slots: Vec<Option<Subspace>>,
    free: Vec<usize>,
}

impl SnapshotPool {
    fn put(&mut self, s: Subspace) -> usize {
        match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(s);
                i
            }
            None => {
                self.slots.push(Some(s));
                self.slots.len() - 1
            }
        }
    }

    fn take(&mut self, i: usize) -> Option<Subspace> {
        let s = self.slots[i].take();
        if s.is_some() {
            self.free.push(i);
        }
        s
    }
}

/// The discrete-event cluster engine.
pub struct DiscreteEventEngine {
    scenario: Scenario,
    traces: Vec<VmTrace>,
    policies: Vec<Box<dyn Admission>>,
    factory: Option<PolicyFactory>,
}

impl DiscreteEventEngine {
    /// One trace + one policy per node (same order). The scenario's
    /// `nodes` is overridden by the fleet size.
    pub fn new(
        scenario: Scenario,
        traces: Vec<VmTrace>,
        policies: Vec<Box<dyn Admission>>,
    ) -> Self {
        assert_eq!(traces.len(), policies.len(), "one policy per node");
        assert!(!traces.is_empty());
        Self { scenario, traces, policies, factory: None }
    }

    /// Install a policy factory: nodes that rejoin after churn restart
    /// with a fresh policy (then optionally pull the federation view).
    pub fn with_policy_factory(mut self, factory: PolicyFactory) -> Self {
        self.factory = Some(factory);
        self
    }

    /// Run to the horizon; consumes the engine.
    pub fn run(self) -> SimReport {
        let Self { scenario, traces, mut policies, factory } = self;
        let n = traces.len();
        let d = traces[0].dim();
        let trace_len = traces.iter().map(VmTrace::len).min().unwrap();
        let steps = scenario.steps.min(trace_len);
        let horizon: SimTime = step_to_ticks(steps);

        // Independent, order-insensitive RNG streams.
        let stream = |tag: u64| {
            let mut sm = SplitMix64::new(scenario.seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            Xoshiro256::seed_from_u64(sm.next_u64())
        };
        let mut arrivals_rng = stream(1);
        let mut duration_rng = stream(2);
        let mut dispatch_rng = stream(3);
        let mut churn_rng = stream(4);
        let mut latency_rng = stream(5);

        let fed = &scenario.federation;
        let mut tree = if fed.enabled {
            Some(FederationTree::new(
                TreeTopology::new(n, fed.fanout.max(2)),
                d,
                fed.rank,
                fed.epsilon,
            ))
        } else {
            None
        };
        let mut pool = SnapshotPool::default();

        // Dense per-node state.
        let mut alive = vec![true; n];
        let mut epoch = vec![0u32; n];
        let mut inflight = vec![0u32; n];
        let mut can_accept = vec![true; n];
        let mut alive_ids: Vec<usize> = (0..n).collect();
        let mut rr_cursor = 0usize;
        let mut burst_on = false;

        let mut report = SimReport {
            scenario: scenario.name.clone(),
            nodes: n,
            steps,
            seed: scenario.seed,
            ..Default::default()
        };
        let expected_jobs =
            (scenario.arrivals.mean_rate() * steps as f64).ceil() as usize;
        report.outcomes.reserve(expected_jobs + 16);

        let mut queue = EventQueue::with_capacity(1024 + expected_jobs / 4);
        let mut candidates: Vec<usize> = Vec::with_capacity(8);
        let mut next_job_id = 0u64;
        let mut total_inflight = 0usize;
        let mut lat_ticks_sum = 0u64;
        let mut lat_count = 0u64;

        // Ground truth for scoring: does `node`'s CPU Ready spike within
        // the score window starting at `step`?
        let spike_ahead = |node: usize, step: usize| -> bool {
            let hi = (step + scenario.score_window).min(steps - 1);
            (step..=hi).any(|tt| traces[node].cpu_ready(tt) >= scenario.ready_threshold)
        };

        queue.schedule(0, Event::TelemetryTick { step: 0 });

        while let Some(ev) = queue.pop() {
            if ev.time >= horizon {
                // Pops are non-decreasing in time: everything left is
                // also past the run. In-flight federation pushes would
                // have delivered after the horizon — count them as late
                // drops (parity with ConcurrentFederation) and stop.
                let mut late = usize::from(matches!(ev.event, Event::FederationPush { .. }));
                while let Some(rest) = queue.pop() {
                    if matches!(rest.event, Event::FederationPush { .. }) {
                        late += 1;
                    }
                }
                report.federation_late_drops = late;
                break;
            }
            match ev.event {
                Event::TelemetryTick { step } => {
                    // 1. Every alive node consumes its metric vector.
                    for i in 0..n {
                        if alive[i] {
                            can_accept[i] = policies[i].observe(traces[i].features(step));
                        }
                    }

                    // 2. Churn hazard (respecting the min-alive floor; the
                    //    provisional counter prevents one tick from
                    //    scheduling the pool below the floor).
                    if let Some(churn) = &scenario.churn {
                        let mut planned_alive = alive_ids.len();
                        for i in 0..n {
                            if alive[i]
                                && planned_alive > churn.min_alive
                                && churn_rng.bernoulli(churn.leave_hazard)
                            {
                                planned_alive -= 1;
                                queue.schedule(ev.time + 1, Event::NodeLeave { node: i });
                            }
                        }
                    }

                    // 3. Job arrivals for this step (regime update first
                    //    for the MMPP pattern).
                    if let ArrivalPattern::Bursty { mean_burst_len, mean_gap_len, .. } =
                        scenario.arrivals
                    {
                        let flip = if burst_on {
                            1.0 / mean_burst_len.max(1.0)
                        } else {
                            1.0 / mean_gap_len.max(1.0)
                        };
                        if arrivals_rng.bernoulli(flip.min(1.0)) {
                            burst_on = !burst_on;
                        }
                    }
                    let lam = scenario.arrivals.rate_at(step, burst_on);
                    let k = arrivals_rng.poisson(lam) as usize;
                    for j in 0..k {
                        let duration_steps = duration_rng
                            .log_normal(scenario.duration_mu, scenario.duration_sigma)
                            .round()
                            .max(1.0) as usize;
                        let job_id = next_job_id;
                        next_job_id += 1;
                        let off = (2 + j as u64).min(TICKS_PER_STEP - 1);
                        queue.schedule(
                            ev.time + off,
                            Event::JobArrival { job_id, duration_steps },
                        );
                    }

                    // 4. Federation push boundary: alive leaves offer
                    //    their iterate; delivery is delayed by the
                    //    latency model (the merged iterate is stale by
                    //    construction).
                    if tree.is_some() && (step + 1) % fed.push_every == 0 {
                        for &leaf in &alive_ids {
                            if let Some(iterate) = policies[leaf].iterate() {
                                let delay = fed.latency.sample(&mut latency_rng);
                                let dt = latency_to_ticks(delay);
                                let snapshot = pool.put(iterate);
                                queue.schedule(
                                    ev.time + dt,
                                    Event::FederationPush { leaf, snapshot, sent_at: ev.time },
                                );
                            }
                        }
                    }

                    // 5. Next tick.
                    if step + 1 < steps {
                        queue.schedule(
                            step_to_ticks(step + 1),
                            Event::TelemetryTick { step: step + 1 },
                        );
                    }
                }

                Event::JobArrival { job_id, duration_steps } => {
                    let step = ticks_to_step(ev.time);
                    report.jobs_arrived += 1;
                    if alive_ids.is_empty() {
                        report.jobs_rejected += 1;
                        report.jobs_unplaceable += 1;
                        report.outcomes.push(JobOutcome::Rejected { at: step });
                        continue;
                    }
                    let m = alive_ids.len();
                    candidates.clear();
                    match scenario.dispatch {
                        DispatchPolicy::RandomProbe => {
                            candidates.push(alive_ids[dispatch_rng.gen_range(m)]);
                        }
                        DispatchPolicy::PowerOfK(k) => {
                            let want = k.max(1).min(m);
                            while candidates.len() < want {
                                let c = alive_ids[dispatch_rng.gen_range(m)];
                                if !candidates.contains(&c) {
                                    candidates.push(c);
                                }
                            }
                        }
                        DispatchPolicy::RoundRobin => {
                            let c = alive_ids[rr_cursor % m];
                            rr_cursor = (rr_cursor + 1) % m;
                            candidates.push(c);
                        }
                    }
                    let placed = candidates.iter().copied().find(|&c| can_accept[c]);
                    match placed {
                        Some(node) => {
                            report.jobs_accepted += 1;
                            if spike_ahead(node, step) {
                                report.bad_accepts += 1;
                            } else {
                                report.good_accepts += 1;
                            }
                            report.outcomes.push(JobOutcome::Accepted { node, at: step });
                            inflight[node] += 1;
                            total_inflight += 1;
                            report.peak_inflight = report.peak_inflight.max(total_inflight);
                            queue.schedule(
                                ev.time + duration_steps as u64 * TICKS_PER_STEP,
                                Event::JobCompletion { node, job_id, epoch: epoch[node] },
                            );
                        }
                        None => {
                            report.jobs_rejected += 1;
                            if candidates.iter().any(|&c| spike_ahead(c, step)) {
                                report.justified_rejections += 1;
                            }
                            report.outcomes.push(JobOutcome::Rejected { at: step });
                        }
                    }
                }

                Event::JobCompletion { node, epoch: job_epoch, .. } => {
                    if alive[node] && epoch[node] == job_epoch && inflight[node] > 0 {
                        inflight[node] -= 1;
                        total_inflight -= 1;
                        report.jobs_completed += 1;
                    }
                }

                Event::FederationPush { leaf, snapshot, sent_at } => {
                    if let Some(snap) = pool.take(snapshot) {
                        if let Some(tree) = tree.as_mut() {
                            tree.push_from_leaf(leaf, &snap);
                        }
                        // Instant models still pay the 1-tick scheduling
                        // floor; don't let that show up as latency.
                        if !fed.latency.is_instant() {
                            lat_ticks_sum += ev.time - sent_at;
                            lat_count += 1;
                        }
                    }
                }

                Event::NodeLeave { node } => {
                    if !alive[node] {
                        continue;
                    }
                    if let Some(churn) = &scenario.churn {
                        if alive_ids.len() <= churn.min_alive {
                            continue; // floor reached since scheduling
                        }
                    }
                    alive[node] = false;
                    epoch[node] = epoch[node].wrapping_add(1);
                    report.jobs_displaced += inflight[node] as usize;
                    total_inflight -= inflight[node] as usize;
                    inflight[node] = 0;
                    report.node_leaves += 1;
                    alive_ids.retain(|&i| i != node);
                    if let Some(churn) = &scenario.churn {
                        if churn.rejoin_delay_mean > 0.0 {
                            let delay =
                                churn_rng.exponential(1.0 / churn.rejoin_delay_mean);
                            queue.schedule(
                                ev.time + latency_to_ticks(delay),
                                Event::NodeJoin { node },
                            );
                        }
                    }
                }

                Event::NodeJoin { node } => {
                    if alive[node] {
                        continue;
                    }
                    alive[node] = true;
                    report.node_joins += 1;
                    alive_ids.push(node);
                    alive_ids.sort_unstable();
                    // A restarted machine comes back with empty local
                    // state…
                    if let Some(f) = &factory {
                        policies[node] = f(node);
                        // …so its first post-restart push must clear the
                        // ε gate even if the re-learned iterate resembles
                        // the pre-restart one.
                        if let Some(tree) = tree.as_mut() {
                            tree.reset_leaf_gate(node);
                        }
                    }
                    // …and (§5.2) seeds it by pulling the merged global
                    // view — possibly stale, which is the point.
                    if fed.pull_on_join {
                        if let Some(tree) = tree.as_ref() {
                            let global = tree.global_view();
                            if !global.is_empty() {
                                policies[node].absorb(global, fed.pull_forget);
                            }
                        }
                    }
                    // Fresh nodes accept until their first telemetry tick
                    // says otherwise (cold PRONTO state raises no signal).
                    can_accept[node] = true;
                }
            }
        }

        if let Some(tree) = &tree {
            report.federation_pushes = tree.pushes();
            report.federation_suppressed = tree.suppressed();
        }
        if lat_count > 0 {
            report.mean_push_latency_steps =
                lat_ticks_sum as f64 / lat_count as f64 / TICKS_PER_STEP as f64;
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{NodeScheduler, ProntoPolicy, RandomPolicy, RejectConfig};
    use crate::sim::scenario::ChurnModel;
    use crate::telemetry::{GeneratorConfig, TraceGenerator};

    fn traces(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
        let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
        (0..n).map(|v| gen.generate_vm_in_cluster(0, v, steps)).collect()
    }

    fn pronto_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
        tr.iter()
            .map(|t| {
                Box::new(ProntoPolicy::new(NodeScheduler::new(
                    t.dim(),
                    RejectConfig::default(),
                ))) as Box<dyn Admission>
            })
            .collect()
    }

    fn always_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
        tr.iter()
            .enumerate()
            .map(|(i, _)| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
            .collect()
    }

    #[test]
    fn conservation_invariants_hold() {
        let tr = traces(4, 800, 1);
        let pol = pronto_policies(&tr);
        let sc = Scenario::default().with_steps(800).with_seed(7);
        let report = DiscreteEventEngine::new(sc, tr, pol).run();
        assert_eq!(report.jobs_arrived, report.jobs_accepted + report.jobs_rejected);
        assert_eq!(report.jobs_accepted, report.good_accepts + report.bad_accepts);
        assert_eq!(report.outcomes.len(), report.jobs_arrived);
        assert!(report.jobs_completed + report.jobs_displaced <= report.jobs_accepted);
    }

    #[test]
    fn same_seed_bitwise_identical_reports() {
        for name in ["baseline-poisson", "bursty"] {
            let sc = Scenario::named(name).unwrap().with_nodes(4).with_steps(600);
            let tr = traces(4, 600, 3);
            let a = DiscreteEventEngine::new(sc.clone(), tr.clone(), always_policies(&tr)).run();
            let b = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
            assert_eq!(a.to_json_string(), b.to_json_string(), "{name} diverged");
            assert_eq!(a.outcomes, b.outcomes);
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let tr = traces(4, 600, 3);
        let a = DiscreteEventEngine::new(
            Scenario::default().with_steps(600).with_seed(1),
            tr.clone(),
            always_policies(&tr),
        )
        .run();
        let b = DiscreteEventEngine::new(
            Scenario::default().with_steps(600).with_seed(2),
            tr.clone(),
            always_policies(&tr),
        )
        .run();
        assert_ne!(a.outcomes_digest(), b.outcomes_digest());
    }

    #[test]
    fn churn_fires_and_pool_recovers() {
        let sc = Scenario {
            churn: Some(ChurnModel {
                leave_hazard: 0.01,
                rejoin_delay_mean: 30.0,
                min_alive: 2,
            }),
            ..Scenario::named("churn").unwrap()
        }
        .with_nodes(6)
        .with_steps(1000);
        let tr = traces(6, 1000, 5);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert!(report.node_leaves > 0, "no churn happened");
        assert!(report.node_joins > 0, "nobody rejoined");
        assert!(report.node_joins <= report.node_leaves);
        assert_eq!(report.jobs_arrived, report.jobs_accepted + report.jobs_rejected);
    }

    #[test]
    fn federation_latency_pushes_are_counted_and_delayed() {
        let sc = Scenario::named("latency").unwrap().with_nodes(4).with_steps(800);
        let tr = traces(4, 800, 9);
        let report = DiscreteEventEngine::new(sc, tr.clone(), pronto_policies(&tr)).run();
        let total = report.federation_pushes + report.federation_suppressed;
        assert!(total > 0, "no pushes offered");
        assert!(report.mean_push_latency_steps > 0.5, "latency not applied");
    }

    #[test]
    fn min_alive_floor_is_respected() {
        let sc = Scenario {
            churn: Some(ChurnModel {
                leave_hazard: 0.5, // drain aggressively
                rejoin_delay_mean: 0.0, // never rejoin
                min_alive: 3,
            }),
            ..Scenario::default()
        }
        .with_nodes(5)
        .with_steps(400);
        let tr = traces(5, 400, 11);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert_eq!(report.node_leaves, 2, "floor violated: {}", report.node_leaves);
        assert_eq!(report.node_joins, 0);
    }

    #[test]
    fn json_report_is_valid_and_roundtrips() {
        let tr = traces(3, 300, 13);
        let sc = Scenario::default().with_steps(300);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        let text = report.to_json_string();
        let parsed = crate::ser::parse_json(&text).expect("valid json");
        assert_eq!(
            parsed.get("jobs_arrived").and_then(JsonValue::as_usize),
            Some(report.jobs_arrived)
        );
        assert_eq!(
            parsed.get("scenario").and_then(JsonValue::as_str),
            Some("baseline-poisson")
        );
    }
}
