//! Empirical CDFs — the primitive behind Figures 6 and 7.

/// An empirical cumulative distribution function over collected samples.
#[derive(Debug, Clone, Default)]
pub struct EmpiricalCdf {
    xs: Vec<f64>,
    sorted: bool,
}

impl EmpiricalCdf {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_samples(xs: &[f64]) -> Self {
        let mut c = Self::new();
        c.extend(xs);
        c
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.xs.extend_from_slice(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
    }

    /// F(x) = P(X ≤ x).
    pub fn eval(&mut self, x: f64) -> f64 {
        assert!(!self.xs.is_empty());
        self.ensure_sorted();
        let count = self.xs.partition_point(|&v| v <= x);
        count as f64 / self.xs.len() as f64
    }

    /// Evenly spaced (x, F(x)) points for plotting/CSV export.
    pub fn series(&mut self, points: usize) -> Vec<(f64, f64)> {
        assert!(points >= 2 && !self.xs.is_empty());
        self.ensure_sorted();
        let lo = self.xs[0];
        let hi = self.xs[self.xs.len() - 1];
        (0..points)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / (points - 1) as f64;
                (x, {
                    let count = self.xs.partition_point(|&v| v <= x);
                    count as f64 / self.xs.len() as f64
                })
            })
            .collect()
    }

    /// Inverse CDF (quantile) by the standard nearest-rank order
    /// statistic: the smallest x with F(x) ≥ q, i.e. sample
    /// `ceil(q·n) - 1` (0-indexed), with q = 0 mapping to the minimum.
    pub fn inverse(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q) && !self.xs.is_empty());
        self.ensure_sorted();
        let n = self.xs.len();
        let rank = (q * n as f64).ceil().max(1.0) as usize;
        self.xs[rank.min(n) - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_known_points() {
        let mut c = EmpiricalCdf::from_samples(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(c.eval(0.5), 0.0);
        assert_eq!(c.eval(1.0), 0.25);
        assert_eq!(c.eval(2.5), 0.5);
        assert_eq!(c.eval(4.0), 1.0);
        assert_eq!(c.eval(9.0), 1.0);
    }

    #[test]
    fn series_is_monotone() {
        let mut c = EmpiricalCdf::from_samples(&[3.0, 1.0, 2.0, 2.0, 5.0]);
        let s = c.series(11);
        assert_eq!(s.len(), 11);
        for w in s.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        assert_eq!(s.last().unwrap().1, 1.0);
    }

    #[test]
    fn inverse_matches_order_stats() {
        let mut c = EmpiricalCdf::from_samples(&[10.0, 20.0, 30.0]);
        assert_eq!(c.inverse(0.0), 10.0);
        assert_eq!(c.inverse(0.5), 20.0);
        assert_eq!(c.inverse(1.0), 30.0);
    }

    #[test]
    fn inverse_uses_nearest_rank_not_round_half_away() {
        // With n = 4 the nearest-rank statistic is ceil(q·4) - 1; the old
        // round((n-1)·q) formula gave sample index 2 (30.0) at the median.
        let mut c = EmpiricalCdf::from_samples(&[40.0, 10.0, 30.0, 20.0]);
        assert_eq!(c.inverse(0.0), 10.0);
        assert_eq!(c.inverse(0.25), 10.0);
        assert_eq!(c.inverse(0.26), 20.0);
        assert_eq!(c.inverse(0.5), 20.0);
        assert_eq!(c.inverse(0.75), 30.0);
        assert_eq!(c.inverse(0.9), 40.0);
        assert_eq!(c.inverse(1.0), 40.0);
    }

    #[test]
    fn inverse_is_smallest_x_with_mass_at_least_q() {
        // The defining property of the nearest-rank quantile, checked
        // against eval(): F(inverse(q)) >= q, and no smaller sample
        // satisfies it. (The engine report's `queue_delay_p<i>` keys are
        // per-priority *means*, not percentiles — the quality rows'
        // lead/decision/recall p50/p90/p99 all route through `inverse`,
        // so this property is the one that keeps those artifact figures
        // honest order statistics.)
        let mut c = EmpiricalCdf::from_samples(&[5.0, 1.0, 9.0, 3.0, 7.0, 2.0, 8.0]);
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let x = c.inverse(q);
            assert!(c.eval(x) >= q, "F({x}) < {q}");
            let smaller: Vec<f64> =
                [1.0, 2.0, 3.0, 5.0, 7.0, 8.0].iter().copied().filter(|&v| v < x).collect();
            if let Some(&prev) = smaller.last() {
                assert!(c.eval(prev) < q, "not minimal at q={q}");
            }
        }
    }
}
