//! Job-level data-center simulator.
//!
//! Trace-driven: each node's telemetry comes from the generator (the same
//! protocol as the paper's evaluation — the admission decision does not
//! feed back into the recorded trace). Jobs arrive as a Poisson stream;
//! the dispatcher probes nodes under a [`DispatchPolicy`]; each probed
//! node answers from its own [`crate::scheduler::Admission`] policy. The
//! simulator scores decision quality against the ground truth: a *good
//! accept* lands on a node whose CPU Ready stays calm over the job's first
//! window; a *bad accept* lands right before/inside a spike episode.

use crate::rng::Xoshiro256;
use crate::scheduler::{Admission, Job, JobOutcome};
use crate::telemetry::VmTrace;

/// How the dispatcher picks candidate nodes for an arriving job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchPolicy {
    /// Probe one uniformly random node (Sparrow-style single probe).
    RandomProbe,
    /// Probe `k` random nodes, accept the first that says yes.
    PowerOfK(usize),
    /// Round-robin over nodes.
    RoundRobin,
}

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Mean job inter-arrival in timesteps (Poisson process).
    pub arrival_rate_per_step: f64,
    /// Log-normal job duration parameters (in timesteps).
    pub duration_mu: f64,
    pub duration_sigma: f64,
    /// Dispatcher policy.
    pub dispatch: DispatchPolicy,
    /// CPU Ready level marking degraded service for scoring.
    pub ready_threshold: f64,
    /// Horizon after acceptance scored for degradation (timesteps).
    pub score_window: usize,
    /// RNG seed for arrivals/durations/probing.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            arrival_rate_per_step: 0.3,
            duration_mu: 3.0,   // e^3 ≈ 20 steps ≈ 7 min
            duration_sigma: 0.8,
            dispatch: DispatchPolicy::PowerOfK(2),
            ready_threshold: 1000.0,
            score_window: 5,
            seed: 7,
        }
    }
}

/// Aggregate result of a simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    pub steps: usize,
    pub nodes: usize,
    pub jobs_arrived: usize,
    pub jobs_accepted: usize,
    pub jobs_rejected: usize,
    /// Accepted jobs whose node stayed calm over the score window.
    pub good_accepts: usize,
    /// Accepted jobs whose node hit a CPU Ready spike in the score window.
    pub bad_accepts: usize,
    /// Rejections where the node indeed spiked in the score window
    /// (justified rejections).
    pub justified_rejections: usize,
    /// Per-job outcomes (ordered by arrival).
    pub outcomes: Vec<JobOutcome>,
}

impl SimReport {
    /// Fraction of accepted jobs placed on nodes that stayed healthy.
    pub fn placement_quality(&self) -> f64 {
        if self.jobs_accepted == 0 {
            return 1.0;
        }
        self.good_accepts as f64 / self.jobs_accepted as f64
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.jobs_arrived == 0 {
            return 1.0;
        }
        self.jobs_accepted as f64 / self.jobs_arrived as f64
    }

    /// Fraction of rejections that avoided a real spike.
    pub fn rejection_precision(&self) -> f64 {
        if self.jobs_rejected == 0 {
            return 1.0;
        }
        self.justified_rejections as f64 / self.jobs_rejected as f64
    }
}

/// The simulator: N nodes with aligned traces and admission policies.
pub struct DataCenterSim {
    cfg: SimConfig,
    traces: Vec<VmTrace>,
    policies: Vec<Box<dyn Admission>>,
}

impl DataCenterSim {
    /// One policy per trace (same order).
    pub fn new(cfg: SimConfig, traces: Vec<VmTrace>, policies: Vec<Box<dyn Admission>>) -> Self {
        assert_eq!(traces.len(), policies.len(), "one policy per node");
        assert!(!traces.is_empty());
        Self { cfg, traces, policies }
    }

    /// Run over the common trace prefix; returns the report.
    pub fn run(mut self) -> SimReport {
        let steps = self.traces.iter().map(VmTrace::len).min().unwrap();
        let n = self.traces.len();
        let mut rng = Xoshiro256::seed_from_u64(self.cfg.seed);
        let mut report = SimReport { nodes: n, steps, ..Default::default() };
        let mut next_job_id = 0u64;
        let mut rr_cursor = 0usize;

        // Per-node current admission answer for this timestep.
        let mut can_accept = vec![true; n];

        for t in 0..steps {
            // 1. Telemetry tick: every node consumes its metric vector.
            for (i, policy) in self.policies.iter_mut().enumerate() {
                can_accept[i] = policy.observe(self.traces[i].features(t));
            }

            // 2. Job arrivals this step.
            let arrivals = rng.poisson(self.cfg.arrival_rate_per_step) as usize;
            for _ in 0..arrivals {
                let duration = rng
                    .log_normal(self.cfg.duration_mu, self.cfg.duration_sigma)
                    .round()
                    .max(1.0) as usize;
                let job = Job::new(next_job_id, t, duration, 1.0);
                next_job_id += 1;
                report.jobs_arrived += 1;

                // 3. Dispatch: probe nodes per policy.
                let candidates: Vec<usize> = match self.cfg.dispatch {
                    DispatchPolicy::RandomProbe => vec![rng.gen_range(n)],
                    DispatchPolicy::PowerOfK(k) => rng.sample_indices(n, k.max(1)),
                    DispatchPolicy::RoundRobin => {
                        let c = rr_cursor;
                        rr_cursor = (rr_cursor + 1) % n;
                        vec![c]
                    }
                };
                let placed = candidates.iter().copied().find(|&c| can_accept[c]);

                // 4. Score against ground truth over the next window.
                let spike_ahead = |node: usize| -> bool {
                    let hi = (t + self.cfg.score_window).min(steps - 1);
                    (t..=hi).any(|tt| {
                        self.traces[node].cpu_ready(tt) >= self.cfg.ready_threshold
                    })
                };
                match placed {
                    Some(node) => {
                        report.jobs_accepted += 1;
                        if spike_ahead(node) {
                            report.bad_accepts += 1;
                        } else {
                            report.good_accepts += 1;
                        }
                        report.outcomes.push(JobOutcome::Accepted { node, at: t });
                    }
                    None => {
                        report.jobs_rejected += 1;
                        if candidates.iter().any(|&c| spike_ahead(c)) {
                            report.justified_rejections += 1;
                        }
                        report.outcomes.push(JobOutcome::Rejected { at: t });
                    }
                }
                let _ = job;
            }
        }
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{CpuReadyOracle, NodeScheduler, ProntoPolicy, RandomPolicy, RejectConfig};
    use crate::telemetry::{GeneratorConfig, TraceGenerator, CPU_READY_IDX};

    fn traces(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
        let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
        (0..n).map(|v| gen.generate_vm_in_cluster(0, v, steps)).collect()
    }

    fn pronto_policies(traces: &[VmTrace]) -> Vec<Box<dyn Admission>> {
        traces
            .iter()
            .map(|t| {
                Box::new(ProntoPolicy::new(NodeScheduler::new(
                    t.dim(),
                    RejectConfig::default(),
                ))) as Box<dyn Admission>
            })
            .collect()
    }

    #[test]
    fn conservation_of_jobs() {
        let tr = traces(4, 800, 1);
        let pol = pronto_policies(&tr);
        let report = DataCenterSim::new(SimConfig::default(), tr, pol).run();
        assert_eq!(
            report.jobs_arrived,
            report.jobs_accepted + report.jobs_rejected
        );
        assert_eq!(report.jobs_accepted, report.good_accepts + report.bad_accepts);
        assert_eq!(report.outcomes.len(), report.jobs_arrived);
    }

    #[test]
    fn oracle_placement_beats_always_accept() {
        let steps = 6000;
        let tr = traces(6, steps, 3);
        let oracle: Vec<Box<dyn Admission>> = tr
            .iter()
            .map(|_| Box::new(CpuReadyOracle::new(CPU_READY_IDX, 1000.0)) as Box<dyn Admission>)
            .collect();
        let always: Vec<Box<dyn Admission>> = tr
            .iter()
            .map(|_| Box::new(RandomPolicy::always_accept(1)) as Box<dyn Admission>)
            .collect();
        let r_oracle = DataCenterSim::new(SimConfig::default(), tr.clone(), oracle).run();
        let r_always = DataCenterSim::new(SimConfig::default(), tr, always).run();
        assert!(
            r_oracle.placement_quality() >= r_always.placement_quality(),
            "oracle {:.3} vs always {:.3}",
            r_oracle.placement_quality(),
            r_always.placement_quality()
        );
    }

    #[test]
    fn round_robin_covers_all_nodes() {
        let tr = traces(3, 500, 9);
        let pol: Vec<Box<dyn Admission>> = tr
            .iter()
            .map(|_| Box::new(RandomPolicy::always_accept(2)) as Box<dyn Admission>)
            .collect();
        let cfg = SimConfig { dispatch: DispatchPolicy::RoundRobin, ..Default::default() };
        let report = DataCenterSim::new(cfg, tr, pol).run();
        let mut nodes_used = [false; 3];
        for o in &report.outcomes {
            if let JobOutcome::Accepted { node, .. } = o {
                nodes_used[*node] = true;
            }
        }
        assert!(nodes_used.iter().all(|&u| u));
    }

    #[test]
    fn power_of_k_reduces_rejections_vs_single_probe() {
        let steps = 4000;
        let tr = traces(8, steps, 11);
        let mk = |tr: &[VmTrace]| pronto_policies(tr);
        let single = DataCenterSim::new(
            SimConfig { dispatch: DispatchPolicy::RandomProbe, ..Default::default() },
            tr.clone(),
            mk(&tr),
        )
        .run();
        let pok = DataCenterSim::new(
            SimConfig { dispatch: DispatchPolicy::PowerOfK(3), ..Default::default() },
            tr.clone(),
            mk(&tr),
        )
        .run();
        assert!(
            pok.acceptance_rate() >= single.acceptance_rate(),
            "PoK {:.3} vs single {:.3}",
            pok.acceptance_rate(),
            single.acceptance_rate()
        );
    }
}
