//! Naive forecaster: the prediction is the last observed value (§3.1
//! method 1).

use super::Forecaster;

/// Last-value persistence forecast.
#[derive(Debug, Clone, Copy, Default)]
pub struct Naive;

impl Forecaster for Naive {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn forecast(&self, history: &[f64], _pool: &[&[f64]], horizon: usize) -> Vec<f64> {
        assert!(!history.is_empty());
        vec![history[history.len() - 1]; horizon]
    }

    fn forecast_rolling(&self, history: &[f64], _pool: &[&[f64]], future: &[f64]) -> Vec<f64> {
        // One-step persistence over the revealed actuals.
        let mut prev = *history.last().expect("empty history");
        future
            .iter()
            .map(|&actual| {
                let pred = prev;
                prev = actual;
                pred
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeats_last_value() {
        let f = Naive;
        assert_eq!(f.forecast(&[1.0, 5.0, 3.0], &[], 3), vec![3.0, 3.0, 3.0]);
    }

    #[test]
    fn ignores_pool() {
        let f = Naive;
        let other = vec![9.0, 9.0, 9.0];
        let pool: Vec<&[f64]> = vec![&other];
        assert_eq!(f.forecast(&[2.0], &pool, 1), vec![2.0]);
    }
}
