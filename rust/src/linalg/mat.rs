//! Column-major dense matrix.
//!
//! Column-major matches the paper's convention (data matrices are d × n with
//! one *column* per observation) and makes appending streaming observations
//! a memcpy.
//!
//! The product kernels come in two backings (see [`LinalgBacking`]): the
//! default **blocked** kernels walk 4-column panels with register-jammed
//! plain-`f64` loops shaped for autovectorization (no intrinsics,
//! std-only), and the **scalar** backing keeps the historical
//! straight-line loops as a debug oracle, selectable at process start via
//! `PRONTO_LINALG=scalar`. Both backings perform, for every output
//! element, the *identical* sequence of floating-point operations — the
//! jam only reorders loads across independent accumulators — so results
//! are bit-identical by construction; `tests/linalg_oracle_parity.rs`
//! pins that forall-style and CI diffs full engine runs across backings
//! (the same contract as `PRONTO_EVENT_QUEUE=heap`).

use std::fmt;
use std::sync::OnceLock;

/// Width of the column panels the blocked kernels jam per pass. Four f64
/// accumulators fit comfortably in one AVX2 register file lane set and
/// still help on plain SSE2; the remainder columns fall back to the
/// single-column loop (which performs the same per-element op sequence).
const PANEL: usize = 4;

/// Which kernel implementation the dispatching [`Mat`] products use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinalgBacking {
    /// Column-panel blocked kernels (default).
    Blocked,
    /// Historical straight-line scalar loops — the debug oracle.
    Scalar,
}

static BACKING: OnceLock<LinalgBacking> = OnceLock::new();

impl LinalgBacking {
    /// Resolve the backing from `PRONTO_LINALG`: `scalar` selects the
    /// oracle, anything else (or unset) the blocked default. Uncached —
    /// the parity test exercises the env plumbing in-process; runtime
    /// callers go through [`LinalgBacking::current`].
    pub fn from_env() -> Self {
        match std::env::var("PRONTO_LINALG") {
            Ok(v) if v == "scalar" => LinalgBacking::Scalar,
            _ => LinalgBacking::Blocked,
        }
    }

    /// The process-wide backing used by the dispatching kernels, resolved
    /// from the environment once at first use (a getenv per matvec would
    /// dominate the small kernels the hot paths issue).
    pub fn current() -> Self {
        *BACKING.get_or_init(Self::from_env)
    }
}

/// Dense, heap-allocated, column-major `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    /// Column-major storage: element (i, j) lives at `data[j * rows + i]`.
    data: Vec<f64>,
}

impl Mat {
    /// Zero-filled rows × cols matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size n.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from a column-major buffer.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Build from a row-major buffer (convenient for literals in tests).
    pub fn from_rows(rows: usize, cols: usize, row_major: &[f64]) -> Self {
        assert_eq!(row_major.len(), rows * cols, "buffer size mismatch");
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.set(i, j, row_major[i * cols + j]);
            }
        }
        m
    }

    /// Build a d × 1 column vector.
    pub fn col_vec(v: &[f64]) -> Self {
        Self::from_col_major(v.len(), 1, v.to_vec())
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m.set(i, i, x);
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// True when either dimension is zero.
    pub fn is_empty(&self) -> bool {
        self.rows == 0 || self.cols == 0
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    /// Raw column-major buffer.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw column-major buffer.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow column j as a slice (free thanks to column-major layout).
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable borrow of column j.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Copy of row i.
    pub fn row(&self, i: usize) -> Vec<f64> {
        (0..self.cols).map(|j| self.get(i, j)).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            for i in 0..self.rows {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Matrix product `self * rhs` (allocating convenience wrapper over
    /// [`Mat::matmul_into`]).
    pub fn matmul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.cols, rhs.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, rhs.cols);
        self.matmul_into(rhs, &mut out);
        out
    }

    /// Matrix product `self * rhs` into a caller-owned output, through the
    /// backing selected by `PRONTO_LINALG` (see [`LinalgBacking`]). Both
    /// backings accumulate every output element over `k` ascending with
    /// one multiply-add per term, so they are bit-identical.
    pub fn matmul_into(&self, rhs: &Mat, out: &mut Mat) {
        self.matmul_into_with(rhs, out, LinalgBacking::current());
    }

    /// Explicit-backing variant of [`Mat::matmul_into`] — used by the
    /// parity oracle to compare both kernels inside one process.
    pub fn matmul_into_with(&self, rhs: &Mat, out: &mut Mat, backing: LinalgBacking) {
        assert_eq!(self.cols, rhs.rows, "matmul dim mismatch");
        assert_eq!(
            (out.rows, out.cols),
            (self.rows, rhs.cols),
            "matmul out shape mismatch"
        );
        match backing {
            LinalgBacking::Scalar => self.matmul_into_scalar(rhs, out),
            LinalgBacking::Blocked => self.matmul_into_blocked(rhs, out),
        }
    }

    /// Batched matrix–vector product: `out[:, j] = self · xs[:, j]` for
    /// every column of `xs` — one panel kernel pass instead of
    /// `xs.cols()` separate matvecs. Shares the matmul core. Unlike
    /// [`Mat::matvec_into`] this path carries no per-element zero skip
    /// (skipping would break the register jam); use the single-vector
    /// path where the historical skip semantics matter.
    pub fn batch_matvec_into(&self, xs: &Mat, out: &mut Mat) {
        self.matmul_into(xs, out);
    }

    /// Explicit-backing variant of [`Mat::batch_matvec_into`].
    pub fn batch_matvec_into_with(&self, xs: &Mat, out: &mut Mat, backing: LinalgBacking) {
        self.matmul_into_with(xs, out, backing);
    }

    /// Scalar oracle: one output column at a time, `k` ascending.
    fn matmul_into_scalar(&self, rhs: &Mat, out: &mut Mat) {
        for j in 0..rhs.cols {
            let ocol = out.col_mut(j);
            ocol.fill(0.0);
            for k in 0..self.cols {
                let b = rhs.data[j * rhs.rows + k];
                let a = &self.data[k * self.rows..(k + 1) * self.rows];
                for i in 0..a.len() {
                    ocol[i] += a[i] * b;
                }
            }
        }
    }

    /// Blocked kernel: 4-wide output-column panels. Per `k` the `self`
    /// column is loaded once and axpy'd into four independent output
    /// columns — the compiler keeps four accumulator streams live and
    /// autovectorizes the inner loop. Each output element still receives
    /// exactly one `+= a·b` per `k`, in `k` order: bit-identical to the
    /// scalar oracle.
    fn matmul_into_blocked(&self, rhs: &Mat, out: &mut Mat) {
        let rows = self.rows;
        let mut j = 0;
        while j + PANEL <= rhs.cols {
            let panel = &mut out.data[j * rows..(j + PANEL) * rows];
            panel.fill(0.0);
            let (c0, rest) = panel.split_at_mut(rows);
            let (c1, rest) = rest.split_at_mut(rows);
            let (c2, c3) = rest.split_at_mut(rows);
            for k in 0..self.cols {
                let a = &self.data[k * rows..(k + 1) * rows];
                let b0 = rhs.data[j * rhs.rows + k];
                let b1 = rhs.data[(j + 1) * rhs.rows + k];
                let b2 = rhs.data[(j + 2) * rhs.rows + k];
                let b3 = rhs.data[(j + 3) * rhs.rows + k];
                for i in 0..a.len() {
                    let ai = a[i];
                    c0[i] += ai * b0;
                    c1[i] += ai * b1;
                    c2[i] += ai * b2;
                    c3[i] += ai * b3;
                }
            }
            j += PANEL;
        }
        while j < rhs.cols {
            let ocol = out.col_mut(j);
            ocol.fill(0.0);
            for k in 0..self.cols {
                let b = rhs.data[j * rhs.rows + k];
                let a = &self.data[k * self.rows..(k + 1) * self.rows];
                for i in 0..a.len() {
                    ocol[i] += a[i] * b;
                }
            }
            j += 1;
        }
    }

    /// `selfᵀ * rhs` without materializing the transpose: each output entry
    /// is a dot product of two columns — both contiguous.
    pub fn transpose_mul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.rows, rhs.rows, "transpose_mul dim mismatch");
        let mut out = Mat::zeros(self.cols, rhs.cols);
        for j in 0..rhs.cols {
            let rcol = rhs.col(j);
            for i in 0..self.cols {
                let lcol = self.col(i);
                let mut s = 0.0;
                for k in 0..lcol.len() {
                    s += lcol[k] * rcol[k];
                }
                out.set(i, j, s);
            }
        }
        out
    }

    /// Gram matrix `selfᵀ · self`, exploiting symmetry (computes the upper
    /// triangle once and mirrors it — ~2× over `transpose_mul(self)`).
    pub fn gram(&self) -> Mat {
        let c = self.cols;
        let mut out = Mat::zeros(c, c);
        for i in 0..c {
            let ci = self.col(i);
            for j in i..c {
                let cj = self.col(j);
                let mut s = 0.0;
                for k in 0..ci.len() {
                    s += ci[k] * cj[k];
                }
                out.set(i, j, s);
                out.set(j, i, s);
            }
        }
        out
    }

    /// Matrix–vector product `self * v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(v, &mut out);
        out
    }

    /// Matrix–vector product `self * v` into a caller-owned buffer
    /// (allocation-free), through the backing selected by `PRONTO_LINALG`.
    /// Both backings keep the historical `x == 0.0` column skip and add
    /// terms in `j` ascending order, so results are bit-identical to each
    /// other and to [`Mat::matvec`].
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        self.matvec_into_with(v, out, LinalgBacking::current());
    }

    /// Explicit-backing variant of [`Mat::matvec_into`] — used by the
    /// parity oracle to compare both kernels inside one process.
    // The fused update below must stay `out[i] = out[i] + t0 + t1 + …`:
    // `+=` would sum the terms *before* folding them into the
    // accumulator, a different FP association than the scalar oracle's
    // one-add-per-term sequence.
    #[allow(clippy::assign_op_pattern)]
    pub fn matvec_into_with(&self, v: &[f64], out: &mut [f64], backing: LinalgBacking) {
        assert_eq!(self.cols, v.len(), "matvec dim mismatch");
        assert_eq!(self.rows, out.len(), "matvec out dim mismatch");
        out.fill(0.0);
        match backing {
            LinalgBacking::Scalar => {
                for (j, &x) in v.iter().enumerate() {
                    if x == 0.0 {
                        continue;
                    }
                    let c = self.col(j);
                    for i in 0..self.rows {
                        out[i] += c[i] * x;
                    }
                }
            }
            LinalgBacking::Blocked => {
                // 4-column jam: one pass over `out` folds four scaled
                // columns, left to right — the same one-add-per-term
                // sequence as the scalar loop. Panels containing a zero
                // coefficient drop to the per-column loop so the skip
                // semantics (and `±0.0`/`inf` edge cases) stay exact.
                let rows = self.rows;
                let mut j = 0;
                while j + PANEL <= self.cols {
                    let (x0, x1, x2, x3) = (v[j], v[j + 1], v[j + 2], v[j + 3]);
                    if x0 != 0.0 && x1 != 0.0 && x2 != 0.0 && x3 != 0.0 {
                        let block = &self.data[j * rows..(j + PANEL) * rows];
                        let (a0, rest) = block.split_at(rows);
                        let (a1, rest) = rest.split_at(rows);
                        let (a2, a3) = rest.split_at(rows);
                        for i in 0..rows {
                            out[i] = out[i] + a0[i] * x0 + a1[i] * x1 + a2[i] * x2 + a3[i] * x3;
                        }
                    } else {
                        for t in 0..PANEL {
                            let x = v[j + t];
                            if x == 0.0 {
                                continue;
                            }
                            let c = self.col(j + t);
                            for i in 0..rows {
                                out[i] += c[i] * x;
                            }
                        }
                    }
                    j += PANEL;
                }
                while j < self.cols {
                    let x = v[j];
                    if x != 0.0 {
                        let c = self.col(j);
                        for i in 0..rows {
                            out[i] += c[i] * x;
                        }
                    }
                    j += 1;
                }
            }
        }
    }

    /// `selfᵀ * v` — projections of v onto each column (allocating
    /// convenience wrapper over [`Mat::transpose_matvec_into`]).
    pub fn transpose_matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.cols];
        self.transpose_matvec_into(v, &mut out);
        out
    }

    /// `selfᵀ * v` into a caller-owned buffer, through the backing
    /// selected by `PRONTO_LINALG`. Every output element is a dot product
    /// accumulated over the row index ascending in both backings —
    /// bit-identical.
    pub fn transpose_matvec_into(&self, v: &[f64], out: &mut [f64]) {
        self.transpose_matvec_into_with(v, out, LinalgBacking::current());
    }

    /// Explicit-backing variant of [`Mat::transpose_matvec_into`].
    pub fn transpose_matvec_into_with(&self, v: &[f64], out: &mut [f64], backing: LinalgBacking) {
        assert_eq!(self.rows, v.len(), "transpose_matvec dim mismatch");
        assert_eq!(self.cols, out.len(), "transpose_matvec out dim mismatch");
        match backing {
            LinalgBacking::Scalar => {
                for j in 0..self.cols {
                    let c = self.col(j);
                    let mut s = 0.0;
                    for i in 0..c.len() {
                        s += c[i] * v[i];
                    }
                    out[j] = s;
                }
            }
            LinalgBacking::Blocked => {
                // 4-column jam sharing each `v` load across four
                // independent accumulators; each accumulator performs the
                // exact op sequence of its scalar dot.
                let rows = self.rows;
                let mut j = 0;
                while j + PANEL <= self.cols {
                    let block = &self.data[j * rows..(j + PANEL) * rows];
                    let (a0, rest) = block.split_at(rows);
                    let (a1, rest) = rest.split_at(rows);
                    let (a2, a3) = rest.split_at(rows);
                    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
                    for i in 0..rows {
                        let vi = v[i];
                        s0 += a0[i] * vi;
                        s1 += a1[i] * vi;
                        s2 += a2[i] * vi;
                        s3 += a3[i] * vi;
                    }
                    out[j] = s0;
                    out[j + 1] = s1;
                    out[j + 2] = s2;
                    out[j + 3] = s3;
                    j += PANEL;
                }
                while j < self.cols {
                    let c = self.col(j);
                    let mut s = 0.0;
                    for i in 0..c.len() {
                        s += c[i] * v[i];
                    }
                    out[j] = s;
                    j += 1;
                }
            }
        }
    }

    /// Horizontal concatenation `[self | rhs]`.
    pub fn hcat(&self, rhs: &Mat) -> Mat {
        if self.is_empty() {
            return rhs.clone();
        }
        if rhs.is_empty() {
            return self.clone();
        }
        assert_eq!(self.rows, rhs.rows, "hcat row mismatch");
        let mut data = Vec::with_capacity(self.data.len() + rhs.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&rhs.data);
        Mat::from_col_major(self.rows, self.cols + rhs.cols, data)
    }

    /// Copy of the leading `k` columns.
    pub fn take_cols(&self, k: usize) -> Mat {
        assert!(k <= self.cols);
        Mat::from_col_major(self.rows, k, self.data[..k * self.rows].to_vec())
    }

    /// Scale every entry in place.
    pub fn scale_in_place(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Scaled copy.
    pub fn scaled(&self, s: f64) -> Mat {
        let mut m = self.clone();
        m.scale_in_place(s);
        m
    }

    /// Column-scaled copy: column j multiplied by `s[j]` (i.e. `self * diag(s)`).
    pub fn mul_diag(&self, s: &[f64]) -> Mat {
        assert_eq!(self.cols, s.len());
        let mut m = self.clone();
        for j in 0..m.cols {
            let f = s[j];
            for x in m.col_mut(j) {
                *x *= f;
            }
        }
        m
    }

    /// Element-wise difference.
    pub fn sub(&self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self
            .data
            .iter()
            .zip(rhs.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Mat::from_col_major(self.rows, self.cols, data)
    }

    /// Element-wise sum.
    pub fn add(&self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self
            .data
            .iter()
            .zip(rhs.data.iter())
            .map(|(a, b)| a + b)
            .collect();
        Mat::from_col_major(self.rows, self.cols, data)
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self.get(i, j))?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(2, 2, &[19.0, 22.0, 43.0, 50.0]));
    }

    #[test]
    fn transpose_mul_matches_explicit() {
        let a = Mat::from_rows(3, 2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Mat::from_rows(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let via_helper = a.transpose_mul(&b);
        let explicit = a.transpose().matmul(&b);
        assert_eq!(via_helper, explicit);
    }

    #[test]
    fn matvec_and_transpose_matvec() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.matvec(&[1.0, 1.0, 1.0]), vec![6.0, 15.0]);
        assert_eq!(a.transpose_matvec(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn hcat_shapes_and_content() {
        let a = Mat::from_rows(2, 1, &[1.0, 2.0]);
        let b = Mat::from_rows(2, 2, &[3.0, 4.0, 5.0, 6.0]);
        let c = a.hcat(&b);
        assert_eq!((c.rows(), c.cols()), (2, 3));
        assert_eq!(c.get(0, 1), 3.0);
        assert_eq!(c.get(1, 2), 6.0);
    }

    #[test]
    fn hcat_with_empty() {
        let e = Mat::zeros(3, 0);
        let a = Mat::from_rows(3, 1, &[1.0, 2.0, 3.0]);
        assert_eq!(e.hcat(&a), a);
        assert_eq!(a.hcat(&e), a);
    }

    #[test]
    fn mul_diag_scales_columns() {
        let a = Mat::from_rows(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let s = a.mul_diag(&[2.0, 3.0]);
        assert_eq!(s, Mat::from_rows(2, 2, &[2.0, 3.0, 2.0, 3.0]));
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(3, 2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn frob_norm_known() {
        let a = Mat::from_rows(2, 2, &[3.0, 0.0, 0.0, 4.0]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
    }

    fn random_mat(rng: &mut crate::rng::Xoshiro256, rows: usize, cols: usize) -> Mat {
        Mat::from_col_major(rows, cols, (0..rows * cols).map(|_| rng.normal()).collect())
    }

    #[test]
    fn matmul_backings_bit_agree_across_shapes() {
        // Shapes straddling the panel width: full panels, remainders,
        // degenerate single-row/column cases.
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(9);
        for &(m, k, n) in &[(5, 7, 9), (8, 4, 4), (3, 1, 6), (1, 5, 1), (6, 6, 5), (4, 3, 8)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let mut blocked = Mat::zeros(m, n);
            let mut scalar = Mat::zeros(m, n);
            a.matmul_into_with(&b, &mut blocked, LinalgBacking::Blocked);
            a.matmul_into_with(&b, &mut scalar, LinalgBacking::Scalar);
            assert_eq!(blocked.data(), scalar.data(), "{m}x{k}·{k}x{n}");
            assert_eq!(a.matmul(&b).data(), blocked.data());
        }
    }

    #[test]
    fn matvec_backings_bit_agree_with_zero_gates_and_remainders() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(10);
        for cols in 1..=11 {
            let a = random_mat(&mut rng, 7, cols);
            let mut v: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            if cols > 2 {
                v[1] = 0.0; // exercise the skip inside a jammed panel
            }
            let mut blocked = vec![0.0; 7];
            let mut scalar = vec![0.0; 7];
            a.matvec_into_with(&v, &mut blocked, LinalgBacking::Blocked);
            a.matvec_into_with(&v, &mut scalar, LinalgBacking::Scalar);
            assert_eq!(blocked, scalar, "matvec cols={cols}");
            assert_eq!(a.matvec(&v), blocked);

            let y: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
            let mut tb = vec![0.0; cols];
            let mut ts = vec![0.0; cols];
            a.transpose_matvec_into_with(&y, &mut tb, LinalgBacking::Blocked);
            a.transpose_matvec_into_with(&y, &mut ts, LinalgBacking::Scalar);
            assert_eq!(tb, ts, "transpose_matvec cols={cols}");
            assert_eq!(a.transpose_matvec(&y), tb);
        }
    }

    #[test]
    fn batch_matvec_matches_per_column_matvec() {
        // Zero-free inputs: the batched kernel (no zero skip) must agree
        // bit-for-bit with the gated single-vector path column by column.
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(11);
        let a = random_mat(&mut rng, 9, 6);
        let xs = random_mat(&mut rng, 6, 7);
        let mut out = Mat::zeros(9, 7);
        a.batch_matvec_into(&xs, &mut out);
        for j in 0..xs.cols() {
            assert_eq!(out.col(j), a.matvec(xs.col(j)).as_slice(), "column {j}");
        }
    }

    #[test]
    fn env_selects_the_scalar_oracle() {
        // `from_env` is the uncached read; the isolated parity binary
        // (tests/linalg_oracle_parity.rs) pins the set_var plumbing.
        // Here we only pin the default.
        assert_eq!(LinalgBacking::current(), LinalgBacking::from_env());
    }
}

#[cfg(test)]
mod gram_tests {
    use super::*;

    #[test]
    fn gram_matches_transpose_mul() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(3);
        let data: Vec<f64> = (0..52 * 36).map(|_| rng.normal()).collect();
        let a = Mat::from_col_major(52, 36, data);
        let fast = a.gram();
        let slow = a.transpose_mul(&a);
        assert!(crate::linalg::frob_diff(&fast, &slow) < 1e-10);
    }
}
