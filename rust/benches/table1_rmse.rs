//! Table 1: average RMSE predicting per-VM daily-median CPU Ready values
//! from the same VM vs same-cluster VMs, 14/21-day windows.
//!
//! Paper shape to reproduce: all errors are large; ARIMA/SVM lower than
//! naive/ExpSmo; SVM benefits from cluster pooling.

use pronto::bench::experiments::{table1_rmse, ExperimentScale};
use pronto::bench::Table;

fn main() {
    let scale = ExperimentScale::from_env();
    let rows = table1_rmse(&scale);
    let mut t = Table::new(
        "Table 1: avg RMSE, per-VM daily-median CPU Ready forecasts",
        &["method", "sameVM 14d", "sameVM 21d", "cluster 14d", "cluster 21d"],
    );
    for (name, c) in rows {
        t.row(&[
            name,
            format!("{:.2}", c[0]),
            format!("{:.2}", c[1]),
            format!("{:.2}", c[2]),
            format!("{:.2}", c[3]),
        ]);
    }
    t.print();
    t.maybe_write_csv("table1");
    println!("\npaper reference (same layout): naive 127.61/128.79/145.61/145.60 | SVM 121.92/118.01/103.66/100.23");
}
