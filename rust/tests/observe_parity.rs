//! Byte-parity of the parallel observe loop: for any scenario, seed, and
//! trace-source backing, `threads ∈ {1, 2, 4, 7}` must produce
//! **byte-identical** `--json` reports — the whole determinism contract
//! of `Scenario::threads`. Covers the full catalog deterministically and
//! random small scenarios property-style (replayable via
//! `PRONTO_PROP_SEED` / `PRONTO_PROP_CASES`, like the other prop suites).

use pronto::proptest::forall;
use pronto::scheduler::{Admission, NodeScheduler, ProntoPolicy, RandomPolicy, RejectConfig};
use pronto::sim::{ArrivalPattern, ChurnModel, DiscreteEventEngine, ProbePolicy, Scenario, CATALOG};
use pronto::telemetry::{fleet_members, GeneratorConfig, TraceGenerator, TraceSource, VmTrace};

const FANOUT: usize = 4;

fn fleet(nodes: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    fleet_members(nodes, FANOUT)
        .into_iter()
        .map(|(c, v)| gen.generate_vm_in_cluster(c, v, steps))
        .collect()
}

#[derive(Clone, Copy)]
enum PolicyKind {
    Always,
    /// Per-node RNG state: exercises statefulness without FPCA cost.
    Random,
    /// Full FPCA pipeline per node.
    Pronto,
}

fn make_policy(kind: PolicyKind, seed: u64, node: usize, dim: usize) -> Box<dyn Admission> {
    match kind {
        PolicyKind::Always => Box::new(RandomPolicy::always_accept(seed ^ node as u64)),
        PolicyKind::Random => Box::new(RandomPolicy::new(0.25, seed ^ node as u64)),
        PolicyKind::Pronto => {
            Box::new(ProntoPolicy::new(NodeScheduler::new(dim, RejectConfig::default())))
        }
    }
}

fn policies(kind: PolicyKind, traces: &[VmTrace], seed: u64) -> Vec<Box<dyn Admission>> {
    traces
        .iter()
        .enumerate()
        .map(|(node, t)| make_policy(kind, seed, node, t.dim()))
        .collect()
}

/// Run `scenario` and return the byte artifact.
fn report_json(
    scenario: &Scenario,
    traces: &[VmTrace],
    kind: PolicyKind,
    threads: usize,
    streaming: bool,
) -> String {
    let scenario = scenario.clone().with_threads(threads);
    let pol = policies(kind, traces, scenario.seed);
    let source = if streaming {
        let gen = TraceGenerator::new(GeneratorConfig::default(), scenario.seed);
        TraceSource::streaming(
            &gen,
            &fleet_members(scenario.nodes, FANOUT),
            scenario.steps,
            scenario.score_window,
        )
    } else {
        TraceSource::materialized(traces.to_vec())
    };
    let mut engine = DiscreteEventEngine::try_from_source(scenario.clone(), source, pol)
        .expect("valid parity fleet");
    if scenario.churn.is_some() {
        let seed = scenario.seed;
        let dims: Vec<usize> = traces.iter().map(VmTrace::dim).collect();
        let factory: pronto::sim::PolicyFactory =
            Box::new(move |node| make_policy(kind, seed, node, dims[node]));
        engine = engine.with_policy_factory(factory);
    }
    engine.run().to_json_string()
}

#[test]
fn every_catalog_scenario_is_byte_identical_across_thread_counts() {
    // The acceptance criterion: `--threads 4` ≡ `--threads 1` for every
    // catalog scenario (shrunk to test sizes — the scale entries keep
    // their arrival/capacity shape, just fewer nodes). `always` keeps
    // the sweep fast; stateful-policy coverage lives in the tests below.
    for name in CATALOG {
        let sc = Scenario::named(name).unwrap().with_nodes(12).with_steps(200).with_seed(71);
        let tr = fleet(12, 200, sc.seed);
        let base = report_json(&sc, &tr, PolicyKind::Always, 1, false);
        for threads in [2, 4, 7] {
            assert_eq!(
                base,
                report_json(&sc, &tr, PolicyKind::Always, threads, false),
                "catalog scenario '{name}' diverged at {threads} threads"
            );
        }
        // Streaming backing under a parallel observe loop: still the
        // same bytes.
        assert_eq!(
            base,
            report_json(&sc, &tr, PolicyKind::Always, 4, true),
            "catalog scenario '{name}' diverged streaming at 4 threads"
        );
    }
}

#[test]
fn stateful_policies_stay_byte_identical_under_sharding() {
    // FPCA iterates (pronto) and per-node RNG (random) carry state from
    // tick to tick — exactly what sharding must not perturb.
    for (name, kind) in [
        ("baseline-poisson", PolicyKind::Pronto),
        ("churn", PolicyKind::Pronto),
        ("capacity", PolicyKind::Random),
        ("flash-crowd", PolicyKind::Random),
    ] {
        let sc = Scenario::named(name).unwrap().with_nodes(8).with_steps(300).with_seed(5);
        let tr = fleet(8, 300, sc.seed);
        let base = report_json(&sc, &tr, kind, 1, false);
        for threads in [2, 7] {
            assert_eq!(
                base,
                report_json(&sc, &tr, kind, threads, false),
                "'{name}' with stateful policies diverged at {threads} threads"
            );
        }
        assert_eq!(
            base,
            report_json(&sc, &tr, kind, 4, true),
            "'{name}' streaming x 4 threads diverged"
        );
    }
}

#[test]
fn random_small_scenarios_are_thread_count_invariant() {
    forall("threads ∈ {1,2,4,7} × sources byte parity", |rng| {
        let nodes = 3 + rng.gen_range(10);
        let steps = 60 + rng.gen_range(120);
        let seed = rng.next_u64();
        let mut sc = Scenario::default().with_nodes(nodes).with_steps(steps).with_seed(seed);
        sc.arrivals = match rng.gen_range(3) {
            0 => ArrivalPattern::Poisson { rate: 0.2 + rng.next_f64() },
            1 => ArrivalPattern::Bursty {
                base_rate: 0.2,
                burst_rate: 1.0 + rng.next_f64() * 3.0,
                mean_burst_len: 10.0,
                mean_gap_len: 40.0,
            },
            _ => ArrivalPattern::Diurnal { base_rate: 0.4, amplitude: 0.8, period_steps: 50 },
        };
        sc.probe = match rng.gen_range(3) {
            0 => ProbePolicy::RandomProbe,
            1 => ProbePolicy::PowerOfK(1 + rng.gen_range(3)),
            _ => ProbePolicy::RoundRobin,
        };
        if rng.bernoulli(0.4) && nodes > 2 {
            sc.churn = Some(ChurnModel {
                leave_hazard: 0.01,
                rejoin_delay_mean: 15.0,
                min_alive: 2,
            });
        }
        if rng.bernoulli(0.5) {
            sc.capacity = Some(Default::default());
        }
        let tr = fleet(nodes, steps, seed);
        let kind = if rng.bernoulli(0.5) {
            PolicyKind::Always
        } else {
            PolicyKind::Random
        };
        let base = report_json(&sc, &tr, kind, 1, false);
        for threads in [2, 4, 7] {
            let got = report_json(&sc, &tr, kind, threads, false);
            if got != base {
                return Err(format!(
                    "materialized diverged at {threads} threads ({nodes} nodes x {steps})"
                ));
            }
        }
        // Streaming vs materialized under a parallel loop.
        let got = report_json(&sc, &tr, kind, 4, true);
        if got != base {
            return Err(format!(
                "streaming x 4 threads diverged ({nodes} nodes x {steps} steps)"
            ));
        }
        Ok(())
    });
}
