//! Synthetic virtualized-data-center trace generator.
//!
//! Substitutes the paper's proprietary Company dataset (DESIGN.md §5). The
//! generator reproduces the *causal structure* PRONTO's premise rests on:
//!
//! 1. **Low-rank telemetry** — a handful of latent workload factors
//!    (cpu / memory / io / network pressure) drive all 52 correlated
//!    counters through an archetype-specific loading matrix, so the
//!    top-r principal subspace captures the workload state.
//! 2. **Contention episodes** — CPU Ready is near its noise floor except
//!    during episodes whose hazard grows with CPU pressure. Each episode
//!    begins with a **precursor ramp** in the latent factors `lead` samples
//!    before the CPU Ready spike: exactly the "projection spike precedes
//!    CPU Ready spike" phenomenon of Figure 4.
//! 3. **Surprise spikes** — a configurable fraction of spikes has no
//!    precursor, bounding achievable prediction accuracy like the real
//!    trace does.
//! 4. **Diurnal + weekly seasonality** and AR(1) jitter, heavy-tailed spike
//!    magnitudes, per-VM archetypes (web / db / batch / idle) so the
//!    KMeans pre-clustering experiments (Table 2) have structure to find.

use crate::linalg::Mat;
use crate::rng::Xoshiro256;
use crate::telemetry::catalog::{vm_metric_names, CPU_READY_IDX, SAMPLE_PERIOD_MS, VM_DIM};
use crate::telemetry::trace::VmTrace;

/// Samples per day at the 20 s cadence.
pub const STEPS_PER_DAY: usize = 24 * 60 * 60 / 20;

/// Number of latent workload factors.
pub const LATENT_K: usize = 4;

/// Number of workload archetypes.
pub const N_ARCHETYPES: usize = 4;

/// Generator knobs. Defaults are calibrated so the fixed-threshold spike
/// rates land near the paper's Table 4 "% of spikes" row
/// (≈9.5 % above 500 ms, ≈2.6 % above 800 ms, ≈0.9 % above 1000 ms).
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Feature dimension (52 = the VM metric catalog).
    pub dim: usize,
    /// Baseline CPU Ready log-normal location (ln ms).
    pub ready_mu: f64,
    /// Baseline CPU Ready log-normal scale.
    pub ready_sigma: f64,
    /// Per-step hazard of starting a contention episode at neutral load.
    pub episode_hazard: f64,
    /// How strongly CPU pressure multiplies the hazard.
    pub hazard_load_gain: f64,
    /// Precursor lead time in samples (projection drift precedes the spike
    /// by up to this many steps).
    pub lead: usize,
    /// Mean episode duration in samples (geometric).
    pub mean_episode_len: f64,
    /// Magnitude of the precursor shift in latent-factor std units.
    pub precursor_gain: f64,
    /// Fraction of episodes that skip the precursor ("surprise" spikes).
    pub surprise_rate: f64,
    /// Per-metric observation noise std (relative to signal scale).
    pub obs_noise: f64,
    /// AR(1) pole for latent factor jitter.
    pub ar_rho: f64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        Self {
            dim: VM_DIM,
            ready_mu: 110.0f64.ln(),
            ready_sigma: 0.8,
            episode_hazard: 0.0022,
            hazard_load_gain: 2.5,
            lead: 5,
            mean_episode_len: 3.5,
            precursor_gain: 6.0,
            surprise_rate: 0.10,
            obs_noise: 0.08,
            ar_rho: 0.9,
        }
    }
}

/// A generated cluster: a set of VM traces sharing cluster-level factor
/// weather (so "same cluster VMs" carry signal for Tables 1–3).
#[derive(Debug, Clone)]
pub struct ClusterTrace {
    pub cluster_id: usize,
    pub vms: Vec<VmTrace>,
}

impl ClusterTrace {
    /// Total spike fraction above `threshold` ms across all VMs.
    pub fn spike_fraction(&self, threshold: f64) -> f64 {
        let mut spikes = 0usize;
        let mut total = 0usize;
        for vm in &self.vms {
            for t in 0..vm.len() {
                total += 1;
                if vm.cpu_ready(t) >= threshold {
                    spikes += 1;
                }
            }
        }
        spikes as f64 / total.max(1) as f64
    }
}

/// Deterministic trace generator. The same (config, seed, cluster, vm)
/// tuple always produces the same trace.
#[derive(Debug, Clone)]
pub struct TraceGenerator {
    cfg: GeneratorConfig,
    seed: u64,
}

/// Metric group boundaries in the VM catalog (see `catalog.rs`):
/// cpu [0,13), mem [13,28), disk [28,40), net [40,48), sys [48,52).
const GROUPS: [(usize, usize); 5] = [(0, 13), (13, 28), (28, 40), (40, 48), (48, 52)];

/// Which latent factor dominates each metric group (sys tracks cpu).
const GROUP_FACTOR: [usize; 5] = [0, 1, 2, 3, 0];

impl TraceGenerator {
    pub fn new(cfg: GeneratorConfig, seed: u64) -> Self {
        assert!(cfg.dim >= 8, "need at least the core metric groups");
        assert!(cfg.lead >= 1);
        Self { cfg, seed }
    }

    pub fn config(&self) -> &GeneratorConfig {
        &self.cfg
    }

    /// Archetype-and-VM-specific loading matrix L ∈ ℝ^{d×k}: block structure
    /// by metric group with mild cross-loadings and per-VM perturbation.
    fn loading_matrix(&self, archetype: usize, rng: &mut Xoshiro256) -> Mat {
        let d = self.cfg.dim;
        let mut l = Mat::zeros(d, LATENT_K);
        // Archetype emphasis over the four factors.
        let emphasis: [f64; LATENT_K] = match archetype % N_ARCHETYPES {
            0 => [1.4, 0.8, 0.6, 1.2], // web: cpu + net heavy
            1 => [1.0, 1.4, 1.3, 0.5], // db: mem + disk heavy
            2 => [1.5, 0.7, 1.2, 0.4], // batch: cpu + disk heavy
            _ => [0.4, 0.5, 0.3, 0.3], // idle-ish
        };
        for (g, &(lo, hi)) in GROUPS.iter().enumerate() {
            let main = GROUP_FACTOR[g];
            for i in lo..hi.min(d) {
                for k in 0..LATENT_K {
                    let base = if k == main { 1.0 } else { 0.15 };
                    let jitter = 1.0 + 0.25 * rng.normal();
                    l.set(i, k, base * emphasis[k] * jitter.max(0.1));
                }
            }
        }
        l
    }

    /// Generate a single VM trace of `steps` samples.
    pub fn generate_vm(&self, vm_id: usize, steps: usize) -> VmTrace {
        self.generate_vm_in_cluster(0, vm_id, steps)
    }

    /// Open an incremental column stream for one VM of a cluster: the
    /// exact sample sequence of [`TraceGenerator::generate_vm_in_cluster`]
    /// produced one step at a time with O(d) state — no full-horizon
    /// materialization. Both paths run the same per-step code, so a
    /// streamed column `t` is bit-identical to column `t` of the
    /// materialized trace.
    pub fn stream_vm_in_cluster(&self, cluster_id: usize, vm_id: usize) -> VmTraceStream {
        let cfg = self.cfg.clone();
        // Independent streams: cluster weather, VM structure, VM noise.
        let cluster_rng = self.derive_rng(&[1, cluster_id as u64]);
        let mut vm_rng = self.derive_rng(&[2, cluster_id as u64, vm_id as u64]);

        let archetype = vm_rng.gen_range(N_ARCHETYPES);
        let loading = self.loading_matrix(archetype, &mut vm_rng);
        let phase = vm_rng.next_f64() * STEPS_PER_DAY as f64;
        let sigma = (1.0 - cfg.ar_rho * cfg.ar_rho).sqrt();

        VmTraceStream {
            cfg,
            vm_id,
            cluster_id,
            archetype,
            loading,
            phase,
            sigma,
            cluster_rng,
            vm_rng,
            weather: 0.0,
            x: [0.0; LATENT_K],
            precursor_left: 0,
            spike_in: None,
            spike_left: 0,
            spike_scale: 0.0,
            t: 0,
        }
    }

    /// Generate one VM belonging to a cluster (shares cluster weather).
    pub fn generate_vm_in_cluster(
        &self,
        cluster_id: usize,
        vm_id: usize,
        steps: usize,
    ) -> VmTrace {
        let d = self.cfg.dim;
        let mut stream = self.stream_vm_in_cluster(cluster_id, vm_id);
        let mut data = Mat::zeros(d, steps);
        for t in 0..steps {
            stream.next_into(data.col_mut(t));
        }
        let names: Vec<String> = if d == VM_DIM {
            vm_metric_names().iter().map(|s| s.to_string()).collect()
        } else {
            (0..d).map(|i| format!("metric.{i}")).collect()
        };
        VmTrace::new(vm_id, cluster_id, stream.archetype, data, names)
    }

    /// Generate a whole cluster of `n_vms` VMs with shared weather.
    pub fn generate_cluster(&self, cluster_id: usize, n_vms: usize, steps: usize) -> ClusterTrace {
        let vms = (0..n_vms)
            .map(|v| self.generate_vm_in_cluster(cluster_id, v, steps))
            .collect();
        ClusterTrace { cluster_id, vms }
    }

    fn derive_rng(&self, stream: &[u64]) -> Xoshiro256 {
        // Fold the hierarchical stream path through `rng::stream_seed`
        // one hop at a time on top of the hashed base seed —
        // byte-identical to the historical inline mixing.
        let mut acc = crate::rng::seed_hash(self.seed);
        for &s in stream {
            acc = crate::rng::stream_seed(acc, s);
        }
        Xoshiro256::seed_from_u64(acc)
    }
}

/// Incremental generator state for one VM: yields the columns of
/// [`TraceGenerator::generate_vm_in_cluster`] one step at a time.
///
/// The whole state is O(d): the loading matrix, two RNGs, the AR(1)
/// latent factors, the scalar cluster-weather level, and the episode
/// machinery. Streaming a 5 000-node fleet therefore costs a few KB per
/// node instead of `steps × d` doubles per node — the memory-limited
/// regime the paper's horizontal-scalability claim lives in. Columns are
/// bit-identical to the materialized trace (both paths run this code).
#[derive(Debug, Clone)]
pub struct VmTraceStream {
    cfg: GeneratorConfig,
    vm_id: usize,
    cluster_id: usize,
    archetype: usize,
    /// Archetype/VM loading matrix L ∈ ℝ^{d×k}.
    loading: Mat,
    phase: f64,
    /// AR(1) innovation scale √(1 − ρ²).
    sigma: f64,
    cluster_rng: Xoshiro256,
    vm_rng: Xoshiro256,
    /// Cluster weather level (AR(1), shared by construction: every VM of
    /// the cluster replays the same `cluster_rng` sequence).
    weather: f64,
    /// Latent factor state.
    x: [f64; LATENT_K],
    precursor_left: usize,
    /// Countdown to spike start.
    spike_in: Option<usize>,
    spike_left: usize,
    spike_scale: f64,
    /// Next step to generate.
    t: usize,
}

impl VmTraceStream {
    pub fn vm_id(&self) -> usize {
        self.vm_id
    }

    pub fn cluster_id(&self) -> usize {
        self.cluster_id
    }

    pub fn archetype(&self) -> usize {
        self.archetype
    }

    /// Feature dimension d of the generated columns.
    pub fn dim(&self) -> usize {
        self.cfg.dim
    }

    /// The next step this stream will generate.
    pub fn step(&self) -> usize {
        self.t
    }

    /// Generate the metric vector for the next step into `out`
    /// (`out.len() == dim()`), allocation-free.
    pub fn next_into(&mut self, out: &mut [f64]) {
        let cfg = &self.cfg;
        let d = cfg.dim;
        debug_assert_eq!(out.len(), d);
        let t = self.t;
        self.t += 1;

        // Cluster weather advances exactly one AR(1) step per column.
        self.weather = 0.995 * self.weather + 0.05 * self.cluster_rng.normal();
        let weather = self.weather;

        // Seasonality: diurnal + weekly modulation.
        let day_pos = (t as f64 + self.phase) / STEPS_PER_DAY as f64 * std::f64::consts::TAU;
        let week_pos = day_pos / 7.0;
        let season = 0.8 * day_pos.sin() + 0.2 * week_pos.sin();

        // Factor dynamics (idiosyncratic AR(1) around the seasonal mean).
        let sigma = self.sigma;
        for (k, xk) in self.x.iter_mut().enumerate() {
            let drive = if k == 0 { season } else { 0.5 * season };
            *xk = cfg.ar_rho * *xk + sigma * self.vm_rng.normal() + 0.05 * drive;
        }
        // Effective factors: idiosyncratic state + seasonal swing +
        // cluster weather (the shared component that makes same-cluster
        // VMs informative about each other, Tables 1–3).
        let mut xe = self.x;
        xe[0] += 0.6 * season + 1.2 * weather;
        xe[1] += 0.4 * weather;
        xe[2] += 0.3 * season + 0.4 * weather;
        xe[3] += 0.4 * season + 0.6 * weather;

        // Effective CPU pressure in [0, ~1].
        let pressure = sigmoid(xe[0]);

        // Episode machinery.
        if self.spike_in.is_none() && self.spike_left == 0 {
            let hazard = cfg.episode_hazard * (1.0 + cfg.hazard_load_gain * pressure);
            if self.vm_rng.bernoulli(hazard) {
                let surprise = self.vm_rng.bernoulli(cfg.surprise_rate);
                let lead = if surprise { 0 } else { 1 + self.vm_rng.gen_range(cfg.lead) };
                self.spike_in = Some(lead);
                self.precursor_left = if surprise { 0 } else { lead };
                self.spike_scale = 1.0 + self.vm_rng.exponential(1.2);
            }
        }

        // Precursor: inject a strong common shift into the latent
        // factors for the lead interval before the spike.
        let mut xe = xe;
        if self.precursor_left > 0 {
            xe[0] += cfg.precursor_gain * sigma;
            xe[2] += 0.5 * cfg.precursor_gain * sigma;
            self.precursor_left -= 1;
        }
        if let Some(cd) = self.spike_in {
            if cd == 0 {
                self.spike_in = None;
                // Geometric duration with the configured mean.
                self.spike_left =
                    1 + sample_geometric(&mut self.vm_rng, 1.0 / cfg.mean_episode_len);
            } else {
                self.spike_in = Some(cd - 1);
            }
        }

        // Metric vector: loading * factors, group-scaled, plus noise.
        self.loading.matvec_into(&xe, out);
        for (g, &(lo, hi)) in GROUPS.iter().enumerate() {
            // Scale groups to plausible counter magnitudes.
            let scale = match g {
                0 => 40.0,  // cpu %
                1 => 55.0,  // mem %
                2 => 30.0,  // disk rates
                3 => 25.0,  // net rates
                _ => 10.0,  // sys
            };
            for item in out.iter_mut().take(hi.min(d)).skip(lo) {
                let noisy = *item + cfg.obs_noise * self.vm_rng.normal();
                *item = (scale * (1.0 + 0.5 * noisy)).max(0.0);
            }
        }

        // CPU Ready: log-normal floor plus episode spikes, clamped to
        // the sampling period.
        let mut ready = self.vm_rng.log_normal(cfg.ready_mu, cfg.ready_sigma);
        if self.spike_left > 0 {
            ready += 450.0 * self.spike_scale * (1.0 + 0.15 * self.vm_rng.normal().abs());
            self.spike_left -= 1;
        }
        out[CPU_READY_IDX] = ready.clamp(0.0, SAMPLE_PERIOD_MS);
    }
}

#[inline]
fn sigmoid(x: f64) -> f64 {
    1.0 / (1.0 + (-x).exp())
}

/// Geometric sample with success probability p (support 0, 1, 2, …).
fn sample_geometric(rng: &mut Xoshiro256, p: f64) -> usize {
    let p = p.clamp(1e-6, 1.0);
    let u = 1.0 - rng.next_f64();
    (u.ln() / (1.0 - p).max(1e-12).ln()).floor().max(0.0) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> TraceGenerator {
        TraceGenerator::new(GeneratorConfig::default(), 1234)
    }

    #[test]
    fn deterministic_given_seed() {
        let a = gen().generate_vm(3, 500);
        let b = gen().generate_vm(3, 500);
        for t in 0..500 {
            assert_eq!(a.features(t), b.features(t));
        }
    }

    #[test]
    fn stream_yields_bit_identical_columns() {
        // The streaming path must be indistinguishable from materializing:
        // exact f64 equality, column by column.
        let g = gen();
        let tr = g.generate_vm_in_cluster(2, 3, 400);
        let mut s = g.stream_vm_in_cluster(2, 3);
        assert_eq!(s.dim(), tr.dim());
        assert_eq!(s.vm_id(), 3);
        assert_eq!(s.cluster_id(), 2);
        let mut col = vec![0.0; tr.dim()];
        for t in 0..400 {
            assert_eq!(s.step(), t);
            s.next_into(&mut col);
            assert_eq!(&col[..], tr.features(t), "column {t} diverged");
        }
        assert_eq!(s.archetype(), tr.archetype);
    }

    #[test]
    fn different_vms_differ() {
        let a = gen().generate_vm(0, 200);
        let b = gen().generate_vm(1, 200);
        let same = (0..200).all(|t| a.features(t) == b.features(t));
        assert!(!same);
    }

    #[test]
    fn values_are_finite_and_ready_in_range() {
        let tr = gen().generate_vm(0, 2000);
        for t in 0..tr.len() {
            for &v in tr.features(t) {
                assert!(v.is_finite());
            }
            let r = tr.cpu_ready(t);
            assert!((0.0..=SAMPLE_PERIOD_MS).contains(&r));
        }
    }

    #[test]
    fn spike_rates_near_paper_calibration() {
        // Paper Table 4 reports 9.54 % / 2.63 % / 0.85 % of values above
        // 500 / 800 / 1000 ms. Accept loose bands — shape over value.
        let cluster = gen().generate_cluster(0, 12, 4000);
        let f500 = cluster.spike_fraction(500.0);
        let f800 = cluster.spike_fraction(800.0);
        let f1000 = cluster.spike_fraction(1000.0);
        assert!((0.04..0.18).contains(&f500), "f500={f500}");
        assert!((0.015..0.08).contains(&f800), "f800={f800}");
        assert!((0.003..0.04).contains(&f1000), "f1000={f1000}");
        assert!(f500 > f800 && f800 > f1000);
    }

    #[test]
    fn episodes_have_precursors_in_latent_metrics() {
        // Around CPU Ready spike onsets, the mean CPU-group metric level in
        // the preceding `lead` steps should exceed the global mean: the
        // precursor ramp is visible in the observable metrics.
        let tr = gen().generate_vm(5, 20_000);
        let ready = tr.cpu_ready_series();
        let cpu_usage = tr.metric_series(1); // cpu.usage.average
        let global_mean = cpu_usage.iter().sum::<f64>() / cpu_usage.len() as f64;

        let mut pre_vals = Vec::new();
        for t in 8..tr.len() {
            let spike = ready[t] >= 1000.0 && ready[t - 1] < 1000.0;
            if spike {
                for dt in 1..=5usize {
                    pre_vals.push(cpu_usage[t - dt]);
                }
            }
        }
        assert!(pre_vals.len() >= 25, "too few spikes to test: {}", pre_vals.len() / 5);
        let pre_mean = pre_vals.iter().sum::<f64>() / pre_vals.len() as f64;
        assert!(
            pre_mean > global_mean * 1.05,
            "no precursor signal: pre={pre_mean:.2} global={global_mean:.2}"
        );
    }

    #[test]
    fn archetypes_are_distinguishable() {
        // Mean metric profiles of different archetypes should differ more
        // across archetypes than within (basis for Table 2 clustering).
        let g = gen();
        let cluster = g.generate_cluster(1, 24, 1500);
        let mut by_arch: Vec<Vec<Vec<f64>>> = vec![Vec::new(); N_ARCHETYPES];
        for vm in &cluster.vms {
            let d = vm.dim();
            let mut mean = vec![0.0; d];
            for t in 0..vm.len() {
                for (i, &v) in vm.features(t).iter().enumerate() {
                    mean[i] += v;
                }
            }
            for m in &mut mean {
                *m /= vm.len() as f64;
            }
            by_arch[vm.archetype].push(mean);
        }
        let arch_means: Vec<Vec<f64>> = by_arch
            .iter()
            .filter(|v| !v.is_empty())
            .map(|vms| {
                let d = vms[0].len();
                let mut m = vec![0.0; d];
                for vm in vms {
                    for i in 0..d {
                        m[i] += vm[i];
                    }
                }
                for x in &mut m {
                    *x /= vms.len() as f64;
                }
                m
            })
            .collect();
        assert!(arch_means.len() >= 2, "want multiple archetypes in sample");
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
        };
        let d01 = dist(&arch_means[0], &arch_means[1]);
        assert!(d01 > 1.0, "archetype profiles indistinct: {d01}");
    }

    #[test]
    fn cluster_weather_is_shared() {
        // Two VMs in the same cluster should correlate more than two VMs in
        // different clusters (cpu.usage series).
        let g = gen();
        let a = g.generate_vm_in_cluster(0, 0, 3000);
        let b = g.generate_vm_in_cluster(0, 1, 3000);
        let c = g.generate_vm_in_cluster(9, 1, 3000);
        let corr = |x: &[f64], y: &[f64]| -> f64 {
            let n = x.len() as f64;
            let mx = x.iter().sum::<f64>() / n;
            let my = y.iter().sum::<f64>() / n;
            let mut num = 0.0;
            let mut dx = 0.0;
            let mut dy = 0.0;
            for i in 0..x.len() {
                num += (x[i] - mx) * (y[i] - my);
                dx += (x[i] - mx).powi(2);
                dy += (y[i] - my).powi(2);
            }
            num / (dx.sqrt() * dy.sqrt()).max(1e-12)
        };
        let s_ab = corr(&a.metric_series(1), &b.metric_series(1));
        let s_ac = corr(&a.metric_series(1), &c.metric_series(1));
        assert!(
            s_ab > s_ac,
            "same-cluster correlation {s_ab:.3} should exceed cross-cluster {s_ac:.3}"
        );
    }
}
