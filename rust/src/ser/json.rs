//! Minimal recursive-descent JSON parser and printer.
//!
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Used for `artifacts/manifest.json` and bench
//! result files.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonValue::Null => write!(f, "null"),
            JsonValue::Bool(b) => write!(f, "{b}"),
            JsonValue::Number(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            JsonValue::String(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            JsonValue::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            JsonValue::Object(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", JsonValue::String(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<JsonValue, String> {
    let bytes: Vec<char> = input.chars().collect();
    let mut p = Parser { chars: &bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.chars.len() {
        return Err(format!("trailing characters at {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    chars: &'a [char],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        self.pos += 1;
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(' ' | '\n' | '\t' | '\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: char) -> Result<(), String> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(format!("expected '{c}' at {}", self.pos - 1))
        }
    }

    fn value(&mut self) -> Result<JsonValue, String> {
        self.skip_ws();
        match self.peek() {
            Some('{') => self.object(),
            Some('[') => self.array(),
            Some('"') => Ok(JsonValue::String(self.string()?)),
            Some('t') => self.keyword("true", JsonValue::Bool(true)),
            Some('f') => self.keyword("false", JsonValue::Bool(false)),
            Some('n') => self.keyword("null", JsonValue::Null),
            Some(c) if c == '-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at {}", self.pos)),
        }
    }

    fn keyword(&mut self, kw: &str, v: JsonValue) -> Result<JsonValue, String> {
        for c in kw.chars() {
            if self.bump() != Some(c) {
                return Err(format!("bad keyword near {}", self.pos));
            }
        }
        Ok(v)
    }

    fn object(&mut self) -> Result<JsonValue, String> {
        self.expect('{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some('}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some('}') => return Ok(JsonValue::Object(map)),
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue, String> {
        self.expect('[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(']') {
            self.pos += 1;
            return Ok(JsonValue::Array(items));
        }
        loop {
            let v = self.value()?;
            items.push(v);
            self.skip_ws();
            match self.bump() {
                Some(',') => continue,
                Some(']') => return Ok(JsonValue::Array(items)),
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect('"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some('"') => return Ok(s),
                Some('\\') => match self.bump() {
                    Some('"') => s.push('"'),
                    Some('\\') => s.push('\\'),
                    Some('/') => s.push('/'),
                    Some('n') => s.push('\n'),
                    Some('t') => s.push('\t'),
                    Some('r') => s.push('\r'),
                    Some('b') => s.push('\u{0008}'),
                    Some('f') => s.push('\u{000C}'),
                    Some('u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")?;
                            code = code * 16
                                + c.to_digit(16).ok_or(format!("bad hex digit {c}"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(c) => s.push(c),
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue, String> {
        let start = self.pos;
        if self.peek() == Some('-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || "+-.eE".contains(c)) {
            self.pos += 1;
        }
        let text: String = self.chars[start..self.pos].iter().collect();
        text.parse::<f64>()
            .map(JsonValue::Number)
            .map_err(|e| format!("bad number '{text}': {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), JsonValue::Null);
        assert_eq!(parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), JsonValue::Number(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), JsonValue::String("hi".into()));
    }

    #[test]
    fn parses_nested_structure() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&JsonValue::Bool(false)));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = JsonValue::String("line\n\"quoted\"\ttab\\".into());
        let text = original.to_string();
        assert_eq!(parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape() {
        let v = parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn display_roundtrips_object() {
        let src = r#"{"config":{"dim":52,"rank":4},"xs":[1,2.5,null,true]}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), JsonValue::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), JsonValue::Object(Default::default()));
    }
}
