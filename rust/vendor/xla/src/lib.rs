//! Compile-time **stub** of the XLA/PJRT Rust binding.
//!
//! The offline build environment has neither the real `xla` crate nor the
//! shared libraries it links against. This stub mirrors the API surface
//! `pronto::runtime` consumes so the crate compiles everywhere;
//! [`PjRtClient::cpu`] returns an error, so every runtime path degrades to
//! the native Rust FPCA implementation exactly as it does when the AOT
//! artifacts have not been built (`pronto::runtime::shared_runtime()`
//! returns `None`). Replace the `xla` path dependency in `Cargo.toml` with
//! the real binding to enable the AOT execution path; no source changes
//! are needed.

#![forbid(unsafe_code)]

/// Error type matching the binding's `Debug`-formatted errors.
#[derive(Debug)]
pub enum Error {
    /// The stub cannot execute anything.
    Unavailable(&'static str),
}

const UNAVAILABLE: Error =
    Error::Unavailable("xla stub: PJRT unavailable in this build (offline vendored stub)");

/// Marker trait for element types crossing the literal boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Host-side literal (stub: holds nothing).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(UNAVAILABLE)
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(UNAVAILABLE)
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(UNAVAILABLE)
    }
}

/// Parsed HLO module (stub: never constructed successfully).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(UNAVAILABLE)
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device buffer handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(UNAVAILABLE)
    }
}

/// Compiled executable (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(UNAVAILABLE)
    }
}

/// PJRT client (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(UNAVAILABLE)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        let msg = format!("{err:?}");
        assert!(msg.contains("stub"));
    }

    #[test]
    fn literal_surface_typechecks() {
        let mut lit = Literal::vec1(&[1.0f32, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.decompose_tuple().is_err());
    }
}
