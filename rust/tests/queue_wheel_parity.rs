//! Wheel-vs-heap equivalence: the hierarchical timing wheel must be an
//! exact drop-in for the historical binary-heap event queue.
//!
//! Two layers of evidence:
//!
//! * queue-level property tests — randomized interleaved
//!   (time, event) schedules, drains, and pops produce the *identical*
//!   sequence from both backings (`EventQueue::with_backing`), with time
//!   offsets spanning every wheel level, the far-future heap, and the
//!   past-schedule path;
//! * engine-level byte identity — every catalog scenario produces
//!   byte-identical `SimReport` JSON under `PRONTO_EVENT_QUEUE=heap` and
//!   the default wheel, at observe-pool widths 1 and 4.
//!
//! Seeded and replayable via `PRONTO_PROP_SEED` / `PRONTO_PROP_CASES`.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::proptest::forall;
use pronto::scheduler::{Admission, RandomPolicy};
use pronto::sim::{
    DiscreteEventEngine, Event, EventQueue, QueueBacking, Scenario, SimTime, TickBatch, CATALOG,
};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

fn tagged(i: usize) -> Event {
    Event::NodeJoin { node: i }
}

fn untag(e: Event) -> usize {
    match e {
        Event::NodeJoin { node } => node,
        other => panic!("unexpected event {other:?}"),
    }
}

/// Offsets spanning the wheel's structure: level 0 (< 2^10 ticks),
/// level 1 (< 2^20), level 2 (< 2^30), and the far-future heap beyond.
fn span_offset(rng: &mut pronto::rng::Xoshiro256, magnitude: usize) -> SimTime {
    match magnitude {
        0 => rng.gen_range(40) as SimTime,
        1 => rng.gen_range(200_000) as SimTime,
        2 => rng.gen_range(200_000_000) as SimTime,
        _ => rng.gen_range(20_000_000_000) as SimTime,
    }
}

#[test]
fn interleaved_schedule_pop_sequences_match_across_backings() {
    forall("wheel ≡ heap: interleaved schedule/pop, all levels", |rng| {
        let mut wheel = EventQueue::with_backing(64, QueueBacking::Wheel);
        let mut heap = EventQueue::with_backing(64, QueueBacking::Heap);
        let rounds = 1 + rng.gen_range(24);
        let mut next_tag = 0usize;
        // The engine's clock contract: schedules never land before the
        // last pop. `floor` tracks it so both queues see legal input.
        let mut floor: SimTime = 0;
        let mut scheduled = 0usize;
        let mut popped = 0usize;
        for _ in 0..rounds {
            for _ in 0..(1 + rng.gen_range(12)) {
                let mag = rng.gen_range(4);
                let t = floor + span_offset(rng, mag);
                wheel.schedule(t, tagged(next_tag));
                heap.schedule(t, tagged(next_tag));
                next_tag += 1;
                scheduled += 1;
            }
            for _ in 0..rng.gen_range(10) {
                let (a, b) = (wheel.pop(), heap.pop());
                match (a, b) {
                    (None, None) => break,
                    (Some(x), Some(y)) => {
                        if x.time != y.time || untag(x.event) != untag(y.event) {
                            return Err(format!(
                                "divergence at pop {popped}: wheel ({}, {}) vs heap ({}, {})",
                                x.time,
                                untag(x.event),
                                y.time,
                                untag(y.event)
                            ));
                        }
                        floor = x.time;
                        popped += 1;
                    }
                    (x, y) => {
                        return Err(format!("one backing drained early: {x:?} vs {y:?}"))
                    }
                }
            }
        }
        loop {
            match (wheel.pop(), heap.pop()) {
                (None, None) => break,
                (Some(x), Some(y))
                    if x.time == y.time && untag(x.event) == untag(y.event) =>
                {
                    popped += 1;
                }
                (x, y) => return Err(format!("drain divergence: {x:?} vs {y:?}")),
            }
        }
        if popped != scheduled {
            return Err(format!("lost events: {popped} of {scheduled}"));
        }
        if wheel.len() != 0 || heap.len() != 0 {
            return Err("a backing still reports queued events".into());
        }
        Ok(())
    });
}

#[test]
fn past_schedules_match_across_backings() {
    // `EventQueue` tolerates schedules below the last popped time (the
    // wheel routes them through its past-heap). Both backings must order
    // such sequences identically — this is deliberately *outside* the
    // engine's clock contract to pin the wheel's past path against the
    // heap oracle.
    forall("wheel ≡ heap: below-cursor schedules", |rng| {
        let mut wheel = EventQueue::with_backing(16, QueueBacking::Wheel);
        let mut heap = EventQueue::with_backing(16, QueueBacking::Heap);
        let n = 2 + rng.gen_range(60);
        let mut tag = 0usize;
        // Advance both cursors first so "past" exists.
        let warm = 1_000 + rng.gen_range(5_000) as SimTime;
        wheel.schedule(warm, tagged(tag));
        heap.schedule(warm, tagged(tag));
        tag += 1;
        let (a, b) = (wheel.pop().unwrap(), heap.pop().unwrap());
        assert_eq!((a.time, untag(a.event)), (b.time, untag(b.event)));
        for _ in 0..n {
            // Mix of past, at-cursor, and future times.
            let t = rng.gen_range(2 * warm as usize + 1) as SimTime;
            wheel.schedule(t, tagged(tag));
            heap.schedule(t, tagged(tag));
            tag += 1;
        }
        loop {
            match (wheel.pop(), heap.pop()) {
                (None, None) => break,
                (Some(x), Some(y))
                    if x.time == y.time && untag(x.event) == untag(y.event) => {}
                (x, y) => return Err(format!("past-path divergence: {x:?} vs {y:?}")),
            }
        }
        Ok(())
    });
}

#[test]
fn drain_tick_batches_match_across_backings_with_mid_batch_schedules() {
    // The engine's actual consumption pattern: batched tick drains with
    // same-timestamp follow-ups scheduled between drains. Batches must
    // agree event-for-event across backings.
    forall("wheel ≡ heap: drain_tick with follow-ups", |rng| {
        let mut wheel = EventQueue::with_backing(32, QueueBacking::Wheel);
        let mut heap = EventQueue::with_backing(32, QueueBacking::Heap);
        let n = 1 + rng.gen_range(200);
        let mut tag = 0usize;
        for _ in 0..n {
            let mag = rng.gen_range(3);
            let t = span_offset(rng, mag);
            wheel.schedule(t, tagged(tag));
            heap.schedule(t, tagged(tag));
            tag += 1;
        }
        let mut wb = TickBatch::default();
        let mut hb = TickBatch::default();
        let mut drained = 0usize;
        loop {
            let (wa, ha) = (wheel.drain_tick(&mut wb), heap.drain_tick(&mut hb));
            if wa != ha {
                return Err(format!("drain_tick availability diverged at batch {drained}"));
            }
            if !wa {
                break;
            }
            if wb.time() != hb.time() || wb.len() != hb.len() {
                return Err(format!(
                    "batch {drained} shape diverged: t={} n={} vs t={} n={}",
                    wb.time(),
                    wb.len(),
                    hb.time(),
                    hb.len()
                ));
            }
            for (x, y) in wb.events().iter().zip(hb.events()) {
                if untag(x.event) != untag(y.event) {
                    return Err(format!(
                        "batch {drained} order diverged: {} vs {}",
                        untag(x.event),
                        untag(y.event)
                    ));
                }
            }
            // Occasionally enqueue same-tick follow-ups mid-batch, like
            // enqueue → start chains do.
            if rng.bernoulli(0.3) {
                for _ in 0..(1 + rng.gen_range(4)) {
                    wheel.schedule(wb.time(), tagged(tag));
                    heap.schedule(hb.time(), tagged(tag));
                    tag += 1;
                }
            }
            drained += 1;
        }
        if wheel.len() != 0 || heap.len() != 0 {
            return Err("undrained events left behind".into());
        }
        Ok(())
    });
}

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 4, v, steps)).collect()
}

fn policies(n: usize, seed: u64) -> Vec<Box<dyn Admission>> {
    (0..n)
        .map(|i| Box::new(RandomPolicy::new(0.3, seed ^ i as u64)) as Box<dyn Admission>)
        .collect()
}

#[test]
fn every_catalog_scenario_is_byte_identical_under_both_backings() {
    // The acceptance criterion of the wheel work: the full scenario
    // catalog, at observe-pool widths 1 and 4, produces byte-identical
    // reports whether the engine's queue is the wheel (default) or the
    // heap oracle (PRONTO_EVENT_QUEUE=heap).
    //
    // The backing is selected per-queue at construction from the
    // environment, so the env var is flipped around each heap run. This
    // is the *only* test in this binary that touches the variable or
    // runs engines, so the process-global mutation cannot race another
    // test's queue construction.
    let nodes = 6;
    let steps = 800;
    let run = |name: &str, threads: usize| {
        let scenario = Scenario::named(name)
            .unwrap()
            .with_nodes(nodes)
            .with_steps(steps)
            .with_seed(0xFEED)
            .with_threads(threads);
        let tr = fleet(nodes, steps, 31);
        DiscreteEventEngine::new(scenario, tr, policies(nodes, 77)).run()
    };
    for name in CATALOG {
        for threads in [1, 4] {
            std::env::remove_var("PRONTO_EVENT_QUEUE");
            let wheel = run(name, threads);
            std::env::set_var("PRONTO_EVENT_QUEUE", "heap");
            let heap = run(name, threads);
            std::env::remove_var("PRONTO_EVENT_QUEUE");
            assert_eq!(
                wheel.to_json_string(),
                heap.to_json_string(),
                "scenario '{name}' at {threads} threads: wheel and heap reports differ"
            );
        }
    }
}
