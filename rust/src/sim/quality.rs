//! Ground-truth-labeled prediction quality: the eval v2 scorer.
//!
//! [`crate::sim::eval`] reproduces the paper's Figure 6/7 *counts*; this
//! module scores the headline *claim* — that the rejection signal
//! predicts CPU Ready responsiveness changes ahead of time — on
//! engine-captured timelines ([`SignalCapture`]):
//!
//! * **Lead time** per spike: steps from the first preceding raise
//!   (within the Figure-5 left half, [`left_span`] steps) to the spike.
//! * **Precision / recall / F1**: a raise is a true positive iff a spike
//!   lands within its forward window `[r, r + left_span]`; a spike is
//!   recalled iff some raise precedes (or coincides with) it — the exact
//!   dual, owned by [`crate::detect::window`].
//! * **False-positive rate**: false raises over the steps whose forward
//!   window holds no spike (the negatives).
//! * **Signal-to-decision latency**: raise onset → first admission
//!   rejection the engine actually issued (from
//!   [`SimReport::outcomes`]), i.e. how fast a raised signal turns into
//!   a scheduling decision.
//!
//! [`score_report`] reduces one engine run to a [`QualityRow`];
//! [`quality_report`] assembles rows across scenarios × methods into the
//! schema-versioned `EVAL_quality.json` document (`pronto eval
//! --scenario`). Rows are derived purely from captured timelines and the
//! outcome ledger — both byte-stable per seed at any `--threads` width
//! and across trace sources — and deliberately record neither setting,
//! so the document inherits that byte-identity.

use crate::detect::window::{classify_spike, lead_time, left_span, raise_true_positive};
use crate::metrics::EmpiricalCdf;
use crate::scheduler::JobOutcome;
use crate::ser::JsonValue;
use crate::sim::engine::{SignalCapture, SimReport};
use std::collections::BTreeMap;

/// Confusion counts and lead times of one node's raised/spike timelines.
#[derive(Debug, Clone, Default)]
pub struct TimelineScore {
    /// Timeline length in steps.
    pub steps: usize,
    /// Ground-truth CPU Ready spikes.
    pub spikes: usize,
    /// Spikes preceded by ≥1 raise within the left half-window.
    pub predicted_spikes: usize,
    /// Steps with the rejection signal raised.
    pub raises: usize,
    /// Raises whose forward window `[r, r + left_span]` holds a spike.
    pub true_positive_raises: usize,
    /// Steps whose forward window holds **no** spike — the population
    /// false raises are scored against.
    pub negatives: usize,
    /// Lead time of each predicted spike, in spike order (steps from the
    /// earliest left-half raise; 0 = coincident).
    pub lead_times: Vec<usize>,
}

impl TimelineScore {
    /// TP raises / all raises. No raises ⇒ vacuous 1.0 (nothing claimed,
    /// nothing wrong).
    pub fn precision(&self) -> f64 {
        if self.raises == 0 {
            1.0
        } else {
            self.true_positive_raises as f64 / self.raises as f64
        }
    }

    /// Predicted spikes / all spikes. No spikes ⇒ vacuous 1.0 (nothing
    /// to predict).
    pub fn recall(&self) -> f64 {
        if self.spikes == 0 {
            1.0
        } else {
            self.predicted_spikes as f64 / self.spikes as f64
        }
    }

    /// Harmonic mean of precision and recall (0.0 when both are 0).
    pub fn f1(&self) -> f64 {
        let (p, r) = (self.precision(), self.recall());
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    /// False raises over negative steps (0.0 when every step's forward
    /// window holds a spike — there is nothing to falsely alarm on).
    pub fn false_positive_rate(&self) -> f64 {
        if self.negatives == 0 {
            0.0
        } else {
            (self.raises - self.true_positive_raises) as f64 / self.negatives as f64
        }
    }
}

/// Score one node's raised timeline against its spike ground truth under
/// a Figure-5 window of size `w`. Both slices index by step and must be
/// equally long.
pub fn score_timeline(raised: &[bool], spikes: &[bool], w: usize) -> TimelineScore {
    assert_eq!(raised.len(), spikes.len(), "timelines must align");
    let steps = raised.len();
    let mut score = TimelineScore { steps, ..Default::default() };
    for t in 0..steps {
        if spikes[t] {
            score.spikes += 1;
            if classify_spike(raised, t, w).left > 0 {
                score.predicted_spikes += 1;
                score.lead_times.push(
                    lead_time(raised, t, w).expect("left-sided raise implies a lead time"),
                );
            }
        }
        let positive_window = raise_true_positive(spikes, t, w);
        if !positive_window {
            score.negatives += 1;
        }
        if raised[t] {
            score.raises += 1;
            if positive_window {
                score.true_positive_raises += 1;
            }
        }
    }
    score
}

/// Signal-to-decision latencies: for every raise **onset** (a false→true
/// transition on some node's raised timeline), the distance in steps to
/// the first admission rejection the engine issued at or after it.
/// Onsets with no subsequent rejection (censored by the horizon) are
/// dropped. `rejection_steps` need not be sorted.
pub fn decision_latencies(raised: &[Vec<bool>], rejection_steps: &[usize]) -> Vec<usize> {
    let mut rejections = rejection_steps.to_vec();
    rejections.sort_unstable();
    let mut out = Vec::new();
    for timeline in raised {
        for (t, &up) in timeline.iter().enumerate() {
            let onset = up && (t == 0 || !timeline[t - 1]);
            if !onset {
                continue;
            }
            let idx = rejections.partition_point(|&r| r < t);
            if idx < rejections.len() {
                out.push(rejections[idx] - t);
            }
        }
    }
    out
}

/// One scenario × method row of `EVAL_quality.json`.
#[derive(Debug, Clone)]
pub struct QualityRow {
    pub scenario: String,
    pub method: String,
    pub nodes: usize,
    pub steps: usize,
    pub seed: u64,
    pub window: usize,
    /// Pooled (micro-averaged) confusion counts across the fleet.
    pub spikes: usize,
    pub predicted_spikes: usize,
    pub raises: usize,
    pub true_positive_raises: usize,
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub false_positive_rate: f64,
    /// Lead-time distribution over all predicted spikes (steps).
    pub mean_lead_steps: f64,
    pub lead_p50: f64,
    pub lead_p90: f64,
    pub lead_p99: f64,
    /// Signal-to-decision latency distribution over raise onsets (steps).
    pub decision_samples: usize,
    pub mean_decision_latency_steps: f64,
    pub decision_p50: f64,
    pub decision_p90: f64,
    pub decision_p99: f64,
    /// Per-node (macro) distribution tails of recall and precision.
    pub recall_node_p50: f64,
    pub recall_node_p90: f64,
    pub precision_node_p50: f64,
    pub precision_node_p90: f64,
    /// Mean fraction of steps with the signal raised (lost capacity).
    pub mean_downtime: f64,
}

/// Nearest-rank quantile with an explicit empty-distribution guard (an
/// empty CDF has no order statistics; rows render 0 there).
fn quantile_or_zero(cdf: &mut EmpiricalCdf, q: f64) -> f64 {
    if cdf.is_empty() {
        0.0
    } else {
        cdf.inverse(q)
    }
}

fn mean_or_zero(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

impl QualityRow {
    /// Canonical JSON rendering (BTreeMap ⇒ sorted keys; seed as a
    /// string for the same 2^53 reason as [`SimReport::to_json`]).
    pub fn to_json(&self) -> JsonValue {
        let mut m = BTreeMap::new();
        let num = JsonValue::Number;
        m.insert("scenario".into(), JsonValue::String(self.scenario.clone()));
        m.insert("method".into(), JsonValue::String(self.method.clone()));
        m.insert("nodes".into(), num(self.nodes as f64));
        m.insert("steps".into(), num(self.steps as f64));
        m.insert("seed".into(), JsonValue::String(self.seed.to_string()));
        m.insert("window".into(), num(self.window as f64));
        m.insert("spikes".into(), num(self.spikes as f64));
        m.insert("predicted_spikes".into(), num(self.predicted_spikes as f64));
        m.insert("raises".into(), num(self.raises as f64));
        m.insert(
            "true_positive_raises".into(),
            num(self.true_positive_raises as f64),
        );
        m.insert("precision".into(), num(self.precision));
        m.insert("recall".into(), num(self.recall));
        m.insert("f1".into(), num(self.f1));
        m.insert("false_positive_rate".into(), num(self.false_positive_rate));
        m.insert("mean_lead_steps".into(), num(self.mean_lead_steps));
        m.insert("lead_p50".into(), num(self.lead_p50));
        m.insert("lead_p90".into(), num(self.lead_p90));
        m.insert("lead_p99".into(), num(self.lead_p99));
        m.insert("decision_samples".into(), num(self.decision_samples as f64));
        m.insert(
            "mean_decision_latency_steps".into(),
            num(self.mean_decision_latency_steps),
        );
        m.insert("decision_p50".into(), num(self.decision_p50));
        m.insert("decision_p90".into(), num(self.decision_p90));
        m.insert("decision_p99".into(), num(self.decision_p99));
        m.insert("recall_node_p50".into(), num(self.recall_node_p50));
        m.insert("recall_node_p90".into(), num(self.recall_node_p90));
        m.insert("precision_node_p50".into(), num(self.precision_node_p50));
        m.insert("precision_node_p90".into(), num(self.precision_node_p90));
        m.insert("mean_downtime".into(), num(self.mean_downtime));
        JsonValue::Object(m)
    }
}

/// Reduce one capture-enabled engine run to a quality row. Panics if the
/// report was produced without
/// [`crate::sim::DiscreteEventEngine::with_signal_capture`].
pub fn score_report(report: &SimReport, window: usize, method: &str) -> QualityRow {
    let capture: &SignalCapture = report
        .signal_capture
        .as_ref()
        .expect("quality scoring needs a capture-enabled run (with_signal_capture)");
    let _ = left_span(window); // window >= 2, checked up front

    let mut pooled = TimelineScore::default();
    let mut lead_cdf = EmpiricalCdf::new();
    let mut leads = Vec::new();
    let mut recall_cdf = EmpiricalCdf::new();
    let mut precision_cdf = EmpiricalCdf::new();
    let mut downtimes = Vec::new();
    for (raised, spikes) in capture.raised.iter().zip(&capture.spikes) {
        let s = score_timeline(raised, spikes, window);
        pooled.steps += s.steps;
        pooled.spikes += s.spikes;
        pooled.predicted_spikes += s.predicted_spikes;
        pooled.raises += s.raises;
        pooled.true_positive_raises += s.true_positive_raises;
        pooled.negatives += s.negatives;
        recall_cdf.push(s.recall());
        precision_cdf.push(s.precision());
        downtimes.push(if s.steps == 0 {
            0.0
        } else {
            s.raises as f64 / s.steps as f64
        });
        for &l in &s.lead_times {
            lead_cdf.push(l as f64);
            leads.push(l as f64);
        }
    }

    // Rejections the engine actually issued, in step units, from the
    // outcome ledger (ordered by arrival; steps are non-decreasing).
    let rejection_steps: Vec<usize> = report
        .outcomes
        .iter()
        .filter_map(|o| match o {
            JobOutcome::Rejected { at } => Some(*at),
            _ => None,
        })
        .collect();
    let latencies = decision_latencies(&capture.raised, &rejection_steps);
    let lat_f: Vec<f64> = latencies.iter().map(|&l| l as f64).collect();
    let mut lat_cdf = EmpiricalCdf::from_samples(&lat_f);

    QualityRow {
        scenario: report.scenario.clone(),
        method: method.to_string(),
        nodes: report.nodes,
        steps: report.steps,
        seed: report.seed,
        window,
        spikes: pooled.spikes,
        predicted_spikes: pooled.predicted_spikes,
        raises: pooled.raises,
        true_positive_raises: pooled.true_positive_raises,
        precision: pooled.precision(),
        recall: pooled.recall(),
        f1: pooled.f1(),
        false_positive_rate: pooled.false_positive_rate(),
        mean_lead_steps: mean_or_zero(&leads),
        lead_p50: quantile_or_zero(&mut lead_cdf, 0.5),
        lead_p90: quantile_or_zero(&mut lead_cdf, 0.9),
        lead_p99: quantile_or_zero(&mut lead_cdf, 0.99),
        decision_samples: latencies.len(),
        mean_decision_latency_steps: mean_or_zero(&lat_f),
        decision_p50: quantile_or_zero(&mut lat_cdf, 0.5),
        decision_p90: quantile_or_zero(&mut lat_cdf, 0.9),
        decision_p99: quantile_or_zero(&mut lat_cdf, 0.99),
        recall_node_p50: quantile_or_zero(&mut recall_cdf, 0.5),
        recall_node_p90: quantile_or_zero(&mut recall_cdf, 0.9),
        precision_node_p50: quantile_or_zero(&mut precision_cdf, 0.5),
        precision_node_p90: quantile_or_zero(&mut precision_cdf, 0.9),
        mean_downtime: mean_or_zero(&downtimes),
    }
}

/// Assemble the `EVAL_quality.json` document: schema-versioned, in the
/// style of `BENCH_engine.json`. Deliberately records **no** trace-source
/// or thread-width field — rows are byte-identical across both, and the
/// document must witness that.
pub fn quality_report(
    window: usize,
    methods: &[&str],
    scenarios: &[String],
    rows: &[QualityRow],
) -> JsonValue {
    let mut doc = BTreeMap::new();
    doc.insert("eval".into(), JsonValue::String("quality".into()));
    doc.insert("schema_version".into(), JsonValue::Number(1.0));
    doc.insert("window".into(), JsonValue::Number(window as f64));
    doc.insert(
        "methods".into(),
        JsonValue::Array(
            methods.iter().map(|m| JsonValue::String(m.to_string())).collect(),
        ),
    );
    doc.insert(
        "scenarios".into(),
        JsonValue::Array(
            scenarios.iter().map(|s| JsonValue::String(s.clone())).collect(),
        ),
    );
    doc.insert(
        "rows".into(),
        JsonValue::Array(rows.iter().map(QualityRow::to_json).collect()),
    );
    JsonValue::Object(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shifted_oracle(spikes: &[bool], shift: usize) -> Vec<bool> {
        let mut raised = vec![false; spikes.len()];
        for (t, &s) in spikes.iter().enumerate() {
            if s && t >= shift {
                raised[t - shift] = true;
            }
        }
        raised
    }

    #[test]
    fn shifted_oracle_scores_perfectly() {
        // Well-spaced spikes, indicator raised exactly one step early:
        // precision = recall = 1.0 and every lead is exactly 1.
        let mut spikes = vec![false; 200];
        for t in (20..190).step_by(17) {
            spikes[t] = true;
        }
        let raised = shifted_oracle(&spikes, 1);
        let s = score_timeline(&raised, &spikes, 10);
        assert_eq!(s.spikes, 10);
        assert_eq!(s.predicted_spikes, 10);
        assert_eq!(s.precision(), 1.0);
        assert_eq!(s.recall(), 1.0);
        assert_eq!(s.f1(), 1.0);
        assert_eq!(s.false_positive_rate(), 0.0);
        assert!(s.lead_times.iter().all(|&l| l == 1));
    }

    #[test]
    fn vacuous_conventions() {
        // No raises: perfect precision, zero FPR, zero recall (spikes
        // exist but nothing predicted them).
        let spikes = [false, true, false, false, true, false];
        let s = score_timeline(&[false; 6], &spikes, 4);
        assert_eq!(s.precision(), 1.0);
        assert_eq!(s.false_positive_rate(), 0.0);
        assert_eq!(s.recall(), 0.0);
        assert_eq!(s.f1(), 0.0);
        // No spikes: vacuous recall, every raise is false.
        let s = score_timeline(&[true, false, true, false, false, false], &[false; 6], 4);
        assert_eq!(s.recall(), 1.0);
        assert_eq!(s.precision(), 0.0);
        assert!(s.false_positive_rate() > 0.0);
        assert_eq!(s.negatives, 6);
        // Empty everything: all vacuous, nothing panics.
        let s = score_timeline(&[], &[], 4);
        assert_eq!(s.precision(), 1.0);
        assert_eq!(s.recall(), 1.0);
        assert_eq!(s.f1(), 1.0);
        assert_eq!(s.false_positive_rate(), 0.0);
    }

    #[test]
    fn confusion_counts_are_window_consistent() {
        // Raise at 3 (spike at 5 within its left_span=2 forward window
        // for w=6) is a TP; raise at 10 sees nothing.
        let mut spikes = vec![false; 20];
        spikes[5] = true;
        let mut raised = vec![false; 20];
        raised[3] = true;
        raised[10] = true;
        let s = score_timeline(&raised, &spikes, 6);
        assert_eq!(s.raises, 2);
        assert_eq!(s.true_positive_raises, 1);
        assert_eq!(s.predicted_spikes, 1);
        assert_eq!(s.lead_times, vec![2]);
        assert_eq!(s.precision(), 0.5);
        assert_eq!(s.recall(), 1.0);
        // Negatives: steps 3..=5 have the spike in their forward window.
        assert_eq!(s.negatives, 17);
        assert_eq!(s.false_positive_rate(), 1.0 / 17.0);
    }

    #[test]
    fn decision_latency_pairs_onsets_with_next_rejection() {
        // Node timeline with onsets at 2 (run of 3) and 8; rejections at
        // 4 and 8: onset 2 → rejection 4 (latency 2), onset 8 →
        // rejection 8 (latency 0).
        let raised = vec![vec![
            false, false, true, true, true, false, false, false, true, false,
        ]];
        let lat = decision_latencies(&raised, &[8, 4]);
        assert_eq!(lat, vec![2, 0]);
        // Censored onset: no rejection at/after it → dropped.
        let lat = decision_latencies(&raised, &[3]);
        assert_eq!(lat, vec![1]);
        // No rejections at all → no samples.
        assert!(decision_latencies(&raised, &[]).is_empty());
    }

    #[test]
    fn row_json_schema_keys_are_pinned() {
        let row = QualityRow {
            scenario: "s".into(),
            method: "PRONTO".into(),
            nodes: 2,
            steps: 10,
            seed: 7,
            window: 10,
            spikes: 1,
            predicted_spikes: 1,
            raises: 1,
            true_positive_raises: 1,
            precision: 1.0,
            recall: 1.0,
            f1: 1.0,
            false_positive_rate: 0.0,
            mean_lead_steps: 1.0,
            lead_p50: 1.0,
            lead_p90: 1.0,
            lead_p99: 1.0,
            decision_samples: 1,
            mean_decision_latency_steps: 0.0,
            decision_p50: 0.0,
            decision_p90: 0.0,
            decision_p99: 0.0,
            recall_node_p50: 1.0,
            recall_node_p90: 1.0,
            precision_node_p50: 1.0,
            precision_node_p90: 1.0,
            mean_downtime: 0.1,
        };
        let json = row.to_json();
        let obj = json.as_object().unwrap();
        let keys: Vec<&str> = obj.keys().map(String::as_str).collect();
        // The artifact schema: additions bump schema_version in
        // quality_report; removals/renames are breaking.
        assert_eq!(
            keys,
            [
                "decision_p50",
                "decision_p90",
                "decision_p99",
                "decision_samples",
                "f1",
                "false_positive_rate",
                "lead_p50",
                "lead_p90",
                "lead_p99",
                "mean_decision_latency_steps",
                "mean_downtime",
                "mean_lead_steps",
                "method",
                "nodes",
                "precision",
                "precision_node_p50",
                "precision_node_p90",
                "predicted_spikes",
                "raises",
                "recall",
                "recall_node_p50",
                "recall_node_p90",
                "scenario",
                "seed",
                "spikes",
                "steps",
                "true_positive_raises",
                "window"
            ]
        );
        assert_eq!(json.get("seed").unwrap().as_str(), Some("7"));
    }

    #[test]
    fn quality_report_document_shape() {
        let doc = quality_report(10, &["PRONTO", "SP"], &["capacity".into()], &[]);
        assert_eq!(doc.get("eval").unwrap().as_str(), Some("quality"));
        assert_eq!(doc.get("schema_version").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("window").unwrap().as_usize(), Some(10));
        assert_eq!(doc.get("methods").unwrap().as_array().unwrap().len(), 2);
        assert!(doc.get("rows").unwrap().as_array().unwrap().is_empty());
        // The byte-identity contract: no environment-shaped keys.
        let obj = doc.as_object().unwrap();
        assert!(!obj.contains_key("threads"));
        assert!(!obj.contains_key("trace_source"));
    }
}
