//! Scenario sweep: every named scenario through the discrete-event
//! engine, PRONTO policies on every node.
//!
//! The paper's fig-1/fig-7 conditions are the `baseline-poisson` row; the
//! rest are the production regimes the paper scopes out (bursty and
//! diurnal arrivals, node churn, WAN push latency, finite host capacity
//! with preemption/migration, trace-driven replay). Emits decision
//! quality, churn/federation/queueing counters, and wall time per
//! scenario; set
//! `PRONTO_BENCH_CSV_DIR` to capture the CSV. `PRONTO_BENCH_QUICK=1`
//! shrinks the fleet for smoke runs.

use pronto::bench::Table;
use pronto::scheduler::{Admission, NodeScheduler, ProntoPolicy, RejectConfig};
use pronto::sim::{DiscreteEventEngine, Scenario, CATALOG};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};
use std::time::Instant;

fn fleet(nodes: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..nodes)
        .map(|v| gen.generate_vm_in_cluster(v / 8, v, steps))
        .collect()
}

fn pronto_policies(traces: &[VmTrace]) -> Vec<Box<dyn Admission>> {
    traces
        .iter()
        .map(|t| {
            Box::new(ProntoPolicy::new(NodeScheduler::new(
                t.dim(),
                RejectConfig::default(),
            ))) as Box<dyn Admission>
        })
        .collect()
}

fn main() {
    let quick = std::env::var("PRONTO_BENCH_QUICK")
        .map(|v| v == "1")
        .unwrap_or(false);
    let (nodes, steps) = if quick { (6, 800) } else { (16, 4_000) };
    let seed = 2021u64;

    let mut table = Table::new(
        &format!("Scenario sweep ({nodes} nodes x {steps} steps, PRONTO policy)"),
        &[
            "scenario", "jobs", "accept%", "quality%", "precision%", "leaves", "joins",
            "pushes", "lat(steps)", "queued", "qwait", "drop", "preempt", "migr", "util%",
            "slo%", "wall(ms)",
        ],
    );

    for name in CATALOG {
        let scenario = Scenario::named(name)
            .expect("catalog entry")
            .with_nodes(nodes)
            .with_steps(steps)
            .with_seed(seed);
        let traces = fleet(nodes, steps, seed);
        let policies = pronto_policies(&traces);
        let t0 = Instant::now();
        let report = DiscreteEventEngine::new(scenario, traces, policies).run();
        let wall = t0.elapsed();
        table.row(&[
            name.to_string(),
            report.jobs_arrived.to_string(),
            format!("{:.1}", 100.0 * report.acceptance_rate()),
            format!("{:.1}", 100.0 * report.placement_quality()),
            format!("{:.1}", 100.0 * report.rejection_precision()),
            report.node_leaves.to_string(),
            report.node_joins.to_string(),
            report.federation_pushes.to_string(),
            format!("{:.2}", report.mean_push_latency_steps),
            report.jobs_queued.to_string(),
            format!("{:.2}", report.mean_queue_delay_steps),
            report.jobs_dropped.to_string(),
            report.jobs_preempted.to_string(),
            report.jobs_migrated.to_string(),
            format!("{:.1}", 100.0 * report.mean_utilization),
            if report.slo_total > 0 {
                format!("{:.1}", 100.0 * report.slo_attainment())
            } else {
                "-".to_string()
            },
            format!("{:.1}", wall.as_secs_f64() * 1e3),
        ]);
    }

    table.print();
    table.maybe_write_csv("scenarios");
}
