//! Robust streaming z-score peak detection (van Brakel 2014), as embedded in
//! Algorithm 1 of the paper.
//!
//! For each tracked signal we keep a lag buffer of the *dampened* signal
//! (peaks contribute with weight β so one spike does not inflate the filter),
//! and flag a new observation as a spike when it deviates from the buffer
//! mean by more than α buffer standard deviations. The sign of the deviation
//! distinguishes positive (+1) from negative (−1) spikes — exactly the
//! ternary `b[i] ∈ {−1, 0, 1}` of Reject-Job.

/// Spike classification for one observation of one signal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Spike {
    /// Positive deviation beyond α·std.
    Positive,
    /// Negative deviation beyond α·std.
    Negative,
    /// Within the band (or warmup).
    None,
}

impl Spike {
    /// The paper's ternary encoding: +1 / −1 / 0.
    #[inline]
    pub fn as_i8(self) -> i8 {
        match self {
            Spike::Positive => 1,
            Spike::Negative => -1,
            Spike::None => 0,
        }
    }
}

/// Detector parameters. Defaults follow Algorithm 1's initialization:
/// `lag = 10`, `alpha = 3.5`, `beta = 0.5`.
#[derive(Debug, Clone, Copy)]
pub struct ZScoreConfig {
    /// Lag-buffer length (observations used for mean/std).
    pub lag: usize,
    /// Z-score threshold for flagging a spike.
    pub alpha: f64,
    /// Influence of flagged observations on the dampened buffer
    /// (0 = ignore peaks entirely, 1 = no dampening).
    pub beta: f64,
}

impl Default for ZScoreConfig {
    fn default() -> Self {
        Self { lag: 10, alpha: 3.5, beta: 0.5 }
    }
}

/// Streaming z-score detector for one scalar signal.
///
/// Memory is O(lag); each observation is O(lag) work (mean/std over the
/// small buffer — recomputed rather than incrementally updated to avoid
/// drift, matching the reference implementation).
#[derive(Debug, Clone)]
pub struct ZScoreDetector {
    cfg: ZScoreConfig,
    /// Dampened history ring buffer.
    buf: Vec<f64>,
    /// Next write position in `buf`.
    head: usize,
    /// Observations seen so far.
    seen: usize,
}

impl ZScoreDetector {
    pub fn new(cfg: ZScoreConfig) -> Self {
        assert!(cfg.lag >= 2, "lag must be >= 2");
        assert!(cfg.alpha > 0.0 && (0.0..=1.0).contains(&cfg.beta));
        Self { cfg, buf: vec![0.0; cfg.lag], head: 0, seen: 0 }
    }

    /// Number of observations consumed.
    pub fn seen(&self) -> usize {
        self.seen
    }

    /// True once the lag buffer has filled and spikes can be flagged.
    pub fn warmed_up(&self) -> bool {
        self.seen >= self.cfg.lag
    }

    /// Current buffer mean (0.0 during warmup of an empty buffer).
    pub fn mean(&self) -> f64 {
        let n = self.seen.min(self.cfg.lag);
        if n == 0 {
            return 0.0;
        }
        self.buf[..n.max(self.cfg.lag).min(self.cfg.lag)]
            .iter()
            .take(n)
            .sum::<f64>()
            / n as f64
    }

    /// Current buffer standard deviation (population).
    pub fn std(&self) -> f64 {
        let n = self.seen.min(self.cfg.lag);
        if n == 0 {
            return 0.0;
        }
        let m = self.mean();
        let var = self.buf.iter().take(n).map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        var.sqrt()
    }

    /// Consume one observation; returns its spike classification.
    pub fn observe(&mut self, x: f64) -> Spike {
        if !self.warmed_up() {
            // Warmup: fill the buffer verbatim, never flag.
            self.push(x);
            return Spike::None;
        }
        let mean = self.mean();
        let std = self.std();
        let spike = if (x - mean).abs() > self.cfg.alpha * std && std > 0.0 {
            if x > mean {
                Spike::Positive
            } else {
                Spike::Negative
            }
        } else {
            Spike::None
        };
        // Dampen flagged observations before they enter the buffer so a
        // burst of spikes does not drag the filter along with it.
        let entering = if spike == Spike::None {
            x
        } else {
            let prev = self.last();
            self.cfg.beta * x + (1.0 - self.cfg.beta) * prev
        };
        self.push(entering);
        spike
    }

    #[inline]
    fn last(&self) -> f64 {
        let idx = (self.head + self.cfg.lag - 1) % self.cfg.lag;
        self.buf[idx]
    }

    #[inline]
    fn push(&mut self, x: f64) {
        self.buf[self.head] = x;
        self.head = (self.head + 1) % self.cfg.lag;
        self.seen += 1;
    }
}

/// Bank of [`ZScoreDetector`]s, one per tracked projection signal.
///
/// This is the `w_avg`/`w_std`/`w_p` state of Algorithm 1 for all r
/// projections at once. The detector count is fixed at construction
/// (`r_max`); when the effective rank is lower, unused lanes simply see
/// zeros and never spike.
#[derive(Debug, Clone)]
pub struct MultiDetector {
    lanes: Vec<ZScoreDetector>,
}

impl MultiDetector {
    pub fn new(r: usize, cfg: ZScoreConfig) -> Self {
        Self { lanes: (0..r).map(|_| ZScoreDetector::new(cfg)).collect() }
    }

    /// Number of tracked signals.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// True once every lane's lag buffer has filled.
    pub fn warmed_up(&self) -> bool {
        self.lanes.iter().all(ZScoreDetector::warmed_up)
    }

    /// Consume one observation per lane; writes each lane's ternary spike
    /// indicator into `out` (len ≥ projections len).
    pub fn observe_into(&mut self, projections: &[f64], out: &mut [i8]) {
        assert!(projections.len() <= self.lanes.len());
        assert!(out.len() >= projections.len());
        for (i, &p) in projections.iter().enumerate() {
            out[i] = self.lanes[i].observe(p).as_i8();
        }
        // Idle lanes observe a constant zero: they warm up alongside the
        // active lanes and can never spike (zero variance).
        for lane in self.lanes.iter_mut().skip(projections.len()) {
            let _ = lane.observe(0.0);
        }
        for o in out.iter_mut().skip(projections.len()) {
            *o = 0;
        }
    }

    /// Convenience allocating variant.
    pub fn observe(&mut self, projections: &[f64]) -> Vec<i8> {
        let mut out = vec![0i8; projections.len()];
        self.observe_into(projections, &mut out);
        out
    }

    /// Reset all lanes (used when a node's subspace is replaced wholesale,
    /// e.g. after a global merge pull).
    pub fn reset(&mut self) {
        let cfg = self.lanes.first().map(|l| l.cfg).unwrap_or_default();
        let n = self.lanes.len();
        self.lanes = (0..n).map(|_| ZScoreDetector::new(cfg)).collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn detector() -> ZScoreDetector {
        ZScoreDetector::new(ZScoreConfig::default())
    }

    #[test]
    fn no_spikes_during_warmup() {
        let mut d = detector();
        for i in 0..10 {
            assert_eq!(d.observe(i as f64 * 100.0), Spike::None, "i={i}");
        }
        assert!(d.warmed_up());
    }

    #[test]
    fn flags_positive_spike() {
        let mut d = detector();
        // Flat-ish baseline with tiny jitter so std > 0.
        for i in 0..20 {
            d.observe(1.0 + 0.01 * ((i % 3) as f64 - 1.0));
        }
        assert_eq!(d.observe(10.0), Spike::Positive);
    }

    #[test]
    fn flags_negative_spike() {
        let mut d = detector();
        for i in 0..20 {
            d.observe(1.0 + 0.01 * ((i % 3) as f64 - 1.0));
        }
        assert_eq!(d.observe(-10.0), Spike::Negative);
    }

    #[test]
    fn zero_variance_never_spikes() {
        let mut d = detector();
        for _ in 0..50 {
            d.observe(5.0);
        }
        // std == 0 → detector refuses to flag (matches reference impl).
        assert_eq!(d.observe(5.0), Spike::None);
    }

    #[test]
    fn dampening_limits_spike_influence() {
        let mut a = ZScoreDetector::new(ZScoreConfig { beta: 0.0, ..Default::default() });
        let mut b = ZScoreDetector::new(ZScoreConfig { beta: 1.0, ..Default::default() });
        for i in 0..20 {
            let x = 1.0 + 0.01 * ((i % 3) as f64 - 1.0);
            a.observe(x);
            b.observe(x);
        }
        a.observe(100.0);
        b.observe(100.0);
        // With beta=0 the spike never enters the buffer: mean stays ~1.
        assert!(a.mean() < 2.0, "a.mean()={}", a.mean());
        // With beta=1 the spike fully enters: mean jumps.
        assert!(b.mean() > 5.0, "b.mean()={}", b.mean());
    }

    #[test]
    fn consecutive_spikes_with_dampening() {
        let mut d = detector();
        for i in 0..20 {
            d.observe(1.0 + 0.01 * ((i % 3) as f64 - 1.0));
        }
        // A sustained step keeps flagging for a while because dampening
        // slows buffer adaptation.
        let flags: Vec<Spike> = (0..4).map(|_| d.observe(50.0)).collect();
        assert_eq!(flags[0], Spike::Positive);
        assert_eq!(flags[1], Spike::Positive);
    }

    #[test]
    fn multi_detector_lanes_independent() {
        let mut m = MultiDetector::new(3, ZScoreConfig::default());
        for i in 0..20 {
            let jitter = 0.01 * ((i % 3) as f64 - 1.0);
            m.observe(&[1.0 + jitter, -1.0 + jitter, 0.0 + jitter]);
        }
        let b = m.observe(&[30.0, -30.0, 0.0]);
        assert_eq!(b, vec![1, -1, 0]);
    }

    #[test]
    fn multi_detector_handles_fewer_projections_than_lanes() {
        let mut m = MultiDetector::new(4, ZScoreConfig::default());
        let mut out = [9i8; 4];
        m.observe_into(&[1.0, 2.0], &mut out);
        assert_eq!(&out[2..], &[0, 0]);
    }

    #[test]
    fn reset_clears_state() {
        let mut m = MultiDetector::new(2, ZScoreConfig::default());
        for _ in 0..15 {
            m.observe(&[1.0, 1.0]);
        }
        assert!(m.warmed_up());
        m.reset();
        assert!(!m.warmed_up());
    }
}
