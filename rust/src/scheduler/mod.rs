//! The PRONTO scheduler (paper §6, Algorithms 1–2).
//!
//! * [`reject`] — Algorithm 1 (`Reject-Job`): project the incoming metric
//!   vector onto the node's subspace iterate, detect per-projection spikes
//!   with the z-score filter, and raise the **rejection signal** when the
//!   singular-value-weighted spike sum crosses the threshold.
//! * [`node`] — [`NodeScheduler`]: one node's full admission pipeline
//!   (embedding tracker + Reject-Job + rejection-signal window), generic
//!   over any [`crate::baselines::StreamingEmbedding`].
//! * [`job`] — the job/task model (paper treats "job" ≡ "task"): slot
//!   demand plus the log-normal service-time distribution.
//!   [`HostCapacity`] (in [`node`]) adds the mechanical side: a slot
//!   budget, the running set, and a bounded FIFO/smallest-first wait
//!   queue the simulator's capacity scenarios drive.
//! * [`policy`] — admission policies for the simulator: PRONTO, always-
//!   accept, random, and CPU-Ready-oracle (upper bound).

mod job;
mod node;
mod policy;
mod reject;
mod standardize;

pub use job::{Job, JobId, JobOutcome, Priority, ServiceTimeModel};
pub use node::{
    AdmissionProbe, HostCapacity, NodeScheduler, NodeStats, QueuePolicy, QueuedJob,
};
pub use policy::{Admission, CpuReadyOracle, ProntoPolicy, RandomPolicy, ThresholdPolicy};
pub use reject::{RejectConfig, RejectJob};
pub use standardize::OnlineStandardizer;
