//! Job/task model (the paper uses the terms interchangeably, §4).

/// Unique job identifier.
pub type JobId = u64;

/// A schedulable unit of work arriving at the data center.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: JobId,
    /// Arrival timestep (20 s trace ticks).
    pub arrival: usize,
    /// Nominal duration in timesteps once started.
    pub duration: usize,
    /// Relative CPU demand (1.0 = one nominal slot).
    pub cpu_demand: f64,
}

impl Job {
    pub fn new(id: JobId, arrival: usize, duration: usize, cpu_demand: f64) -> Self {
        assert!(duration >= 1);
        assert!(cpu_demand > 0.0);
        Self { id, arrival, duration, cpu_demand }
    }
}

/// Final disposition of a job in the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobOutcome {
    /// Accepted by a node at the given timestep.
    Accepted { node: usize, at: usize },
    /// Rejected by every probed node.
    Rejected { at: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_construction() {
        let j = Job::new(1, 0, 10, 1.5);
        assert_eq!(j.duration, 10);
    }

    #[test]
    #[should_panic]
    fn zero_duration_rejected() {
        let _ = Job::new(1, 0, 0, 1.0);
    }
}
