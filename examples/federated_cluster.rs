//! Federated cluster: thread-per-node leaves, DASM aggregation tree,
//! ε-gated iterate propagation, merged global view at the root.
//!
//! ```bash
//! cargo run --release --example federated_cluster -- [nodes] [fanout]
//! ```

use pronto::federation::{ConcurrentFederation, TreeTopology};
use pronto::telemetry::{GeneratorConfig, TraceGenerator};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let nodes: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let fanout: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps = 2_048;

    println!("federation: {nodes} leaves, fanout {fanout}, {steps} steps/leaf");
    let gen = TraceGenerator::new(GeneratorConfig::default(), 7);
    let traces: Vec<_> = (0..nodes)
        .map(|v| gen.generate_vm_in_cluster(v / fanout, v, steps))
        .collect();

    let topo = TreeTopology::new(nodes, fanout);
    println!("tree levels above leaves: {}", topo.levels());

    let fed = ConcurrentFederation::new(topo, 4, 0.5).with_push_every(64);
    // `run()` is wall-clock-free (determinism invariant); time it here.
    let started = std::time::Instant::now();
    let report = fed.run(traces).with_wall(started.elapsed());

    println!("\nfederation report");
    println!("  wall time            : {:?}", report.wall);
    println!(
        "  throughput           : {:.0} obs/s aggregate",
        report.throughput()
    );
    println!("  iterate pushes       : {}", report.pushes);
    println!("  suppressed by ε gate : {}", report.suppressed);
    println!(
        "  rejection steps      : {} (of {})",
        report.rejected_steps,
        nodes * report.steps_per_leaf
    );
    println!("\nglobal view at root (rank {}):", report.global_view.rank());
    for (i, s) in report.global_view.sigma.iter().enumerate() {
        println!("  sigma[{i}] = {s:.3}");
    }
}
