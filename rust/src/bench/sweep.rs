//! `pronto sweep` — the declared-grid runner behind `SWEEP_*.json`.
//!
//! Sweeps fleet size × dispatch policy × failure rate through the
//! discrete-event engine with the streaming trace source and cost-free
//! `always` admission, the sensitivity-grid counterpart of `pronto bench
//! engine`'s size ladder. Every cell is an independent run (fresh
//! generator, source, policies, engine) whose deterministic fields are
//! byte-identical at any `--threads` width, so two artifacts diff row by
//! row. The failure axis maps to the correlated rack-outage hazard of
//! the scenario's `FailureModel`; rate 0 runs the same grid cell with no
//! failure layer at all, anchoring each column.
//!
//! Rows carry a composite grid id in their `scenario` field —
//! `sweep/<policy>/f<rate>` — alongside `nodes`/`threads`, so
//! `pronto bench diff` joins sweep artifacts by grid coordinates with
//! the same `(scenario, nodes, threads)` key it uses for engine rows.
//!
//! ```text
//! pronto sweep --quick --out SWEEP_quick.json
//! pronto bench diff SWEEP_baseline.json SWEEP_quick.json --require-baseline
//! ```

use super::Table;
use crate::scheduler::{Admission, QueuePolicy, RandomPolicy};
use crate::ser::JsonValue;
use crate::sim::{
    CapacityModel, DiscreteEventEngine, DispatchPolicy, FailureModel, FederationSpec, Scenario,
};
use crate::telemetry::{fleet_members, GeneratorConfig, TraceGenerator, TraceSource};
use anyhow::Result;
use std::collections::BTreeMap;
use std::time::Instant;

/// Cluster grouping for generated fleets (matches the engine bench).
const SWEEP_FANOUT: usize = 8;

/// Nodes per rack on the failure axis; fleet sizes should divide by it
/// so outages take whole racks.
const SWEEP_RACK_SIZE: usize = 4;

/// The declared grid: every combination of these axes runs once.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Fleet sizes (multiples of the rack size keep outages whole-rack).
    pub sizes: Vec<usize>,
    /// Dispatch policies to score candidates with.
    pub policies: Vec<DispatchPolicy>,
    /// Per-rack per-step outage hazards; `0.0` disables the failure
    /// layer entirely for that column.
    pub failure_rates: Vec<f64>,
    /// Steps per cell.
    pub steps: usize,
    pub seed: u64,
    /// Observe-loop worker threads per cell (deterministic fields are
    /// byte-identical across widths; recorded per row for the diff key).
    pub threads: usize,
    /// Quick sizing (CI smoke) — recorded in the artifact.
    pub quick: bool,
}

impl SweepConfig {
    /// Full sizing: 24/48/96 nodes × 3 policies × 3 hazards.
    pub fn full() -> Self {
        Self {
            sizes: vec![24, 48, 96],
            policies: vec![
                DispatchPolicy::SignalOnly,
                DispatchPolicy::QueueAware,
                DispatchPolicy::LeastLoaded,
            ],
            failure_rates: vec![0.0, 0.002, 0.01],
            steps: 800,
            seed: 2021,
            threads: 1,
            quick: false,
        }
    }

    /// Quick sizing for CI smoke: same 3×3×3 grid shape at smaller
    /// fleets and a shorter trajectory (the acceptance floor is ≥ 3
    /// sizes × 3 policies × 3 rates).
    pub fn quick() -> Self {
        Self {
            sizes: vec![12, 24, 48],
            steps: 240,
            quick: true,
            ..Self::full()
        }
    }

    /// Honour `PRONTO_BENCH_QUICK=1`.
    pub fn from_env() -> Self {
        if std::env::var("PRONTO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false) {
            Self::quick()
        } else {
            Self::full()
        }
    }

    /// Cells in the declared grid.
    pub fn cells(&self) -> usize {
        self.sizes.len() * self.policies.len() * self.failure_rates.len()
    }
}

/// Stable artifact name for a dispatch policy.
pub fn policy_name(p: DispatchPolicy) -> &'static str {
    match p {
        DispatchPolicy::SignalOnly => "signal-only",
        DispatchPolicy::QueueAware => "queue-aware",
        DispatchPolicy::LeastLoaded => "least-loaded",
    }
}

/// Composite grid id carried in the row's `scenario` field: the
/// non-numeric grid coordinates, fixed-width so ids are stable strings
/// (`sweep/queue-aware/f0.0020`). `nodes` and `threads` stay separate —
/// together the three make up `bench diff`'s `(scenario, nodes,
/// threads)` join key.
pub fn grid_id(policy: DispatchPolicy, failure_rate: f64) -> String {
    format!("sweep/{}/f{:.4}", policy_name(policy), failure_rate)
}

/// One grid cell's measurement.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub policy: DispatchPolicy,
    pub failure_rate: f64,
    pub nodes: usize,
    pub steps: usize,
    pub seed: u64,
    pub threads: usize,
    pub wall_ms: f64,
    pub events: usize,
    pub events_per_sec: f64,
    pub jobs_arrived: usize,
    pub jobs_completed: usize,
    pub jobs_rejected: usize,
    pub rack_outages: usize,
}

impl SweepRow {
    pub fn grid_id(&self) -> String {
        grid_id(self.policy, self.failure_rate)
    }

    pub fn to_json(&self) -> JsonValue {
        let mut m = BTreeMap::new();
        let num = |x: usize| JsonValue::Number(x as f64);
        m.insert("scenario".into(), JsonValue::String(self.grid_id()));
        m.insert("policy".into(), JsonValue::String(policy_name(self.policy).into()));
        m.insert("failure_rate".into(), JsonValue::Number(self.failure_rate));
        m.insert("nodes".into(), num(self.nodes));
        m.insert("steps".into(), num(self.steps));
        m.insert("seed".into(), JsonValue::String(self.seed.to_string()));
        m.insert("threads".into(), num(self.threads));
        m.insert("wall_ms".into(), JsonValue::Number(self.wall_ms));
        m.insert("events".into(), num(self.events));
        m.insert("events_per_sec".into(), JsonValue::Number(self.events_per_sec));
        m.insert("jobs_arrived".into(), num(self.jobs_arrived));
        m.insert("jobs_completed".into(), num(self.jobs_completed));
        m.insert("jobs_rejected".into(), num(self.jobs_rejected));
        m.insert("rack_outages".into(), num(self.rack_outages));
        JsonValue::Object(m)
    }
}

/// The scenario one grid cell runs: capacity + federation on, the
/// requested dispatch policy, and — at a non-zero rate — whole-rack
/// outages floored at a quarter of the fleet.
fn cell_scenario(
    nodes: usize,
    steps: usize,
    seed: u64,
    threads: usize,
    policy: DispatchPolicy,
    failure_rate: f64,
) -> Scenario {
    let failures = (failure_rate > 0.0).then(|| FailureModel {
        rack_size: SWEEP_RACK_SIZE,
        rack_outage_hazard: failure_rate,
        rack_outage_duration_mean: 30.0,
        min_alive: (nodes / 4).max(1),
        ..FailureModel::default()
    });
    Scenario {
        name: grid_id(policy, failure_rate),
        dispatch: policy,
        capacity: Some(CapacityModel {
            slots_per_node: 4,
            contended_slots: 4,
            queue_capacity: 8,
            max_job_slots: 2,
            queue_policy: QueuePolicy::Fifo,
            migration_limit: 2,
            ..CapacityModel::default()
        }),
        federation: FederationSpec { enabled: true, ..FederationSpec::default() },
        failures,
        ..Scenario::default()
    }
    .with_nodes(nodes)
    .with_steps(steps)
    .with_seed(seed)
    .with_threads(threads)
}

/// Run one grid cell through the streaming source with `always`-accept
/// policies, timed end to end. Cells share no state (see the engine
/// bench's row-independence contract).
pub fn run_sweep_cell(
    nodes: usize,
    policy: DispatchPolicy,
    failure_rate: f64,
    steps: usize,
    seed: u64,
    threads: usize,
) -> Result<SweepRow> {
    let scenario = cell_scenario(nodes, steps, seed, threads, policy, failure_rate);
    scenario.validate()?;
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    let members = fleet_members(nodes, SWEEP_FANOUT);
    let source = TraceSource::streaming(&gen, &members, steps, scenario.score_window);
    let policies: Vec<Box<dyn Admission>> = (0..nodes)
        .map(|i| {
            Box::new(RandomPolicy::always_accept(seed ^ i as u64)) as Box<dyn Admission>
        })
        .collect();
    let engine = DiscreteEventEngine::try_from_source(scenario, source, policies)?;
    let t0 = Instant::now();
    let report = engine.run();
    let wall = t0.elapsed();
    Ok(SweepRow {
        policy,
        failure_rate,
        nodes,
        steps,
        seed,
        threads,
        wall_ms: wall.as_secs_f64() * 1e3,
        events: report.events_processed,
        events_per_sec: report.events_processed as f64 / wall.as_secs_f64().max(1e-9),
        jobs_arrived: report.jobs_arrived,
        jobs_completed: report.jobs_completed,
        jobs_rejected: report.jobs_rejected,
        rack_outages: report.rack_outages,
    })
}

/// Run the whole declared grid in axis order (size-major, then policy,
/// then rate), logging one line per cell to stderr.
pub fn run_sweep(cfg: &SweepConfig) -> Result<Vec<SweepRow>> {
    let mut rows = Vec::with_capacity(cfg.cells());
    for &nodes in &cfg.sizes {
        for &policy in &cfg.policies {
            for &rate in &cfg.failure_rates {
                let row = run_sweep_cell(nodes, policy, rate, cfg.steps, cfg.seed, cfg.threads)?;
                eprintln!(
                    "sweep: {:<26} {:>6} nodes — {:>8.1} ms, {} outages, {} jobs",
                    row.grid_id(),
                    row.nodes,
                    row.wall_ms,
                    row.rack_outages,
                    row.jobs_arrived
                );
                rows.push(row);
            }
        }
    }
    Ok(rows)
}

/// The deterministic stdout table: grid coordinates plus the counters
/// that must be byte-identical across `--threads` widths. Wall time and
/// throughput live only in the JSON artifact, so CI can diff two
/// renders of this table directly.
pub fn sweep_table(rows: &[SweepRow]) -> Table {
    let mut t = Table::new(
        "pronto sweep — fleet × dispatch × failure rate",
        &["grid", "nodes", "events", "arrived", "completed", "rejected", "outages"],
    );
    for r in rows {
        t.row(&[
            r.grid_id(),
            r.nodes.to_string(),
            r.events.to_string(),
            r.jobs_arrived.to_string(),
            r.jobs_completed.to_string(),
            r.jobs_rejected.to_string(),
            r.rack_outages.to_string(),
        ]);
    }
    t
}

/// The `SWEEP_*.json` document (schema documented in the README): grid
/// metadata plus one entry per cell.
pub fn sweep_report(cfg: &SweepConfig, rows: &[SweepRow]) -> JsonValue {
    let mut m = BTreeMap::new();
    m.insert("bench".into(), JsonValue::String("sweep".into()));
    m.insert("schema_version".into(), JsonValue::Number(1.0));
    m.insert("quick".into(), JsonValue::Bool(cfg.quick));
    m.insert("policy".into(), JsonValue::String("always".into()));
    m.insert("trace_source".into(), JsonValue::String("streaming".into()));
    m.insert("steps".into(), JsonValue::Number(cfg.steps as f64));
    m.insert("seed".into(), JsonValue::String(cfg.seed.to_string()));
    m.insert("threads".into(), JsonValue::Number(cfg.threads as f64));
    m.insert(
        "sizes".into(),
        JsonValue::Array(cfg.sizes.iter().map(|&s| JsonValue::Number(s as f64)).collect()),
    );
    m.insert(
        "policies".into(),
        JsonValue::Array(
            cfg.policies
                .iter()
                .map(|&p| JsonValue::String(policy_name(p).into()))
                .collect(),
        ),
    );
    m.insert(
        "failure_rates".into(),
        JsonValue::Array(cfg.failure_rates.iter().map(|&r| JsonValue::Number(r)).collect()),
    );
    m.insert(
        "rows".into(),
        JsonValue::Array(rows.iter().map(SweepRow::to_json).collect()),
    );
    JsonValue::Object(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::bench_diff;
    use crate::ser::parse_json;

    fn tiny() -> SweepConfig {
        SweepConfig {
            sizes: vec![8],
            policies: vec![DispatchPolicy::SignalOnly, DispatchPolicy::QueueAware],
            failure_rates: vec![0.0, 0.05],
            steps: 60,
            seed: 9,
            threads: 1,
            quick: true,
        }
    }

    #[test]
    fn declared_grids_meet_the_acceptance_floor() {
        for cfg in [SweepConfig::full(), SweepConfig::quick()] {
            assert!(cfg.sizes.len() >= 3);
            assert!(cfg.policies.len() >= 3);
            assert!(cfg.failure_rates.len() >= 3);
            assert_eq!(cfg.cells(), 27);
            assert!(cfg.failure_rates.contains(&0.0), "grid needs its no-failure anchor");
            assert!(
                cfg.sizes.iter().all(|s| s % SWEEP_RACK_SIZE == 0),
                "sizes must divide into whole racks"
            );
        }
    }

    #[test]
    fn grid_ids_are_stable_and_unique_per_cell() {
        let cfg = SweepConfig::quick();
        let mut seen = std::collections::BTreeSet::new();
        for &p in &cfg.policies {
            for &r in &cfg.failure_rates {
                assert!(seen.insert(grid_id(p, r)), "duplicate grid id");
            }
        }
        assert_eq!(grid_id(DispatchPolicy::QueueAware, 0.002), "sweep/queue-aware/f0.0020");
    }

    #[test]
    fn rows_are_deterministic_across_observe_widths() {
        let a = run_sweep(&tiny()).unwrap();
        let b = run_sweep(&SweepConfig { threads: 3, ..tiny() }).unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.grid_id(), y.grid_id());
            assert_eq!(x.events, y.events, "{} diverged across widths", x.grid_id());
            assert_eq!(x.jobs_arrived, y.jobs_arrived);
            assert_eq!(x.jobs_completed, y.jobs_completed);
            assert_eq!(x.jobs_rejected, y.jobs_rejected);
            assert_eq!(x.rack_outages, y.rack_outages);
        }
        // The rendered table carries only deterministic columns, so the
        // two renders are byte-identical even at different widths.
        assert_eq!(sweep_table(&a).render(), sweep_table(&b).render());
        // The failure axis is live: the hazard column saw outages, the
        // anchor column none.
        let hot: usize =
            a.iter().filter(|r| r.failure_rate > 0.0).map(|r| r.rack_outages).sum();
        let cold: usize =
            a.iter().filter(|r| r.failure_rate == 0.0).map(|r| r.rack_outages).sum();
        assert!(hot > 0, "hazard column never fired an outage");
        assert_eq!(cold, 0, "anchor column must stay failure-free");
    }

    #[test]
    fn sweep_artifacts_join_in_bench_diff_by_grid_coordinates() {
        let cfg = tiny();
        let rows = run_sweep(&cfg).unwrap();
        let doc = sweep_report(&cfg, &rows).to_string();
        let parsed = parse_json(&doc).expect("valid json");
        assert_eq!(parsed.get("bench").and_then(JsonValue::as_str), Some("sweep"));
        assert_eq!(parsed.get("schema_version").and_then(JsonValue::as_usize), Some(1));
        // A sweep artifact diffs against itself: every row joins on the
        // (grid id, nodes, threads) key and nothing regresses.
        let d = bench_diff(&doc, &doc).unwrap();
        assert_eq!(d.rows.len(), rows.len());
        assert!(d.only_old.is_empty() && d.only_new.is_empty());
        assert!(d.regressions_beyond(0.0).is_empty());
    }
}
