//! Fleet-scale engine benchmark target: the `pronto bench engine` sweep
//! as a `cargo bench` binary (same driver, same JSON artifact schema).
//!
//! `PRONTO_BENCH_QUICK=1` shrinks the ladder for smoke runs;
//! `PRONTO_BENCH_JSON=path` additionally writes `BENCH_engine.json`.

use pronto::bench::{bench_engine, bench_engine_report, EngineBenchConfig, Table};

fn main() {
    let cfg = EngineBenchConfig::from_env();
    let runs = match bench_engine(&cfg) {
        Ok(runs) => runs,
        Err(e) => {
            eprintln!("engine bench failed: {e:#}");
            std::process::exit(1);
        }
    };

    let mut table = Table::new(
        &format!(
            "Engine scale sweep (streaming source, always policy, {} steps)",
            cfg.steps
        ),
        &["scenario", "nodes", "events", "wall(ms)", "events/s", "peakq", "jobs"],
    );
    for r in &runs {
        table.row(&[
            r.scenario.clone(),
            r.nodes.to_string(),
            r.events.to_string(),
            format!("{:.1}", r.wall_ms),
            format!("{:.0}", r.events_per_sec),
            r.peak_queue_len.to_string(),
            r.jobs_arrived.to_string(),
        ]);
    }
    table.print();
    table.maybe_write_csv("engine_scale");

    if let Ok(path) = std::env::var("PRONTO_BENCH_JSON") {
        let doc = bench_engine_report(&cfg, &runs);
        if let Err(e) = std::fs::write(&path, format!("{doc}\n")) {
            eprintln!("warn: could not write {path}: {e}");
        }
    }
}
