//! Property-based tests for the deterministic event queue, in the style
//! of `linalg_props.rs`: seeded, replayable via `PRONTO_PROP_SEED` /
//! `PRONTO_PROP_CASES`.
//!
//! The invariants under test are exactly what the engine's
//! bit-reproducibility rests on: pops are globally ordered by
//! `(time, seq)`, same-time events preserve schedule order (FIFO), and
//! the step/tick conversions round-trip.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::proptest::forall;
use pronto::sim::{
    latency_to_ticks, step_to_ticks, ticks_to_step, Event, EventQueue, SimTime, TickBatch,
    TICKS_PER_STEP,
};

/// Tag each scheduled event with its insertion index so the pop sequence
/// can be compared against a reference model.
fn tagged(node: usize) -> Event {
    Event::NodeJoin { node }
}

fn untag(e: Event) -> usize {
    match e {
        Event::NodeJoin { node } => node,
        other => panic!("unexpected event {other:?}"),
    }
}

#[test]
fn pops_match_a_stable_sort_by_time_then_schedule_order() {
    forall("EventQueue ≡ stable sort by (time, insertion)", |rng| {
        let n = 1 + rng.gen_range(300);
        let mut q = EventQueue::with_capacity(n);
        let mut model: Vec<(SimTime, usize)> = Vec::with_capacity(n);
        for i in 0..n {
            // Small time range forces plenty of ties.
            let t = rng.gen_range(40) as SimTime;
            q.schedule(t, tagged(i));
            model.push((t, i));
        }
        // Reference: stable sort by time keeps insertion order on ties;
        // sorting the (time, index) pairs is the same thing.
        model.sort();
        let mut popped = Vec::with_capacity(n);
        while let Some(s) = q.pop() {
            let idx = untag(s.event);
            if s.time != model.iter().find(|&&(_, i)| i == idx).unwrap().0 {
                return Err(format!("event {idx} popped with a mutated time {}", s.time));
            }
            popped.push((s.time, idx));
        }
        if popped.len() != n {
            return Err(format!("popped {} of {n} events", popped.len()));
        }
        if popped != model {
            return Err("pop order diverged from stable (time, seq) sort".into());
        }
        Ok(())
    });
}

#[test]
fn pops_are_globally_ordered_under_interleaved_scheduling() {
    forall("interleaved schedule/pop keeps (time, seq) order", |rng| {
        let mut q = EventQueue::with_capacity(64);
        let rounds = 1 + rng.gen_range(20);
        let mut next_tag = 0usize;
        let mut tag_time: Vec<SimTime> = Vec::new();
        let mut last: Option<(SimTime, usize)> = None;
        let mut popped = 0usize;
        // Clock floor: new events may never be scheduled before the last
        // pop (the engine only schedules at or after `now`), otherwise
        // global pop ordering is unachievable by construction.
        let mut floor: SimTime = 0;
        for _ in 0..rounds {
            for _ in 0..(1 + rng.gen_range(10)) {
                let t = floor + rng.gen_range(30) as SimTime;
                q.schedule(t, tagged(next_tag));
                tag_time.push(t);
                next_tag += 1;
            }
            for _ in 0..rng.gen_range(8) {
                let Some(s) = q.pop() else { break };
                popped += 1;
                let idx = untag(s.event);
                if s.time != tag_time[idx] {
                    return Err(format!("tag {idx}: time {} != scheduled {}", s.time, tag_time[idx]));
                }
                if let Some((lt, lidx)) = last {
                    if s.time < lt {
                        return Err(format!("time went backwards: {} after {lt}", s.time));
                    }
                    if s.time == lt && idx < lidx {
                        return Err(format!(
                            "same-time FIFO violated: tag {idx} after {lidx} at t={lt}"
                        ));
                    }
                }
                floor = s.time;
                last = Some((s.time, idx));
            }
        }
        // Drain the rest; the invariant must hold to the end.
        while let Some(s) = q.pop() {
            popped += 1;
            let idx = untag(s.event);
            if let Some((lt, lidx)) = last {
                if s.time < lt || (s.time == lt && idx < lidx) {
                    return Err(format!("drain violated order at tag {idx}"));
                }
            }
            last = Some((s.time, idx));
        }
        if popped != next_tag {
            return Err(format!("lost events: {popped} of {next_tag}"));
        }
        Ok(())
    });
}

#[test]
fn same_time_events_pop_in_schedule_order_exactly() {
    forall("equal timestamps drain FIFO", |rng| {
        let mut q = EventQueue::with_capacity(64);
        let t = rng.gen_range(1_000) as SimTime;
        let n = 2 + rng.gen_range(100);
        for i in 0..n {
            q.schedule(t, tagged(i));
        }
        for want in 0..n {
            let s = q.pop().ok_or("queue drained early")?;
            if s.time != t {
                return Err(format!("time changed: {}", s.time));
            }
            let got = untag(s.event);
            if got != want {
                return Err(format!("FIFO broken: got {got}, want {want}"));
            }
        }
        if !q.is_empty() {
            return Err("queue not empty after draining".into());
        }
        Ok(())
    });
}

#[test]
fn tick_batches_partition_the_pop_order_by_timestamp() {
    // The engine's batched event loop is only sound if concatenating
    // drained batches reproduces the per-event pop order exactly, with
    // each batch holding *all* events of one timestamp. Timestamps are
    // drawn from a tiny range so duplicates are the norm, not the
    // exception.
    forall("drain_tick ≡ pop, grouped by equal timestamps", |rng| {
        let n = 1 + rng.gen_range(400);
        let time_range = 1 + rng.gen_range(8); // aggressive duplication
        let mut batched = EventQueue::with_capacity(n);
        let mut reference = EventQueue::with_capacity(n);
        for i in 0..n {
            let t = rng.gen_range(time_range) as SimTime;
            batched.schedule(t, tagged(i));
            reference.schedule(t, tagged(i));
        }
        let mut batch = TickBatch::default();
        let mut last_time: Option<SimTime> = None;
        let mut drained = 0usize;
        while batched.drain_tick(&mut batch) {
            if batch.is_empty() {
                return Err("drain_tick returned true with an empty batch".into());
            }
            if let Some(lt) = last_time {
                if batch.time() <= lt {
                    return Err(format!(
                        "batch times not strictly increasing: {} after {lt}",
                        batch.time()
                    ));
                }
            }
            last_time = Some(batch.time());
            for s in batch.events() {
                if s.time != batch.time() {
                    return Err(format!(
                        "mixed timestamps in one batch: {} in a t={} batch",
                        s.time,
                        batch.time()
                    ));
                }
                let want = reference.pop().ok_or("reference queue drained early")?;
                if s.time != want.time || untag(s.event) != untag(want.event) {
                    return Err(format!(
                        "batch order diverged from pop order at tag {}",
                        untag(s.event)
                    ));
                }
                drained += 1;
            }
            // A batch must be maximal: the next pending event (if any)
            // carries a strictly later timestamp.
            if let Some(next) = batched.peek_time() {
                if next == batch.time() {
                    return Err("batch left a same-timestamp event behind".into());
                }
            }
        }
        if drained != n {
            return Err(format!("drained {drained} of {n} events"));
        }
        if reference.pop().is_some() {
            return Err("reference queue still has events".into());
        }
        Ok(())
    });
}

#[test]
fn events_scheduled_mid_batch_land_in_a_later_batch() {
    // The engine schedules same-timestamp follow-ups (enqueue → start)
    // while processing a batch; they must surface in the *next* drain at
    // that timestamp, in schedule order — exactly where per-event
    // popping would have put them.
    forall("mid-batch schedules drain next, FIFO", |rng| {
        let t = rng.gen_range(100) as SimTime;
        let first = 1 + rng.gen_range(20);
        let mut q = EventQueue::with_capacity(64);
        for i in 0..first {
            q.schedule(t, tagged(i));
        }
        let mut batch = TickBatch::default();
        if !q.drain_tick(&mut batch) || batch.len() != first {
            return Err(format!("expected a {first}-event batch"));
        }
        // "Handlers" enqueue follow-ups at the same timestamp.
        let extra = 1 + rng.gen_range(20);
        for i in 0..extra {
            q.schedule(t, tagged(first + i));
        }
        if !q.drain_tick(&mut batch) {
            return Err("follow-up batch missing".into());
        }
        if batch.time() != t || batch.len() != extra {
            return Err(format!(
                "follow-ups mis-batched: {} events at t={}",
                batch.len(),
                batch.time()
            ));
        }
        let tags: Vec<usize> = batch.events().iter().map(|s| untag(s.event)).collect();
        let want: Vec<usize> = (first..first + extra).collect();
        if tags != want {
            return Err(format!("follow-up order {tags:?} != schedule order {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn drain_tick_into_reuses_a_caller_owned_batch_across_queues() {
    // The allocation-churn fix: `drain_tick_into` clears and refills the
    // caller's `TickBatch` instead of building a fresh one per tick. The
    // same buffer must be safely reusable across drains *and across
    // queues* — stale contents from a previous (larger) batch may never
    // leak into a later one.
    forall("drain_tick_into: caller-owned buffer, no stale events", |rng| {
        let mut big = EventQueue::with_capacity(64);
        let mut small = EventQueue::with_capacity(8);
        let t = rng.gen_range(500) as SimTime;
        let wide = 10 + rng.gen_range(40);
        for i in 0..wide {
            big.schedule(t, tagged(i));
        }
        let narrow = 1 + rng.gen_range(5);
        for i in 0..narrow {
            small.schedule(t + 1, tagged(1_000 + i));
        }
        let mut batch = TickBatch::default();
        if !big.drain_tick_into(&mut batch) || batch.len() != wide {
            return Err(format!("wide drain returned {} of {wide}", batch.len()));
        }
        // Refill the same buffer from the other queue: old events gone,
        // new ones in schedule order.
        if !small.drain_tick_into(&mut batch) {
            return Err("narrow drain missing".into());
        }
        if batch.len() != narrow || batch.time() != t + 1 {
            return Err(format!(
                "stale batch state: {} events at t={}",
                batch.len(),
                batch.time()
            ));
        }
        let tags: Vec<usize> = batch.events().iter().map(|s| untag(s.event)).collect();
        let want: Vec<usize> = (1_000..1_000 + narrow).collect();
        if tags != want {
            return Err(format!("refill order {tags:?} != {want:?}"));
        }
        // Exhausted queues report false and leave the batch empty.
        if big.drain_tick_into(&mut batch) {
            return Err("drained queue reported another batch".into());
        }
        if !batch.is_empty() {
            return Err("failed drain left stale events in the batch".into());
        }
        Ok(())
    });
}

#[test]
fn step_tick_conversions_roundtrip_for_arbitrary_steps() {
    forall("step↔tick round-trip", |rng| {
        // Any step a realistic run could reach (u64 ticks cap the step
        // space at 2^64 / TICKS_PER_STEP; stay well inside).
        let step = rng.gen_range(1 << 40);
        let base = step_to_ticks(step);
        if ticks_to_step(base) != step {
            return Err(format!("step {step}: base tick maps to {}", ticks_to_step(base)));
        }
        // Every tick within the step maps back to it…
        let off = rng.gen_range(TICKS_PER_STEP as usize) as SimTime;
        if ticks_to_step(base + off) != step {
            return Err(format!("step {step} + {off} ticks leaked to another step"));
        }
        // …and the first tick past it does not.
        if ticks_to_step(base + TICKS_PER_STEP) != step + 1 {
            return Err("step boundary off by one".into());
        }
        Ok(())
    });
}

#[test]
fn same_tick_storm_interleavings_conserve_the_job_ledger() {
    // Engine-level TickBatch coverage: replayed arrival storms past the
    // per-step scheduling-offset clamp (> TICKS_PER_STEP − 2 arrivals in
    // one step) force genuinely same-timestamp arrival events, which
    // then collide with enqueues, starts, completions, preemptions, and
    // churn at single ticks. Whatever the interleaving, the job ledger
    // must balance and the run must be byte-reproducible.
    use pronto::scheduler::{Admission, RandomPolicy};
    use pronto::sim::{
        ArrivalPattern, CapacityModel, ChurnModel, DiscreteEventEngine, ReplaySchedule, Scenario,
    };
    use pronto::telemetry::{GeneratorConfig, TraceGenerator};

    forall("same-tick storms: ledger conservation + determinism", |rng| {
        let nodes = 4 + rng.gen_range(5);
        let steps = 8 + rng.gen_range(8);
        // Mostly quiet steps with 1–3 storms big enough to clamp.
        let mut counts = vec![0u32; steps];
        for _ in 0..(1 + rng.gen_range(3)) {
            counts[rng.gen_range(steps)] = 1_000 + rng.gen_range(600) as u32;
        }
        let seed = rng.next_u64();
        let mut sc = Scenario {
            arrivals: ArrivalPattern::Replay {
                schedule: std::sync::Arc::new(ReplaySchedule::from_counts(
                    counts, "prop-storm",
                )),
            },
            capacity: Some(CapacityModel {
                slots_per_node: 1 + rng.gen_range(3) as u32,
                queue_capacity: rng.gen_range(6),
                migration_limit: rng.gen_range(3) as u32,
                ..CapacityModel::default()
            }),
            duration_mu: 0.4,
            duration_sigma: 0.3,
            ..Scenario::default()
        }
        .with_nodes(nodes)
        .with_steps(steps)
        .with_seed(seed);
        // contended_slots must not exceed the drawn slots_per_node.
        if let Some(c) = sc.capacity.as_mut() {
            c.contended_slots = c.slots_per_node;
        }
        if rng.bernoulli(0.5) {
            sc.churn = Some(ChurnModel {
                leave_hazard: 0.1,
                rejoin_delay_mean: 2.0,
                min_alive: 2,
            });
        }
        let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
        let tr: Vec<_> = (0..nodes).map(|v| gen.generate_vm_in_cluster(v / 4, v, steps)).collect();
        let run = |threads: usize| {
            let pol: Vec<Box<dyn Admission>> = (0..nodes)
                .map(|i| Box::new(RandomPolicy::new(0.2, seed ^ i as u64)) as Box<dyn Admission>)
                .collect();
            DiscreteEventEngine::new(sc.clone().with_threads(threads), tr.clone(), pol).run()
        };
        let a = run(1);
        let b = run(1);
        if a.to_json_string() != b.to_json_string() {
            return Err("storm run not reproducible".into());
        }
        let c = run(4);
        if a.to_json_string() != c.to_json_string() {
            return Err("thread width changed storm bytes".into());
        }
        if a.jobs_arrived < 1_000 {
            return Err(format!("storm too thin: {}", a.jobs_arrived));
        }
        let settled = a.jobs_rejected
            + a.jobs_completed
            + a.jobs_dropped
            + a.jobs_displaced
            + a.jobs_still_queued
            + a.jobs_still_running;
        if a.jobs_arrived != settled {
            return Err(format!(
                "ledger leaked: {} arrived vs {settled} settled",
                a.jobs_arrived
            ));
        }
        if a.jobs_arrived != a.jobs_accepted + a.jobs_rejected {
            return Err("accept/reject split leaked".into());
        }
        Ok(())
    });
}

#[test]
fn in_batch_partition_heal_ordering_is_sound_and_replays_later_same_tick() {
    // The failure layer's partition machinery rests on two queue
    // properties: (1) a partition's Start always drains before its Heal
    // even when both land in the *same* batch (Start is scheduled
    // first, and same-time events drain FIFO), so the engine's per-node
    // overlap counters never under-run; (2) the stale replays a Heal
    // handler schedules *at the batch's own timestamp* surface in a
    // later batch at that same tick — after every heal of the tick has
    // been applied, exactly like the JobEnqueue follow-up pattern.
    forall("partition start/heal: overlap counters + stale replay batching", |rng| {
        let nodes = 4 + rng.gen_range(12);
        let parts = 1 + rng.gen_range(10);
        let mut q = EventQueue::with_capacity(64);
        let mut spans: Vec<(SimTime, SimTime)> = Vec::with_capacity(parts);
        for p in 0..parts {
            // Tight ranges force same-tick starts, heals, and overlaps
            // between distinct partitions; a zero-length span puts a
            // partition's own start and heal in one batch.
            let ts = rng.gen_range(20) as SimTime;
            let th = ts + rng.gen_range(10) as SimTime;
            q.schedule(ts, Event::PartitionStart { partition: p });
            q.schedule(th, Event::PartitionHeal { partition: p });
            spans.push((ts, th));
        }
        // Deterministic member sets that overlap across partitions, so
        // a node can sit under several concurrent cuts.
        let members = |p: usize| (0..3).map(move |k| (p + k) % nodes);
        let mut overlap = vec![0i64; nodes];
        let mut pending_replays: Vec<(usize, SimTime)> = Vec::new();
        let mut healed = 0usize;
        let mut replayed = 0usize;
        let mut batch = TickBatch::default();
        while q.drain_tick(&mut batch) {
            let t = batch.time();
            for s in batch.events() {
                match s.event {
                    Event::PartitionStart { partition } => {
                        for m in members(partition) {
                            overlap[m] += 1;
                        }
                    }
                    Event::PartitionHeal { partition } => {
                        for m in members(partition) {
                            overlap[m] -= 1;
                            if overlap[m] < 0 {
                                return Err(format!(
                                    "overlap under-ran on node {m} at t={t}: \
                                     a heal drained before its start"
                                ));
                            }
                        }
                        healed += 1;
                        // Engine-style stale replay: scheduled at the
                        // batch's own timestamp with the original
                        // send-time payload.
                        q.schedule(
                            t,
                            Event::FederationPush {
                                leaf: partition % nodes,
                                snapshot: partition,
                                sent_at: spans[partition].0,
                            },
                        );
                        pending_replays.push((partition, t));
                    }
                    Event::FederationPush { snapshot, sent_at, .. } => {
                        let pos = pending_replays
                            .iter()
                            .position(|&(p, _)| p == snapshot)
                            .ok_or("replay delivered that no heal scheduled")?;
                        let (_, heal_t) = pending_replays.swap_remove(pos);
                        if t != heal_t {
                            return Err(format!(
                                "stale replay drifted: healed at {heal_t}, delivered at {t}"
                            ));
                        }
                        if sent_at != spans[snapshot].0 {
                            return Err("replay lost its original send time".into());
                        }
                        replayed += 1;
                    }
                    other => return Err(format!("unexpected event {other:?}")),
                }
            }
        }
        if healed != parts || replayed != parts {
            return Err(format!(
                "lost partitions: {healed} healed, {replayed} replayed of {parts}"
            ));
        }
        if !pending_replays.is_empty() {
            return Err("a scheduled replay never drained".into());
        }
        if overlap.iter().any(|&c| c != 0) {
            return Err("overlap counters did not return to zero".into());
        }
        Ok(())
    });
}

#[test]
fn latency_to_ticks_is_monotone_and_never_zero() {
    forall("latency_to_ticks: floor 1, monotone, exact on whole steps", |rng| {
        let a = rng.next_f64() * 50.0;
        let b = rng.next_f64() * 50.0;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let (tl, th) = (latency_to_ticks(lo), latency_to_ticks(hi));
        if tl == 0 || th == 0 {
            return Err("a delayed event may never tie its cause (zero ticks)".into());
        }
        if tl > th {
            return Err(format!("monotonicity broken: {lo}->{tl}, {hi}->{th}"));
        }
        let k = 1 + rng.gen_range(100) as u64;
        if latency_to_ticks(k as f64) != k * TICKS_PER_STEP {
            return Err(format!("whole-step latency {k} not exact"));
        }
        if latency_to_ticks(-1.0) != 1 {
            return Err("negative latency must clamp to the 1-tick floor".into());
        }
        Ok(())
    });
}
