// Fixture: registered key, read-only access.
pub fn quick() -> bool {
    std::env::var("PRONTO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}
