//! Typed simulation events and the deterministic event queue.
//!
//! The queue orders events by `(time, seq)`: `time` is a fixed-point tick
//! count ([`TICKS_PER_STEP`] ticks per 20 s telemetry step, so sub-step
//! latencies order correctly without floating-point comparisons) and
//! `seq` is a monotone insertion counter that breaks ties
//! deterministically — two runs that schedule the same events in the same
//! order pop them in the same order, which is what makes reports
//! bit-reproducible. Event payloads are small `Copy` data; anything large
//! (federation subspace snapshots) lives in a pooled slab on the engine
//! side and is referenced here by index, keeping the hot loop free of
//! per-event allocation.
//!
//! # Backings: hierarchical timing wheel vs binary-heap oracle
//!
//! Two interchangeable backings implement the queue, selected per
//! instance by [`QueueBacking`] and **guaranteed to produce the exact
//! same `(time, seq)` pop order** (property-tested against each other in
//! `tests/queue_wheel_parity.rs`, and byte-identical per catalog
//! scenario):
//!
//! * [`QueueBacking::Wheel`] (the default) — a three-level hierarchical
//!   timing wheel, 1024 slots per level (1 tick / 1024 ticks / 2²⁰ ticks
//!   of slot granularity, ~2³⁰ ticks ≈ a million steps of total span),
//!   with per-level occupancy bitmaps so empty stretches cost one word
//!   scan instead of a slot walk. Schedule and pop are O(1) amortized at
//!   storm rates — the `BinaryHeap`'s O(log n) comparisons (and its
//!   cache-hostile sift paths) were the top engine cost at 100k-node
//!   fleet sizes, where hundreds of thousands of arrival/completion
//!   events are resident at once. Far-future events (beyond the top
//!   level's span — only reachable through pathological service-time
//!   draws) overflow into a small heap and re-enter the wheel when the
//!   cursor reaches their span; events scheduled before the current
//!   cursor (the engine never does this — it only schedules at or after
//!   the tick being drained) are held in a strictly-earlier heap so the
//!   pop order stays exact even for that misuse.
//! * [`QueueBacking::Heap`] — the historical binary min-heap, kept as the
//!   debug oracle. Build with `--features heap-oracle` (or set
//!   `PRONTO_EVENT_QUEUE=heap` at run time) to force every queue in the
//!   process onto the heap; CI diffs full catalog runs across the two
//!   backings byte-for-byte.

use crate::scheduler::JobId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation clock: integer ticks.
pub type SimTime = u64;

/// Ticks per telemetry step (20 s of simulated wall time).
pub const TICKS_PER_STEP: u64 = 1_000;

/// Convert a step index to its tick timestamp.
#[inline]
pub fn step_to_ticks(step: usize) -> SimTime {
    step as u64 * TICKS_PER_STEP
}

/// Convert a tick timestamp to the telemetry step it falls in.
#[inline]
pub fn ticks_to_step(t: SimTime) -> usize {
    (t / TICKS_PER_STEP) as usize
}

/// Convert a latency in (possibly fractional) steps to whole ticks,
/// always at least one tick so a delayed event never ties its cause.
#[inline]
pub fn latency_to_ticks(steps: f64) -> u64 {
    ((steps.max(0.0) * TICKS_PER_STEP as f64).round() as u64).max(1)
}

/// Everything that can happen in the cluster.
///
/// Job lifecycle events carry `gen` — the job's *placement generation*,
/// bumped every time the job is displaced or re-placed. A handler ignores
/// an event whose generation no longer matches the job's, which makes
/// stale events (a completion for a job that was preempted in between, a
/// preemption for a job that already finished) safe no-ops instead of
/// double bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// All alive nodes consume their telemetry vector for `step`.
    TelemetryTick { step: usize },
    /// A job arrives at the dispatcher (demand/duration live in the
    /// engine's job table).
    JobArrival { job_id: JobId },
    /// A job admitted by `node` is handed to the host: it either starts,
    /// parks in the bounded wait queue, or is dropped when the queue is
    /// full.
    JobEnqueue { node: usize, job_id: JobId },
    /// A job begins service on `node` (slots were reserved when the start
    /// was scheduled).
    JobStart { node: usize, job_id: JobId, gen: u32 },
    /// A previously started job finishes on `node`.
    JobCompletion { node: usize, job_id: JobId, gen: u32 },
    /// An over-committed node sheds a running job (pressure preemption:
    /// the rejection signal is raised and usage exceeds the contended
    /// budget).
    JobPreempt { node: usize, job_id: JobId, gen: u32 },
    /// A displaced job is re-offered to peers; `from` (the node that shed
    /// it) is excluded from the probe.
    JobMigrate { job_id: JobId, from: usize },
    /// A leaf's iterate snapshot (pooled at `snapshot`) reaches its
    /// aggregator after the configured push latency.
    FederationPush { leaf: usize, snapshot: usize, sent_at: SimTime },
    /// A node joins (or rejoins) the pool.
    NodeJoin { node: usize },
    /// A node leaves the pool; its in-flight jobs are displaced.
    NodeLeave { node: usize },
    /// A federation network partition opens: the member set indexed by
    /// `partition` in the engine's partition table loses its uplink
    /// (pushes are queued or dropped until the matching heal).
    PartitionStart { partition: usize },
    /// The partition closes; queued pushes replay *stale* (original
    /// send-time snapshots), exercising the §5.2 stale-merge path.
    PartitionHeal { partition: usize },
}

/// An event bound to a point on the simulation clock.
#[derive(Debug, Clone, Copy)]
pub struct Scheduled {
    pub time: SimTime,
    seq: u64,
    pub event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    /// Reverse ordering so `BinaryHeap` (a max-heap) pops the earliest
    /// `(time, seq)` first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Bits per timing-wheel level: 1024 slots each.
const LEVEL_BITS: u32 = 10;
/// Slots per level.
const WHEEL_SLOTS: usize = 1 << LEVEL_BITS;
/// Low-bits mask for one level's slot index.
const SLOT_MASK: u64 = (WHEEL_SLOTS - 1) as u64;
/// Levels in the hierarchy (1-tick, 2¹⁰-tick, 2²⁰-tick granularity).
const WHEEL_LEVELS: usize = 3;
/// `u64` words in one level's occupancy bitmap.
const WHEEL_WORDS: usize = WHEEL_SLOTS / 64;

/// One wheel level: 1024 event buckets plus an occupancy bitmap so the
/// "next non-empty slot" scan reads 16 words instead of 1024 `Vec` heads.
#[derive(Debug)]
struct WheelLevel {
    slots: Vec<Vec<Scheduled>>,
    occupied: [u64; WHEEL_WORDS],
}

impl WheelLevel {
    fn new() -> Self {
        Self {
            slots: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            occupied: [0; WHEEL_WORDS],
        }
    }

    #[inline]
    fn mark(&mut self, slot: usize) {
        self.occupied[slot >> 6] |= 1u64 << (slot & 63);
    }

    #[inline]
    fn clear(&mut self, slot: usize) {
        self.occupied[slot >> 6] &= !(1u64 << (slot & 63));
    }

    /// First occupied slot index `>= from`, if any.
    fn next_occupied(&self, from: usize) -> Option<usize> {
        if from >= WHEEL_SLOTS {
            return None;
        }
        let mut w = from >> 6;
        let mut word = self.occupied[w] & (!0u64 << (from & 63));
        loop {
            if word != 0 {
                return Some((w << 6) + word.trailing_zeros() as usize);
            }
            w += 1;
            if w == WHEEL_WORDS {
                return None;
            }
            word = self.occupied[w];
        }
    }
}

/// Hierarchical timing wheel with exact `(time, seq)` pop order.
///
/// Placement is by *shared span with the cursor* (the timestamp of the
/// most recently popped event): an event goes to the deepest level whose
/// parent span it shares with the cursor — level 0 when it falls in the
/// cursor's current 1024-tick span, level 1 when it shares the 2²⁰-tick
/// span, level 2 when it shares the 2³⁰-tick span, and the `far` overflow
/// heap beyond that. This absolute-indexed scheme has no lap ambiguity:
///
/// * every level-0 slot holds events of exactly **one** timestamp, so a
///   tick drains as one bucket take + one in-bucket sort by `seq`;
/// * all level-0 events precede all level-1 events, which precede all
///   level-2 events, which precede everything in `far` — the minimum
///   pending time is found level by level without cross-level compares;
/// * when the cursor enters an upper slot's span, the slot *fully*
///   cascades one level down (each event re-placed by the same rule), so
///   each event moves at most twice over its lifetime — O(1) amortized.
#[derive(Debug)]
struct TimingWheel {
    levels: Vec<WheelLevel>,
    /// Timestamp of the most recent pop/drain (never decreases). All
    /// wheel-resident events have `time >= cursor`.
    cursor: SimTime,
    /// Events resident in the wheel levels (excludes `past`/`far`).
    in_wheel: usize,
    /// Events scheduled strictly before the cursor. The engine never
    /// produces these (it only schedules at or after the tick being
    /// drained); kept so the pop order stays exact even for that misuse.
    past: BinaryHeap<Scheduled>,
    /// Events beyond the top level's span (cursor's 2³⁰-tick epoch);
    /// re-placed into the wheel when the cursor reaches their epoch.
    far: BinaryHeap<Scheduled>,
}

impl TimingWheel {
    fn new() -> Self {
        Self {
            levels: (0..WHEEL_LEVELS).map(|_| WheelLevel::new()).collect(),
            cursor: 0,
            in_wheel: 0,
            past: BinaryHeap::new(),
            far: BinaryHeap::new(),
        }
    }

    fn len(&self) -> usize {
        self.in_wheel + self.past.len() + self.far.len()
    }

    fn schedule(&mut self, s: Scheduled) {
        if s.time < self.cursor {
            self.past.push(s);
        } else {
            self.place(s);
        }
    }

    /// Insert an event at or after the cursor into its level.
    fn place(&mut self, s: Scheduled) {
        let t = s.time;
        let c = self.cursor;
        debug_assert!(t >= c, "place() below the cursor");
        let (lvl, idx) = if t >> LEVEL_BITS == c >> LEVEL_BITS {
            (0, (t & SLOT_MASK) as usize)
        } else if t >> (2 * LEVEL_BITS) == c >> (2 * LEVEL_BITS) {
            (1, ((t >> LEVEL_BITS) & SLOT_MASK) as usize)
        } else if t >> (3 * LEVEL_BITS) == c >> (3 * LEVEL_BITS) {
            (2, ((t >> (2 * LEVEL_BITS)) & SLOT_MASK) as usize)
        } else {
            self.far.push(s);
            return;
        };
        self.levels[lvl].slots[idx].push(s);
        self.levels[lvl].mark(idx);
        self.in_wheel += 1;
    }

    /// Exact timestamp of the earliest level-0 event. Level-0 events all
    /// live in the cursor's 1024-tick span (one timestamp per slot), so
    /// the first occupied slot at or after the cursor's offset *is* the
    /// minimum.
    fn level0_min(&self) -> Option<SimTime> {
        let from = (self.cursor & SLOT_MASK) as usize;
        self.levels[0]
            .next_occupied(from)
            .map(|s| (self.cursor & !SLOT_MASK) | s as u64)
    }

    /// Read-only exact minimum pending timestamp (the `peek_time`
    /// contract). Levels are totally ordered (see the type docs), so the
    /// first non-empty tier answers; within an upper-level slot the
    /// events share the slot's span but not a single tick, hence the
    /// in-slot min scan (only reached when every lower level is empty).
    fn min_time(&self) -> Option<SimTime> {
        if let Some(p) = self.past.peek() {
            return Some(p.time);
        }
        if let Some(t) = self.level0_min() {
            return Some(t);
        }
        for lvl in 1..WHEEL_LEVELS {
            let idx = ((self.cursor >> (lvl as u32 * LEVEL_BITS)) & SLOT_MASK) as usize;
            if let Some(s) = self.levels[lvl].next_occupied(idx) {
                return self.levels[lvl].slots[s].iter().map(|e| e.time).min();
            }
        }
        self.far.peek().map(|e| e.time)
    }

    /// Advance the cursor to the earliest pending event, cascading upper
    /// levels down as their spans are entered, and return its timestamp —
    /// which is then guaranteed to sit in a level-0 slot. `None` when
    /// only `past` events (or nothing) remain.
    fn advance(&mut self) -> Option<SimTime> {
        loop {
            if let Some(t) = self.level0_min() {
                self.cursor = t;
                return Some(t);
            }
            let mut cascaded = false;
            for lvl in 1..WHEEL_LEVELS {
                let idx = ((self.cursor >> (lvl as u32 * LEVEL_BITS)) & SLOT_MASK) as usize;
                let Some(s) = self.levels[lvl].next_occupied(idx) else {
                    continue;
                };
                // Enter the slot's span: every event in an upper slot
                // shares it, so the whole bucket re-places one level
                // down (the placement rule sees the advanced cursor).
                let span_bits = (lvl as u32 + 1) * LEVEL_BITS;
                let base = (self.cursor >> span_bits) << span_bits;
                let slot_start = base | ((s as u64) << (lvl as u32 * LEVEL_BITS));
                self.cursor = self.cursor.max(slot_start);
                let moved = std::mem::take(&mut self.levels[lvl].slots[s]);
                self.levels[lvl].clear(s);
                self.in_wheel -= moved.len();
                for e in moved {
                    self.schedule(e);
                }
                cascaded = true;
                break;
            }
            if cascaded {
                continue;
            }
            // Wheels empty: jump to the far heap's epoch and pull in
            // everything that now fits (far times are strictly beyond
            // the cursor's previous top-level span, so this only moves
            // the cursor forward).
            let Some(next_epoch_time) = self.far.peek().map(|e| e.time) else {
                return None;
            };
            self.cursor = self.cursor.max(next_epoch_time);
            let epoch = self.cursor >> (WHEEL_LEVELS as u32 * LEVEL_BITS);
            while let Some(p) = self.far.peek() {
                if p.time >> (WHEEL_LEVELS as u32 * LEVEL_BITS) != epoch {
                    break;
                }
                let e = self.far.pop().expect("peeked far event present");
                self.place(e);
            }
        }
    }

    fn pop(&mut self) -> Option<Scheduled> {
        if let Some(p) = self.past.pop() {
            return Some(p);
        }
        let t = self.advance()?;
        let idx = (t & SLOT_MASK) as usize;
        let slot = &mut self.levels[0].slots[idx];
        // All events in a level-0 slot share one timestamp; pop the
        // lowest insertion seq. Linear, but `drain_tick_into` (the hot
        // path) takes the bucket wholesale instead.
        let mut k = 0;
        for i in 1..slot.len() {
            if slot[i].seq < slot[k].seq {
                k = i;
            }
        }
        let s = slot.swap_remove(k);
        if slot.is_empty() {
            self.levels[0].clear(idx);
        }
        self.in_wheel -= 1;
        debug_assert_eq!(s.time, t);
        Some(s)
    }

    fn drain_tick_into(&mut self, batch: &mut TickBatch) -> bool {
        batch.events.clear();
        // `past` times are strictly below the cursor, hence below every
        // wheel-resident time — a tick can never straddle the two.
        if let Some(first) = self.past.peek().map(|p| p.time) {
            batch.time = first;
            while let Some(p) = self.past.peek() {
                if p.time != first {
                    break;
                }
                batch.events.push(self.past.pop().expect("peeked past event"));
            }
            return true;
        }
        let Some(t) = self.advance() else {
            batch.time = 0;
            return false;
        };
        batch.time = t;
        let idx = (t & SLOT_MASK) as usize;
        let slot = &mut self.levels[0].slots[idx];
        self.in_wheel -= slot.len();
        // Drain (not take): the bucket keeps its capacity, so steady
        // storm ticks re-fill it without reallocating.
        batch.events.extend(slot.drain(..));
        self.levels[0].clear(idx);
        // One timestamp per bucket ⇒ sorting by seq alone restores the
        // exact global pop order (cascade order scrambled it).
        batch.events.sort_unstable_by_key(|e| e.seq);
        true
    }
}

/// The historical binary min-heap backing, kept as the debug oracle for
/// the timing wheel (`--features heap-oracle` / `PRONTO_EVENT_QUEUE=heap`
/// switch every queue onto it; the parity suite diffs the two).
#[derive(Debug, Default)]
struct HeapQueue {
    heap: BinaryHeap<Scheduled>,
}

impl HeapQueue {
    fn with_capacity(cap: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(cap) }
    }

    fn schedule(&mut self, s: Scheduled) {
        self.heap.push(s);
    }

    fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop()
    }

    fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.time)
    }

    fn len(&self) -> usize {
        self.heap.len()
    }

    fn drain_tick_into(&mut self, batch: &mut TickBatch) -> bool {
        batch.events.clear();
        let Some(first) = self.heap.pop() else {
            batch.time = 0;
            return false;
        };
        batch.time = first.time;
        batch.events.push(first);
        while let Some(next) = self.heap.peek() {
            if next.time != batch.time {
                break;
            }
            batch.events.push(self.heap.pop().expect("peeked event present"));
        }
        true
    }
}

/// Which data structure backs an [`EventQueue`]. Both produce the exact
/// same `(time, seq)` pop order; the wheel is the fast path, the heap the
/// debug oracle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueBacking {
    /// Hierarchical timing wheel — O(1) amortized schedule/pop (default).
    Wheel,
    /// Binary min-heap — the historical O(log n) reference.
    Heap,
}

impl QueueBacking {
    /// Process-wide default: the wheel, unless the `heap-oracle` feature
    /// is compiled in or `PRONTO_EVENT_QUEUE=heap` is set (both exist so
    /// CI and local debugging can diff full runs across backings without
    /// touching call sites).
    pub fn from_env() -> Self {
        if cfg!(feature = "heap-oracle") {
            return QueueBacking::Heap;
        }
        match std::env::var("PRONTO_EVENT_QUEUE").as_deref() {
            Ok("heap") => QueueBacking::Heap,
            _ => QueueBacking::Wheel,
        }
    }
}

#[derive(Debug)]
enum Backing {
    Wheel(Box<TimingWheel>),
    Heap(HeapQueue),
}

/// Deterministic event queue (see the module docs for the two backings).
#[derive(Debug)]
pub struct EventQueue {
    backing: Backing,
    next_seq: u64,
    scheduled_total: usize,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::with_capacity(0)
    }
}

impl EventQueue {
    /// Queue with pre-reserved capacity on the default backing
    /// ([`QueueBacking::from_env`]). The wheel's buckets grow on demand
    /// and are drained (never freed) per tick, so it ignores the hint;
    /// the heap oracle pre-reserves as before.
    pub fn with_capacity(cap: usize) -> Self {
        Self::with_backing(cap, QueueBacking::from_env())
    }

    /// Queue on an explicit backing (the parity tests drive both
    /// side by side; everything else goes through `with_capacity`).
    pub fn with_backing(cap: usize, backing: QueueBacking) -> Self {
        let backing = match backing {
            QueueBacking::Wheel => Backing::Wheel(Box::new(TimingWheel::new())),
            QueueBacking::Heap => Backing::Heap(HeapQueue::with_capacity(cap)),
        };
        Self { backing, next_seq: 0, scheduled_total: 0 }
    }

    /// Which backing this queue runs on.
    pub fn backing(&self) -> QueueBacking {
        match self.backing {
            Backing::Wheel(_) => QueueBacking::Wheel,
            Backing::Heap(_) => QueueBacking::Heap,
        }
    }

    /// Schedule `event` at `time`. Events at equal times fire in
    /// scheduling order (FIFO) — the insertion counter breaks the tie.
    pub fn schedule(&mut self, time: SimTime, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.scheduled_total += 1;
        let s = Scheduled { time, seq, event };
        match &mut self.backing {
            Backing::Wheel(w) => w.schedule(s),
            Backing::Heap(h) => h.schedule(s),
        }
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Scheduled> {
        match &mut self.backing {
            Backing::Wheel(w) => w.pop(),
            Backing::Heap(h) => h.pop(),
        }
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time(&self) -> Option<SimTime> {
        match &self.backing {
            Backing::Wheel(w) => w.min_time(),
            Backing::Heap(h) => h.peek_time(),
        }
    }

    /// Drain **every** event sharing the earliest timestamp into the
    /// caller-owned `batch` (clearing it first), in exactly the order
    /// [`EventQueue::pop`] would have produced. Returns `false` when the
    /// queue is empty. The batch's backing `Vec` is reused across calls,
    /// and on the wheel the drained bucket keeps its capacity too — a
    /// steady storm tick allocates nothing on either side.
    ///
    /// Events scheduled *while a batch is being processed* — even at the
    /// batch's own timestamp — carry higher sequence numbers, so they
    /// land in a later batch, exactly where per-event popping would have
    /// put them. Concatenating drained batches therefore reproduces the
    /// per-event pop order byte-for-byte; the batch only gives the
    /// engine a same-tick view to hoist per-tick work out of per-event
    /// handlers.
    pub fn drain_tick_into(&mut self, batch: &mut TickBatch) -> bool {
        match &mut self.backing {
            Backing::Wheel(w) => w.drain_tick_into(batch),
            Backing::Heap(h) => h.drain_tick_into(batch),
        }
    }

    /// Alias of [`EventQueue::drain_tick_into`] (the historical name).
    pub fn drain_tick(&mut self, batch: &mut TickBatch) -> bool {
        self.drain_tick_into(batch)
    }

    pub fn len(&self) -> usize {
        match &self.backing {
            Backing::Wheel(w) => w.len(),
            Backing::Heap(h) => h.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events scheduled over the queue's lifetime.
    pub fn scheduled_total(&self) -> usize {
        self.scheduled_total
    }
}

/// All events sharing one simulation timestamp, in `(time, seq)` pop
/// order — the unit the engine's event loop now dispatches. Reused
/// across ticks (the backing `Vec` is cleared, not reallocated), so
/// steady-state batching stays allocation-free.
#[derive(Debug, Default)]
pub struct TickBatch {
    time: SimTime,
    events: Vec<Scheduled>,
}

impl TickBatch {
    /// The shared timestamp (meaningless while empty).
    pub fn time(&self) -> SimTime {
        self.time
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The batch's events in pop order.
    pub fn events(&self) -> &[Scheduled] {
        &self.events
    }

    /// Job ids of the arrivals in this batch, in pop order.
    pub fn arrivals(&self) -> impl Iterator<Item = crate::scheduler::JobId> + '_ {
        self.events.iter().filter_map(|s| match s.event {
            Event::JobArrival { job_id } => Some(job_id),
            _ => None,
        })
    }

    /// Completions in this batch as `(node, job_id)`, in pop order.
    pub fn completions(&self) -> impl Iterator<Item = (usize, crate::scheduler::JobId)> + '_ {
        self.events.iter().filter_map(|s| match s.event {
            Event::JobCompletion { node, job_id, .. } => Some((node, job_id)),
            _ => None,
        })
    }

    /// Churn events in this batch as `(node, is_join)`, in pop order.
    pub fn churn(&self) -> impl Iterator<Item = (usize, bool)> + '_ {
        self.events.iter().filter_map(|s| match s.event {
            Event::NodeJoin { node } => Some((node, true)),
            Event::NodeLeave { node } => Some((node, false)),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn both() -> [EventQueue; 2] {
        [
            EventQueue::with_backing(8, QueueBacking::Wheel),
            EventQueue::with_backing(8, QueueBacking::Heap),
        ]
    }

    #[test]
    fn pops_in_time_order() {
        for mut q in both() {
            q.schedule(30, Event::TelemetryTick { step: 3 });
            q.schedule(10, Event::TelemetryTick { step: 1 });
            q.schedule(20, Event::TelemetryTick { step: 2 });
            let times: Vec<SimTime> = std::iter::from_fn(|| q.pop()).map(|s| s.time).collect();
            assert_eq!(times, vec![10, 20, 30], "{:?}", q.backing());
        }
    }

    #[test]
    fn equal_times_pop_fifo() {
        for mut q in both() {
            for node in 0..5 {
                q.schedule(42, Event::NodeJoin { node });
            }
            let order: Vec<usize> = std::iter::from_fn(|| q.pop())
                .map(|s| match s.event {
                    Event::NodeJoin { node } => node,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(order, vec![0, 1, 2, 3, 4], "{:?}", q.backing());
        }
    }

    #[test]
    fn interleaved_schedule_pop_is_stable() {
        for mut q in both() {
            q.schedule(5, Event::TelemetryTick { step: 0 });
            q.schedule(1, Event::NodeLeave { node: 9 });
            assert_eq!(q.len(), 2);
            assert_eq!(q.pop().unwrap().time, 1);
            q.schedule(2, Event::NodeJoin { node: 9 });
            assert_eq!(q.pop().unwrap().time, 2);
            assert_eq!(q.pop().unwrap().time, 5);
            assert!(q.pop().is_none());
            assert!(q.is_empty());
            assert_eq!(q.scheduled_total(), 3);
        }
    }

    #[test]
    fn tick_conversions_roundtrip() {
        assert_eq!(step_to_ticks(7), 7 * TICKS_PER_STEP);
        assert_eq!(ticks_to_step(step_to_ticks(7) + TICKS_PER_STEP - 1), 7);
        assert_eq!(latency_to_ticks(0.0), 1);
        assert_eq!(latency_to_ticks(2.0), 2 * TICKS_PER_STEP);
        assert_eq!(latency_to_ticks(0.5), TICKS_PER_STEP / 2);
    }

    #[test]
    fn drain_tick_groups_same_timestamp_events_in_pop_order() {
        for mut q in both() {
            q.schedule(20, Event::JobArrival { job_id: 2 });
            q.schedule(10, Event::JobArrival { job_id: 0 });
            q.schedule(10, Event::NodeLeave { node: 5 });
            q.schedule(10, Event::JobArrival { job_id: 1 });
            let mut batch = TickBatch::default();

            assert!(q.drain_tick(&mut batch));
            assert_eq!(batch.time(), 10);
            assert_eq!(batch.len(), 3);
            assert_eq!(batch.arrivals().collect::<Vec<_>>(), vec![0, 1]);
            assert_eq!(batch.churn().collect::<Vec<_>>(), vec![(5, false)]);
            assert!(batch.completions().next().is_none());
            // In-batch order is pop order, not grouped-by-kind order.
            assert!(matches!(batch.events()[1].event, Event::NodeLeave { node: 5 }));

            // The batch is reused: the next drain clears it first.
            assert!(q.drain_tick_into(&mut batch));
            assert_eq!(batch.time(), 20);
            assert_eq!(batch.len(), 1);
            assert!(q.is_empty());
            assert!(!q.drain_tick_into(&mut batch));
            assert!(batch.is_empty());
        }
    }

    #[test]
    fn peek_time_tracks_the_head() {
        for mut q in both() {
            assert_eq!(q.peek_time(), None);
            q.schedule(7, Event::TelemetryTick { step: 0 });
            q.schedule(3, Event::TelemetryTick { step: 1 });
            assert_eq!(q.peek_time(), Some(3));
            q.pop();
            assert_eq!(q.peek_time(), Some(7));
        }
    }

    #[test]
    fn wheel_handles_upper_level_and_far_future_times() {
        // One event per tier: level 0 (same 2¹⁰ span as cursor 0),
        // level 1 (same 2²⁰ span), level 2 (same 2³⁰ span), far heap
        // (beyond), plus a second far epoch. Pop order must be global
        // time order regardless of tier, and peek must be exact at
        // every stage (upper tiers answer via the in-slot min scan).
        let mut q = EventQueue::with_backing(0, QueueBacking::Wheel);
        let times: [SimTime; 6] =
            [5, 1_500, 2_000_000, 40_000_000, 3_000_000_000, 5_000_000_000];
        for (i, &t) in times.iter().enumerate().rev() {
            q.schedule(t, Event::TelemetryTick { step: i });
        }
        assert_eq!(q.len(), 6);
        for &t in &times {
            assert_eq!(q.peek_time(), Some(t));
            let s = q.pop().unwrap();
            assert_eq!(s.time, t);
        }
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn wheel_cascade_preserves_fifo_within_one_tick() {
        // Events landing on the same future tick via an upper level must
        // still pop in scheduling order after cascading down (the bucket
        // sort by seq at drain time).
        let mut q = EventQueue::with_backing(0, QueueBacking::Wheel);
        let t: SimTime = 700_000; // level 1 from cursor 0
        for node in 0..7 {
            q.schedule(t, Event::NodeJoin { node });
        }
        q.schedule(3, Event::TelemetryTick { step: 0 });
        assert_eq!(q.pop().unwrap().time, 3);
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|s| match s.event {
                Event::NodeJoin { node } => node,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn wheel_accepts_schedules_at_the_drained_tick() {
        // The engine's hot pattern: drain tick T, then schedule more
        // work at exactly T (JobEnqueue/JobStart). Those must come back
        // in the *next* batch at the same timestamp.
        for mut q in both() {
            q.schedule(10, Event::JobArrival { job_id: 0 });
            let mut batch = TickBatch::default();
            assert!(q.drain_tick_into(&mut batch));
            assert_eq!(batch.time(), 10);
            q.schedule(10, Event::JobEnqueue { node: 1, job_id: 0 });
            q.schedule(11, Event::TelemetryTick { step: 0 });
            assert!(q.drain_tick_into(&mut batch));
            assert_eq!(batch.time(), 10);
            assert_eq!(batch.len(), 1);
            assert!(matches!(batch.events()[0].event, Event::JobEnqueue { .. }));
            assert!(q.drain_tick_into(&mut batch));
            assert_eq!(batch.time(), 11);
        }
    }

    #[test]
    fn wheel_orders_past_schedules_exactly() {
        // Scheduling below the cursor is engine-illegal but must still
        // pop in exact (time, seq) order via the `past` heap.
        let mut q = EventQueue::with_backing(0, QueueBacking::Wheel);
        q.schedule(100, Event::TelemetryTick { step: 0 });
        q.schedule(200, Event::TelemetryTick { step: 1 });
        assert_eq!(q.pop().unwrap().time, 100);
        q.schedule(50, Event::NodeLeave { node: 1 });
        q.schedule(40, Event::NodeLeave { node: 2 });
        assert_eq!(q.peek_time(), Some(40));
        assert_eq!(q.pop().unwrap().time, 40);
        assert_eq!(q.pop().unwrap().time, 50);
        assert_eq!(q.pop().unwrap().time, 200);
        assert!(q.is_empty());
    }

    #[test]
    fn default_backing_honours_the_oracle_feature() {
        let q = EventQueue::with_capacity(4);
        if cfg!(feature = "heap-oracle") {
            assert_eq!(q.backing(), QueueBacking::Heap);
        } else if std::env::var("PRONTO_EVENT_QUEUE").as_deref() != Ok("heap") {
            assert_eq!(q.backing(), QueueBacking::Wheel);
        }
    }
}
