//! Job-level data-center simulator — the fixed-step façade.
//!
//! Historically this module held its own `for t in 0..steps` loop; the
//! simulation now runs on the deterministic discrete-event engine
//! ([`super::engine`]). [`DataCenterSim`] remains as the simple entry
//! point used by the CLI, benches, and integration tests: it translates a
//! [`SimConfig`] into the equivalent steady-Poisson [`Scenario`] (no
//! churn, instant federation — the paper's setting) and runs the engine.
//! Trace-driven as before: admission decisions do not feed back into the
//! recorded telemetry, and decision quality is scored against the CPU
//! Ready ground truth.

use super::engine::DiscreteEventEngine;
use super::scenario::Scenario;
use crate::scheduler::Admission;
use crate::telemetry::VmTrace;

pub use super::engine::SimReport;
pub use super::scenario::{DispatchPolicy, ProbePolicy};

/// Simulation parameters (the compact, scenario-free configuration).
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Mean job inter-arrival in timesteps (Poisson process).
    pub arrival_rate_per_step: f64,
    /// Log-normal job duration parameters (in timesteps).
    pub duration_mu: f64,
    pub duration_sigma: f64,
    /// Candidate selection for arriving jobs (the facade always scores
    /// signal-only, the paper's dispatch).
    pub probe: ProbePolicy,
    /// CPU Ready level marking degraded service for scoring.
    pub ready_threshold: f64,
    /// Horizon after acceptance scored for degradation (timesteps).
    pub score_window: usize,
    /// RNG seed for arrivals/durations/probing.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            arrival_rate_per_step: 0.3,
            duration_mu: 3.0,   // e^3 ≈ 20 steps ≈ 7 min
            duration_sigma: 0.8,
            probe: ProbePolicy::PowerOfK(2),
            ready_threshold: 1000.0,
            score_window: 5,
            seed: 7,
        }
    }
}

impl SimConfig {
    /// The scenario equivalent of this fixed-step configuration: steady
    /// Poisson arrivals, full membership, no federation link. Named
    /// distinctly from the catalog's `baseline-poisson` because its
    /// parameters come from this config, not the catalog.
    pub fn to_scenario(&self, nodes: usize, steps: usize) -> Scenario {
        Scenario {
            name: "fixed-step-poisson".to_string(),
            nodes,
            steps,
            seed: self.seed,
            arrivals: super::scenario::ArrivalPattern::Poisson {
                rate: self.arrival_rate_per_step,
            },
            probe: self.probe,
            dispatch: DispatchPolicy::SignalOnly,
            duration_mu: self.duration_mu,
            duration_sigma: self.duration_sigma,
            ready_threshold: self.ready_threshold,
            score_window: self.score_window,
            churn: None,
            federation: super::scenario::FederationSpec::default(),
            capacity: None,
        }
    }
}

/// The simulator: N nodes with aligned traces and admission policies.
pub struct DataCenterSim {
    cfg: SimConfig,
    traces: Vec<VmTrace>,
    policies: Vec<Box<dyn Admission>>,
}

impl DataCenterSim {
    /// One policy per trace (same order).
    pub fn new(cfg: SimConfig, traces: Vec<VmTrace>, policies: Vec<Box<dyn Admission>>) -> Self {
        assert_eq!(traces.len(), policies.len(), "one policy per node");
        assert!(!traces.is_empty());
        Self { cfg, traces, policies }
    }

    /// Run over the common trace prefix; returns the report.
    pub fn run(self) -> SimReport {
        let steps = self.traces.iter().map(VmTrace::len).min().unwrap();
        let scenario = self.cfg.to_scenario(self.traces.len(), steps);
        DiscreteEventEngine::new(scenario, self.traces, self.policies).run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{CpuReadyOracle, NodeScheduler, ProntoPolicy, RandomPolicy, RejectConfig};
    use crate::telemetry::{GeneratorConfig, TraceGenerator, CPU_READY_IDX};

    fn traces(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
        let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
        (0..n).map(|v| gen.generate_vm_in_cluster(0, v, steps)).collect()
    }

    fn pronto_policies(traces: &[VmTrace]) -> Vec<Box<dyn Admission>> {
        traces
            .iter()
            .map(|t| {
                Box::new(ProntoPolicy::new(NodeScheduler::new(
                    t.dim(),
                    RejectConfig::default(),
                ))) as Box<dyn Admission>
            })
            .collect()
    }

    #[test]
    fn conservation_of_jobs() {
        let tr = traces(4, 800, 1);
        let pol = pronto_policies(&tr);
        let report = DataCenterSim::new(SimConfig::default(), tr, pol).run();
        assert_eq!(
            report.jobs_arrived,
            report.jobs_accepted + report.jobs_rejected
        );
        assert_eq!(report.jobs_accepted, report.good_accepts + report.bad_accepts);
        assert_eq!(report.outcomes.len(), report.jobs_arrived);
    }

    #[test]
    fn oracle_placement_beats_always_accept() {
        let steps = 6000;
        let tr = traces(6, steps, 3);
        let oracle: Vec<Box<dyn Admission>> = tr
            .iter()
            .map(|_| Box::new(CpuReadyOracle::new(CPU_READY_IDX, 1000.0)) as Box<dyn Admission>)
            .collect();
        let always: Vec<Box<dyn Admission>> = tr
            .iter()
            .map(|_| Box::new(RandomPolicy::always_accept(1)) as Box<dyn Admission>)
            .collect();
        let r_oracle = DataCenterSim::new(SimConfig::default(), tr.clone(), oracle).run();
        let r_always = DataCenterSim::new(SimConfig::default(), tr, always).run();
        assert!(
            r_oracle.placement_quality() >= r_always.placement_quality(),
            "oracle {:.3} vs always {:.3}",
            r_oracle.placement_quality(),
            r_always.placement_quality()
        );
    }

    #[test]
    fn round_robin_covers_all_nodes() {
        let tr = traces(3, 500, 9);
        let pol: Vec<Box<dyn Admission>> = tr
            .iter()
            .map(|_| Box::new(RandomPolicy::always_accept(2)) as Box<dyn Admission>)
            .collect();
        let cfg = SimConfig { probe: ProbePolicy::RoundRobin, ..Default::default() };
        let report = DataCenterSim::new(cfg, tr, pol).run();
        let mut nodes_used = [false; 3];
        for o in &report.outcomes {
            if let crate::scheduler::JobOutcome::Accepted { node, .. } = o {
                nodes_used[*node] = true;
            }
        }
        assert!(nodes_used.iter().all(|&u| u));
    }

    #[test]
    fn power_of_k_reduces_rejections_vs_single_probe() {
        let steps = 4000;
        let tr = traces(8, steps, 11);
        let mk = |tr: &[VmTrace]| pronto_policies(tr);
        let single = DataCenterSim::new(
            SimConfig { probe: ProbePolicy::RandomProbe, ..Default::default() },
            tr.clone(),
            mk(&tr),
        )
        .run();
        let pok = DataCenterSim::new(
            SimConfig { probe: ProbePolicy::PowerOfK(3), ..Default::default() },
            tr.clone(),
            mk(&tr),
        )
        .run();
        assert!(
            pok.acceptance_rate() >= single.acceptance_rate(),
            "PoK {:.3} vs single {:.3}",
            pok.acceptance_rate(),
            single.acceptance_rate()
        );
    }

    #[test]
    fn to_scenario_maps_every_sim_config_field() {
        let cfg = SimConfig {
            arrival_rate_per_step: 0.7,
            duration_mu: 2.5,
            duration_sigma: 0.4,
            probe: ProbePolicy::RoundRobin,
            ready_threshold: 800.0,
            score_window: 9,
            seed: 123,
        };
        let s = cfg.to_scenario(5, 777);
        assert_eq!(s.nodes, 5);
        assert_eq!(s.steps, 777);
        assert_eq!(s.seed, 123);
        assert!(matches!(
            s.arrivals,
            crate::sim::ArrivalPattern::Poisson { rate } if rate == 0.7
        ));
        assert_eq!(s.probe, ProbePolicy::RoundRobin);
        assert_eq!(s.dispatch, DispatchPolicy::SignalOnly, "facade stays signal-only");
        assert_eq!(s.duration_mu, 2.5);
        assert_eq!(s.duration_sigma, 0.4);
        assert_eq!(s.ready_threshold, 800.0);
        assert_eq!(s.score_window, 9);
        assert!(s.churn.is_none(), "facade must not enable churn");
        assert!(!s.federation.enabled, "facade must not enable federation");
    }
}
