//! Minimal TOML-subset parser.
//!
//! Supports: `[section]` headers, `key = value` pairs with strings
//! (double-quoted), integers, floats, booleans, and flat arrays; `#`
//! comments; blank lines. Dotted keys, inline tables, dates, and
//! multi-line strings are out of scope (and rejected loudly).

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    String(String),
    Number(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::String(s) => Some(s),
            _ => None,
        }
    }
}

/// Parsed document: ordered (section, entries) pairs. Keys before any
/// section header land in the section named "" (root).
pub type TomlDoc = Vec<(String, Vec<(String, TomlValue)>)>;

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<TomlDoc, String> {
    let mut doc: TomlDoc = Vec::new();
    let mut current = String::new();
    doc.push((current.clone(), Vec::new()));

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {}: malformed section header", lineno + 1));
            }
            current = line[1..line.len() - 1].trim().to_string();
            if current.is_empty() || current.contains('[') {
                return Err(format!("line {}: bad section name", lineno + 1));
            }
            doc.push((current.clone(), Vec::new()));
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(format!("line {}: expected key = value", lineno + 1));
        };
        let key = line[..eq].trim();
        if key.is_empty() || key.contains(' ') || key.contains('.') {
            return Err(format!("line {}: bad key '{key}'", lineno + 1));
        }
        let value = parse_value(line[eq + 1..].trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        doc.last_mut().unwrap().1.push((key.to_string(), value));
    }
    // Drop the root section if empty.
    if doc[0].1.is_empty() {
        doc.remove(0);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // '#' outside a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(end) = rest.find('"') else {
            return Err("unterminated string".into());
        };
        if !rest[end + 1..].trim().is_empty() {
            return Err("trailing characters after string".into());
        }
        return Ok(TomlValue::String(rest[..end].to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err("unterminated array".into());
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    // TOML integers may use underscores.
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    cleaned
        .parse::<f64>()
        .map(TomlValue::Number)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse_toml(
            r#"
# top comment
[alpha]
x = 1
y = 2.5          # trailing comment
name = "hello"
flag = true
xs = [1, 2, 3]

[beta]
z = 1_000
"#,
        )
        .unwrap();
        assert_eq!(doc.len(), 2);
        let (name, entries) = &doc[0];
        assert_eq!(name, "alpha");
        assert_eq!(entries[0], ("x".into(), TomlValue::Number(1.0)));
        assert_eq!(entries[1], ("y".into(), TomlValue::Number(2.5)));
        assert_eq!(entries[2], ("name".into(), TomlValue::String("hello".into())));
        assert_eq!(entries[3], ("flag".into(), TomlValue::Bool(true)));
        assert_eq!(
            entries[4],
            (
                "xs".into(),
                TomlValue::Array(vec![
                    TomlValue::Number(1.0),
                    TomlValue::Number(2.0),
                    TomlValue::Number(3.0)
                ])
            )
        );
        assert_eq!(doc[1].1[0], ("z".into(), TomlValue::Number(1000.0)));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse_toml("[s]\nk = \"a#b\"\n").unwrap();
        assert_eq!(doc[0].1[0].1, TomlValue::String("a#b".into()));
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_toml("[unclosed\n").is_err());
        assert!(parse_toml("[s]\nno_equals_here\n").is_err());
        assert!(parse_toml("[s]\nbad key = 1\n").is_err());
        assert!(parse_toml("[s]\nk = \n").is_err());
        assert!(parse_toml("[s]\nk = \"unterminated\n").is_err());
    }

    #[test]
    fn root_keys_allowed() {
        let doc = parse_toml("top = 5\n[s]\nk = 1\n").unwrap();
        assert_eq!(doc[0].0, "");
        assert_eq!(doc[0].1[0], ("top".into(), TomlValue::Number(5.0)));
    }
}
