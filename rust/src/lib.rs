//! # PRONTO — federated task scheduling
//!
//! Production-quality reproduction of *"Pronto: Federated Task Scheduling"*
//! (Grammenos, Kalyvianaki, Pietzuch, 2021): a federated, streaming,
//! memory-limited scheduler in which every data-center node tracks the
//! top-r principal subspace of its own telemetry via FPCA-Edge, projects
//! incoming metric vectors onto it, detects projection spikes with a
//! streaming z-score filter, and raises a **rejection signal** that gates
//! job admission — no global synchronization on the decision path.
//!
//! The crate is the L3 layer of a three-layer Rust + JAX + Pallas stack:
//! the FPCA block update / merge / project-detect graphs are authored in
//! JAX (calling Pallas kernels) and AOT-lowered to HLO text that
//! [`runtime`] loads and executes through the PJRT CPU client. A
//! numerically identical native implementation lives in [`fpca`] and is
//! used as the test oracle and as a fallback when artifacts are absent.
//!
//! Quick tour (see `examples/quickstart.rs`):
//!
//! ```no_run
//! # // no_run: doctest binaries miss the libstdc++ rpath the xla crate
//! # // needs at load time; the example is compile-checked only.
//! use pronto::scheduler::{NodeScheduler, RejectConfig};
//! use pronto::telemetry::{GeneratorConfig, TraceGenerator};
//!
//! let gen = TraceGenerator::new(GeneratorConfig::default(), 42);
//! let trace = gen.generate_vm(0, 64);
//! let mut node = NodeScheduler::new(trace.dim(), RejectConfig::default());
//! for t in 0..trace.len() {
//!     let _accept = node.observe(trace.features(t)); // admission decision
//! }
//! assert_eq!(node.stats().steps, 64);
//! ```

// Dense `for i in 0..n` loops over parallel per-node/per-step arrays are
// the house style throughout the numeric kernels (linalg, FPCA, detect,
// scheduler): the index couples several same-length buffers at once, and
// rewriting them as zipped iterator chains obscures the stride structure
// the loops are written to expose. Scoped here instead of a CI-wide `-A`
// flag so every other clippy lint stays enforced at `-D warnings`.
#![allow(clippy::needless_range_loop)]

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod detect;
pub mod forecast;
pub mod federation;
pub mod fpca;
pub mod linalg;
pub mod lint;
pub mod metrics;
pub mod proptest;
pub mod rng;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod ser;
pub mod telemetry;

pub use linalg::Mat;
