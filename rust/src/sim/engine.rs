//! The deterministic discrete-event cluster engine.
//!
//! Replaces the fixed-step simulator loop: the cluster is driven by a
//! timing-wheel event queue ([`super::events`]) over typed events —
//! telemetry ticks, job arrivals/starts/completions, host-level queueing,
//! preemption and migration of displaced jobs, federation pushes with
//! delivery latency, and node churn. Determinism guarantees:
//!
//! * events order by `(time, seq)` — no hash maps, no wall clock;
//! * every stochastic component draws from its **own** RNG stream derived
//!   from the scenario seed (arrivals, durations, dispatch, churn,
//!   latency, slot demands, migration probes, job priorities, host-class
//!   assignment), so enabling churn does not shift the arrival sequence
//!   and enabling capacity, priorities, or heterogeneity does not shift
//!   anything else;
//! * the same `(Scenario, traces, policies)` triple therefore produces a
//!   bit-identical [`SimReport`] — `SimReport::to_json_string` output is
//!   byte-comparable across runs, which the determinism regression tests
//!   rely on.
//!
//! # Dispatch
//!
//! Candidate selection ([`ProbePolicy`]: random / power-of-k /
//! round-robin) is separate from candidate scoring
//! ([`DispatchPolicy`]): each probed host answers with a structured
//! [`AdmissionProbe`] — rejection signal, free slots, queue depth,
//! queue-delay EWMA — and the dispatcher either takes the first
//! signal-clear candidate (`signal-only`, the paper's rule and the
//! byte-identical legacy behaviour) or the least congested / least
//! loaded one. Migration peer selection scores the same way. Scoring is
//! a pure function of deterministic state, so switching policies never
//! shifts any RNG stream.
//!
//! # Capacity, preemption, migration
//!
//! With a [`CapacityModel`] on the scenario, every node carries a
//! [`HostCapacity`]: a slot budget, the running set, and a bounded wait
//! queue. An admitted job starts if it fits, parks if the queue has room,
//! and is dropped otherwise. Jobs are displaced two ways: a **departing**
//! node evacuates its running set and wait queue, and an
//! **over-committed** node — rejection signal raised while usage exceeds
//! `contended_slots` — sheds its newest jobs at the telemetry tick. A
//! displaced job with migration budget left is re-offered to peers,
//! picking the target via each peer's admission signal (the paper's
//! rejection signal closing the loop); otherwise it is lost
//! (`jobs_displaced`). Without a capacity model the engine behaves as
//! before: accepted jobs consume nothing and never queue.
//!
//! # Telemetry input and fleet scale
//!
//! The engine drives telemetry through a [`TraceSource`]: either fully
//! materialized traces (the legacy path — CSV replay and most tests) or
//! windowed per-node streaming generators with O(nodes + window) memory,
//! which is what lets multi-thousand-node × multi-thousand-step fleets
//! run without `O(nodes × steps × dims)` materialization. The two
//! backings produce bit-identical metric vectors, so reports are
//! byte-identical across them (regression-tested per catalog scenario).
//!
//! The hot loop is allocation-free in steady state: events are small
//! `Copy` values, federation subspace snapshots live in a free-listed
//! slab referenced by index, probe candidates (and the stamp-mask
//! fallback of the bounded distinct sampler, [`SampleScratch`]) reuse
//! dedicated buffers, and per-node state lives in the struct-of-arrays
//! layout of [`super::fleet`]: [`FleetState`] keeps the liveness flags,
//! merged rejection signal, sorted alive-id list (maintained
//! incrementally with a dense id→rank map), and round-robin cursor;
//! [`HostTable`] keeps the hosts plus contiguous mirrors of their hot
//! scalars, so the per-tick scans and probe answers touch dense arrays
//! instead of chasing per-node structs.
//!
//! # Parallel observe loop (`threads`)
//!
//! The per-tick observe loop — trace advancement, FPCA iterate, and
//! rejection-signal scoring for every alive node — is embarrassingly
//! parallel by construction (the paper's horizontal-scalability claim:
//! each node's signal is a pure function of its own telemetry and local
//! state). `Scenario::threads > 1` shards the **sorted alive set into
//! contiguous chunks** across a [`minipool::WorkerPool`]: each worker
//! owns a disjoint slice of the policies, the `can_accept` output, and
//! the per-node [`crate::telemetry::NodeView`] trace state, so there is
//! no shared mutation and the merged result (written in place, node-id
//! order) is **byte-identical** to the sequential run. `threads = 1`
//! (the default) executes today's exact sequential code path. Everything
//! outside the observe loop — dispatch, capacity, churn, federation —
//! stays sequential and single-ordered, which is what keeps reports
//! byte-stable across widths (regression-tested per catalog scenario).
//!
//! # Same-tick event batching
//!
//! The event loop drains all events sharing a timestamp into a typed
//! [`TickBatch`] before dispatch. In-batch order is exactly the
//! `(time, seq)` pop order — handlers run unchanged, so the report byte
//! contract is untouched — but the batch view lets per-tick work be
//! hoisted out of per-event handlers: the ground-truth spike scan behind
//! placement scoring is memoized per `(node, step)` for the duration of
//! a step, so an arrival burst probing overlapping candidates fills the
//! probe buffer once per tick instead of once per arrival (a measured
//! hot-path win on `large-fleet` / `flash-crowd`, whose bursts put
//! hundreds of same-step arrivals behind one telemetry tick).

use super::events::{
    latency_to_ticks, step_to_ticks, ticks_to_step, Event, EventQueue, SimTime, TickBatch,
    TICKS_PER_STEP,
};
use super::fleet::{FleetState, HostTable};
use super::scenario::{ArrivalPattern, CapacityModel, DispatchPolicy, ProbePolicy, Scenario};
use crate::federation::{FederationTree, TreeTopology};
use crate::fpca::Subspace;
use crate::rng::{streams, Xoshiro256};
use crate::scheduler::{
    Admission, AdmissionProbe, HostCapacity, JobId, JobOutcome, Priority, ServiceTimeModel,
};
use crate::ser::JsonValue;
use crate::telemetry::{TraceSource, VmTrace};
use minipool::WorkerPool;
use std::collections::BTreeMap;
use std::fmt;

/// Peers probed when re-placing a displaced job.
const MIGRATION_PROBES: usize = 3;

/// Why a [`DiscreteEventEngine`] could not be constructed. Surfaced as a
/// typed error (instead of the historical index panic) so the CLI can
/// report a malformed fleet — e.g. an empty `--replay` directory or a
/// zero-column trace CSV — as a normal error message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineError {
    /// No traces at all: the engine needs at least one node.
    EmptyFleet,
    /// A node's trace has zero timesteps.
    EmptyTrace { node: usize },
    /// A node's trace has zero metric columns.
    ZeroDim { node: usize },
    /// The traces and policies differ in length.
    PolicyCountMismatch { traces: usize, policies: usize },
    /// A streaming source was built with a smaller look-ahead window than
    /// the scenario's spike-scoring horizon needs.
    WindowTooSmall { window: usize, need: usize },
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::EmptyFleet => {
                write!(f, "simulation fleet is empty (no traces; nothing to drive)")
            }
            EngineError::EmptyTrace { node } => {
                write!(f, "trace for node {node} has zero timesteps")
            }
            EngineError::ZeroDim { node } => {
                write!(f, "trace for node {node} has zero metric columns")
            }
            EngineError::PolicyCountMismatch { traces, policies } => write!(
                f,
                "one admission policy per node required ({traces} traces, {policies} policies)"
            ),
            EngineError::WindowTooSmall { window, need } => write!(
                f,
                "streaming window of {window} steps cannot cover the scenario's \
                 score look-ahead (need {need}; build the source with \
                 lookahead >= score_window)"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

/// Opt-in per-node signal timelines recorded during a run (see
/// [`DiscreteEventEngine::with_signal_capture`]): the raw material of the
/// prediction-quality evaluation ([`crate::sim::quality`]). Indexed
/// `[node][step]`; a dead node records `false` on both timelines for the
/// steps it is down, so shapes are always `nodes × steps` and capture is
/// byte-equivalent across trace sources and observe-pool widths.
#[derive(Debug, Clone, Default)]
pub struct SignalCapture {
    /// `raised[node][step]`: the node's admission policy was refusing
    /// work at that step (the rejection signal, post-observe).
    pub raised: Vec<Vec<bool>>,
    /// `spikes[node][step]`: the node's CPU Ready ground truth was at or
    /// above the scenario's `ready_threshold`.
    pub spikes: Vec<Vec<bool>>,
}

/// Aggregate result of a simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// Scenario name the run was driven by.
    pub scenario: String,
    pub steps: usize,
    pub nodes: usize,
    pub seed: u64,
    pub jobs_arrived: usize,
    pub jobs_accepted: usize,
    pub jobs_rejected: usize,
    /// Jobs that ran to completion within the horizon.
    pub jobs_completed: usize,
    /// Jobs lost after admission: killed by a departing node with no
    /// migration budget left, or whose re-placement probe found no taker.
    pub jobs_displaced: usize,
    /// Arrivals that found zero alive nodes.
    pub jobs_unplaceable: usize,
    /// Admitted jobs dropped because the target's wait queue was full.
    pub jobs_dropped: usize,
    /// Preemption events — a job preempted from two nodes counts twice.
    pub jobs_preempted: usize,
    /// Successful re-placements of displaced jobs onto a peer.
    pub jobs_migrated: usize,
    /// Wait-queue parks (a migrated job that parks again counts again).
    pub jobs_queued: usize,
    /// Jobs waiting — parked or awaiting re-placement — when the run
    /// ended.
    pub jobs_still_queued: usize,
    /// Jobs still running when the run ended.
    pub jobs_still_running: usize,
    /// Accepted jobs whose node stayed calm over the score window.
    pub good_accepts: usize,
    /// Accepted jobs whose node hit a CPU Ready spike in the score window.
    pub bad_accepts: usize,
    /// Rejections where a probed node indeed spiked in the score window.
    pub justified_rejections: usize,
    /// Churn events that actually fired.
    pub node_joins: usize,
    pub node_leaves: usize,
    /// Federation pushes that propagated / were ε-suppressed.
    pub federation_pushes: usize,
    pub federation_suppressed: usize,
    /// Pushes still in flight when the run ended (delivery would have
    /// landed past the horizon) — parity with
    /// [`crate::federation::FederationReport::late_drops`].
    pub federation_late_drops: usize,
    /// Mean observed push delivery latency in steps (0 when instant or no
    /// pushes happened).
    pub mean_push_latency_steps: f64,
    /// Mean wait between entering a queue and starting service, in steps,
    /// over jobs that did start (0 when nothing queued).
    pub mean_queue_delay_steps: f64,
    /// Mean queue delay per priority class, in steps, indexed by priority
    /// (0 = lowest). Empty on single-class fleets (`priority_levels` 1),
    /// which keeps legacy reports byte-identical.
    pub mean_queue_delay_by_priority: Vec<f64>,
    /// Jobs that arrived carrying a completion deadline (0 when the
    /// scenario sets no SLO).
    pub slo_total: usize,
    /// Deadline-carrying jobs that completed on time. Everything else —
    /// rejected, dropped, lost, late, or still in flight at the horizon —
    /// counts against attainment.
    pub slo_attained: usize,
    /// Correlated rack outages that fired (fault injection; the key is
    /// serialized only when a [`super::scenario::FailureModel`] is set).
    pub rack_outages: usize,
    /// Federation partitions that opened (fault injection).
    pub partition_events: usize,
    /// Pushes dropped at a partition cut (`partition_queue = false`).
    pub federation_partition_drops: usize,
    /// Queued pushes replayed *stale* when their partition healed.
    pub federation_stale_replays: usize,
    /// Antagonist-tenant breakdown (keys serialized only when the tenant
    /// is active): arrivals, rejections, and SLO accounting of the second
    /// stream. Primary-tenant figures are the totals minus these.
    pub antagonist_jobs_arrived: usize,
    pub antagonist_jobs_rejected: usize,
    pub antagonist_slo_total: usize,
    pub antagonist_slo_attained: usize,
    /// Gate: a failure model was configured. Controls serialization of
    /// the fault-injection keys; not itself serialized.
    pub fault_injection: bool,
    /// Gate: the antagonist tenant was configured. Controls serialization
    /// of the per-tenant keys; not itself serialized.
    pub antagonist_active: bool,
    /// Deepest wait queue observed on any node.
    pub peak_queue_len: usize,
    /// Time-averaged slot utilization over alive nodes — slot-ticks used
    /// divided by slot-ticks available, integrated event-by-event so
    /// mid-step churn and placements are accounted exactly (0 when the
    /// scenario has no capacity model). Never exceeds 1.
    pub mean_utilization: f64,
    /// Peak number of concurrently running jobs across the cluster.
    pub peak_inflight: usize,
    /// Events the engine dispatched over the run (telemetry ticks, job
    /// lifecycle, churn, federation). Deliberately **not** serialized into
    /// the JSON document — it is an engine-throughput diagnostic for
    /// `pronto bench engine`, and keeping it out preserves the byte-stable
    /// report contract of earlier releases.
    pub events_processed: usize,
    /// Per-job outcomes (ordered by arrival).
    pub outcomes: Vec<JobOutcome>,
    /// Raised/spike timelines, present only when the engine was built
    /// with [`DiscreteEventEngine::with_signal_capture`]. Like
    /// `events_processed`, deliberately **not** serialized — the JSON
    /// report byte contract is frozen; quality scoring consumes this
    /// in-process.
    pub signal_capture: Option<SignalCapture>,
}

impl SimReport {
    /// Fraction of accepted jobs placed on nodes that stayed healthy.
    pub fn placement_quality(&self) -> f64 {
        if self.jobs_accepted == 0 {
            return 1.0;
        }
        self.good_accepts as f64 / self.jobs_accepted as f64
    }

    pub fn acceptance_rate(&self) -> f64 {
        if self.jobs_arrived == 0 {
            return 1.0;
        }
        self.jobs_accepted as f64 / self.jobs_arrived as f64
    }

    /// Fraction of rejections that avoided a real spike.
    pub fn rejection_precision(&self) -> f64 {
        if self.jobs_rejected == 0 {
            return 1.0;
        }
        self.justified_rejections as f64 / self.jobs_rejected as f64
    }

    /// Fraction of deadline-carrying jobs that completed on time (1.0
    /// when the scenario sets no SLO).
    pub fn slo_attainment(&self) -> f64 {
        if self.slo_total == 0 {
            return 1.0;
        }
        self.slo_attained as f64 / self.slo_total as f64
    }

    /// Fraction of the antagonist tenant's deadline-carrying jobs that
    /// completed on time (1.0 when the tenant set none).
    pub fn antagonist_slo_attainment(&self) -> f64 {
        if self.antagonist_slo_total == 0 {
            return 1.0;
        }
        self.antagonist_slo_attained as f64 / self.antagonist_slo_total as f64
    }

    /// Order-sensitive FNV/SplitMix fold over the outcome sequence: two
    /// runs with identical per-job outcomes (and only those) agree.
    pub fn outcomes_digest(&self) -> u64 {
        // One SplitMix64 hop per folded value — exactly `rng::stream_seed`
        // with the value as the tag, so the digest shares the audited
        // mixing path instead of hand-rolling gamma arithmetic.
        fn mix(h: u64, v: u64) -> u64 {
            crate::rng::stream_seed(h, v)
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for o in &self.outcomes {
            h = match *o {
                JobOutcome::Accepted { node, at } => {
                    mix(mix(mix(h, 1), node as u64), at as u64)
                }
                JobOutcome::Rejected { at } => mix(mix(h, 2), at as u64),
            };
        }
        h
    }

    /// Canonical JSON rendering (BTreeMap ⇒ sorted keys ⇒ byte-stable for
    /// identical runs). The outcome list is folded into a digest so the
    /// document stays small while still witnessing per-job divergence.
    pub fn to_json(&self) -> JsonValue {
        let mut m = BTreeMap::new();
        let num = |x: usize| JsonValue::Number(x as f64);
        m.insert("scenario".into(), JsonValue::String(self.scenario.clone()));
        m.insert("steps".into(), num(self.steps));
        m.insert("nodes".into(), num(self.nodes));
        // String: a u64 seed above 2^53 would lose precision as a JSON
        // number, and the seed is the reproduction key.
        m.insert("seed".into(), JsonValue::String(self.seed.to_string()));
        m.insert("jobs_arrived".into(), num(self.jobs_arrived));
        m.insert("jobs_accepted".into(), num(self.jobs_accepted));
        m.insert("jobs_rejected".into(), num(self.jobs_rejected));
        m.insert("jobs_completed".into(), num(self.jobs_completed));
        m.insert("jobs_displaced".into(), num(self.jobs_displaced));
        m.insert("jobs_unplaceable".into(), num(self.jobs_unplaceable));
        m.insert("jobs_dropped".into(), num(self.jobs_dropped));
        m.insert("jobs_preempted".into(), num(self.jobs_preempted));
        m.insert("jobs_migrated".into(), num(self.jobs_migrated));
        m.insert("jobs_queued".into(), num(self.jobs_queued));
        m.insert("jobs_still_queued".into(), num(self.jobs_still_queued));
        m.insert("jobs_still_running".into(), num(self.jobs_still_running));
        m.insert("good_accepts".into(), num(self.good_accepts));
        m.insert("bad_accepts".into(), num(self.bad_accepts));
        m.insert("justified_rejections".into(), num(self.justified_rejections));
        m.insert("node_joins".into(), num(self.node_joins));
        m.insert("node_leaves".into(), num(self.node_leaves));
        m.insert("federation_pushes".into(), num(self.federation_pushes));
        m.insert(
            "federation_suppressed".into(),
            num(self.federation_suppressed),
        );
        m.insert(
            "federation_late_drops".into(),
            num(self.federation_late_drops),
        );
        m.insert(
            "mean_push_latency_steps".into(),
            JsonValue::Number(self.mean_push_latency_steps),
        );
        m.insert(
            "mean_queue_delay_steps".into(),
            JsonValue::Number(self.mean_queue_delay_steps),
        );
        // Priority/SLO keys appear only when the feature is active, so a
        // scenario that predates them renders byte-identical JSON.
        for (p, d) in self.mean_queue_delay_by_priority.iter().enumerate() {
            m.insert(format!("queue_delay_p{p}"), JsonValue::Number(*d));
        }
        if self.slo_total > 0 {
            m.insert("slo_total".into(), num(self.slo_total));
            m.insert("slo_attained".into(), num(self.slo_attained));
            m.insert(
                "slo_attainment".into(),
                JsonValue::Number(self.slo_attainment()),
            );
        }
        // Fault-injection keys appear only when a failure model was
        // configured; legacy scenarios render byte-identical JSON.
        if self.fault_injection {
            m.insert("rack_outages".into(), num(self.rack_outages));
            m.insert("partition_events".into(), num(self.partition_events));
            m.insert(
                "federation_partition_drops".into(),
                num(self.federation_partition_drops),
            );
            m.insert(
                "federation_stale_replays".into(),
                num(self.federation_stale_replays),
            );
        }
        // Per-tenant breakdown, gated on the antagonist tenant. Primary
        // figures are serialized explicitly so downstream tooling never
        // has to re-derive the split.
        if self.antagonist_active {
            m.insert(
                "antagonist_jobs_arrived".into(),
                num(self.antagonist_jobs_arrived),
            );
            m.insert(
                "antagonist_jobs_rejected".into(),
                num(self.antagonist_jobs_rejected),
            );
            m.insert(
                "primary_jobs_rejected".into(),
                num(self.jobs_rejected - self.antagonist_jobs_rejected),
            );
            if self.slo_total > 0 {
                m.insert(
                    "antagonist_slo_total".into(),
                    num(self.antagonist_slo_total),
                );
                m.insert(
                    "antagonist_slo_attained".into(),
                    num(self.antagonist_slo_attained),
                );
                m.insert(
                    "antagonist_slo_attainment".into(),
                    JsonValue::Number(self.antagonist_slo_attainment()),
                );
                m.insert(
                    "primary_slo_total".into(),
                    num(self.slo_total - self.antagonist_slo_total),
                );
                m.insert(
                    "primary_slo_attained".into(),
                    num(self.slo_attained - self.antagonist_slo_attained),
                );
            }
        }
        m.insert("peak_queue_len".into(), num(self.peak_queue_len));
        m.insert(
            "mean_utilization".into(),
            JsonValue::Number(self.mean_utilization),
        );
        m.insert("peak_inflight".into(), num(self.peak_inflight));
        m.insert(
            "acceptance_rate".into(),
            JsonValue::Number(self.acceptance_rate()),
        );
        m.insert(
            "placement_quality".into(),
            JsonValue::Number(self.placement_quality()),
        );
        m.insert(
            "rejection_precision".into(),
            JsonValue::Number(self.rejection_precision()),
        );
        m.insert(
            "outcomes_digest".into(),
            JsonValue::String(format!("{:016x}", self.outcomes_digest())),
        );
        JsonValue::Object(m)
    }

    /// Canonical JSON string — the byte-comparable determinism artifact.
    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }
}

/// Builds a fresh admission policy for a node that rejoins after churn (a
/// restarted machine loses its in-memory subspace state).
pub type PolicyFactory = Box<dyn Fn(usize) -> Box<dyn Admission>>;

/// Pooled storage for in-flight federation snapshots: events carry a slab
/// index instead of the (heap-heavy) subspace itself.
#[derive(Default)]
struct SnapshotPool {
    slots: Vec<Option<Subspace>>,
    free: Vec<usize>,
}

impl SnapshotPool {
    fn put(&mut self, s: Subspace) -> usize {
        match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(s);
                i
            }
            None => {
                self.slots.push(Some(s));
                self.slots.len() - 1
            }
        }
    }

    fn take(&mut self, i: usize) -> Option<Subspace> {
        let s = self.slots[i].take();
        if s.is_some() {
            self.free.push(i);
        }
        s
    }
}

/// Where a job is in its lifecycle. Terminal states are `Completed`,
/// `Rejected`, `Dropped`, and `Displaced`; everything else is still in
/// the system when the run ends.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum JobState {
    /// Created, admission or a hand-off event pending.
    Dispatching,
    /// Parked in `node`'s wait queue.
    Queued { node: usize },
    /// Holding slots on `node`.
    Running { node: usize },
    /// Displaced, re-placement probe pending.
    Migrating,
    Completed,
    /// Admission said no (or no alive node existed).
    Rejected,
    /// Admitted but the wait queue was full.
    Dropped,
    /// Lost: departing node or failed migration.
    Displaced,
}

/// Engine-side job record; events carry only the job id and the placement
/// generation (`gen`), which is bumped on every displacement so stale
/// lifecycle events become no-ops. `demand`/`duration_steps` are the
/// compact hot-loop mirror of [`crate::scheduler::Job`]'s `slots` and
/// `duration` — keep their semantics in sync. `demand` is the drawn
/// demand; the slots actually held on a host are clamped to that host's
/// budget at hand-off (the host records the clamped figure).
#[derive(Debug, Clone, Copy)]
struct JobRec {
    demand: u32,
    duration_steps: usize,
    gen: u32,
    migrations_left: u32,
    priority: Priority,
    state: JobState,
    /// Tick the job last entered a wait queue (for the delay metric).
    enqueued_at: Option<SimTime>,
    /// Completion deadline (SLO), set at arrival when the scenario
    /// configures one.
    deadline: Option<SimTime>,
    /// The job belongs to the antagonist tenant (fault injection).
    antagonist: bool,
}

/// Event-driven slot-utilization integral: slot-ticks in use and
/// slot-ticks available, advanced at every event that changes either.
/// Replaces the tick-sampled accounting, whose denominator only saw the
/// fleet at telemetry boundaries and so over/under-counted capacity
/// around mid-step churn. Inactive (all no-ops) without a capacity model.
struct UtilMeter {
    active: bool,
    used: u64,
    cap: u64,
    used_ticks: u128,
    cap_ticks: u128,
    last: SimTime,
}

impl UtilMeter {
    fn new(active: bool, initial_cap: u64) -> Self {
        Self { active, used: 0, cap: initial_cap, used_ticks: 0, cap_ticks: 0, last: 0 }
    }

    /// Integrate up to `now` (events pop in non-decreasing time order).
    fn advance(&mut self, now: SimTime) {
        if !self.active {
            return;
        }
        let dt = (now - self.last) as u128;
        self.used_ticks += self.used as u128 * dt;
        self.cap_ticks += self.cap as u128 * dt;
        self.last = now;
    }

    fn job_started(&mut self, now: SimTime, demand: u32) {
        if self.active {
            self.advance(now);
            self.used += demand as u64;
        }
    }

    fn job_finished(&mut self, now: SimTime, demand: u32) {
        if self.active {
            self.advance(now);
            self.used -= demand as u64;
        }
    }

    fn node_left(&mut self, now: SimTime, slots: u32) {
        if self.active {
            self.advance(now);
            self.cap -= slots as u64;
        }
    }

    fn node_joined(&mut self, now: SimTime, slots: u32) {
        if self.active {
            self.advance(now);
            self.cap += slots as u64;
        }
    }

    /// Time-averaged utilization over the integrated interval. Usage
    /// never exceeds the budgets it runs under, so this is ≤ 1.
    fn mean(&self) -> f64 {
        if self.cap_ticks == 0 {
            0.0
        } else {
            self.used_ticks as f64 / self.cap_ticks as f64
        }
    }
}

/// Does probe `a` strictly beat the incumbent `b` under `policy`? Ties
/// keep the incumbent (the earlier-probed candidate), which is what makes
/// scored dispatch deterministic. `SignalOnly` never prefers a later
/// candidate — the signal-clear filter upstream already decided.
fn probe_beats(policy: DispatchPolicy, a: &AdmissionProbe, b: &AdmissionProbe) -> bool {
    match policy {
        DispatchPolicy::SignalOnly => false,
        DispatchPolicy::QueueAware => {
            if a.queue_depth != b.queue_depth {
                return a.queue_depth < b.queue_depth;
            }
            if a.queue_delay_ewma != b.queue_delay_ewma {
                return a.queue_delay_ewma < b.queue_delay_ewma;
            }
            a.free_slots > b.free_slots
        }
        DispatchPolicy::LeastLoaded => {
            if a.free_slots != b.free_slots {
                return a.free_slots > b.free_slots;
            }
            a.queue_depth < b.queue_depth
        }
    }
}

/// Pick the winning candidate: each probed host answers with its full
/// [`AdmissionProbe`] (the admission policy's signal included); raised
/// signals and `eligible` failures are filtered out, the rest scored by
/// [`probe_beats`]. Under `SignalOnly` this reduces exactly to "first
/// eligible signal-clear candidate" — the pre-probe dispatch.
fn pick_candidate(
    candidates: &[usize],
    policy: DispatchPolicy,
    can_accept: &[bool],
    hosts: &HostTable,
    mut eligible: impl FnMut(usize) -> bool,
) -> Option<usize> {
    let mut best: Option<(usize, AdmissionProbe)> = None;
    for &c in candidates {
        let p = hosts.probe(c, !can_accept[c]);
        if p.signal_raised || !eligible(c) {
            continue;
        }
        let better = match &best {
            None => true,
            Some((_, b)) => probe_beats(policy, &p, b),
        };
        if better {
            best = Some((c, p));
        }
    }
    best.map(|(c, _)| c)
}

/// Reusable state for [`sample_distinct`]: a generation-stamped
/// membership mask keyed by pool index (O(1) "already drawn?" checks,
/// reset by bumping the epoch instead of clearing the array) plus the
/// Fisher–Yates fallback buffer. One instance serves any sequence of
/// pools; the stamp array grows to the largest pool seen and is never
/// cleared between calls.
///
/// The historical scratch was a bare `Vec<usize>` and the membership
/// test was `out.contains(&c)` — O(want) per draw, and the fallback's
/// `pool.iter().filter(!contains)` walk made a dense draw over a 100k
/// alive-set O(pool·want). The stamps make both O(1) per element while
/// reproducing the exact historical acceptance sequence (pool entries
/// are distinct, so index-keyed and value-keyed membership agree).
#[derive(Debug, Default)]
pub struct SampleScratch {
    fallback: Vec<usize>,
    stamp: Vec<u32>,
    epoch: u32,
}

/// Fill `out` with `want` distinct members of the sorted, duplicate-free
/// `pool` (minus `exclude`), drawn uniformly via `rng`.
///
/// Strategy: rejection-sample with a bounded draw budget — byte-identical
/// to the historical unbounded `while !contains` loop whenever that loop
/// would have finished within the budget, which the catalog's power-of-2
/// probes do essentially always (a fallback needs ~`4·want` consecutive
/// collisions) — then complete any remainder with a partial Fisher–Yates
/// over the reusable `scratch` buffer. Worst-case cost is
/// O(want + |pool|) draws *and* O(want + |pool|) work: the scratch's
/// stamp mask answers membership in O(1), so a dense draw over a
/// 100k-node alive-set no longer degenerates quadratically.
///
/// Public so the integration suite can cover the `k ≥ alive − 1`
/// fallback boundary directly (`tests/probe_regressions.rs`); not part
/// of the stable API surface otherwise.
pub fn sample_distinct(
    rng: &mut Xoshiro256,
    pool: &[usize],
    exclude: Option<usize>,
    want: usize,
    out: &mut Vec<usize>,
    scratch: &mut SampleScratch,
) {
    out.clear();
    let excluded_in_pool = exclude.is_some_and(|e| pool.binary_search(&e).is_ok());
    let avail = pool.len() - usize::from(excluded_in_pool);
    let want = want.min(avail);
    if want == 0 {
        return;
    }
    let m = pool.len();
    if scratch.stamp.len() < m {
        scratch.stamp.resize(m, 0);
    }
    scratch.epoch = scratch.epoch.wrapping_add(1);
    if scratch.epoch == 0 {
        // Epoch wrapped: stale stamps from 2³² calls ago could collide.
        scratch.stamp.iter_mut().for_each(|s| *s = 0);
        scratch.epoch = 1;
    }
    let epoch = scratch.epoch;
    let mut budget = 4 * want + 8;
    while out.len() < want && budget > 0 {
        budget -= 1;
        let j = rng.gen_range(m);
        let c = pool[j];
        // Distinct pool entries make the index-keyed stamp equivalent to
        // the historical value-keyed `out.contains(&c)` test: same
        // acceptances, same RNG positions.
        if Some(c) != exclude && scratch.stamp[j] != epoch {
            scratch.stamp[j] = epoch;
            out.push(c);
        }
    }
    // Budget exhausted: finish deterministically over the survivors.
    if out.len() < want {
        let SampleScratch { fallback, stamp, .. } = scratch;
        fallback.clear();
        fallback.extend(
            pool.iter()
                .enumerate()
                .filter(|&(j, &c)| Some(c) != exclude && stamp[j] != epoch)
                .map(|(_, &c)| c),
        );
        while out.len() < want {
            let j = rng.gen_range(fallback.len());
            out.push(fallback.swap_remove(j));
        }
    }
}

/// Per-step memo of the ground-truth spike scan (`spike_within`): one
/// look-ahead scan per `(node, step)` instead of one per probe. An
/// arrival burst behind one telemetry tick probes overlapping candidate
/// sets — on `flash-crowd` storms hundreds of same-step arrivals share a
/// handful of hosts — so the probe buffer is effectively filled once per
/// tick. Pure caching of a deterministic function: results (and the
/// streaming window access pattern, which only ever re-reads already
/// buffered spans) are untouched, so reports stay byte-identical.
struct SpikeMemo {
    /// `stamp[node] == step + 1` ⇒ `val[node]` holds the verdict for
    /// `step` (0 = never computed; avoids a sentinel clash at step 0).
    stamp: Vec<usize>,
    val: Vec<bool>,
}

impl SpikeMemo {
    fn new(nodes: usize) -> Self {
        Self { stamp: vec![0; nodes], val: vec![false; nodes] }
    }

    /// `source.spike_within(node, lo, hi, threshold)`, memoized per
    /// `(node, lo)` — callers always derive `hi` from `lo`, so `lo` keys
    /// the whole query.
    fn spike_within(
        &mut self,
        source: &mut TraceSource,
        node: usize,
        lo: usize,
        hi: usize,
        threshold: f64,
    ) -> bool {
        if self.stamp[node] == lo + 1 {
            return self.val[node];
        }
        let v = source.spike_within(node, lo, hi, threshold);
        self.stamp[node] = lo + 1;
        self.val[node] = v;
        v
    }
}

/// The sharded observe loop: split the sorted alive set into contiguous
/// chunks (one per pool thread), give each chunk exclusive slices of the
/// policies, the `can_accept` output, and the per-node trace views, and
/// run trace advancement + policy observe (FPCA iterate + rejection
/// signal) per chunk. Chunks cover disjoint node-id ranges, so the
/// merged result — written in place, node-id order — is byte-identical
/// to the sequential loop regardless of scheduling.
fn parallel_observe(
    pool: &WorkerPool,
    alive_ids: &[usize],
    source: &mut TraceSource,
    policies: &mut [Box<dyn Admission>],
    can_accept: &mut [bool],
    step: usize,
) {
    let mut views = source.node_views();
    let per = alive_ids.len().div_ceil(pool.threads());
    let mut tasks: Vec<minipool::Task<'_>> = Vec::with_capacity(pool.threads());
    // Walk the state arrays left to right, carving off the id range each
    // chunk covers. `base` is the absolute node id where the remaining
    // (`*_rest`) slices start.
    let mut pol_rest = policies;
    let mut acc_rest = can_accept;
    let mut view_rest = views.as_mut_slice();
    let mut base = 0usize;
    for ids in alive_ids.chunks(per.max(1)) {
        let lo = ids[0];
        let hi = ids[ids.len() - 1] + 1;
        let (_, tail) = std::mem::take(&mut pol_rest).split_at_mut(lo - base);
        let (pol_chunk, tail) = tail.split_at_mut(hi - lo);
        pol_rest = tail;
        let (_, tail) = std::mem::take(&mut acc_rest).split_at_mut(lo - base);
        let (acc_chunk, tail) = tail.split_at_mut(hi - lo);
        acc_rest = tail;
        let (_, tail) = std::mem::take(&mut view_rest).split_at_mut(lo - base);
        let (view_chunk, tail) = tail.split_at_mut(hi - lo);
        view_rest = tail;
        base = hi;
        tasks.push(Box::new(move || {
            for &id in ids {
                let k = id - lo;
                acc_chunk[k] = pol_chunk[k].observe(view_chunk[k].features(step));
            }
        }));
    }
    pool.run(tasks);
}

/// Start every waiting job on `node` that fits within `budget` slots.
#[allow(clippy::too_many_arguments)]
fn drain_queue(
    node: usize,
    budget: u32,
    hosts: &mut HostTable,
    jobs: &mut [JobRec],
    queue: &mut EventQueue,
    now: SimTime,
    total_inflight: &mut usize,
    util: &mut UtilMeter,
    report: &mut SimReport,
) {
    while let Some(qj) = hosts.pop_startable(node, budget) {
        let rec = &mut jobs[qj.job_id as usize];
        debug_assert_eq!(rec.state, JobState::Queued { node });
        hosts.start(node, qj.job_id, qj.demand);
        util.job_started(now, qj.demand);
        rec.state = JobState::Running { node };
        *total_inflight += 1;
        report.peak_inflight = report.peak_inflight.max(*total_inflight);
        queue.schedule(now, Event::JobStart { node, job_id: qj.job_id, gen: rec.gen });
    }
}

/// The discrete-event cluster engine.
pub struct DiscreteEventEngine {
    scenario: Scenario,
    source: TraceSource,
    policies: Vec<Box<dyn Admission>>,
    factory: Option<PolicyFactory>,
    capture: bool,
}

impl DiscreteEventEngine {
    /// One trace + one policy per node (same order). The scenario's
    /// `nodes` is overridden by the fleet size. Panics on a malformed
    /// fleet; use [`DiscreteEventEngine::try_new`] to get a typed error
    /// instead (the CLI does).
    pub fn new(
        scenario: Scenario,
        traces: Vec<VmTrace>,
        policies: Vec<Box<dyn Admission>>,
    ) -> Self {
        Self::try_new(scenario, traces, policies)
            .unwrap_or_else(|e| panic!("invalid engine inputs: {e}"))
    }

    /// Fallible constructor over pre-materialized traces — the historical
    /// entry point, now a thin wrapper over
    /// [`DiscreteEventEngine::try_from_source`].
    pub fn try_new(
        scenario: Scenario,
        traces: Vec<VmTrace>,
        policies: Vec<Box<dyn Admission>>,
    ) -> Result<Self, EngineError> {
        Self::try_from_source(scenario, TraceSource::materialized(traces), policies)
    }

    /// Fallible constructor over any [`TraceSource`] — materialized
    /// replay (legacy, byte-identical reports) or windowed streaming
    /// (O(nodes + window) memory; large fleets). Validates that the fleet
    /// is non-empty, telemetry has at least one timestep and one metric
    /// column, and the policy list matches. A zero-length or zero-dim
    /// trace set — easy to hit via an empty or header-only `--replay`
    /// directory — previously panicked on `traces[0]` inside `run`.
    pub fn try_from_source(
        scenario: Scenario,
        source: TraceSource,
        policies: Vec<Box<dyn Admission>>,
    ) -> Result<Self, EngineError> {
        if source.nodes() == 0 {
            return Err(EngineError::EmptyFleet);
        }
        if source.nodes() != policies.len() {
            return Err(EngineError::PolicyCountMismatch {
                traces: source.nodes(),
                policies: policies.len(),
            });
        }
        match &source {
            TraceSource::Materialized(traces) => {
                for (node, t) in traces.iter().enumerate() {
                    if t.is_empty() {
                        return Err(EngineError::EmptyTrace { node });
                    }
                    if t.dim() == 0 {
                        return Err(EngineError::ZeroDim { node });
                    }
                }
            }
            TraceSource::Streaming(fleet) => {
                if source.is_empty() {
                    return Err(EngineError::EmptyTrace { node: 0 });
                }
                if source.dim() == 0 {
                    return Err(EngineError::ZeroDim { node: 0 });
                }
                let need = scenario.score_window + 2;
                if fleet.window() < need {
                    return Err(EngineError::WindowTooSmall {
                        window: fleet.window(),
                        need,
                    });
                }
            }
        }
        Ok(Self { scenario, source, policies, factory: None, capture: false })
    }

    /// Install a policy factory: nodes that rejoin after churn restart
    /// with a fresh policy (then optionally pull the federation view).
    pub fn with_policy_factory(mut self, factory: PolicyFactory) -> Self {
        self.factory = Some(factory);
        self
    }

    /// Record per-node raised/spike timelines into
    /// [`SimReport::signal_capture`]. Off by default: capture costs
    /// `2 · nodes · steps` booleans and the serialized report never
    /// carries it, so only the quality evaluation turns it on.
    pub fn with_signal_capture(mut self) -> Self {
        self.capture = true;
        self
    }

    /// Run to the horizon; consumes the engine.
    pub fn run(self) -> SimReport {
        let Self { scenario, mut source, mut policies, factory, capture } = self;
        let n = source.nodes();
        let d = source.dim();
        let trace_len = source.len();
        let steps = scenario.steps.min(trace_len);
        let horizon: SimTime = step_to_ticks(steps);

        // Independent, order-insensitive RNG streams (the shared
        // convention in `crate::rng::stream_seed`; tags are the named
        // constants of the central `rng::streams` registry).
        let stream =
            |tag: u64| Xoshiro256::seed_from_u64(crate::rng::stream_seed(scenario.seed, tag));
        let mut arrivals_rng = stream(streams::ARRIVALS);
        let mut duration_rng = stream(streams::DURATION);
        let mut dispatch_rng = stream(streams::DISPATCH);
        let mut churn_rng = stream(streams::CHURN);
        let mut latency_rng = stream(streams::FED_LATENCY);
        let mut demand_rng = stream(streams::DEMAND);
        let mut migrate_rng = stream(streams::MIGRATE);
        let mut priority_rng = stream(streams::PRIORITY);
        let mut hetero_rng = stream(streams::HETERO);
        let mut rack_rng = stream(streams::RACK_OUTAGE);
        let mut partition_rng = stream(streams::PARTITION);
        let mut antagonist_rng = stream(streams::ANTAGONIST);

        let fed = &scenario.federation;
        let mut tree = if fed.enabled {
            Some(FederationTree::new(
                TreeTopology::new(n, fed.fanout.max(2)),
                d,
                fed.rank,
                fed.epsilon,
            ))
        } else {
            None
        };
        let mut pool = SnapshotPool::default();

        let cap: Option<CapacityModel> = scenario.capacity.clone();
        let initial_migrations = cap.as_ref().map_or(0, |c| c.migration_limit);
        let priority_levels = cap.as_ref().map_or(1, |c| c.priority_levels);
        let service = ServiceTimeModel::log_normal(scenario.duration_mu, scenario.duration_sigma);

        // Dense per-node state, struct-of-arrays (see `super::fleet`).
        // Heterogeneous fleets draw each node's slot budget from the
        // class distribution (dedicated stream, so turning hetero on
        // shifts nothing else).
        let raw_hosts: Vec<HostCapacity> = (0..n)
            .map(|_| match &cap {
                Some(c) => HostCapacity::new(
                    c.draw_slots(&mut hetero_rng),
                    c.queue_capacity,
                    c.queue_policy,
                ),
                None => HostCapacity::unbounded(),
            })
            .collect();
        let initial_cap: u64 = if cap.is_some() {
            raw_hosts.iter().map(|h| h.slots() as u64).sum()
        } else {
            0
        };
        let mut hosts = HostTable::new(raw_hosts);
        let mut util = UtilMeter::new(cap.is_some(), initial_cap);
        let mut fleet = FleetState::new(n);
        let mut burst_on = false;

        // Fault-injection state. Stragglers are designated once at init
        // from their own stream; each carries a push-latency multiplier
        // (1.0 on healthy nodes, so the multiply is an exact identity on
        // legacy runs) and, with an observe lag, a small ring of its
        // recent rejection signals. Partitions index a member table;
        // `partitioned` counts overlapping cuts per leaf, and queued
        // pushes wait in `partition_pending` until their leaf reconnects.
        let failures = scenario.failures;
        let mut straggler_mult: Vec<f64> = vec![1.0; n];
        let mut straggler = vec![false; n];
        let straggler_lag = failures
            .filter(|f| f.stragglers_enabled())
            .map_or(0, |f| f.straggler_observe_lag);
        if let Some(f) = failures.filter(|f| f.stragglers_enabled()) {
            let mut straggler_rng = stream(streams::STRAGGLER);
            let id_pool: Vec<usize> = (0..n).collect();
            let want = ((n as f64 * f.straggler_fraction).round() as usize).clamp(1, n);
            let mut picked = Vec::new();
            let mut scratch = SampleScratch::default();
            sample_distinct(&mut straggler_rng, &id_pool, None, want, &mut picked, &mut scratch);
            for &i in &picked {
                straggler[i] = true;
                straggler_mult[i] = f.straggler_delay_multiplier;
            }
        }
        let mut straggler_rings: Vec<std::collections::VecDeque<bool>> = if straggler_lag > 0 {
            vec![std::collections::VecDeque::with_capacity(straggler_lag + 1); n]
        } else {
            Vec::new()
        };
        let partitions_active =
            failures.is_some_and(|f| f.partitions_enabled()) && fed.enabled;
        let mut partitions: Vec<Vec<usize>> = Vec::new();
        let mut partition_members_buf: Vec<usize> = Vec::new();
        let mut partitioned: Vec<u32> = vec![0; n];
        let mut partition_pending: Vec<(usize, usize, SimTime)> = Vec::new();

        let mut report = SimReport {
            scenario: scenario.name.clone(),
            nodes: n,
            steps,
            seed: scenario.seed,
            fault_injection: failures.is_some(),
            antagonist_active: failures.is_some_and(|f| f.antagonist_enabled()),
            ..Default::default()
        };
        let mut capture: Option<SignalCapture> = if capture {
            Some(SignalCapture {
                raised: vec![Vec::with_capacity(steps); n],
                spikes: vec![Vec::with_capacity(steps); n],
            })
        } else {
            None
        };
        let expected_jobs =
            (scenario.arrivals.mean_rate() * steps as f64).ceil() as usize;
        report.outcomes.reserve(expected_jobs + 16);

        let mut queue = EventQueue::with_capacity(1024 + expected_jobs / 4);
        let mut candidates: Vec<usize> = Vec::with_capacity(8);
        // Stamp mask + Fisher–Yates fallback buffer for distinct probe
        // draws (reused so the arrival/probe hot path stays
        // allocation-free in steady state).
        let mut probe_scratch = SampleScratch::default();
        let mut jobs: Vec<JobRec> = Vec::with_capacity(expected_jobs + 16);
        let mut total_inflight = 0usize;
        let mut lat_ticks_sum = 0u64;
        let mut lat_count = 0u64;
        let mut qdelay_ticks_sum = 0u64;
        let mut qdelay_count = 0u64;
        let mut qdelay_p_sum = vec![0u64; priority_levels as usize];
        let mut qdelay_p_count = vec![0u64; priority_levels as usize];

        // Ground truth for scoring: does `node`'s CPU Ready spike within
        // the score window starting at `step`? (A bounded look-ahead — the
        // streaming source sizes its window from `score_window` so these
        // reads never leave the buffered span.)
        let score_hi = |step: usize| (step + scenario.score_window).min(steps - 1);
        let ready_threshold = scenario.ready_threshold;

        queue.schedule(0, Event::TelemetryTick { step: 0 });

        // Pool + per-step memo for the batched tick dispatch (see the
        // module docs): batches preserve pop order exactly, so handler
        // semantics and report bytes match the historical per-event loop.
        let workers = WorkerPool::new(scenario.threads);
        let mut memo = SpikeMemo::new(n);
        let mut batch = TickBatch::default();
        // Federation deliveries collected per tick batch and flushed once
        // through the sharded `push_from_leaves` fan-in (reused buffer).
        let mut fed_batch: Vec<(usize, Subspace)> = Vec::new();
        while queue.drain_tick(&mut batch) {
            if batch.time() >= horizon {
                // Pops are non-decreasing in time: everything left is
                // also past the run. In-flight federation pushes would
                // have delivered after the horizon — count them as late
                // drops (parity with ConcurrentFederation) and stop.
                let mut late = batch
                    .events()
                    .iter()
                    .filter(|s| matches!(s.event, Event::FederationPush { .. }))
                    .count();
                while let Some(rest) = queue.pop() {
                    if matches!(rest.event, Event::FederationPush { .. }) {
                        late += 1;
                    }
                }
                // Pushes still parked at an unhealed partition cut would
                // have replayed past the horizon too.
                late += partition_pending.len();
                report.federation_late_drops = late;
                break;
            }
            for idx in 0..batch.len() {
                let ev = batch.events()[idx];
                report.events_processed += 1;
                match ev.event {
                    Event::TelemetryTick { step } => {
                        // 1. Every alive node consumes its metric vector —
                        //    the observe loop. Width 1 runs the exact
                        //    historical sequential path; wider pools shard
                        //    the sorted alive set into contiguous chunks
                        //    with fully disjoint per-node state, so the
                        //    in-place merge (node-id order) is
                        //    byte-identical to the sequential result.
                        {
                            let (alive_ids, can_accept) = fleet.observe_split();
                            if workers.is_parallel() && alive_ids.len() > 1 {
                                parallel_observe(
                                    &workers,
                                    alive_ids,
                                    &mut source,
                                    &mut policies,
                                    can_accept,
                                    step,
                                );
                            } else {
                                // Iterating the sorted alive ids visits the
                                // same nodes in the same (ascending) order
                                // as the historical `0..n` + alive-flag
                                // scan — the dense list just skips the
                                // dead stretches.
                                for &i in alive_ids {
                                    can_accept[i] =
                                        policies[i].observe(source.features(i, step));
                                }
                            }
                        }

                        // 1a'. Stragglers publish a *lagged* rejection
                        //      signal: the freshly computed value enters a
                        //      per-node ring and dispatch sees the value
                        //      from `straggler_observe_lag` steps ago
                        //      (delayed telemetry columns). Sequential
                        //      post-pass in node-id order, so reports stay
                        //      byte-identical at any pool width.
                        if straggler_lag > 0 {
                            for i in 0..n {
                                if !straggler[i] || !fleet.is_alive(i) {
                                    continue;
                                }
                                let ring = &mut straggler_rings[i];
                                ring.push_back(fleet.can_accept(i));
                                let lagged = if ring.len() > straggler_lag {
                                    ring.pop_front().unwrap()
                                } else {
                                    *ring.front().unwrap()
                                };
                                fleet.set_can_accept(i, lagged);
                            }
                        }

                        // 1a. Signal capture (opt-in): record the merged
                        //     rejection signal and the ground-truth spike
                        //     indicator for every node. Runs sequentially
                        //     after the observe merge, so the timelines
                        //     are byte-equivalent at any pool width; dead
                        //     nodes record `false` without touching their
                        //     trace state (streaming parity: their stream
                        //     advances lazily on rejoin either way).
                        if let Some(capt) = capture.as_mut() {
                            for i in 0..n {
                                capt.raised[i]
                                    .push(fleet.is_alive(i) && !fleet.can_accept(i));
                                let spiked = fleet.is_alive(i)
                                    && source.cpu_ready(i, step) >= ready_threshold;
                                capt.spikes[i].push(spiked);
                            }
                        }

                        // 1b. Capacity progress: let idle slots pick up queued
                        //     work (completions drain too, but a queue built
                        //     while the node was contended must not wait for
                        //     the next completion once the signal clears).
                        //     Utilization needs no sampling here — the meter
                        //     integrates event-by-event.
                        if let Some(c) = &cap {
                            // The queue-depth scan runs over the dense
                            // alive list against the contiguous SoA
                            // mirror — same visit order as the historical
                            // full-fleet flag scan.
                            for &i in fleet.alive_ids() {
                                if hosts.queue_len(i) > 0 {
                                    let budget = if fleet.can_accept(i) {
                                        hosts.slots(i)
                                    } else {
                                        c.contended_budget(hosts.slots(i))
                                    };
                                    drain_queue(
                                        i,
                                        budget,
                                        &mut hosts,
                                        &mut jobs,
                                        &mut queue,
                                        ev.time,
                                        &mut total_inflight,
                                        &mut util,
                                        &mut report,
                                    );
                                }
                            }
                        }

                        // 2. Churn hazard (respecting the min-alive floor; the
                        //    provisional counter prevents one tick from
                        //    scheduling the pool below the floor).
                        if let Some(churn) = &scenario.churn {
                            let mut planned_alive = fleet.alive_count();
                            // Alive-id iteration draws the hazard for the
                            // same nodes in the same order as the flag
                            // scan (dead nodes never drew — the flag
                            // short-circuited before the RNG).
                            for &i in fleet.alive_ids() {
                                if planned_alive > churn.min_alive
                                    && churn_rng.bernoulli(churn.leave_hazard)
                                {
                                    planned_alive -= 1;
                                    queue.schedule(ev.time + 1, Event::NodeLeave { node: i });
                                }
                            }
                        }

                        // 2a. Correlated rack outages: each rack draws its
                        //     hazard from the dedicated stream (one draw
                        //     per rack per tick, so the stream position
                        //     never depends on outcomes); a failing rack
                        //     schedules NodeLeave for every alive member
                        //     at the next tick and a shared NodeJoin burst
                        //     when the outage elapses — the whole rack
                        //     restarts together.
                        if let Some(f) = failures.filter(|f| f.rack_outages_enabled()) {
                            let mut planned_alive = fleet.alive_count();
                            let racks = n.div_ceil(f.rack_size);
                            for r in 0..racks {
                                if !rack_rng.bernoulli(f.rack_outage_hazard) {
                                    continue;
                                }
                                let lo = r * f.rack_size;
                                let hi = ((r + 1) * f.rack_size).min(n);
                                let members =
                                    (lo..hi).filter(|&m| fleet.is_alive(m)).count();
                                if members == 0
                                    || planned_alive.saturating_sub(members) < f.min_alive
                                {
                                    continue; // outage floor
                                }
                                planned_alive -= members;
                                report.rack_outages += 1;
                                let dur = rack_rng
                                    .exponential(1.0 / f.rack_outage_duration_mean.max(1e-9));
                                let rejoin_at = ev.time + 1 + latency_to_ticks(dur);
                                for m in lo..hi {
                                    if fleet.is_alive(m) {
                                        queue.schedule(
                                            ev.time + 1,
                                            Event::NodeLeave { node: m },
                                        );
                                        queue.schedule(rejoin_at, Event::NodeJoin { node: m });
                                    }
                                }
                            }
                        }

                        // 2a'. Federation partition hazard: open a cut over
                        //      a drawn member set; the heal is scheduled up
                        //      front from the same stream, and the §5.2
                        //      stale-merge path runs at heal time.
                        if partitions_active {
                            let f = failures.unwrap();
                            if partition_rng.bernoulli(f.partition_hazard) {
                                let want = ((fleet.alive_count() as f64
                                    * f.partition_fraction)
                                    .ceil() as usize)
                                    .max(1);
                                sample_distinct(
                                    &mut partition_rng,
                                    fleet.alive_ids(),
                                    None,
                                    want,
                                    &mut partition_members_buf,
                                    &mut probe_scratch,
                                );
                                let idx = partitions.len();
                                partitions.push(partition_members_buf.clone());
                                let dur = partition_rng
                                    .exponential(1.0 / f.partition_duration_mean.max(1e-9));
                                queue.schedule(
                                    ev.time + 1,
                                    Event::PartitionStart { partition: idx },
                                );
                                queue.schedule(
                                    ev.time + 1 + latency_to_ticks(dur),
                                    Event::PartitionHeal { partition: idx },
                                );
                            }
                        }

                        // 2b. Pressure preemption: a node whose rejection
                        //     signal is raised sheds running jobs down to the
                        //     contended budget — lowest priority class first,
                        //     newest first within a class. Scheduled after
                        //     the churn leaves so a departing node's own
                        //     evacuation wins (stale preempts no-op on the
                        //     generation check).
                        if let Some(c) = &cap {
                            if c.pressure_enabled() {
                                for &i in fleet.alive_ids() {
                                    let contended = c.contended_budget(hosts.slots(i));
                                    if !fleet.can_accept(i) && hosts.used(i) > contended {
                                        let mut over = hosts.used(i) - contended;
                                        'shed: for p in 0..priority_levels {
                                            for &(job_id, demand) in
                                                hosts.running(i).iter().rev()
                                            {
                                                if jobs[job_id as usize].priority != p {
                                                    continue;
                                                }
                                                if over == 0 {
                                                    break 'shed;
                                                }
                                                queue.schedule(
                                                    ev.time + 1,
                                                    Event::JobPreempt {
                                                        node: i,
                                                        job_id,
                                                        gen: jobs[job_id as usize].gen,
                                                    },
                                                );
                                                over = over.saturating_sub(demand);
                                            }
                                        }
                                    }
                                }
                            }
                        }

                        // 3. Job arrivals for this step (regime update first
                        //    for the MMPP pattern; replay injects exact
                        //    counts and consumes no randomness).
                        if let ArrivalPattern::Bursty { mean_burst_len, mean_gap_len, .. } =
                            scenario.arrivals
                        {
                            let flip = if burst_on {
                                1.0 / mean_burst_len.max(1.0)
                            } else {
                                1.0 / mean_gap_len.max(1.0)
                            };
                            if arrivals_rng.bernoulli(flip.min(1.0)) {
                                burst_on = !burst_on;
                            }
                        }
                        let k = match &scenario.arrivals {
                            ArrivalPattern::Replay { schedule } => schedule.count_at(step) as usize,
                            pattern => {
                                let lam = pattern.rate_at(step, burst_on);
                                arrivals_rng.poisson(lam) as usize
                            }
                        };
                        for j in 0..k {
                            let duration_steps = service.sample(&mut duration_rng);
                            let demand = match &cap {
                                Some(c) => {
                                    1 + demand_rng.gen_range(c.max_job_slots as usize) as u32
                                }
                                None => 1,
                            };
                            // Priority draws use their own stream, and only
                            // when classes exist — single-class fleets stay
                            // byte-identical to the pre-priority engine.
                            let priority: Priority = if priority_levels > 1 {
                                priority_rng.gen_range(priority_levels as usize) as Priority
                            } else {
                                0
                            };
                            let job_id = jobs.len() as JobId;
                            jobs.push(JobRec {
                                demand,
                                duration_steps,
                                gen: 0,
                                migrations_left: initial_migrations,
                                priority,
                                state: JobState::Dispatching,
                                enqueued_at: None,
                                deadline: None,
                                antagonist: false,
                            });
                            let off = (2 + j as u64).min(TICKS_PER_STEP - 1);
                            queue.schedule(ev.time + off, Event::JobArrival { job_id });
                        }

                        // 3a. Antagonist tenant arrivals: a second Poisson
                        //     stream whose count, duration, and demand all
                        //     draw from the dedicated stream — enabling the
                        //     tenant never shifts the primary workload.
                        //     Scheduled after the primary batch within the
                        //     tick (offsets continue where the batch ended).
                        if let Some(f) = failures.filter(|f| f.antagonist_enabled()) {
                            let ka = antagonist_rng.poisson(f.antagonist_rate) as usize;
                            for j in 0..ka {
                                let duration_steps =
                                    service.sample(&mut antagonist_rng);
                                let demand = match &cap {
                                    Some(c) => {
                                        1 + antagonist_rng
                                            .gen_range(c.max_job_slots as usize)
                                            as u32
                                    }
                                    None => 1,
                                };
                                let priority: Priority = if priority_levels > 1 {
                                    f.antagonist_priority.min(priority_levels - 1)
                                        as Priority
                                } else {
                                    0
                                };
                                let job_id = jobs.len() as JobId;
                                jobs.push(JobRec {
                                    demand,
                                    duration_steps,
                                    gen: 0,
                                    migrations_left: initial_migrations,
                                    priority,
                                    state: JobState::Dispatching,
                                    enqueued_at: None,
                                    deadline: None,
                                    antagonist: true,
                                });
                                let off =
                                    (2 + (k + j) as u64).min(TICKS_PER_STEP - 1);
                                queue.schedule(ev.time + off, Event::JobArrival { job_id });
                            }
                        }

                        // 4. Federation push boundary: alive leaves offer
                        //    their iterate; delivery is delayed by the
                        //    latency model (the merged iterate is stale by
                        //    construction).
                        if tree.is_some() && (step + 1) % fed.push_every == 0 {
                            for &leaf in fleet.alive_ids() {
                                if let Some(iterate) = policies[leaf].iterate() {
                                    // The latency draw happens for every
                                    // offer, partitioned or not, so the
                                    // stream position depends only on the
                                    // offer sequence.
                                    let delay = fed.latency.sample(&mut latency_rng);
                                    if partitioned[leaf] > 0 {
                                        // Uplink cut: queue the snapshot
                                        // for a stale replay on heal, or
                                        // drop and count it.
                                        if failures.is_some_and(|f| f.partition_queue) {
                                            let snapshot = pool.put(iterate);
                                            partition_pending
                                                .push((leaf, snapshot, ev.time));
                                        } else {
                                            report.federation_partition_drops += 1;
                                        }
                                        continue;
                                    }
                                    // Stragglers push slower: the per-node
                                    // multiplier scales the sampled delay
                                    // (×1.0 — an exact identity — on
                                    // healthy nodes).
                                    let dt =
                                        latency_to_ticks(delay * straggler_mult[leaf]);
                                    let snapshot = pool.put(iterate);
                                    queue.schedule(
                                        ev.time + dt,
                                        Event::FederationPush { leaf, snapshot, sent_at: ev.time },
                                    );
                                }
                            }
                        }

                        // 5. Next tick.
                        if step + 1 < steps {
                            queue.schedule(
                                step_to_ticks(step + 1),
                                Event::TelemetryTick { step: step + 1 },
                            );
                        }
                    }

                    Event::JobArrival { job_id } => {
                        let step = ticks_to_step(ev.time);
                        let antagonist = jobs[job_id as usize].antagonist;
                        report.jobs_arrived += 1;
                        if antagonist {
                            report.antagonist_jobs_arrived += 1;
                        }
                        // SLO clock starts at arrival, whatever happens next:
                        // rejected/dropped/lost jobs count against attainment.
                        if let Some(slo) = cap.as_ref().and_then(|c| c.slo_steps) {
                            jobs[job_id as usize].deadline =
                                Some(ev.time + slo as u64 * TICKS_PER_STEP);
                            report.slo_total += 1;
                            if antagonist {
                                report.antagonist_slo_total += 1;
                            }
                        }
                        if fleet.alive_count() == 0 {
                            report.jobs_rejected += 1;
                            report.jobs_unplaceable += 1;
                            if antagonist {
                                report.antagonist_jobs_rejected += 1;
                            }
                            report.outcomes.push(JobOutcome::Rejected { at: step });
                            jobs[job_id as usize].state = JobState::Rejected;
                            continue;
                        }
                        candidates.clear();
                        match scenario.probe {
                            ProbePolicy::RandomProbe => {
                                let m = fleet.alive_count();
                                candidates
                                    .push(fleet.alive_ids()[dispatch_rng.gen_range(m)]);
                            }
                            ProbePolicy::PowerOfK(k) => {
                                // Bounded distinct draw (see `sample_distinct`):
                                // byte-identical to the historical rejection
                                // loop on the catalog, O(k + alive) worst case.
                                sample_distinct(
                                    &mut dispatch_rng,
                                    fleet.alive_ids(),
                                    None,
                                    k.max(1),
                                    &mut candidates,
                                    &mut probe_scratch,
                                );
                            }
                            ProbePolicy::RoundRobin => {
                                // Identity-tracked cursor (see
                                // `FleetState::rr_probe`): probe the first
                                // alive node with id >= the cursor
                                // (wrapping), then advance past it — an
                                // index-modulo cursor re-aliased every later
                                // probe after churn and could starve hosts.
                                if let Some(c) = fleet.rr_probe() {
                                    candidates.push(c);
                                }
                            }
                        }
                        // Score the probe answers: SignalOnly reduces to "first
                        // signal-clear candidate" (byte-identical to the
                        // pre-probe dispatch); the scored policies compare
                        // congestion among signal-clear candidates.
                        let placed = pick_candidate(
                            &candidates,
                            scenario.dispatch,
                            fleet.can_accept_slice(),
                            &hosts,
                            |_| true,
                        );
                        match placed {
                            Some(node) => {
                                report.jobs_accepted += 1;
                                let hi = score_hi(step);
                                if memo.spike_within(&mut source, node, step, hi, ready_threshold) {
                                    report.bad_accepts += 1;
                                } else {
                                    report.good_accepts += 1;
                                }
                                report.outcomes.push(JobOutcome::Accepted { node, at: step });
                                // Hand the job to the host: it starts, parks,
                                // or drops in the JobEnqueue handler.
                                queue.schedule(ev.time, Event::JobEnqueue { node, job_id });
                            }
                            None => {
                                report.jobs_rejected += 1;
                                if antagonist {
                                    report.antagonist_jobs_rejected += 1;
                                }
                                let hi = score_hi(step);
                                let justified = candidates.iter().any(|&c| {
                                    memo.spike_within(&mut source, c, step, hi, ready_threshold)
                                });
                                if justified {
                                    report.justified_rejections += 1;
                                }
                                report.outcomes.push(JobOutcome::Rejected { at: step });
                                jobs[job_id as usize].state = JobState::Rejected;
                            }
                        }
                    }

                    Event::JobEnqueue { node, job_id } => {
                        let rec = &mut jobs[job_id as usize];
                        if rec.state != JobState::Dispatching {
                            continue;
                        }
                        if !fleet.is_alive(node) {
                            // The target vanished between admission and
                            // hand-off (mass-churn interleavings make this
                            // reachable). The job used to be written off
                            // outright, stranding its migration budget —
                            // route it through the migrate path like any
                            // other displacement so the ledger treatment
                            // matches a post-placement departure.
                            if rec.migrations_left > 0 {
                                rec.migrations_left -= 1;
                                rec.state = JobState::Migrating;
                                queue.schedule(
                                    ev.time + 1,
                                    Event::JobMigrate { job_id, from: node },
                                );
                            } else {
                                rec.state = JobState::Displaced;
                                report.jobs_displaced += 1;
                            }
                            continue;
                        }
                        // Clamp to the placed host's budget: on heterogeneous
                        // fleets (or an unvalidated scenario with
                        // max_job_slots > slots_per_node) an oversized draw
                        // would otherwise park a job that can never start and,
                        // under FIFO, wedge the whole queue behind it for the
                        // rest of the run.
                        let demand = rec.demand.min(hosts.slots(node));
                        if hosts.queue_len(node) == 0 && hosts.can_start(node, demand) {
                            hosts.start(node, job_id, demand);
                            util.job_started(ev.time, demand);
                            rec.state = JobState::Running { node };
                            total_inflight += 1;
                            report.peak_inflight = report.peak_inflight.max(total_inflight);
                            queue.schedule(
                                ev.time,
                                Event::JobStart { node, job_id, gen: rec.gen },
                            );
                        } else if hosts.try_enqueue(node, job_id, demand, rec.priority, ev.time)
                        {
                            rec.state = JobState::Queued { node };
                            rec.enqueued_at = Some(ev.time);
                            report.jobs_queued += 1;
                            report.peak_queue_len =
                                report.peak_queue_len.max(hosts.queue_len(node));
                        } else {
                            rec.state = JobState::Dropped;
                            report.jobs_dropped += 1;
                        }
                    }

                    Event::JobStart { node, job_id, gen } => {
                        let rec = &mut jobs[job_id as usize];
                        if rec.gen != gen || rec.state != (JobState::Running { node }) {
                            continue;
                        }
                        if let Some(t0) = rec.enqueued_at.take() {
                            let waited = ev.time - t0;
                            qdelay_ticks_sum += waited;
                            qdelay_count += 1;
                            qdelay_p_sum[rec.priority as usize] += waited;
                            qdelay_p_count[rec.priority as usize] += 1;
                            hosts.note_queue_delay(node, waited);
                        }
                        queue.schedule(
                            ev.time + rec.duration_steps as u64 * TICKS_PER_STEP,
                            Event::JobCompletion { node, job_id, gen },
                        );
                    }

                    Event::JobCompletion { node, job_id, gen } => {
                        let rec = &mut jobs[job_id as usize];
                        if rec.gen != gen || rec.state != (JobState::Running { node }) {
                            continue;
                        }
                        let freed = hosts.finish(node, job_id).unwrap_or(0);
                        util.job_finished(ev.time, freed);
                        rec.state = JobState::Completed;
                        report.jobs_completed += 1;
                        if let Some(deadline) = rec.deadline {
                            if ev.time <= deadline {
                                report.slo_attained += 1;
                                if rec.antagonist {
                                    report.antagonist_slo_attained += 1;
                                }
                            }
                        }
                        total_inflight -= 1;
                        if let Some(c) = &cap {
                            let budget = if fleet.can_accept(node) {
                                hosts.slots(node)
                            } else {
                                c.contended_budget(hosts.slots(node))
                            };
                            drain_queue(
                                node,
                                budget,
                                &mut hosts,
                                &mut jobs,
                                &mut queue,
                                ev.time,
                                &mut total_inflight,
                                &mut util,
                                &mut report,
                            );
                        }
                    }

                    Event::JobPreempt { node, job_id, gen } => {
                        let rec = &mut jobs[job_id as usize];
                        if rec.gen != gen || rec.state != (JobState::Running { node }) {
                            continue; // completed or already displaced — stale
                        }
                        let freed = hosts.finish(node, job_id).unwrap_or(0);
                        util.job_finished(ev.time, freed);
                        rec.gen = rec.gen.wrapping_add(1);
                        total_inflight -= 1;
                        report.jobs_preempted += 1;
                        if rec.migrations_left > 0 {
                            rec.migrations_left -= 1;
                            rec.state = JobState::Migrating;
                            queue.schedule(ev.time + 1, Event::JobMigrate { job_id, from: node });
                        } else {
                            rec.state = JobState::Displaced;
                            report.jobs_displaced += 1;
                        }
                        // No queue drain here: the node is contended — the
                        // freed slots stay free until the signal clears (the
                        // telemetry tick drains) or a completion fires.
                    }

                    Event::JobMigrate { job_id, from } => {
                        let rec = &jobs[job_id as usize];
                        if rec.state != JobState::Migrating {
                            continue;
                        }
                        let demand = rec.demand;
                        // Probe a few distinct alive peers (excluding the node
                        // that shed the job) with the same bounded sampler as
                        // arrivals. Peer selection mirrors arrival dispatch: a
                        // peer is eligible when its admission signal is clear
                        // *and* it can hold the job (clamped to its own
                        // budget); SignalOnly takes the first such peer, the
                        // scored policies compare congestion.
                        sample_distinct(
                            &mut migrate_rng,
                            fleet.alive_ids(),
                            Some(from),
                            MIGRATION_PROBES,
                            &mut candidates,
                            &mut probe_scratch,
                        );
                        let target = pick_candidate(
                            &candidates,
                            scenario.dispatch,
                            fleet.can_accept_slice(),
                            &hosts,
                            |c| {
                                hosts.can_start(c, demand.min(hosts.slots(c)))
                                    || hosts.queue_has_room(c)
                            },
                        );
                        let rec = &mut jobs[job_id as usize];
                        match target {
                            Some(node) => {
                                rec.state = JobState::Dispatching;
                                report.jobs_migrated += 1;
                                queue.schedule(ev.time, Event::JobEnqueue { node, job_id });
                            }
                            None => {
                                rec.state = JobState::Displaced;
                                report.jobs_displaced += 1;
                            }
                        }
                    }

                    Event::FederationPush { leaf, snapshot, sent_at } => {
                        if let Some(snap) = pool.take(snapshot) {
                            // Deliveries accumulate across the tick batch
                            // and flush once through the sharded
                            // `push_from_leaves` fan-in after the batch —
                            // batches preserve pop order, so each leaf's
                            // iterates reach its level-0 group in the same
                            // order the per-event path applied them, and
                            // the derived upper levels land in the same
                            // final state.
                            if tree.is_some() {
                                fed_batch.push((leaf, snap));
                            }
                            // Instant models still pay the 1-tick scheduling
                            // floor; don't let that show up as latency.
                            if !fed.latency.is_instant() {
                                lat_ticks_sum += ev.time - sent_at;
                                lat_count += 1;
                            }
                        }
                    }

                    Event::NodeLeave { node } => {
                        if !fleet.is_alive(node) {
                            continue;
                        }
                        if let Some(churn) = &scenario.churn {
                            if fleet.alive_count() <= churn.min_alive {
                                continue; // floor reached since scheduling
                            }
                        }
                        // Rack outages carry their own hard floor: the
                        // hazard pre-checks it at scheduling time, but
                        // same-tick interleavings with the churn model
                        // could still overshoot — re-check at execution.
                        if let Some(f) = failures.filter(|f| f.rack_outages_enabled()) {
                            if fleet.alive_count() <= f.min_alive {
                                continue;
                            }
                        }
                        // The sorted alive list and its dense rank map are
                        // maintained incrementally (O(shift)) — same
                        // resulting order as the historical binary-search
                        // remove.
                        fleet.leave(node);
                        report.node_leaves += 1;
                        // Evacuate the host: running jobs are preempted and —
                        // with migration budget — re-offered to peers; the
                        // flushed wait queue gets the same treatment (minus
                        // the preemption count: those jobs never held slots).
                        let (running, queued) = hosts.evacuate(node);
                        util.node_left(ev.time, hosts.slots(node));
                        for (job_id, demand) in running {
                            util.job_finished(ev.time, demand);
                            let rec = &mut jobs[job_id as usize];
                            rec.gen = rec.gen.wrapping_add(1);
                            total_inflight -= 1;
                            if cap.is_some() {
                                report.jobs_preempted += 1;
                            }
                            if rec.migrations_left > 0 {
                                rec.migrations_left -= 1;
                                rec.state = JobState::Migrating;
                                queue.schedule(
                                    ev.time + 1,
                                    Event::JobMigrate { job_id, from: node },
                                );
                            } else {
                                rec.state = JobState::Displaced;
                                report.jobs_displaced += 1;
                            }
                        }
                        for qj in queued {
                            let rec = &mut jobs[qj.job_id as usize];
                            rec.gen = rec.gen.wrapping_add(1);
                            rec.enqueued_at = None;
                            if rec.migrations_left > 0 {
                                rec.migrations_left -= 1;
                                rec.state = JobState::Migrating;
                                queue.schedule(
                                    ev.time + 1,
                                    Event::JobMigrate { job_id: qj.job_id, from: node },
                                );
                            } else {
                                rec.state = JobState::Displaced;
                                report.jobs_displaced += 1;
                            }
                        }
                        if let Some(churn) = &scenario.churn {
                            if churn.rejoin_delay_mean > 0.0 {
                                let delay =
                                    churn_rng.exponential(1.0 / churn.rejoin_delay_mean);
                                queue.schedule(
                                    ev.time + latency_to_ticks(delay),
                                    Event::NodeJoin { node },
                                );
                            }
                        }
                    }

                    Event::NodeJoin { node } => {
                        if fleet.is_alive(node) {
                            continue;
                        }
                        // Sorted insert at the id's rank (same order the
                        // historical binary-search insert produced), rank
                        // map updated in the same pass.
                        fleet.join(node);
                        report.node_joins += 1;
                        util.node_joined(ev.time, hosts.slots(node));
                        // Rejoin bugfix: the pre-outage queue-delay EWMA
                        // and sample count describe a host that no longer
                        // exists — forget them so post-heal probes don't
                        // steer queue-aware dispatch on stale congestion.
                        hosts.reset_telemetry(node);
                        // A restarted machine comes back with empty local
                        // state…
                        if let Some(f) = &factory {
                            policies[node] = f(node);
                            // …so its first post-restart push must clear the
                            // ε gate even if the re-learned iterate resembles
                            // the pre-restart one.
                            if let Some(tree) = tree.as_mut() {
                                tree.reset_leaf_gate(node);
                            }
                        }
                        // …and (§5.2) seeds it by pulling the merged global
                        // view — possibly stale, which is the point.
                        if fed.pull_on_join {
                            if let Some(tree) = tree.as_ref() {
                                let global = tree.global_view();
                                if !global.is_empty() {
                                    policies[node].absorb(global, fed.pull_forget);
                                }
                            }
                        }
                        // Fresh nodes accept until their first telemetry tick
                        // says otherwise (cold PRONTO state raises no signal).
                        fleet.set_can_accept(node, true);
                    }

                    Event::PartitionStart { partition } => {
                        report.partition_events += 1;
                        // Counted, not flagged: overlapping cuts over the
                        // same leaf must all heal before it reconnects.
                        for &m in &partitions[partition] {
                            partitioned[m] += 1;
                        }
                    }

                    Event::PartitionHeal { partition } => {
                        for &m in &partitions[partition] {
                            partitioned[m] -= 1;
                        }
                        // Queued pushes from now-reconnected leaves replay
                        // *stale*: the original send-time snapshot delivers
                        // at heal time, which is exactly the §5.2
                        // stale-merge regime. Scan order preserves the
                        // queueing order, so replays merge FIFO per leaf.
                        let mut i = 0;
                        while i < partition_pending.len() {
                            let (leaf, snapshot, sent_at) = partition_pending[i];
                            if partitioned[leaf] == 0 {
                                partition_pending.remove(i);
                                report.federation_stale_replays += 1;
                                queue.schedule(
                                    ev.time,
                                    Event::FederationPush { leaf, snapshot, sent_at },
                                );
                            } else {
                                i += 1;
                            }
                        }
                    }
                }
            }

            // End-of-batch federation flush: this tick's deliveries merge
            // through the sharded fan-in on the engine pool. Group merges
            // run on disjoint aggregator state in batch order and the
            // upward reduction is a fixed fold, so the flush is
            // bit-identical at every `--threads` width; joins in the same
            // batch pull the pre-batch global view (also width-invariant).
            if !fed_batch.is_empty() {
                if let Some(tree) = tree.as_mut() {
                    let pending: Vec<(usize, &Subspace)> =
                        fed_batch.iter().map(|(leaf, snap)| (*leaf, snap)).collect();
                    tree.push_from_leaves(&pending, &workers);
                }
                fed_batch.clear();
            }
        }

        if let Some(tree) = &tree {
            report.federation_pushes = tree.pushes();
            report.federation_suppressed = tree.suppressed();
        }
        if lat_count > 0 {
            report.mean_push_latency_steps =
                lat_ticks_sum as f64 / lat_count as f64 / TICKS_PER_STEP as f64;
        }
        if qdelay_count > 0 {
            report.mean_queue_delay_steps =
                qdelay_ticks_sum as f64 / qdelay_count as f64 / TICKS_PER_STEP as f64;
        }
        if priority_levels > 1 {
            report.mean_queue_delay_by_priority = (0..priority_levels as usize)
                .map(|p| {
                    if qdelay_p_count[p] > 0 {
                        qdelay_p_sum[p] as f64
                            / qdelay_p_count[p] as f64
                            / TICKS_PER_STEP as f64
                    } else {
                        0.0
                    }
                })
                .collect();
        }
        // Close the utilization integral at the horizon (jobs still
        // running and capacity still online count up to the run's end).
        util.advance(horizon);
        report.mean_utilization = util.mean();
        // Close the ledger: everything not in a terminal state is still
        // waiting or running at the horizon.
        for rec in &jobs {
            match rec.state {
                JobState::Queued { .. } | JobState::Migrating | JobState::Dispatching => {
                    report.jobs_still_queued += 1;
                }
                JobState::Running { .. } => report.jobs_still_running += 1,
                _ => {}
            }
        }
        report.signal_capture = capture;
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::{
        NodeScheduler, ProntoPolicy, QueuePolicy, RandomPolicy, RejectConfig,
    };
    use crate::sim::scenario::ChurnModel;
    use crate::telemetry::{GeneratorConfig, TraceGenerator};

    fn traces(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
        let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
        (0..n).map(|v| gen.generate_vm_in_cluster(0, v, steps)).collect()
    }

    fn pronto_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
        tr.iter()
            .map(|t| {
                Box::new(ProntoPolicy::new(NodeScheduler::new(
                    t.dim(),
                    RejectConfig::default(),
                ))) as Box<dyn Admission>
            })
            .collect()
    }

    fn always_policies(tr: &[VmTrace]) -> Vec<Box<dyn Admission>> {
        tr.iter()
            .enumerate()
            .map(|(i, _)| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
            .collect()
    }

    fn assert_ledger(report: &SimReport) {
        assert_eq!(
            report.jobs_arrived,
            report.jobs_rejected
                + report.jobs_completed
                + report.jobs_dropped
                + report.jobs_displaced
                + report.jobs_still_queued
                + report.jobs_still_running,
            "job ledger leaked"
        );
    }

    #[test]
    fn conservation_invariants_hold() {
        let tr = traces(4, 800, 1);
        let pol = pronto_policies(&tr);
        let sc = Scenario::default().with_steps(800).with_seed(7);
        let report = DiscreteEventEngine::new(sc, tr, pol).run();
        assert_eq!(report.jobs_arrived, report.jobs_accepted + report.jobs_rejected);
        assert_eq!(report.jobs_accepted, report.good_accepts + report.bad_accepts);
        assert_eq!(report.outcomes.len(), report.jobs_arrived);
        assert!(report.jobs_completed + report.jobs_displaced <= report.jobs_accepted);
        assert_ledger(&report);
    }

    #[test]
    fn same_seed_bitwise_identical_reports() {
        for name in ["baseline-poisson", "bursty"] {
            let sc = Scenario::named(name).unwrap().with_nodes(4).with_steps(600);
            let tr = traces(4, 600, 3);
            let a = DiscreteEventEngine::new(sc.clone(), tr.clone(), always_policies(&tr)).run();
            let b = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
            assert_eq!(a.to_json_string(), b.to_json_string(), "{name} diverged");
            assert_eq!(a.outcomes, b.outcomes);
        }
    }

    #[test]
    fn signal_capture_has_full_shape_and_leaves_report_bytes_alone() {
        let sc = Scenario::default().with_nodes(3).with_steps(400).with_seed(11);
        let tr = traces(3, 400, 11);
        let plain =
            DiscreteEventEngine::new(sc.clone(), tr.clone(), pronto_policies(&tr)).run();
        assert!(plain.signal_capture.is_none(), "capture must be opt-in");
        let captured = DiscreteEventEngine::new(sc, tr.clone(), pronto_policies(&tr))
            .with_signal_capture()
            .run();
        // Capture changes nothing observable in the serialized report.
        assert_eq!(plain.to_json_string(), captured.to_json_string());
        let capt = captured.signal_capture.expect("capture requested");
        assert_eq!(capt.raised.len(), 3);
        assert_eq!(capt.spikes.len(), 3);
        for node in 0..3 {
            assert_eq!(capt.raised[node].len(), 400);
            assert_eq!(capt.spikes[node].len(), 400);
        }
        // Calibrated traces must contain ground-truth spikes somewhere.
        assert!(capt.spikes.iter().flatten().any(|&s| s));
    }

    #[test]
    fn different_seeds_diverge() {
        let tr = traces(4, 600, 3);
        let a = DiscreteEventEngine::new(
            Scenario::default().with_steps(600).with_seed(1),
            tr.clone(),
            always_policies(&tr),
        )
        .run();
        let b = DiscreteEventEngine::new(
            Scenario::default().with_steps(600).with_seed(2),
            tr.clone(),
            always_policies(&tr),
        )
        .run();
        assert_ne!(a.outcomes_digest(), b.outcomes_digest());
    }

    #[test]
    fn churn_fires_and_pool_recovers() {
        let sc = Scenario {
            churn: Some(ChurnModel {
                leave_hazard: 0.01,
                rejoin_delay_mean: 30.0,
                min_alive: 2,
            }),
            ..Scenario::named("churn").unwrap()
        }
        .with_nodes(6)
        .with_steps(1000);
        let tr = traces(6, 1000, 5);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert!(report.node_leaves > 0, "no churn happened");
        assert!(report.node_joins > 0, "nobody rejoined");
        assert!(report.node_joins <= report.node_leaves);
        assert_eq!(report.jobs_arrived, report.jobs_accepted + report.jobs_rejected);
        assert_ledger(&report);
    }

    #[test]
    fn federation_latency_pushes_are_counted_and_delayed() {
        let sc = Scenario::named("latency").unwrap().with_nodes(4).with_steps(800);
        let tr = traces(4, 800, 9);
        let report = DiscreteEventEngine::new(sc, tr.clone(), pronto_policies(&tr)).run();
        let total = report.federation_pushes + report.federation_suppressed;
        assert!(total > 0, "no pushes offered");
        assert!(report.mean_push_latency_steps > 0.5, "latency not applied");
    }

    #[test]
    fn min_alive_floor_is_respected() {
        let sc = Scenario {
            churn: Some(ChurnModel {
                leave_hazard: 0.5, // drain aggressively
                rejoin_delay_mean: 0.0, // never rejoin
                min_alive: 3,
            }),
            ..Scenario::default()
        }
        .with_nodes(5)
        .with_steps(400);
        let tr = traces(5, 400, 11);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert_eq!(report.node_leaves, 2, "floor violated: {}", report.node_leaves);
        assert_eq!(report.node_joins, 0);
    }

    #[test]
    fn json_report_is_valid_and_roundtrips() {
        let tr = traces(3, 300, 13);
        let sc = Scenario::default().with_steps(300);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        let text = report.to_json_string();
        let parsed = crate::ser::parse_json(&text).expect("valid json");
        assert_eq!(
            parsed.get("jobs_arrived").and_then(JsonValue::as_usize),
            Some(report.jobs_arrived)
        );
        assert_eq!(
            parsed.get("jobs_preempted").and_then(JsonValue::as_usize),
            Some(report.jobs_preempted)
        );
        assert_eq!(
            parsed.get("scenario").and_then(JsonValue::as_str),
            Some("baseline-poisson")
        );
    }

    #[test]
    fn capacity_queues_and_drops_under_overload() {
        // 6 nodes × 2 slots vs ~36 slot-steps/step of offered load: the
        // bounded queues must fill, delay jobs, and drop the excess.
        let sc = Scenario::named("capacity").unwrap().with_nodes(6).with_steps(1200);
        let tr = traces(6, 1200, 21);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert!(report.jobs_queued > 0, "nothing ever queued");
        assert!(report.peak_queue_len > 0);
        assert!(report.mean_queue_delay_steps > 0.0, "zero queueing delay");
        assert!(report.jobs_dropped > 0, "bounded queue never dropped");
        assert!(report.mean_utilization > 0.5, "overloaded cluster mostly idle?");
        assert!(report.mean_utilization <= 1.0 + 1e-12);
        assert_ledger(&report);
    }

    #[test]
    fn departing_node_preempts_and_migrates_jobs() {
        let sc = Scenario {
            capacity: Some(CapacityModel {
                slots_per_node: 4,
                contended_slots: 4, // leave-driven preemption only
                queue_capacity: 8,
                max_job_slots: 1,
                queue_policy: QueuePolicy::Fifo,
                migration_limit: 2,
                ..CapacityModel::default()
            }),
            churn: Some(ChurnModel {
                leave_hazard: 0.004,
                rejoin_delay_mean: 60.0,
                min_alive: 2,
            }),
            arrivals: ArrivalPattern::Poisson { rate: 0.8 },
            ..Scenario::default()
        }
        .with_nodes(6)
        .with_steps(1500);
        let tr = traces(6, 1500, 33);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert!(report.node_leaves > 0, "churn never fired");
        assert!(report.jobs_preempted > 0, "departures preempted nothing");
        assert!(report.jobs_migrated > 0, "no displaced job found a peer");
        assert_ledger(&report);
    }

    #[test]
    fn pressure_preemption_sheds_contended_nodes() {
        // Random policies raise the signal ~30% of ticks; a full node
        // (4 used) over the contended budget (1) must shed jobs.
        let sc = Scenario {
            capacity: Some(CapacityModel {
                slots_per_node: 4,
                contended_slots: 1,
                queue_capacity: 4,
                max_job_slots: 1,
                queue_policy: QueuePolicy::Fifo,
                migration_limit: 1,
                ..CapacityModel::default()
            }),
            arrivals: ArrivalPattern::Poisson { rate: 1.0 },
            ..Scenario::default()
        }
        .with_nodes(4)
        .with_steps(800);
        let tr = traces(4, 800, 41);
        let pol: Vec<Box<dyn Admission>> = tr
            .iter()
            .enumerate()
            .map(|(i, _)| Box::new(RandomPolicy::new(0.3, i as u64)) as Box<dyn Admission>)
            .collect();
        let report = DiscreteEventEngine::new(sc, tr, pol).run();
        assert!(report.jobs_preempted > 0, "pressure preemption never fired");
        assert_ledger(&report);
    }

    #[test]
    fn try_new_returns_typed_errors_instead_of_panicking() {
        fn expect_err(
            r: Result<DiscreteEventEngine, EngineError>,
        ) -> EngineError {
            match r {
                Ok(_) => panic!("malformed fleet must not construct"),
                Err(e) => e,
            }
        }
        // Empty fleet (the empty `--replay` directory shape).
        let sc = Scenario::default();
        let err = expect_err(DiscreteEventEngine::try_new(sc.clone(), Vec::new(), Vec::new()));
        assert_eq!(err, EngineError::EmptyFleet);
        assert!(err.to_string().contains("empty"));

        // Policy count mismatch.
        let tr = traces(2, 100, 1);
        let err = expect_err(DiscreteEventEngine::try_new(
            sc.clone(),
            tr.clone(),
            always_policies(&tr[..1]),
        ));
        assert_eq!(err, EngineError::PolicyCountMismatch { traces: 2, policies: 1 });

        // A zero-length trace (header-only CSV) is caught per node.
        let mut tr = traces(2, 100, 1);
        tr[1] = tr[1].slice(0, 0);
        let pol = always_policies(&tr);
        let err = expect_err(DiscreteEventEngine::try_new(sc, tr, pol));
        assert_eq!(err, EngineError::EmptyTrace { node: 1 });
    }

    #[test]
    fn oversized_demand_is_clamped_not_deadlocked() {
        // Regression: a scenario with max_job_slots > slots_per_node
        // (reachable by constructing the scenario in code, bypassing TOML
        // validation) drew jobs that could never start; under FIFO the
        // first such job wedged the queue head for the rest of the run.
        // The hand-off clamp caps demand at the host budget instead.
        let sc = Scenario {
            capacity: Some(CapacityModel {
                slots_per_node: 2,
                contended_slots: 2,
                queue_capacity: 8,
                max_job_slots: 4, // > slots_per_node: every host too small
                queue_policy: QueuePolicy::Fifo,
                migration_limit: 0,
                ..CapacityModel::default()
            }),
            arrivals: ArrivalPattern::Poisson { rate: 0.1 },
            duration_mu: 1.0,
            duration_sigma: 0.3,
            ..Scenario::default()
        }
        .with_nodes(4)
        .with_steps(2_000);
        let tr = traces(4, 2_000, 61);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert!(report.jobs_arrived > 50, "load too thin to mean anything");
        // Before the clamp the first oversized job froze its queue:
        // almost nothing completed and the backlog never drained.
        assert!(
            report.jobs_completed * 2 > report.jobs_arrived,
            "queues wedged: {} of {} completed",
            report.jobs_completed,
            report.jobs_arrived
        );
        assert_ledger(&report);
    }

    #[test]
    fn hetero_fleet_draws_distinct_budgets_and_runs_clean() {
        let sc = Scenario::named("hetero").unwrap().with_nodes(12).with_steps(1_200);
        let tr = traces(12, 1_200, 71);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert!(report.jobs_completed > 0);
        assert!(report.mean_utilization > 0.0 && report.mean_utilization <= 1.0);
        assert_ledger(&report);
    }

    #[test]
    fn priority_scenario_scores_slo_and_per_class_delay() {
        let sc = Scenario::named("priority").unwrap().with_nodes(6).with_steps(1_500);
        let tr = traces(6, 1_500, 81);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert_eq!(report.slo_total, report.jobs_arrived);
        assert!(report.slo_attained > 0, "nothing ever met its deadline");
        assert!(report.slo_attained <= report.slo_total);
        assert_eq!(report.mean_queue_delay_by_priority.len(), 3);
        // The JSON gains the SLO/priority keys only when active.
        let text = report.to_json_string();
        assert!(text.contains("\"slo_attainment\""));
        assert!(text.contains("\"queue_delay_p2\""));
        let legacy = {
            let sc = Scenario::named("capacity").unwrap().with_nodes(4).with_steps(300);
            let tr = traces(4, 300, 82);
            DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr))
                .run()
                .to_json_string()
        };
        assert!(!legacy.contains("slo_"), "legacy report grew SLO keys");
        assert!(!legacy.contains("queue_delay_p"), "legacy report grew priority keys");
        assert_ledger(&report);
    }

    #[test]
    fn sample_distinct_is_bounded_complete_and_sparse_compatible() {
        let pool: Vec<usize> = (0..64).collect();
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut out = Vec::new();
        let mut scratch = SampleScratch::default();

        // Dense draw (want == pool): the historical rejection loop would
        // coupon-collect ~300 draws; the bounded sampler finishes via the
        // Fisher–Yates fallback and still returns a full permutation.
        sample_distinct(&mut rng, &pool, None, 64, &mut out, &mut scratch);
        assert_eq!(out.len(), 64);
        let mut sorted = out.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted, pool, "dense draw is not a permutation");

        // Exclusion caps the reachable set and never appears.
        sample_distinct(&mut rng, &pool, Some(7), 64, &mut out, &mut scratch);
        assert_eq!(out.len(), 63);
        assert!(!out.contains(&7));

        // Fully-excluded pools return empty without consuming randomness.
        let mut before = rng.clone();
        sample_distinct(&mut rng, &[3], Some(3), 2, &mut out, &mut scratch);
        assert!(out.is_empty());
        assert_eq!(rng.next_u64(), before.next_u64(), "empty draw consumed RNG");

        // Sparse draws reproduce the historical rejection-loop sequence
        // exactly (catalog byte-stability depends on this).
        let mut a = Xoshiro256::seed_from_u64(9);
        let mut b = Xoshiro256::seed_from_u64(9);
        for _ in 0..200 {
            sample_distinct(&mut a, &pool, None, 2, &mut out, &mut scratch);
            let mut legacy: Vec<usize> = Vec::new();
            while legacy.len() < 2 {
                let c = pool[b.gen_range(64)];
                if !legacy.contains(&c) {
                    legacy.push(c);
                }
            }
            assert_eq!(out, legacy);
        }
        assert_eq!(a.next_u64(), b.next_u64(), "rng positions diverged");
    }

    #[test]
    fn round_robin_cycles_in_identity_order_without_churn() {
        let sc = Scenario {
            probe: ProbePolicy::RoundRobin,
            arrivals: ArrivalPattern::Poisson { rate: 0.5 },
            ..Scenario::default()
        }
        .with_nodes(4)
        .with_steps(800);
        let tr = traces(4, 800, 91);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        // always-accept + round-robin: placements walk node ids cyclically.
        let placed: Vec<usize> = report
            .outcomes
            .iter()
            .filter_map(|o| match o {
                JobOutcome::Accepted { node, .. } => Some(*node),
                _ => None,
            })
            .collect();
        assert!(placed.len() > 100, "load too thin: {}", placed.len());
        for w in placed.windows(2) {
            assert_eq!(w[1], (w[0] + 1) % 4, "cursor skipped or re-aliased");
        }
    }

    #[test]
    fn round_robin_under_churn_is_deterministic_and_starves_nobody() {
        // Regression for the index-aliased cursor: `cursor % alive_count`
        // re-aliased every later probe after a leave/join and could park
        // the rotation away from surviving hosts. The identity cursor
        // keeps rotating over whoever is alive.
        let sc = Scenario {
            probe: ProbePolicy::RoundRobin,
            arrivals: ArrivalPattern::Poisson { rate: 0.8 },
            churn: Some(ChurnModel {
                leave_hazard: 0.003,
                rejoin_delay_mean: 60.0,
                min_alive: 3,
            }),
            ..Scenario::default()
        }
        .with_nodes(6)
        .with_steps(2_000);
        let tr = traces(6, 2_000, 93);
        let a = DiscreteEventEngine::new(sc.clone(), tr.clone(), always_policies(&tr)).run();
        let b = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert_eq!(
            a.to_json_string(),
            b.to_json_string(),
            "round-robin under churn not reproducible"
        );
        assert!(a.node_leaves > 0, "churn never fired");
        let mut seen = [false; 6];
        for o in &a.outcomes {
            if let JobOutcome::Accepted { node, .. } = o {
                seen[*node] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "a host was starved: {seen:?}");
        assert_ledger(&a);
    }

    #[test]
    fn streaming_source_runs_and_validates() {
        let gen = TraceGenerator::new(GeneratorConfig::default(), 11);
        let members: Vec<(usize, usize)> = (0..4).map(|v| (0, v)).collect();
        let sc = Scenario::default().with_nodes(4).with_steps(400);
        let source = TraceSource::streaming(&gen, &members, 400, sc.score_window);
        let pol: Vec<Box<dyn Admission>> = (0..4)
            .map(|i| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
            .collect();
        let report = DiscreteEventEngine::try_from_source(sc.clone(), source, pol)
            .unwrap()
            .run();
        assert!(report.jobs_arrived > 0);
        assert!(report.events_processed > 400, "ticks alone exceed this");
        assert_ledger(&report);

        // Empty streaming fleets and undersized windows are typed errors.
        let empty = TraceSource::streaming(&gen, &[], 100, 5);
        assert_eq!(
            DiscreteEventEngine::try_from_source(Scenario::default(), empty, Vec::new())
                .err(),
            Some(EngineError::EmptyFleet)
        );
        let narrow = TraceSource::streaming(&gen, &members, 400, sc.score_window - 1);
        let pol: Vec<Box<dyn Admission>> = (0..4)
            .map(|i| Box::new(RandomPolicy::always_accept(i as u64)) as Box<dyn Admission>)
            .collect();
        match DiscreteEventEngine::try_from_source(sc, narrow, pol).err() {
            Some(EngineError::WindowTooSmall { window, need }) => {
                assert!(window < need);
            }
            other => panic!("undersized window must be typed, got {other:?}"),
        }
    }

    #[test]
    fn parallel_observe_is_byte_identical_to_sequential() {
        // The quick in-crate parity check (the integration suite sweeps
        // the full catalog): sequential and sharded observe loops must
        // produce byte-identical reports with stateful FPCA policies.
        for name in ["baseline-poisson", "capacity", "churn"] {
            let sc = Scenario::named(name).unwrap().with_nodes(6).with_steps(400);
            let tr = traces(6, 400, 17);
            let base = DiscreteEventEngine::new(
                sc.clone().with_threads(1),
                tr.clone(),
                pronto_policies(&tr),
            )
            .run();
            for threads in [2, 3, 7] {
                let par = DiscreteEventEngine::new(
                    sc.clone().with_threads(threads),
                    tr.clone(),
                    pronto_policies(&tr),
                )
                .run();
                assert_eq!(
                    base.to_json_string(),
                    par.to_json_string(),
                    "{name} diverged at {threads} threads"
                );
            }
        }
    }

    #[test]
    fn same_tick_arrival_storms_batch_without_leaking_the_ledger() {
        // > TICKS_PER_STEP − 2 arrivals per step forces genuinely
        // same-timestamp arrival events (the per-arrival scheduling
        // offset clamps at the step boundary), so the TickBatch path
        // sees arrival/enqueue/start/completion/churn collisions at one
        // tick. The ledger must balance and the report must stay
        // byte-identical across runs and thread widths.
        use crate::sim::scenario::ReplaySchedule;
        let counts: Vec<u32> = (0..12).map(|t| if t % 4 == 0 { 1_200 } else { 0 }).collect();
        let sc = Scenario {
            arrivals: ArrivalPattern::Replay {
                schedule: std::sync::Arc::new(ReplaySchedule::from_counts(counts, "storm")),
            },
            capacity: Some(CapacityModel {
                slots_per_node: 2,
                contended_slots: 2,
                queue_capacity: 4,
                max_job_slots: 1,
                queue_policy: QueuePolicy::Fifo,
                migration_limit: 1,
                ..CapacityModel::default()
            }),
            churn: Some(ChurnModel {
                leave_hazard: 0.05,
                rejoin_delay_mean: 2.0,
                min_alive: 2,
            }),
            duration_mu: 0.5,
            duration_sigma: 0.2,
            ..Scenario::default()
        }
        .with_nodes(6)
        .with_steps(12);
        let tr = traces(6, 12, 3);
        let run = |threads: usize| {
            DiscreteEventEngine::new(
                sc.clone().with_threads(threads),
                tr.clone(),
                always_policies(&tr),
            )
            .run()
        };
        let a = run(1);
        let b = run(1);
        let c = run(4);
        assert_eq!(a.to_json_string(), b.to_json_string(), "storm not reproducible");
        assert_eq!(a.to_json_string(), c.to_json_string(), "threads changed bytes");
        assert!(a.jobs_arrived >= 3_600, "storm too thin: {}", a.jobs_arrived);
        assert!(a.jobs_dropped > 0, "storm never overflowed the bounded queues");
        assert_ledger(&a);
    }

    #[test]
    fn rack_outages_fire_rejoin_and_conserve_the_ledger() {
        let sc = Scenario::named("rack-outage").unwrap().with_steps(1_500);
        let tr = traces(24, 1_500, 101);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert!(report.rack_outages > 0, "no rack ever failed");
        assert!(report.node_leaves > 0, "outages scheduled no departures");
        assert!(report.node_joins > 0, "no rack ever came back");
        let text = report.to_json_string();
        assert!(text.contains("\"rack_outages\""));
        assert!(text.contains("\"federation_stale_replays\""));
        assert_ledger(&report);
    }

    #[test]
    fn mass_rack_churn_storms_conserve_the_ledger_exactly() {
        // Whole racks die under same-tick arrival storms: every
        // JobEnqueue/NodeLeave interleaving must keep the ledger exact
        // and the report byte-identical across observe-pool widths.
        use crate::sim::scenario::{FailureModel, ReplaySchedule};
        let counts: Vec<u32> = (0..40).map(|t| if t % 5 == 0 { 400 } else { 0 }).collect();
        let sc = Scenario {
            arrivals: ArrivalPattern::Replay {
                schedule: std::sync::Arc::new(ReplaySchedule::from_counts(
                    counts,
                    "rack-storm",
                )),
            },
            capacity: Some(CapacityModel {
                slots_per_node: 2,
                contended_slots: 2,
                queue_capacity: 4,
                max_job_slots: 1,
                queue_policy: QueuePolicy::Fifo,
                migration_limit: 1,
                ..CapacityModel::default()
            }),
            failures: Some(FailureModel {
                rack_size: 3,
                rack_outage_hazard: 0.2,
                rack_outage_duration_mean: 3.0,
                min_alive: 3,
                ..FailureModel::default()
            }),
            duration_mu: 0.5,
            duration_sigma: 0.2,
            ..Scenario::default()
        }
        .with_nodes(12)
        .with_steps(40);
        let tr = traces(12, 40, 7);
        let run = |threads: usize| {
            DiscreteEventEngine::new(
                sc.clone().with_threads(threads),
                tr.clone(),
                always_policies(&tr),
            )
            .run()
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(
            a.to_json_string(),
            b.to_json_string(),
            "rack storm changed bytes across widths"
        );
        assert!(a.rack_outages > 2, "storm hazard barely fired: {}", a.rack_outages);
        assert!(a.node_joins > 0, "racks never rejoined");
        assert!(a.jobs_arrived >= 3_000, "storm too thin: {}", a.jobs_arrived);
        assert_ledger(&a);
    }

    #[test]
    fn partitions_queue_and_replay_stale_pushes() {
        let sc = Scenario::named("partition").unwrap().with_nodes(12).with_steps(2_000);
        let tr = traces(12, 2_000, 103);
        let report = DiscreteEventEngine::new(sc, tr.clone(), pronto_policies(&tr)).run();
        assert!(report.partition_events > 0, "no partition ever opened");
        assert!(
            report.federation_stale_replays > 0,
            "no queued push ever replayed stale"
        );
        assert_eq!(
            report.federation_partition_drops, 0,
            "queue mode must not drop pushes"
        );
        let text = report.to_json_string();
        assert!(text.contains("\"partition_events\""));
        assert_ledger(&report);
    }

    #[test]
    fn stragglers_slow_their_pushes_measurably() {
        use crate::federation::LatencyModel;
        use crate::sim::scenario::{FailureModel, FederationSpec};
        // Constant base latency isolates the multiplier: healthy nodes
        // deliver at 2 steps, the straggler fifth at 16 — the observed
        // mean must sit strictly above the healthy constant.
        let sc = Scenario {
            federation: FederationSpec {
                enabled: true,
                latency: LatencyModel::Constant { steps: 2.0 },
                ..Default::default()
            },
            failures: Some(FailureModel {
                straggler_fraction: 0.2,
                straggler_delay_multiplier: 8.0,
                straggler_observe_lag: 2,
                ..FailureModel::default()
            }),
            ..Scenario::default()
        }
        .with_nodes(10)
        .with_steps(1_000);
        let tr = traces(10, 1_000, 107);
        let report =
            DiscreteEventEngine::new(sc.clone(), tr.clone(), pronto_policies(&tr)).run();
        let total = report.federation_pushes + report.federation_suppressed;
        assert!(total > 0, "no pushes offered");
        assert!(
            report.mean_push_latency_steps > 2.1,
            "straggler multiplier had no effect: mean {}",
            report.mean_push_latency_steps
        );
        assert_ledger(&report);
    }

    #[test]
    fn antagonist_tenant_reports_per_tenant_breakdown() {
        let sc = Scenario::named("antagonist").unwrap().with_nodes(6).with_steps(1_200);
        let tr = traces(6, 1_200, 105);
        let report = DiscreteEventEngine::new(sc.clone(), tr.clone(), always_policies(&tr)).run();
        assert!(report.antagonist_jobs_arrived > 0, "antagonist never showed up");
        assert!(report.antagonist_jobs_arrived < report.jobs_arrived);
        assert!(report.antagonist_slo_total > 0);
        assert!(report.antagonist_slo_total <= report.slo_total);
        assert!(report.antagonist_jobs_rejected <= report.jobs_rejected);
        assert!(report.antagonist_slo_attained <= report.slo_attained);
        let text = report.to_json_string();
        assert!(text.contains("\"antagonist_slo_attainment\""));
        assert!(text.contains("\"primary_jobs_rejected\""));
        assert_ledger(&report);

        // Enabling the tenant must not shift the primary workload: the
        // same seed without the failure layer draws the same primary
        // arrival sequence.
        let plain = DiscreteEventEngine::new(
            Scenario { failures: None, ..sc },
            tr.clone(),
            always_policies(&tr),
        )
        .run();
        assert_eq!(
            report.jobs_arrived - report.antagonist_jobs_arrived,
            plain.jobs_arrived,
            "antagonist stream shifted the primary arrivals"
        );
        assert!(!plain.to_json_string().contains("antagonist_"));
    }

    #[test]
    fn spike_memo_agrees_with_direct_scans() {
        let tr = traces(3, 60, 5);
        let mut direct = TraceSource::materialized(tr.clone());
        let mut memo_src = TraceSource::materialized(tr);
        let mut memo = SpikeMemo::new(3);
        for step in (0..50).chain(10..20) {
            let hi = (step + 5).min(59);
            for node in 0..3 {
                // Repeated queries (same node+step twice) hit the memo.
                let want = direct.spike_within(node, step, hi, 400.0);
                assert_eq!(
                    memo.spike_within(&mut memo_src, node, step, hi, 400.0),
                    want
                );
                assert_eq!(
                    memo.spike_within(&mut memo_src, node, step, hi, 400.0),
                    want,
                    "memoized re-read diverged at node {node} step {step}"
                );
            }
        }
    }

    #[test]
    fn capacity_off_keeps_legacy_behaviour() {
        // Without a capacity model nothing queues, drops, or preempts —
        // the admission-only semantics of the original engine.
        let tr = traces(4, 1000, 51);
        let sc = Scenario::default().with_nodes(4).with_steps(1000);
        let report = DiscreteEventEngine::new(sc, tr.clone(), always_policies(&tr)).run();
        assert_eq!(report.jobs_queued, 0);
        assert_eq!(report.jobs_dropped, 0);
        assert_eq!(report.jobs_preempted, 0);
        assert_eq!(report.jobs_migrated, 0);
        assert_eq!(report.jobs_still_queued, 0);
        assert_eq!(report.mean_utilization, 0.0);
        assert_ledger(&report);
    }
}
