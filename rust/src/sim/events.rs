//! Typed simulation events and the deterministic event queue.
//!
//! The queue is a binary min-heap ordered by `(time, seq)`: `time` is a
//! fixed-point tick count ([`TICKS_PER_STEP`] ticks per 20 s telemetry
//! step, so sub-step latencies order correctly without floating-point
//! comparisons) and `seq` is a monotone insertion counter that breaks ties
//! deterministically — two runs that schedule the same events in the same
//! order pop them in the same order, which is what makes reports
//! bit-reproducible. Event payloads are small `Copy` data; anything large
//! (federation subspace snapshots) lives in a pooled slab on the engine
//! side and is referenced here by index, keeping the hot loop free of
//! per-event allocation.

use crate::scheduler::JobId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Simulation clock: integer ticks.
pub type SimTime = u64;

/// Ticks per telemetry step (20 s of simulated wall time).
pub const TICKS_PER_STEP: u64 = 1_000;

/// Convert a step index to its tick timestamp.
#[inline]
pub fn step_to_ticks(step: usize) -> SimTime {
    step as u64 * TICKS_PER_STEP
}

/// Convert a tick timestamp to the telemetry step it falls in.
#[inline]
pub fn ticks_to_step(t: SimTime) -> usize {
    (t / TICKS_PER_STEP) as usize
}

/// Convert a latency in (possibly fractional) steps to whole ticks,
/// always at least one tick so a delayed event never ties its cause.
#[inline]
pub fn latency_to_ticks(steps: f64) -> u64 {
    ((steps.max(0.0) * TICKS_PER_STEP as f64).round() as u64).max(1)
}

/// Everything that can happen in the cluster.
///
/// Job lifecycle events carry `gen` — the job's *placement generation*,
/// bumped every time the job is displaced or re-placed. A handler ignores
/// an event whose generation no longer matches the job's, which makes
/// stale events (a completion for a job that was preempted in between, a
/// preemption for a job that already finished) safe no-ops instead of
/// double bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// All alive nodes consume their telemetry vector for `step`.
    TelemetryTick { step: usize },
    /// A job arrives at the dispatcher (demand/duration live in the
    /// engine's job table).
    JobArrival { job_id: JobId },
    /// A job admitted by `node` is handed to the host: it either starts,
    /// parks in the bounded wait queue, or is dropped when the queue is
    /// full.
    JobEnqueue { node: usize, job_id: JobId },
    /// A job begins service on `node` (slots were reserved when the start
    /// was scheduled).
    JobStart { node: usize, job_id: JobId, gen: u32 },
    /// A previously started job finishes on `node`.
    JobCompletion { node: usize, job_id: JobId, gen: u32 },
    /// An over-committed node sheds a running job (pressure preemption:
    /// the rejection signal is raised and usage exceeds the contended
    /// budget).
    JobPreempt { node: usize, job_id: JobId, gen: u32 },
    /// A displaced job is re-offered to peers; `from` (the node that shed
    /// it) is excluded from the probe.
    JobMigrate { job_id: JobId, from: usize },
    /// A leaf's iterate snapshot (pooled at `snapshot`) reaches its
    /// aggregator after the configured push latency.
    FederationPush { leaf: usize, snapshot: usize, sent_at: SimTime },
    /// A node joins (or rejoins) the pool.
    NodeJoin { node: usize },
    /// A node leaves the pool; its in-flight jobs are displaced.
    NodeLeave { node: usize },
}

/// An event bound to a point on the simulation clock.
#[derive(Debug, Clone, Copy)]
pub struct Scheduled {
    pub time: SimTime,
    seq: u64,
    pub event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    /// Reverse ordering so `BinaryHeap` (a max-heap) pops the earliest
    /// `(time, seq)` first.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    next_seq: u64,
    scheduled_total: usize,
}

impl EventQueue {
    /// Queue with pre-reserved capacity (the engine sizes this from the
    /// scenario so steady-state operation never reallocates).
    pub fn with_capacity(cap: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(cap), next_seq: 0, scheduled_total: 0 }
    }

    /// Schedule `event` at `time`. Events at equal times fire in
    /// scheduling order (FIFO) — the insertion counter breaks the tie.
    pub fn schedule(&mut self, time: SimTime, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.scheduled_total += 1;
        self.heap.push(Scheduled { time, seq, event });
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop()
    }

    /// Timestamp of the earliest pending event.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|s| s.time)
    }

    /// Drain **every** event sharing the earliest timestamp into `batch`
    /// (clearing it first), in exactly the order [`EventQueue::pop`]
    /// would have produced. Returns `false` when the queue is empty.
    ///
    /// Events scheduled *while a batch is being processed* — even at the
    /// batch's own timestamp — carry higher sequence numbers, so they
    /// land in a later batch, exactly where per-event popping would have
    /// put them. Concatenating drained batches therefore reproduces the
    /// per-event pop order byte-for-byte; the batch only gives the
    /// engine a same-tick view to hoist per-tick work out of per-event
    /// handlers.
    pub fn drain_tick(&mut self, batch: &mut TickBatch) -> bool {
        batch.events.clear();
        let Some(first) = self.heap.pop() else {
            batch.time = 0;
            return false;
        };
        batch.time = first.time;
        batch.events.push(first);
        while let Some(next) = self.heap.peek() {
            if next.time != batch.time {
                break;
            }
            batch.events.push(self.heap.pop().expect("peeked event present"));
        }
        true
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events scheduled over the queue's lifetime.
    pub fn scheduled_total(&self) -> usize {
        self.scheduled_total
    }
}

/// All events sharing one simulation timestamp, in `(time, seq)` pop
/// order — the unit the engine's event loop now dispatches. Reused
/// across ticks (the backing `Vec` is cleared, not reallocated), so
/// steady-state batching stays allocation-free.
#[derive(Debug, Default)]
pub struct TickBatch {
    time: SimTime,
    events: Vec<Scheduled>,
}

impl TickBatch {
    /// The shared timestamp (meaningless while empty).
    pub fn time(&self) -> SimTime {
        self.time
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The batch's events in pop order.
    pub fn events(&self) -> &[Scheduled] {
        &self.events
    }

    /// Job ids of the arrivals in this batch, in pop order.
    pub fn arrivals(&self) -> impl Iterator<Item = crate::scheduler::JobId> + '_ {
        self.events.iter().filter_map(|s| match s.event {
            Event::JobArrival { job_id } => Some(job_id),
            _ => None,
        })
    }

    /// Completions in this batch as `(node, job_id)`, in pop order.
    pub fn completions(&self) -> impl Iterator<Item = (usize, crate::scheduler::JobId)> + '_ {
        self.events.iter().filter_map(|s| match s.event {
            Event::JobCompletion { node, job_id, .. } => Some((node, job_id)),
            _ => None,
        })
    }

    /// Churn events in this batch as `(node, is_join)`, in pop order.
    pub fn churn(&self) -> impl Iterator<Item = (usize, bool)> + '_ {
        self.events.iter().filter_map(|s| match s.event {
            Event::NodeJoin { node } => Some((node, true)),
            Event::NodeLeave { node } => Some((node, false)),
            _ => None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::with_capacity(8);
        q.schedule(30, Event::TelemetryTick { step: 3 });
        q.schedule(10, Event::TelemetryTick { step: 1 });
        q.schedule(20, Event::TelemetryTick { step: 2 });
        let times: Vec<SimTime> = std::iter::from_fn(|| q.pop()).map(|s| s.time).collect();
        assert_eq!(times, vec![10, 20, 30]);
    }

    #[test]
    fn equal_times_pop_fifo() {
        let mut q = EventQueue::with_capacity(8);
        for node in 0..5 {
            q.schedule(42, Event::NodeJoin { node });
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|s| match s.event {
                Event::NodeJoin { node } => node,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn interleaved_schedule_pop_is_stable() {
        let mut q = EventQueue::with_capacity(8);
        q.schedule(5, Event::TelemetryTick { step: 0 });
        q.schedule(1, Event::NodeLeave { node: 9 });
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().time, 1);
        q.schedule(2, Event::NodeJoin { node: 9 });
        assert_eq!(q.pop().unwrap().time, 2);
        assert_eq!(q.pop().unwrap().time, 5);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
        assert_eq!(q.scheduled_total(), 3);
    }

    #[test]
    fn tick_conversions_roundtrip() {
        assert_eq!(step_to_ticks(7), 7 * TICKS_PER_STEP);
        assert_eq!(ticks_to_step(step_to_ticks(7) + TICKS_PER_STEP - 1), 7);
        assert_eq!(latency_to_ticks(0.0), 1);
        assert_eq!(latency_to_ticks(2.0), 2 * TICKS_PER_STEP);
        assert_eq!(latency_to_ticks(0.5), TICKS_PER_STEP / 2);
    }

    #[test]
    fn drain_tick_groups_same_timestamp_events_in_pop_order() {
        let mut q = EventQueue::with_capacity(8);
        q.schedule(20, Event::JobArrival { job_id: 2 });
        q.schedule(10, Event::JobArrival { job_id: 0 });
        q.schedule(10, Event::NodeLeave { node: 5 });
        q.schedule(10, Event::JobArrival { job_id: 1 });
        let mut batch = TickBatch::default();

        assert!(q.drain_tick(&mut batch));
        assert_eq!(batch.time(), 10);
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.arrivals().collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(batch.churn().collect::<Vec<_>>(), vec![(5, false)]);
        assert!(batch.completions().next().is_none());
        // In-batch order is pop order, not grouped-by-kind order.
        assert!(matches!(batch.events()[1].event, Event::NodeLeave { node: 5 }));

        // The batch is reused: the next drain clears it first.
        assert!(q.drain_tick(&mut batch));
        assert_eq!(batch.time(), 20);
        assert_eq!(batch.len(), 1);
        assert!(q.is_empty());
        assert!(!q.drain_tick(&mut batch));
        assert!(batch.is_empty());
    }

    #[test]
    fn peek_time_tracks_the_head() {
        let mut q = EventQueue::with_capacity(4);
        assert_eq!(q.peek_time(), None);
        q.schedule(7, Event::TelemetryTick { step: 0 });
        q.schedule(3, Event::TelemetryTick { step: 1 });
        assert_eq!(q.peek_time(), Some(3));
        q.pop();
        assert_eq!(q.peek_time(), Some(7));
    }
}
