//! Hand-rolled property tests (the crate's `proptest::forall` driver —
//! no external proptest crate) for the Figure-5 window semantics: side
//! classification, lead-time extraction, and the raise/spike duality
//! that the prediction-quality scorer builds on.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::detect::window::{
    classify_spike, lead_time, left_span, raise_true_positive, right_span, SlidingWindow,
    SpikeSide,
};
use pronto::proptest::forall;
use pronto::rng::Xoshiro256;

fn gen_timeline(rng: &mut Xoshiro256, len: usize, p: f64) -> Vec<bool> {
    (0..len).map(|_| rng.next_f64() < p).collect()
}

fn gen_window(rng: &mut Xoshiro256) -> usize {
    2 + rng.gen_range(12)
}

#[test]
fn classify_spike_matches_manual_range_counts() {
    forall("classify_spike == manual range counts", |rng| {
        let len = 5 + rng.gen_range(60);
        let raised = gen_timeline(rng, len, 0.3);
        let w = gen_window(rng);
        let t = rng.gen_range(len);
        let c = classify_spike(&raised, t, w);
        let lo = t.saturating_sub(left_span(w));
        let left = raised[lo..=t].iter().filter(|&&r| r).count();
        let hi = (t + right_span(w)).min(len - 1);
        let right = if t < len - 1 {
            raised[t + 1..=hi].iter().filter(|&&r| r).count()
        } else {
            0
        };
        if c.left == left && c.right == right {
            Ok(())
        } else {
            Err(format!(
                "w={w} t={t}: got {c:?}, manual left={left} right={right}, raised={raised:?}"
            ))
        }
    });
}

#[test]
fn lead_time_is_earliest_left_raise() {
    forall("lead iff left raise, earliest wins", |rng| {
        let len = 5 + rng.gen_range(60);
        let raised = gen_timeline(rng, len, 0.2);
        let w = gen_window(rng);
        let t = rng.gen_range(len);
        let c = classify_spike(&raised, t, w);
        match lead_time(&raised, t, w) {
            Some(lead) => {
                if c.left == 0 {
                    return Err(format!("lead {lead} but left count 0 (w={w}, t={t})"));
                }
                if lead > left_span(w) {
                    return Err(format!("lead {lead} > left_span {}", left_span(w)));
                }
                let s = t - lead;
                if !raised[s] {
                    return Err(format!("no raise at claimed lead origin {s}"));
                }
                // Earliest: nothing raised between the window edge and s.
                let lo = t.saturating_sub(left_span(w));
                if raised[lo..s].iter().any(|&r| r) {
                    return Err(format!("raise earlier than lead origin {s} (lo={lo})"));
                }
                Ok(())
            }
            None => {
                if c.left == 0 {
                    Ok(())
                } else {
                    Err(format!("left count {} but no lead time (w={w}, t={t})", c.left))
                }
            }
        }
    });
}

#[test]
fn predicted_spike_and_tp_raise_are_dual() {
    forall("spike predicted <=> witnessing raise is a TP", |rng| {
        let len = 10 + rng.gen_range(60);
        let raised = gen_timeline(rng, len, 0.2);
        let spikes = gen_timeline(rng, len, 0.15);
        let w = gen_window(rng);
        for t in 0..len {
            if spikes[t] {
                if let Some(lead) = lead_time(&raised, t, w) {
                    // The raise that predicted this spike must itself
                    // score as a true positive.
                    if !raise_true_positive(&spikes, t - lead, w) {
                        return Err(format!(
                            "spike {t} predicted by raise {} which is not a TP (w={w})",
                            t - lead
                        ));
                    }
                }
            }
            if raised[t] && raise_true_positive(&spikes, t, w) {
                // A TP raise must make at least one forward spike
                // left-predicted.
                let hi = (t + left_span(w)).min(len - 1);
                let witnessed = (t..=hi)
                    .any(|s| spikes[s] && classify_spike(&raised, s, w).left > 0);
                if !witnessed {
                    return Err(format!("TP raise {t} predicts no spike (w={w})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn sliding_window_sides_partition_and_count() {
    forall("side_of partitions ages; side_counts sums events", |rng| {
        let w = gen_window(rng);
        let mut win = SlidingWindow::new(w);
        let events = gen_timeline(rng, w + rng.gen_range(10), 0.4);
        for &e in &events {
            win.push(e);
        }
        // Every age is on exactly one side; the boundary sits at w/2 with
        // the reference (and everything older) on the Left.
        for age in 0..w {
            let side = win.side_of(age);
            let expect = if age >= w / 2 { SpikeSide::Left } else { SpikeSide::Right };
            if side != expect {
                return Err(format!("w={w} age={age}: {side:?}, expected {expect:?}"));
            }
        }
        let c = win.side_counts();
        let total = (0..w).filter(|&a| win.get_back(a)).count();
        if c.total() != total {
            return Err(format!("side counts {c:?} don't sum to {total}"));
        }
        if left_span(w) + 1 + right_span(w) != w {
            return Err(format!("spans don't partition w={w}"));
        }
        Ok(())
    });
}
