// Fixture: hand-rolled seed mixing and literal stream tags.
pub fn hand_mixed(seed: u64) -> u64 {
    seed ^ 7u64.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

pub fn raw_splitmix(seed: u64) -> u64 {
    let mut sm = crate::rng::SplitMix64::new(seed);
    sm.next_u64()
}

pub fn literal_tag(seed: u64) -> u64 {
    crate::rng::stream_seed(seed, 3)
}
