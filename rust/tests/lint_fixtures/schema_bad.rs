// Fixture: report keys missing from the pinned schema manifest.
use std::collections::BTreeMap;

pub fn render(m: &mut BTreeMap<String, u64>, p: usize) {
    m.insert("mystery_counter".into(), 1);
    m.insert(format!("mystery_p{p}"), 2);
}
