//! Column-major dense matrix.
//!
//! Column-major matches the paper's convention (data matrices are d × n with
//! one *column* per observation) and makes appending streaming observations
//! a memcpy.

use std::fmt;

/// Dense, heap-allocated, column-major `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    /// Column-major storage: element (i, j) lives at `data[j * rows + i]`.
    data: Vec<f64>,
}

impl Mat {
    /// Zero-filled rows × cols matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix of size n.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Build from a column-major buffer.
    pub fn from_col_major(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer size mismatch");
        Self { rows, cols, data }
    }

    /// Build from a row-major buffer (convenient for literals in tests).
    pub fn from_rows(rows: usize, cols: usize, row_major: &[f64]) -> Self {
        assert_eq!(row_major.len(), rows * cols, "buffer size mismatch");
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.set(i, j, row_major[i * cols + j]);
            }
        }
        m
    }

    /// Build a d × 1 column vector.
    pub fn col_vec(v: &[f64]) -> Self {
        Self::from_col_major(v.len(), 1, v.to_vec())
    }

    /// Diagonal matrix from a slice.
    pub fn diag(d: &[f64]) -> Self {
        let mut m = Self::zeros(d.len(), d.len());
        for (i, &x) in d.iter().enumerate() {
            m.set(i, i, x);
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// True when either dimension is zero.
    pub fn is_empty(&self) -> bool {
        self.rows == 0 || self.cols == 0
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.rows + i] = v;
    }

    /// Raw column-major buffer.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Mutable raw column-major buffer.
    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow column j as a slice (free thanks to column-major layout).
    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        debug_assert!(j < self.cols);
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Mutable borrow of column j.
    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        debug_assert!(j < self.cols);
        &mut self.data[j * self.rows..(j + 1) * self.rows]
    }

    /// Copy of row i.
    pub fn row(&self, i: usize) -> Vec<f64> {
        (0..self.cols).map(|j| self.get(i, j)).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for j in 0..self.cols {
            for i in 0..self.rows {
                t.set(j, i, self.get(i, j));
            }
        }
        t
    }

    /// Matrix product `self * rhs` with a column-blocked kernel: for each
    /// output column we accumulate scaled columns of `self`, which walks both
    /// operands in storage order.
    pub fn matmul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.cols, rhs.rows, "matmul dim mismatch");
        let mut out = Mat::zeros(self.rows, rhs.cols);
        for j in 0..rhs.cols {
            let rcol = rhs.col(j);
            let ocol = out.col_mut(j);
            for (k, &rv) in rcol.iter().enumerate() {
                if rv == 0.0 {
                    continue;
                }
                let lcol = self.col(k);
                for i in 0..lcol.len() {
                    ocol[i] += lcol[i] * rv;
                }
            }
        }
        out
    }

    /// `selfᵀ * rhs` without materializing the transpose: each output entry
    /// is a dot product of two columns — both contiguous.
    pub fn transpose_mul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.rows, rhs.rows, "transpose_mul dim mismatch");
        let mut out = Mat::zeros(self.cols, rhs.cols);
        for j in 0..rhs.cols {
            let rcol = rhs.col(j);
            for i in 0..self.cols {
                let lcol = self.col(i);
                let mut s = 0.0;
                for k in 0..lcol.len() {
                    s += lcol[k] * rcol[k];
                }
                out.set(i, j, s);
            }
        }
        out
    }

    /// Gram matrix `selfᵀ · self`, exploiting symmetry (computes the upper
    /// triangle once and mirrors it — ~2× over `transpose_mul(self)`).
    pub fn gram(&self) -> Mat {
        let c = self.cols;
        let mut out = Mat::zeros(c, c);
        for i in 0..c {
            let ci = self.col(i);
            for j in i..c {
                let cj = self.col(j);
                let mut s = 0.0;
                for k in 0..ci.len() {
                    s += ci[k] * cj[k];
                }
                out.set(i, j, s);
                out.set(j, i, s);
            }
        }
        out
    }

    /// Matrix–vector product `self * v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.matvec_into(v, &mut out);
        out
    }

    /// Matrix–vector product `self * v` into a caller-owned buffer
    /// (allocation-free; same accumulation order as [`Mat::matvec`], so
    /// results are bit-identical).
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(self.cols, v.len(), "matvec dim mismatch");
        assert_eq!(self.rows, out.len(), "matvec out dim mismatch");
        out.fill(0.0);
        for (j, &x) in v.iter().enumerate() {
            if x == 0.0 {
                continue;
            }
            let c = self.col(j);
            for i in 0..self.rows {
                out[i] += c[i] * x;
            }
        }
    }

    /// `selfᵀ * v` — projections of v onto each column.
    pub fn transpose_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "transpose_matvec dim mismatch");
        (0..self.cols)
            .map(|j| {
                let c = self.col(j);
                c.iter().zip(v).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    /// Horizontal concatenation `[self | rhs]`.
    pub fn hcat(&self, rhs: &Mat) -> Mat {
        if self.is_empty() {
            return rhs.clone();
        }
        if rhs.is_empty() {
            return self.clone();
        }
        assert_eq!(self.rows, rhs.rows, "hcat row mismatch");
        let mut data = Vec::with_capacity(self.data.len() + rhs.data.len());
        data.extend_from_slice(&self.data);
        data.extend_from_slice(&rhs.data);
        Mat::from_col_major(self.rows, self.cols + rhs.cols, data)
    }

    /// Copy of the leading `k` columns.
    pub fn take_cols(&self, k: usize) -> Mat {
        assert!(k <= self.cols);
        Mat::from_col_major(self.rows, k, self.data[..k * self.rows].to_vec())
    }

    /// Scale every entry in place.
    pub fn scale_in_place(&mut self, s: f64) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Scaled copy.
    pub fn scaled(&self, s: f64) -> Mat {
        let mut m = self.clone();
        m.scale_in_place(s);
        m
    }

    /// Column-scaled copy: column j multiplied by `s[j]` (i.e. `self * diag(s)`).
    pub fn mul_diag(&self, s: &[f64]) -> Mat {
        assert_eq!(self.cols, s.len());
        let mut m = self.clone();
        for j in 0..m.cols {
            let f = s[j];
            for x in m.col_mut(j) {
                *x *= f;
            }
        }
        m
    }

    /// Element-wise difference.
    pub fn sub(&self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self
            .data
            .iter()
            .zip(rhs.data.iter())
            .map(|(a, b)| a - b)
            .collect();
        Mat::from_col_major(self.rows, self.cols, data)
    }

    /// Element-wise sum.
    pub fn add(&self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let data = self
            .data
            .iter()
            .zip(rhs.data.iter())
            .map(|(a, b)| a + b)
            .collect();
        Mat::from_col_major(self.rows, self.cols, data)
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self.get(i, j))?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let i = Mat::eye(3);
        assert_eq!(a.matmul(&i), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_rows(2, 2, &[5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(2, 2, &[19.0, 22.0, 43.0, 50.0]));
    }

    #[test]
    fn transpose_mul_matches_explicit() {
        let a = Mat::from_rows(3, 2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Mat::from_rows(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let via_helper = a.transpose_mul(&b);
        let explicit = a.transpose().matmul(&b);
        assert_eq!(via_helper, explicit);
    }

    #[test]
    fn matvec_and_transpose_matvec() {
        let a = Mat::from_rows(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.matvec(&[1.0, 1.0, 1.0]), vec![6.0, 15.0]);
        assert_eq!(a.transpose_matvec(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn hcat_shapes_and_content() {
        let a = Mat::from_rows(2, 1, &[1.0, 2.0]);
        let b = Mat::from_rows(2, 2, &[3.0, 4.0, 5.0, 6.0]);
        let c = a.hcat(&b);
        assert_eq!((c.rows(), c.cols()), (2, 3));
        assert_eq!(c.get(0, 1), 3.0);
        assert_eq!(c.get(1, 2), 6.0);
    }

    #[test]
    fn hcat_with_empty() {
        let e = Mat::zeros(3, 0);
        let a = Mat::from_rows(3, 1, &[1.0, 2.0, 3.0]);
        assert_eq!(e.hcat(&a), a);
        assert_eq!(a.hcat(&e), a);
    }

    #[test]
    fn mul_diag_scales_columns() {
        let a = Mat::from_rows(2, 2, &[1.0, 1.0, 1.0, 1.0]);
        let s = a.mul_diag(&[2.0, 3.0]);
        assert_eq!(s, Mat::from_rows(2, 2, &[2.0, 3.0, 2.0, 3.0]));
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_rows(3, 2, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn frob_norm_known() {
        let a = Mat::from_rows(2, 2, &[3.0, 0.0, 0.0, 4.0]);
        assert!((a.frob_norm() - 5.0).abs() < 1e-12);
    }
}

#[cfg(test)]
mod gram_tests {
    use super::*;

    #[test]
    fn gram_matches_transpose_mul() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(3);
        let data: Vec<f64> = (0..52 * 36).map(|_| rng.normal()).collect();
        let a = Mat::from_col_major(52, 36, data);
        let fast = a.gram();
        let slow = a.transpose_mul(&a);
        assert!(crate::linalg::frob_diff(&fast, &slow) < 1e-10);
    }
}
