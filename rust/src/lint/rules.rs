//! The six determinism & safety rules.
//!
//! Each rule is a pure function over a lexed file: `(path, tokens,
//! test-region map)` → findings. Rules only ever match real code tokens —
//! the lexer has already separated strings and comments — so prose about
//! `Instant::now()` or `HashMap` never trips anything.

use super::lexer::{int_value, Token, TokenKind};
use super::registry;

/// One rule violation at a source location.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule identifier (one of [`registry::RULES`], or `pragma` for
    /// problems with the suppression pragmas themselves — those are
    /// never suppressible).
    pub rule: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
}

/// Per-file facts the tree-level checks need beyond findings.
#[derive(Debug, Clone, Copy, Default)]
pub struct FileFacts {
    /// File contains an `unsafe` token anywhere (tests included).
    pub has_unsafe: bool,
    /// File contains `forbid(unsafe_code)`.
    pub has_forbid_unsafe: bool,
}

/// Mark the token ranges belonging to `#[test]` functions and
/// `#[cfg(test)]` items: the body (brace-matched) following such an
/// attribute. `#[cfg(not(test))]` is explicitly *not* a test region.
pub fn test_regions(tokens: &[Token]) -> Vec<bool> {
    let mut mark = vec![false; tokens.len()];
    let sig: Vec<usize> = (0..tokens.len()).filter(|&i| !tokens[i].is_comment()).collect();
    let mut j = 0;
    while j < sig.len() {
        if !(tokens[sig[j]].is_punct('#')
            && j + 1 < sig.len()
            && tokens[sig[j + 1]].is_punct('['))
        {
            j += 1;
            continue;
        }
        // Scan the attribute to its matching `]`, collecting idents.
        let mut k = j + 2;
        let mut depth = 1usize;
        let mut idents: Vec<&str> = Vec::new();
        while k < sig.len() && depth > 0 {
            let t = &tokens[sig[k]];
            if t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(']') {
                depth -= 1;
            } else if t.kind == TokenKind::Ident {
                idents.push(&t.text);
            }
            k += 1;
        }
        let is_test_attr = idents == ["test"]
            || (idents.first() == Some(&"cfg")
                && idents.contains(&"test")
                && !idents.contains(&"not"));
        if !is_test_attr {
            j = k;
            continue;
        }
        // Find the item body: first `{` before any top-level `;`, then
        // brace-match to its close. (`#[cfg(test)] use …;` has no body.)
        let mut m = k;
        let mut braces = 0usize;
        let mut start = None;
        while m < sig.len() {
            let t = &tokens[sig[m]];
            if t.is_punct('{') {
                if start.is_none() {
                    start = Some(m);
                }
                braces += 1;
            } else if t.is_punct('}') {
                braces = braces.saturating_sub(1);
                if start.is_some() && braces == 0 {
                    break;
                }
            } else if t.is_punct(';') && start.is_none() {
                break;
            }
            m += 1;
        }
        if start.is_some() && m < sig.len() {
            for idx in sig[j]..=sig[m] {
                mark[idx] = true;
            }
            j = m + 1;
        } else {
            j = k;
        }
    }
    mark
}

/// Run every per-file rule. `in_test[i]` must parallel `tokens`.
pub fn check_file(path: &str, tokens: &[Token], in_test: &[bool]) -> (Vec<Finding>, FileFacts) {
    let mut findings = Vec::new();
    let mut facts = FileFacts::default();
    let sig: Vec<usize> = (0..tokens.len()).filter(|&i| !tokens[i].is_comment()).collect();

    let vendor = registry::is_vendor(path);
    let test_file = registry::is_test_path(path);
    let module = registry::src_module(path);
    let module = module.as_deref();

    let finding = |rule: &'static str, line: usize, message: String| Finding {
        rule,
        path: path.to_string(),
        line,
        message,
    };

    // ---- unsafe-audit (applies everywhere, vendor and tests included) ----
    for t in tokens.iter() {
        if t.is_ident("unsafe") {
            facts.has_unsafe = true;
            let covered = tokens.iter().any(|c| {
                c.is_comment()
                    && c.text.contains("SAFETY:")
                    && c.line <= t.line
                    && c.line + 8 >= t.line
            });
            if !covered {
                findings.push(finding(
                    "unsafe-audit",
                    t.line,
                    "`unsafe` without a `// SAFETY:` comment in the preceding lines".into(),
                ));
            }
        }
    }
    for w in sig.windows(3) {
        if tokens[w[0]].is_ident("forbid")
            && tokens[w[1]].is_punct('(')
            && tokens[w[2]].is_ident("unsafe_code")
        {
            facts.has_forbid_unsafe = true;
        }
    }
    if vendor {
        // Vendored crates keep upstream style for everything else.
        return (findings, facts);
    }

    // ---- wall-clock ----
    if !test_file {
        if let Some(m) = module {
            if registry::WALL_CLOCK_BANNED.contains(&m) {
                for (i, t) in tokens.iter().enumerate() {
                    if in_test[i] {
                        continue;
                    }
                    if t.is_ident("Instant") || t.is_ident("SystemTime") {
                        findings.push(finding(
                            "wall-clock",
                            t.line,
                            format!(
                                "`{}` in deterministic module `{m}`; inject time from a \
                                 caller in `bench`/`cli` instead",
                                t.text
                            ),
                        ));
                    }
                }
            }
        }
    }

    // ---- rng-discipline: no hand-rolled seed mixing in engine paths ----
    if !test_file {
        if let Some(m) = module {
            if registry::RNG_DISCIPLINE.contains(&m) {
                for (i, t) in tokens.iter().enumerate() {
                    if in_test[i] {
                        continue;
                    }
                    if t.is_ident("SplitMix64") {
                        findings.push(finding(
                            "rng-discipline",
                            t.line,
                            format!(
                                "direct `SplitMix64` use in engine module `{m}`; derive \
                                 seeds via `rng::stream_seed`/`node_stream_seed`"
                            ),
                        ));
                    }
                    if t.kind == TokenKind::IntLit {
                        let stripped: String =
                            t.text.chars().filter(|&c| c != '_').collect::<String>().to_ascii_lowercase();
                        if stripped.starts_with("0x9e37")
                            || int_value(&t.text) == Some(registry::STREAM_GAMMA)
                        {
                            findings.push(finding(
                                "rng-discipline",
                                t.line,
                                "hand-rolled stream-gamma mixing; use `rng::stream_seed` \
                                 (the gamma lives in `rng` only)"
                                    .into(),
                            ));
                        }
                    }
                }
            }
        }
    }

    // ---- rng-discipline: stream tags must be named registry constants ----
    if !test_file {
        let mut j = 0;
        while j + 2 < sig.len() {
            let t = &tokens[sig[j]];
            if (t.is_ident("stream_seed") || t.is_ident("node_stream_seed"))
                && tokens[sig[j + 1]].is_punct('(')
                && !in_test[sig[j]]
            {
                // Find the token after the first top-level comma: the tag.
                let mut depth = 1usize;
                let mut k = j + 2;
                while k < sig.len() && depth > 0 {
                    let a = &tokens[sig[k]];
                    if a.is_punct('(') || a.is_punct('[') {
                        depth += 1;
                    } else if a.is_punct(')') || a.is_punct(']') {
                        depth -= 1;
                    } else if a.is_punct(',') && depth == 1 {
                        if let Some(tag) = sig.get(k + 1).map(|&i| &tokens[i]) {
                            if tag.kind == TokenKind::IntLit {
                                findings.push(finding(
                                    "rng-discipline",
                                    tag.line,
                                    format!(
                                        "integer-literal stream tag `{}`; use a named \
                                         constant from `rng::streams`",
                                        tag.text
                                    ),
                                ));
                            }
                        }
                        break;
                    }
                    k += 1;
                }
            }
            j += 1;
        }
    }

    // ---- unordered-iter ----
    if module.is_some() {
        for (i, t) in tokens.iter().enumerate() {
            if in_test[i] {
                continue;
            }
            if t.is_ident("HashMap") || t.is_ident("HashSet") {
                findings.push(finding(
                    "unordered-iter",
                    t.line,
                    format!(
                        "`{}` iteration order is nondeterministic; use `BTreeMap`/`BTreeSet` \
                         (reports must render byte-identically)",
                        t.text
                    ),
                ));
            }
        }
    }

    // ---- env-registry ----
    for t in tokens.iter() {
        // pronto-lint: allow(env-registry) — the match prefix itself, not an env read
        if t.kind == TokenKind::StrLit && t.text.starts_with("PRONTO_") {
            let key = registry::leading_env_key(&t.text);
            if !registry::ENV_KEYS.contains(&key) {
                findings.push(finding(
                    "env-registry",
                    t.line,
                    format!("unregistered env key `{key}`; add it to `lint::registry::ENV_KEYS`"),
                ));
            }
        }
    }
    if !registry::SET_VAR_ALLOWED_FILES.iter().any(|f| path.ends_with(f)) {
        for t in tokens.iter() {
            if t.is_ident("set_var") || t.is_ident("remove_var") {
                findings.push(finding(
                    "env-registry",
                    t.line,
                    format!(
                        "`{}` outside the isolated backing-parity test binaries races \
                         the process environment",
                        t.text
                    ),
                ));
            }
        }
    }

    // ---- schema-pin ----
    if registry::is_schema_file(path) {
        let mut j = 0;
        while j + 2 < sig.len() {
            let t = &tokens[sig[j]];
            if t.is_ident("insert") && tokens[sig[j + 1]].is_punct('(') && !in_test[sig[j]] {
                let arg = &tokens[sig[j + 2]];
                if arg.kind == TokenKind::StrLit {
                    if !registry::REPORT_KEYS.contains(&arg.text.as_str()) {
                        findings.push(finding(
                            "schema-pin",
                            arg.line,
                            format!(
                                "report key \"{}\" is not in the pinned schema manifest \
                                 (`lint::registry::REPORT_KEYS`)",
                                arg.text
                            ),
                        ));
                    }
                } else if arg.is_ident("format")
                    && j + 5 < sig.len()
                    && tokens[sig[j + 3]].is_punct('!')
                    && tokens[sig[j + 4]].is_punct('(')
                    && tokens[sig[j + 5]].kind == TokenKind::StrLit
                {
                    let lit = &tokens[sig[j + 5]];
                    let prefix = lit.text.split('{').next().unwrap_or("");
                    if !registry::REPORT_KEY_PREFIXES.contains(&prefix) {
                        findings.push(finding(
                            "schema-pin",
                            lit.line,
                            format!(
                                "dynamic report key \"{}\" has no registered prefix \
                                 (`lint::registry::REPORT_KEY_PREFIXES`)",
                                lit.text
                            ),
                        ));
                    }
                }
            }
            j += 1;
        }
    }

    (findings, facts)
}
