//! `pronto lint` — the determinism & safety static-analysis pass.
//!
//! Every claim the repo makes about byte-identical reports rests on
//! invariants that are easy to erode one innocuous edit at a time: no
//! wall-clock reads in engine paths, RNG streams derived only through
//! the audited `rng::stream_seed` helpers with registered tags, no
//! nondeterministically-ordered containers, environment knobs drawn from
//! a single registry, audited `unsafe`, and a pinned report schema.
//! This module machine-checks all of them with a lightweight tokenizer
//! ([`lexer`]) and a rule engine ([`rules`]) — no rustc, no syn, std
//! only — so the check runs as a plain blocking CI job:
//!
//! ```bash
//! cargo run --release -- lint --json . ../examples
//! ```
//!
//! Violations can be waived per-site with an explained pragma
//! ([`pragma`]): `// pronto-lint: allow(<rule>) — <reason>`. Unexplained,
//! unknown, or unused pragmas are themselves findings, so the exemption
//! list can only shrink.

pub mod lexer;
pub mod pragma;
pub mod registry;
pub mod rules;

pub use rules::Finding;

use crate::ser::JsonValue;
use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Directories the tree walker never descends into. `lint_fixtures`
/// holds the deliberately-violating test corpus.
const SKIP_DIRS: &[&str] = &["target", "lint_fixtures", "node_modules"];

/// Outcome of linting a set of roots.
#[derive(Debug)]
pub struct LintReport {
    pub files_scanned: usize,
    /// Sorted by (path, line, rule).
    pub findings: Vec<Finding>,
}

impl LintReport {
    pub fn is_clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable document (stable key order via `BTreeMap`).
    pub fn to_json(&self) -> JsonValue {
        let mut m = BTreeMap::new();
        m.insert("lint".into(), JsonValue::String("pronto".into()));
        m.insert("schema_version".into(), JsonValue::Number(1.0));
        m.insert(
            "files_scanned".into(),
            JsonValue::Number(self.files_scanned as f64),
        );
        m.insert(
            "findings".into(),
            JsonValue::Array(
                self.findings
                    .iter()
                    .map(|f| {
                        let mut o = BTreeMap::new();
                        o.insert("rule".into(), JsonValue::String(f.rule.into()));
                        o.insert("path".into(), JsonValue::String(f.path.clone()));
                        o.insert("line".into(), JsonValue::Number(f.line as f64));
                        o.insert("message".into(), JsonValue::String(f.message.clone()));
                        JsonValue::Object(o)
                    })
                    .collect(),
            ),
        );
        JsonValue::Object(m)
    }

    /// Human-readable rendering, one `path:line: [rule] message` per
    /// finding plus a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.path, f.line, f.rule, f.message));
        }
        out.push_str(&format!(
            "pronto lint: {} file(s) scanned, {} finding(s)\n",
            self.files_scanned,
            self.findings.len()
        ));
        out
    }
}

/// Lint a single source text under a (possibly virtual) path. Pragma
/// handling included; path classification follows the same rules as the
/// tree walk, so fixtures can impersonate engine files
/// (`lint_source("src/sim/fixture.rs", src)`).
pub fn lint_source(path: &str, source: &str) -> Vec<Finding> {
    lint_source_full(path, source).0
}

fn lint_source_full(path: &str, source: &str) -> (Vec<Finding>, rules::FileFacts) {
    let path = registry::norm_path(path);
    let tokens = lexer::lex(source);
    let in_test = rules::test_regions(&tokens);
    let (mut findings, facts) = rules::check_file(&path, &tokens, &in_test);

    // Apply suppression pragmas, then report pragma problems.
    let pragmas = pragma::parse_pragmas(&tokens);
    let mut used = vec![false; pragmas.len()];
    findings.retain(|f| {
        for (i, p) in pragmas.iter().enumerate() {
            if f.rule != "pragma" && p.covers(f.rule, f.line) {
                used[i] = true;
                return false;
            }
        }
        true
    });
    for (i, p) in pragmas.iter().enumerate() {
        if p.malformed {
            findings.push(Finding {
                rule: "pragma",
                path: path.clone(),
                line: p.line,
                message: "malformed pragma; expected `pronto-lint: allow(<rule>) — <reason>`"
                    .into(),
            });
            continue;
        }
        let mut known = true;
        for r in &p.rules {
            if !registry::RULES.contains(&r.as_str()) {
                known = false;
                findings.push(Finding {
                    rule: "pragma",
                    path: path.clone(),
                    line: p.line,
                    message: format!("pragma names unknown rule `{r}`"),
                });
            }
        }
        if !p.has_reason {
            findings.push(Finding {
                rule: "pragma",
                path: path.clone(),
                line: p.line,
                message: "pragma without a reason never suppresses; add `— <reason>`".into(),
            });
        } else if known && !used[i] {
            findings.push(Finding {
                rule: "pragma",
                path: path.clone(),
                line: p.line,
                message: "unused pragma (suppresses nothing); remove it".into(),
            });
        }
    }
    (findings, facts)
}

/// Per-crate accumulator for the unsafe-free `forbid(unsafe_code)` check.
#[derive(Default)]
struct CrateFacts {
    has_unsafe: bool,
    lib_rs: Option<String>,
    lib_has_forbid: bool,
}

/// Walk `roots` (files or directories), lint every `.rs` file, and run
/// the tree-level checks: per-crate `#![forbid(unsafe_code)]` for
/// unsafe-free crates, and uniqueness of the RNG stream-tag registry.
pub fn lint_tree(roots: &[PathBuf]) -> io::Result<LintReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    for root in roots {
        if root.is_file() {
            files.push(root.clone());
        } else {
            walk(root, &mut files)?;
        }
    }
    files.sort();
    files.dedup();

    let mut findings = Vec::new();
    let mut crates: BTreeMap<String, CrateFacts> = BTreeMap::new();
    for file in &files {
        let source = fs::read_to_string(file)?;
        let path = registry::norm_path(&file.to_string_lossy());
        let (file_findings, facts) = lint_source_full(&path, &source);
        findings.extend(file_findings);
        if let Some(root) = crate_src_root(&path) {
            let entry = crates.entry(root).or_default();
            entry.has_unsafe |= facts.has_unsafe;
            if path.ends_with("src/lib.rs") {
                entry.lib_rs = Some(path.clone());
                entry.lib_has_forbid = facts.has_forbid_unsafe;
            }
        }
    }

    for facts in crates.values() {
        if let Some(lib) = &facts.lib_rs {
            if !facts.has_unsafe && !facts.lib_has_forbid {
                findings.push(Finding {
                    rule: "unsafe-audit",
                    path: lib.clone(),
                    line: 1,
                    message: "crate has no `unsafe` code; add `#![forbid(unsafe_code)]`".into(),
                });
            }
        }
    }

    // The stream-tag registry is code, so check it directly: tags and
    // names must be unique or two "independent" streams would collide.
    {
        let mut tags: Vec<u64> = crate::rng::streams::ALL.iter().map(|&(t, _)| t).collect();
        let mut names: Vec<&str> = crate::rng::streams::ALL.iter().map(|&(_, n)| n).collect();
        tags.sort_unstable();
        names.sort_unstable();
        let dup_tag = tags.windows(2).any(|w| w[0] == w[1]);
        let dup_name = names.windows(2).any(|w| w[0] == w[1]);
        if dup_tag || dup_name {
            findings.push(Finding {
                rule: "rng-discipline",
                path: "src/rng.rs".into(),
                line: 1,
                message: "duplicate entry in `rng::streams::ALL`; stream tags and names \
                          must be unique"
                    .into(),
            });
        }
    }

    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    Ok(LintReport { files_scanned: files.len(), findings })
}

/// `…/src/lib.rs` → the crate directory owning that `src/` tree.
fn crate_src_root(path: &str) -> Option<String> {
    let segs: Vec<&str> = path.split('/').collect();
    let at = segs.iter().position(|&s| s == "src")?;
    Some(segs[..at].join("/"))
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let name = entry.file_name().to_string_lossy().into_owned();
        let ty = entry.file_type()?;
        if ty.is_dir() {
            if name.starts_with('.') || SKIP_DIRS.contains(&name.as_str()) {
                continue;
            }
            walk(&entry.path(), out)?;
        } else if name.ends_with(".rs") {
            out.push(entry.path());
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_engine_snippet_has_no_findings() {
        let src = "pub fn step(seed: u64) -> u64 {\n    crate::rng::stream_seed(seed, crate::rng::streams::ARRIVALS)\n}\n";
        assert!(lint_source("src/sim/snippet.rs", src).is_empty());
    }

    #[test]
    fn pragma_suppresses_and_is_marked_used() {
        let src = "// pronto-lint: allow(wall-clock) — illustrative snippet for the docs\nlet t = Instant::now();\n";
        let findings = lint_source("src/sim/snippet.rs", src);
        assert!(findings.is_empty(), "unexpected: {findings:?}");
    }

    #[test]
    fn unused_pragma_is_reported() {
        let src = "// pronto-lint: allow(wall-clock) — nothing here needs it\nlet x = 1;\n";
        let findings = lint_source("src/sim/snippet.rs", src);
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].rule, "pragma");
        assert!(findings[0].message.contains("unused"));
    }

    #[test]
    fn report_renders_text_and_json() {
        let report = LintReport {
            files_scanned: 2,
            findings: vec![Finding {
                rule: "wall-clock",
                path: "src/sim/a.rs".into(),
                line: 3,
                message: "msg".into(),
            }],
        };
        let text = report.render_text();
        assert!(text.contains("src/sim/a.rs:3: [wall-clock] msg"));
        assert!(text.contains("1 finding(s)"));
        let json = report.to_json().to_string();
        assert!(json.contains("\"files_scanned\":2"));
        assert!(json.contains("\"rule\":\"wall-clock\""));
    }
}
