//! Streaming moments and quantiles.

/// Welford online mean/variance plus min/max.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantiles over a retained sample (fine for bench-scale data).
#[derive(Debug, Clone, Default)]
pub struct Quantiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Quantiles {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    pub fn extend(&mut self, xs: &[f64]) {
        self.xs.extend_from_slice(xs);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Quantile by linear interpolation; `q` in [0, 1].
    pub fn quantile(&mut self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        assert!(!self.xs.is_empty());
        if !self.sorted {
            self.xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            self.sorted = true;
        }
        let pos = q * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
    }

    pub fn median(&mut self) -> f64 {
        self.quantile(0.5)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_match_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = OnlineStats::new();
        for &x in &xs {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn quantiles_interpolate() {
        let mut q = Quantiles::new();
        q.extend(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(q.quantile(0.0), 1.0);
        assert_eq!(q.quantile(1.0), 4.0);
        assert!((q.median() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_single_element() {
        let mut q = Quantiles::new();
        q.push(7.0);
        assert_eq!(q.quantile(0.37), 7.0);
    }
}
