//! Property-based tests for the priority-aware `HostCapacity` wait queue,
//! alongside the event-queue proptests (`event_queue_props.rs`): seeded,
//! replayable via `PRONTO_PROP_SEED` / `PRONTO_PROP_CASES`.
//!
//! Invariants under test:
//! * `pop_startable` never returns a job that does not fit the budget;
//! * strict priority: no returned job is outranked by a *startable*
//!   waiting job of a higher class (no starvation of high classes);
//! * within a priority class the configured order is preserved (FIFO
//!   arrival order / smallest-first demand order);
//! * enqueue/pop/evacuate conserve jobs — nothing is lost or duplicated.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::proptest::forall;
use pronto::rng::Xoshiro256;
use pronto::scheduler::{HostCapacity, JobId, Priority, QueuePolicy, QueuedJob};
use std::collections::BTreeSet;

/// A random host with a parked population (the host itself stays idle so
/// any budget we pass to `pop_startable` is exercised directly).
fn fill_host(
    rng: &mut Xoshiro256,
    policy: QueuePolicy,
    slots: u32,
    max_priority: Priority,
) -> (HostCapacity, Vec<QueuedJob>) {
    let n = 1 + rng.gen_range(40);
    let mut h = HostCapacity::new(slots, n, policy);
    let mut parked = Vec::new();
    for id in 0..n as JobId {
        let demand = 1 + rng.gen_range(slots as usize + 1) as u32; // may exceed budget
        let priority = rng.gen_range(max_priority as usize + 1) as Priority;
        assert!(h.try_enqueue(id, demand, priority, id));
        parked.push(QueuedJob { job_id: id, demand, priority, enqueued_at: id });
    }
    (h, parked)
}

#[test]
fn pop_startable_never_returns_a_non_fitting_job() {
    forall("popped jobs always fit the offered budget", |rng| {
        for policy in [QueuePolicy::Fifo, QueuePolicy::SmallestFirst] {
            let slots = 1 + rng.gen_range(6) as u32;
            let (mut h, _) = fill_host(rng, policy, slots, 3);
            // Random budgets, including 0 and over-budget values.
            for _ in 0..20 {
                let budget = rng.gen_range(slots as usize + 2) as u32;
                if let Some(qj) = h.pop_startable(budget) {
                    if qj.demand > budget {
                        return Err(format!(
                            "{policy:?}: popped demand {} against budget {budget}",
                            qj.demand
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn no_priority_class_is_starved_by_a_lower_one() {
    forall("a pop is never outranked by a startable higher class", |rng| {
        for policy in [QueuePolicy::Fifo, QueuePolicy::SmallestFirst] {
            let slots = 2 + rng.gen_range(6) as u32;
            let (mut h, _) = fill_host(rng, policy, slots, 3);
            loop {
                let waiting: Vec<QueuedJob> = snapshot(&mut h);
                let Some(qj) = h.pop_startable(slots) else { break };
                // Under FIFO the class representative is its earliest job
                // (which may block); under smallest-first any startable
                // higher-class job outranks the popped one.
                let outranked = waiting.iter().any(|w| {
                    w.priority > qj.priority
                        && match policy {
                            QueuePolicy::Fifo => false, // head checked below
                            QueuePolicy::SmallestFirst => w.demand <= slots,
                        }
                });
                if outranked {
                    return Err(format!(
                        "{policy:?}: popped p{} while a startable higher class waited",
                        qj.priority
                    ));
                }
                if policy == QueuePolicy::Fifo {
                    // FIFO: the pop must be the earliest job of the
                    // highest waiting class, startable or not.
                    let top = waiting.iter().map(|w| w.priority).max().unwrap();
                    let head = waiting
                        .iter()
                        .filter(|w| w.priority == top)
                        .min_by_key(|w| w.enqueued_at)
                        .unwrap();
                    if qj.job_id != head.job_id {
                        return Err(format!(
                            "FIFO popped {} but the top-class head was {}",
                            qj.job_id, head.job_id
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn within_class_order_is_preserved() {
    forall("per-class FIFO / smallest-first order survives the pops", |rng| {
        for policy in [QueuePolicy::Fifo, QueuePolicy::SmallestFirst] {
            let slots = 1 + rng.gen_range(6) as u32;
            let (mut h, parked) = fill_host(rng, policy, slots, 2);
            // Pops with the full budget until nothing startable remains.
            let mut popped: Vec<QueuedJob> = Vec::new();
            while let Some(qj) = h.pop_startable(slots) {
                popped.push(qj);
            }
            for w in popped.windows(2) {
                let (a, b) = (&w[0], &w[1]);
                if a.priority < b.priority {
                    return Err(format!("{policy:?}: class order inverted"));
                }
                if a.priority == b.priority {
                    let ok = match policy {
                        QueuePolicy::Fifo => a.enqueued_at < b.enqueued_at,
                        QueuePolicy::SmallestFirst => {
                            a.demand < b.demand
                                || (a.demand == b.demand && a.enqueued_at < b.enqueued_at)
                        }
                    };
                    if !ok {
                        return Err(format!(
                            "{policy:?}: within-class order broken: {a:?} before {b:?}"
                        ));
                    }
                }
            }
            // FIFO with the full budget drains every fitting job unless an
            // oversized head blocks its class; conservation is checked via
            // the evacuate property below. Here: everything popped was
            // genuinely parked, exactly once.
            let ids: BTreeSet<JobId> = popped.iter().map(|q| q.job_id).collect();
            if ids.len() != popped.len() {
                return Err(format!("{policy:?}: a job popped twice"));
            }
            for qj in &popped {
                let src = &parked[qj.job_id as usize];
                if (src.demand, src.priority) != (qj.demand, qj.priority) {
                    return Err(format!("{policy:?}: job {} mutated in queue", qj.job_id));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn enqueue_pop_evacuate_conserve_jobs() {
    forall("no job is lost or duplicated across pops and evacuation", |rng| {
        for policy in [QueuePolicy::Fifo, QueuePolicy::SmallestFirst] {
            let slots = 1 + rng.gen_range(5) as u32;
            let (mut h, parked) = fill_host(rng, policy, slots, 3);
            let mut seen: BTreeSet<JobId> = BTreeSet::new();
            // Interleave pops (random budgets) with a final evacuation.
            for _ in 0..rng.gen_range(30) {
                let budget = rng.gen_range(slots as usize + 1) as u32;
                if let Some(qj) = h.pop_startable(budget) {
                    if !seen.insert(qj.job_id) {
                        return Err(format!("{policy:?}: job {} duplicated", qj.job_id));
                    }
                }
            }
            let (running, flushed) = h.evacuate();
            if !running.is_empty() {
                return Err("nothing ever started on this host".into());
            }
            for qj in flushed {
                if !seen.insert(qj.job_id) {
                    return Err(format!(
                        "{policy:?}: job {} both popped and flushed",
                        qj.job_id
                    ));
                }
            }
            if seen.len() != parked.len() {
                return Err(format!(
                    "{policy:?}: {} of {} jobs accounted for",
                    seen.len(),
                    parked.len()
                ));
            }
            if h.queue_len() != 0 {
                return Err("queue not empty after evacuation".into());
            }
        }
        Ok(())
    });
}

/// Non-destructive view of the wait queue: evacuate and re-park (the type
/// deliberately exposes no iterator over parked jobs).
fn snapshot(h: &mut HostCapacity) -> Vec<QueuedJob> {
    let (running, queued) = h.evacuate();
    assert!(running.is_empty(), "snapshot host must be idle");
    for qj in &queued {
        assert!(h.try_enqueue(qj.job_id, qj.demand, qj.priority, qj.enqueued_at));
    }
    queued
}
