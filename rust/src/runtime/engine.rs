//! Typed entry points over the raw runtime + the artifact-backed embedding.
//!
//! [`XlaFpca`] implements [`crate::baselines::StreamingEmbedding`] on top of
//! the `fpca_update` artifact: it buffers observations into blocks (padding
//! the feature vector to the compiled `dim`) and refreshes its `(U, Σ)`
//! estimate by executing the AOT-compiled HLO — the production
//! configuration where Python never runs. The native [`crate::fpca`] path
//! remains the numerical oracle; `rust/tests/runtime_parity.rs` pins the
//! two against each other.

use super::client::{HostTensor, XlaRuntime};
use crate::fpca::Subspace;
use crate::linalg::Mat;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Artifact-backed FPCA-Edge (fixed rank, as compiled).
pub struct XlaFpca {
    rt: Arc<XlaRuntime>,
    /// Logical feature dimension (≤ compiled dim; padded with zeros).
    d: usize,
    /// Compiled shapes.
    cd: usize,
    rank: usize,
    block: usize,
    forget: f32,
    /// Current estimate, row-major (cd × rank) on the artifact side.
    u: Vec<f32>,
    s: Vec<f32>,
    /// Block buffer, row-major (cd × block): element (i, j) at i*block+j.
    buf: Vec<f32>,
    buffered: usize,
    blocks: usize,
}

impl XlaFpca {
    /// `d` is the logical feature dimension; it must not exceed the
    /// compiled dimension recorded in the manifest.
    pub fn new(rt: Arc<XlaRuntime>, d: usize) -> Result<Self> {
        let cfg = rt.manifest().config;
        if d > cfg.dim {
            bail!("feature dim {d} exceeds compiled dim {}", cfg.dim);
        }
        Ok(Self {
            rt,
            d,
            cd: cfg.dim,
            rank: cfg.rank,
            block: cfg.block,
            forget: 1.0,
            u: vec![0.0; cfg.dim * cfg.rank],
            s: vec![0.0; cfg.rank],
            buf: vec![0.0; cfg.dim * cfg.block],
            buffered: 0,
            blocks: 0,
        })
    }

    pub fn with_forget(mut self, forget: f64) -> Self {
        self.forget = forget as f32;
        self
    }

    /// Blocks processed so far.
    pub fn blocks_processed(&self) -> usize {
        self.blocks
    }

    fn flush_block(&mut self) -> Result<()> {
        let inputs = vec![
            HostTensor::F32(self.u.clone()),
            HostTensor::F32(self.s.clone()),
            HostTensor::F32(self.buf.clone()),
            HostTensor::F32(vec![self.forget]),
        ];
        let out = self.rt.execute("fpca_update", &inputs)?;
        self.u = out[0].as_f32()?.to_vec();
        self.s = out[1].as_f32()?.to_vec();
        self.buf.iter_mut().for_each(|x| *x = 0.0);
        self.buffered = 0;
        self.blocks += 1;
        Ok(())
    }
}

impl crate::baselines::StreamingEmbedding for XlaFpca {
    fn observe(&mut self, y: &[f64]) {
        assert_eq!(y.len(), self.d, "feature dim mismatch");
        // Column `buffered` of the row-major (cd × block) buffer.
        for (i, &v) in y.iter().enumerate() {
            self.buf[i * self.block + self.buffered] = v as f32;
        }
        self.buffered += 1;
        if self.buffered == self.block {
            self.flush_block().expect("fpca_update artifact execution failed");
        }
    }

    fn estimate(&self) -> Subspace {
        if self.blocks == 0 {
            return Subspace::empty(self.d);
        }
        // Row-major (cd × rank) → column-major Mat over the logical d rows.
        let mut u = Mat::zeros(self.d, self.rank);
        for i in 0..self.d {
            for j in 0..self.rank {
                u.set(i, j, f64::from(self.u[i * self.rank + j]));
            }
        }
        let sigma: Vec<f64> = self.s.iter().map(|&x| f64::from(x)).collect();
        Subspace::new(u, sigma)
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn rank(&self) -> usize {
        self.rank
    }

    fn name(&self) -> &'static str {
        "PRONTO-XLA"
    }

    fn has_spectrum(&self) -> bool {
        true
    }

    fn version(&self) -> Option<u64> {
        Some(self.blocks as u64)
    }
}

/// Execute the `merge_subspaces` artifact on two host-side estimates.
/// Both must have the compiled rank; dimensions are padded to the compiled
/// dim.
pub fn xla_merge(
    rt: &XlaRuntime,
    s1: &Subspace,
    s2: &Subspace,
    forget: f64,
) -> Result<Subspace> {
    let cfg = rt.manifest().config;
    let (cd, r) = (cfg.dim, cfg.rank);
    if s1.dim() > cd || s2.dim() > cd {
        bail!("subspace dim exceeds compiled dim {cd}");
    }
    if s1.rank() != r || s2.rank() != r {
        bail!("merge artifact requires rank {r} on both sides");
    }
    let pack = |s: &Subspace| -> (Vec<f32>, Vec<f32>) {
        let mut u = vec![0.0f32; cd * r];
        for i in 0..s.dim() {
            for j in 0..r {
                u[i * r + j] = s.u.get(i, j) as f32;
            }
        }
        let sig: Vec<f32> = s.sigma.iter().map(|&x| x as f32).collect();
        (u, sig)
    };
    let (u1, sg1) = pack(s1);
    let (u2, sg2) = pack(s2);
    let out = rt.execute(
        "merge_subspaces",
        &[
            HostTensor::F32(u1),
            HostTensor::F32(sg1),
            HostTensor::F32(u2),
            HostTensor::F32(sg2),
            HostTensor::F32(vec![forget as f32]),
        ],
    )?;
    let um = out[0].as_f32()?;
    let sm = out[1].as_f32()?;
    let d = s1.dim();
    let mut u = Mat::zeros(d, r);
    for i in 0..d {
        for j in 0..r {
            u.set(i, j, f64::from(um[i * r + j]));
        }
    }
    Ok(Subspace::new(u, sm.iter().map(|&x| f64::from(x)).collect()))
}

/// Batched Reject-Job over the `project_detect` artifact: holds the z-score
/// filter state across calls (threading `buf`/`seen` exactly like the
/// native detector).
pub struct XlaProjectDetect {
    rt: Arc<XlaRuntime>,
    buf: Vec<f32>,
    seen: i32,
    b: usize,
    d: usize,
    r: usize,
}

impl XlaProjectDetect {
    pub fn new(rt: Arc<XlaRuntime>) -> Self {
        let cfg = rt.manifest().config;
        Self {
            buf: vec![0.0; cfg.rank * cfg.lag],
            seen: 0,
            b: cfg.block,
            d: cfg.dim,
            r: cfg.rank,
            rt,
        }
    }

    /// Process one block of observations (row-major (b × d)) against the
    /// estimate; returns (flags row-major (b × r), reject (b)).
    pub fn run_block(
        &mut self,
        estimate: &Subspace,
        y_block: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        assert_eq!(y_block.len(), self.b * self.d);
        let mut u = vec![0.0f32; self.d * self.r];
        for i in 0..estimate.dim().min(self.d) {
            for j in 0..estimate.rank().min(self.r) {
                u[i * self.r + j] = estimate.u.get(i, j) as f32;
            }
        }
        let mut s = vec![0.0f32; self.r];
        for (j, sv) in estimate.sigma.iter().take(self.r).enumerate() {
            s[j] = *sv as f32;
        }
        let out = self.rt.execute(
            "project_detect",
            &[
                HostTensor::F32(u),
                HostTensor::F32(s),
                HostTensor::F32(y_block.to_vec()),
                HostTensor::F32(self.buf.clone()),
                HostTensor::I32(vec![self.seen]),
            ],
        )?;
        let flags = out[0].as_f32()?.to_vec();
        let reject = out[1].as_f32()?.to_vec();
        self.buf = out[2].as_f32()?.to_vec();
        self.seen = out[3].as_i32()?[0];
        Ok((flags, reject))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StreamingEmbedding;
    use crate::runtime::artifacts_available;

    fn runtime() -> Option<Arc<XlaRuntime>> {
        if !artifacts_available() {
            return None;
        }
        crate::runtime::shared_runtime()
    }

    #[test]
    fn xla_fpca_tracks_low_rank_stream() {
        let Some(rt) = runtime() else { return };
        let d = rt.manifest().config.dim;
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(5);
        let data = crate::proptest::gen_low_rank(&mut rng, d, 256, 3, 0.01);
        let mut xf = XlaFpca::new(rt, d).unwrap();
        for t in 0..data.cols() {
            xf.observe(data.col(t));
        }
        assert!(xf.blocks_processed() >= 8);
        let est = xf.estimate();
        let truth = crate::linalg::svd_truncated(&data, 3);
        let dist = crate::linalg::subspace_distance(&est.truncate(3).u, &truth.u);
        assert!(dist < 0.2, "artifact-tracked subspace off: {dist}");
    }

    #[test]
    fn xla_merge_matches_native() {
        let Some(rt) = runtime() else { return };
        let cfg = rt.manifest().config;
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(9);
        let s1 = Subspace::new(
            crate::proptest::gen_orthonormal(&mut rng, cfg.dim, cfg.rank),
            vec![4.0, 3.0, 2.0, 1.0],
        );
        let s2 = Subspace::new(
            crate::proptest::gen_orthonormal(&mut rng, cfg.dim, cfg.rank),
            vec![2.0, 1.5, 1.0, 0.5],
        );
        let xla = xla_merge(&rt, &s1, &s2, 1.0).unwrap();
        let native = crate::fpca::merge_subspaces(
            &s1,
            &s2,
            crate::fpca::MergeOptions::rank(cfg.rank),
        );
        for (a, b) in xla.sigma.iter().zip(native.sigma.iter()) {
            let rel = (a - b).abs() / b.max(1e-9);
            assert!(rel < 0.03, "sigma {a} vs {b}");
        }
        let dist = crate::linalg::subspace_distance(&xla.u, &native.u);
        assert!(dist < 0.05, "merged span mismatch {dist}");
    }

    #[test]
    fn xla_project_detect_matches_native_flags() {
        let Some(rt) = runtime() else { return };
        let cfg = rt.manifest().config;
        let (d, r, b) = (cfg.dim, cfg.rank, cfg.block);
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(13);
        let u = crate::proptest::gen_orthonormal(&mut rng, d, r);
        let est = Subspace::new(u.clone(), vec![4.0, 3.0, 2.0, 1.0]);

        // Stream: steady noise plus one aligned spike per block after warmup.
        let mut y = vec![0.0f32; b * d];
        for t in 0..b {
            for i in 0..d {
                y[t * d + i] = (0.05 * rng.normal()) as f32;
            }
        }
        for i in 0..d {
            y[20 * d + i] += (40.0 * u.get(i, 0)) as f32;
        }

        let mut xpd = XlaProjectDetect::new(rt);
        let (_, reject) = xpd.run_block(&est, &y).unwrap();

        // Native path over the same stream.
        let mut rj = crate::scheduler::RejectJob::new(crate::scheduler::RejectConfig {
            max_rank: r,
            ..Default::default()
        });
        let mut native_reject = Vec::new();
        for t in 0..b {
            let row: Vec<f64> = (0..d).map(|i| f64::from(y[t * d + i])).collect();
            native_reject.push(rj.observe(&est, &row) as u8 as f32);
        }
        assert_eq!(reject.len(), native_reject.len());
        for (t, (a, nb)) in reject.iter().zip(native_reject.iter()).enumerate() {
            assert_eq!(a, nb, "rejection mismatch at t={t}");
        }
        assert!(reject[20] == 1.0, "aligned spike must reject");
    }
}
