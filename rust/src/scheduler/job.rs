//! Job/task model (the paper uses the terms interchangeably, §4).

use crate::rng::Xoshiro256;

/// Unique job identifier.
pub type JobId = u64;

/// Scheduling priority: higher values are served first. Wait queues order
/// strictly by priority (FIFO or smallest-first *within* a priority class)
/// and pressure preemption sheds the lowest priority first, so `0` is the
/// most preemptible class and `u8::MAX` the most protected. The default
/// single-class fleets put every job at `0`.
pub type Priority = u8;

/// A schedulable unit of work arriving at the data center.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: JobId,
    /// Arrival timestep (20 s trace ticks).
    pub arrival: usize,
    /// Nominal duration in timesteps once started.
    pub duration: usize,
    /// Relative CPU demand (1.0 = one nominal slot).
    pub cpu_demand: f64,
    /// Whole scheduling slots the job occupies on its host while running.
    /// The discrete-event engine keeps its own compact per-job record
    /// (`sim::engine`) for the hot loop; its `demand` field must mean the
    /// same thing as this one.
    pub slots: u32,
    /// Scheduling class (see [`Priority`]); default 0.
    pub priority: Priority,
}

impl Job {
    pub fn new(id: JobId, arrival: usize, duration: usize, cpu_demand: f64) -> Self {
        assert!(duration >= 1);
        assert!(cpu_demand > 0.0);
        Self { id, arrival, duration, cpu_demand, slots: 1, priority: 0 }
    }

    /// Builder-style slot demand override.
    pub fn with_slots(mut self, slots: u32) -> Self {
        assert!(slots >= 1);
        self.slots = slots;
        self
    }

    /// Builder-style priority override.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }
}

/// Log-normal service-time distribution in whole telemetry steps — the
/// job-length model every scenario draws from (heavy right tail: most jobs
/// are short, a few run for a long time).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceTimeModel {
    /// Mean of the underlying normal.
    pub mu: f64,
    /// Standard deviation of the underlying normal.
    pub sigma: f64,
}

impl ServiceTimeModel {
    pub fn log_normal(mu: f64, sigma: f64) -> Self {
        Self { mu, sigma }
    }

    /// Draw a whole-step duration, always at least one step.
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        rng.log_normal(self.mu, self.sigma).round().max(1.0) as usize
    }

    /// Expected duration in steps (log-normal mean).
    pub fn mean_steps(&self) -> f64 {
        (self.mu + 0.5 * self.sigma * self.sigma).exp()
    }
}

/// Final disposition of a job in the simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobOutcome {
    /// Accepted by a node at the given timestep.
    Accepted { node: usize, at: usize },
    /// Rejected by every probed node.
    Rejected { at: usize },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_construction() {
        let j = Job::new(1, 0, 10, 1.5);
        assert_eq!(j.duration, 10);
        assert_eq!(j.slots, 1);
        assert_eq!(j.priority, 0);
        let j = j.with_slots(3).with_priority(2);
        assert_eq!(j.slots, 3);
        assert_eq!(j.priority, 2);
    }

    #[test]
    #[should_panic]
    fn zero_duration_rejected() {
        let _ = Job::new(1, 0, 0, 1.0);
    }

    #[test]
    #[should_panic]
    fn zero_slots_rejected() {
        let _ = Job::new(1, 0, 5, 1.0).with_slots(0);
    }

    #[test]
    fn service_time_samples_are_positive_and_deterministic() {
        let model = ServiceTimeModel::log_normal(3.0, 0.8);
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        for _ in 0..500 {
            let da = model.sample(&mut a);
            assert!(da >= 1);
            assert_eq!(da, model.sample(&mut b));
        }
        // Sample mean tracks the analytic log-normal mean.
        let mut rng = Xoshiro256::seed_from_u64(11);
        let n = 20_000;
        let mean: f64 =
            (0..n).map(|_| model.sample(&mut rng) as f64).sum::<f64>() / n as f64;
        assert!(
            (mean - model.mean_steps()).abs() / model.mean_steps() < 0.1,
            "mean={mean} expected≈{}",
            model.mean_steps()
        );
    }
}
