//! Evaluation metrics: RMSE, the paper's spike accuracy, streaming moments,
//! and empirical CDFs (the figures' primitive).

mod cdf;
mod stats;

pub use cdf::EmpiricalCdf;
pub use stats::{OnlineStats, Quantiles};

/// Root mean square error between prediction and truth.
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty());
    let mse = pred
        .iter()
        .zip(truth)
        .map(|(p, t)| (p - t) * (p - t))
        .sum::<f64>()
        / pred.len() as f64;
    mse.sqrt()
}

/// The paper's spike-forecast accuracy (§3.2): the balanced mean of the
/// spike hit-rate and the non-spike hit-rate,
/// `(predicted_spikes/actual_spikes + predicted_nonspikes/actual_nonspikes) / 2`.
/// Classes absent from the truth contribute a perfect score (matching the
/// convention that a method cannot be penalized for a class that never
/// occurs).
pub fn spike_accuracy(pred_spike: &[bool], true_spike: &[bool]) -> f64 {
    assert_eq!(pred_spike.len(), true_spike.len());
    let mut tp = 0usize;
    let mut tn = 0usize;
    let mut p = 0usize;
    let mut n = 0usize;
    for (&pr, &tr) in pred_spike.iter().zip(true_spike) {
        if tr {
            p += 1;
            if pr {
                tp += 1;
            }
        } else {
            n += 1;
            if !pr {
                tn += 1;
            }
        }
    }
    let spike_rate = if p == 0 { 1.0 } else { tp as f64 / p as f64 };
    let non_rate = if n == 0 { 1.0 } else { tn as f64 / n as f64 };
    (spike_rate + non_rate) / 2.0
}

/// Min-max normalization to [0, 1] (paper §3.1: inputs are scaled before
/// fitting "to improve the stability of the solvers"). Returns the scaled
/// series with the (min, span) needed to de-normalize.
pub fn normalize(xs: &[f64]) -> (Vec<f64>, f64, f64) {
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = if (hi - lo).abs() < 1e-12 { 1.0 } else { hi - lo };
    (xs.iter().map(|x| (x - lo) / span).collect(), lo, span)
}

/// Undo [`normalize`].
pub fn denormalize(xs: &[f64], lo: f64, span: f64) -> Vec<f64> {
    xs.iter().map(|x| x * span + lo).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmse_known() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn spike_accuracy_perfect_and_inverted() {
        let t = [true, false, true, false];
        assert_eq!(spike_accuracy(&t, &t), 1.0);
        let inv: Vec<bool> = t.iter().map(|x| !x).collect();
        assert_eq!(spike_accuracy(&inv, &t), 0.0);
    }

    #[test]
    fn spike_accuracy_balanced() {
        // Predict everything non-spike on 25% spikes: 0.5·(0 + 1) = 0.5.
        let truth = [true, false, false, false];
        let pred = [false, false, false, false];
        assert_eq!(spike_accuracy(&pred, &truth), 0.5);
    }

    #[test]
    fn spike_accuracy_no_spikes_in_truth() {
        let truth = [false, false];
        assert_eq!(spike_accuracy(&[false, false], &truth), 1.0);
        assert_eq!(spike_accuracy(&[true, true], &truth), 0.5);
    }

    #[test]
    fn normalize_roundtrip() {
        let xs = [5.0, 10.0, 7.5];
        let (n, lo, span) = normalize(&xs);
        assert_eq!(n, vec![0.0, 1.0, 0.5]);
        assert_eq!(denormalize(&n, lo, span), xs.to_vec());
    }

    #[test]
    fn normalize_constant_series() {
        let (n, _, _) = normalize(&[3.0, 3.0, 3.0]);
        assert!(n.iter().all(|x| x.is_finite()));
    }
}
