// Fixture: a pragma that suppresses nothing is itself a finding.
// pronto-lint: allow(wall-clock) — stale waiver kept after the fix landed
pub fn logical(now_steps: u64) -> u64 {
    now_steps
}
