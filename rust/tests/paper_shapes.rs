//! Paper-shape regression tests: the qualitative claims of every table and
//! figure must hold at quick scale. These are the "does the reproduction
//! still reproduce" guardrails; exact values live in EXPERIMENTS.md.

use pronto::bench::experiments::*;
use pronto::forecast::SpikeThreshold;
use pronto::sim::EvalConfig;

fn scale() -> ExperimentScale {
    ExperimentScale {
        vms_per_cluster: 4,
        clusters: 2,
        steps_per_day: 144,
        history_days: 21,
        fleet: 8,
        fleet_steps: 4_000,
        seed: 0xBEEF,
    }
}

#[test]
fn table1_shape_errors_large_everywhere() {
    // §3's point: no offline method forecasts CPU Ready well. All cells
    // carry substantial error relative to the typical daily-median level.
    let rows = table1_rmse(&scale());
    for (name, cells) in &rows {
        for &c in cells {
            assert!(c.is_finite() && c > 1.0, "{name}: suspiciously small RMSE {c}");
        }
    }
}

#[test]
fn table3_shape_rmse_grows_as_window_shrinks() {
    let (_, rows) = table3_windows(&scale());
    for (name, cells) in &rows {
        // Short windows (1h and below — last 3 columns) must be much worse
        // than the 1-day column.
        let long = cells[0];
        let short_worst = cells[cells.len() - 3..]
            .iter()
            .cloned()
            .fold(f64::MIN, f64::max);
        assert!(
            short_worst > long,
            "{name}: short-window RMSE {short_worst} not worse than 1-day {long}"
        );
    }
}

#[test]
fn table456_shape_rarer_spikes_are_easier() {
    let (rows, pct) = spike_tables(
        &scale(),
        &[
            SpikeThreshold::Fixed(500.0),
            SpikeThreshold::Fixed(1000.0),
            SpikeThreshold::Median,
        ],
    );
    // Spike fraction: 500 > 1000; median much larger than both.
    assert!(pct[0] > pct[1], "spike% ordering broken: {pct:?}");
    assert!(pct[2] > pct[0], "median threshold should flag most values: {pct:?}");
    for (name, cells) in &rows {
        // Accuracy at 1000 must beat accuracy at the median threshold
        // (well-defined rare spikes vs half-the-data "spikes").
        assert!(
            cells[1] > cells[2],
            "{name}: 1000ms acc {} not above median acc {}",
            cells[1],
            cells[2]
        );
    }
}

#[test]
fn fig6_shape_left_raises_exceed_right() {
    let fleets = figure67_fleets(&scale(), &EvalConfig::default());
    for f in &fleets {
        let left: usize = f.nodes.iter().flat_map(|n| &n.left_counts).sum();
        let right: usize = f.nodes.iter().flat_map(|n| &n.right_counts).sum();
        assert!(
            left >= right,
            "{}: left {left} < right {right} (early warnings should dominate)",
            f.method
        );
    }
}

#[test]
fn fig6_shape_pronto_catches_spikes() {
    let fleets = figure67_fleets(&scale(), &EvalConfig::default());
    let pronto = &fleets[0];
    assert_eq!(pronto.method, "PRONTO");
    assert!(
        pronto.mean_prediction_rate() > 0.35,
        "PRONTO prediction rate collapsed: {:.3}",
        pronto.mean_prediction_rate()
    );
}

#[test]
fn fig7_shape_downtime_low_for_all_embedding_methods() {
    // Paper: PRONTO/SP/PM very low downtime. (FD's pathological >50%
    // downtime stems from the original prototype's unstable sketch basis;
    // our cleaner FD implementation does not reproduce the collapse — see
    // EXPERIMENTS.md §Deviations.)
    let fleets = figure67_fleets(&scale(), &EvalConfig::default());
    for f in &fleets {
        assert!(
            f.mean_downtime() < 0.3,
            "{}: downtime {:.3} unexpectedly high",
            f.method,
            f.mean_downtime()
        );
    }
}

#[test]
fn contained_pct_near_or_above_spike_rate() {
    // Figure 7b: methods raise the signal at a rate comparable to (or
    // above) the spike rate itself.
    let fleets = figure67_fleets(&scale(), &EvalConfig::default());
    for f in &fleets {
        let total_spikes: usize = f.nodes.iter().map(|n| n.ready_spikes).sum();
        let total_raises: usize = f.nodes.iter().map(|n| n.rejection_raises).sum();
        assert!(total_spikes > 0);
        assert!(
            total_raises * 2 >= total_spikes,
            "{}: raises {total_raises} ≪ spikes {total_spikes}",
            f.method
        );
    }
}
