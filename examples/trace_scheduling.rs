//! End-to-end driver: the full three-layer system on a realistic workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example trace_scheduling
//! ```
//!
//! Composition proof for the whole stack:
//!   L1/L2 — the AOT-compiled `fpca_update` / `project_detect` HLO
//!           artifacts (Pallas projection kernel inside) execute on the
//!           PJRT CPU client for node 0's pipeline;
//!   L3    — the Rust coordinator runs a 24-node data center: telemetry
//!           ticks, Poisson job arrivals, power-of-2 dispatch, per-node
//!           PRONTO admission (native FPCA-Edge on the other 23 nodes).
//!
//! Reports the paper's headline quantities: spike-prediction rate,
//! downtime, placement quality vs the always-accept and oracle baselines,
//! plus decision latency. Results are recorded in EXPERIMENTS.md §E2E.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::baselines::StreamingEmbedding;
use pronto::fpca::{FpcaEdge, FpcaEdgeConfig};
use pronto::scheduler::{
    Admission, CpuReadyOracle, NodeScheduler, ProntoPolicy, RandomPolicy, RejectConfig,
};
use pronto::sim::{DataCenterSim, SimConfig};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, CPU_READY_IDX};
use std::time::Instant;

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<pronto::telemetry::VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 8, v, steps)).collect()
}

fn run_policy(
    label: &str,
    traces: &[pronto::telemetry::VmTrace],
    policies: Vec<Box<dyn Admission>>,
) {
    let t0 = Instant::now();
    let report = DataCenterSim::new(SimConfig::default(), traces.to_vec(), policies).run();
    let wall = t0.elapsed();
    let decisions = report.steps * report.nodes;
    println!(
        "{label:<14} accept {:>5.1}%  placement-quality {:>5.1}%  rejection-precision {:>5.1}%  ({} jobs, {:.2} µs/decision)",
        100.0 * report.acceptance_rate(),
        100.0 * report.placement_quality(),
        100.0 * report.rejection_precision(),
        report.jobs_arrived,
        wall.as_micros() as f64 / decisions as f64,
    );
}

fn main() {
    let nodes = 24;
    let steps = 6_000; // ≈ 33 h of 20 s samples per node
    println!("end-to-end: {nodes} nodes x {steps} steps, Poisson job stream\n");
    let traces = fleet(nodes, steps, 2021);
    let d = traces[0].dim();

    // --- L1/L2 composition check: artifact-backed pipeline on node 0 ----
    match pronto::runtime::shared_runtime() {
        Some(rt) => {
            let t0 = Instant::now();
            let mut xf = pronto::runtime::XlaFpca::new(rt.clone(), d).expect("XlaFpca");
            let mut pd = pronto::runtime::XlaProjectDetect::new(rt.clone());
            let cfg = rt.manifest().config;
            let mut rejects = 0usize;
            let mut blocks = 0usize;
            let tr = &traces[0];
            let mut block_buf = vec![0.0f32; cfg.block * cfg.dim];
            for t in 0..steps {
                let y = tr.features(t);
                // Fill the detect block row-major (b × d).
                let row = t % cfg.block;
                for i in 0..d.min(cfg.dim) {
                    block_buf[row * cfg.dim + i] = y[i] as f32;
                }
                xf.observe(y);
                if row == cfg.block - 1 {
                    let est = xf.estimate();
                    if !est.is_empty() {
                        let (_, reject) = pd.run_block(&est, &block_buf).expect("detect");
                        rejects += reject.iter().filter(|&&r| r == 1.0).count();
                    }
                    blocks += 1;
                }
            }
            println!(
                "L1/L2 artifact path (node 0): {blocks} blocks through fpca_update + project_detect, {} rejection steps, {:.1} µs/observation",
                rejects,
                t0.elapsed().as_micros() as f64 / steps as f64
            );
        }
        None => {
            println!("L1/L2 artifacts not built (run `make artifacts`); skipping XLA path");
        }
    }

    // --- L3: full-fleet simulations under competing policies -----------
    println!("\npolicy comparison (same traces, same job stream):");
    let pronto_policies: Vec<Box<dyn Admission>> = traces
        .iter()
        .map(|t| {
            Box::new(ProntoPolicy::new(NodeScheduler::with_embedding(
                FpcaEdge::new(t.dim(), FpcaEdgeConfig::default()),
                RejectConfig::default(),
            ))) as Box<dyn Admission>
        })
        .collect();
    run_policy("PRONTO", &traces, pronto_policies);

    let always: Vec<Box<dyn Admission>> = traces
        .iter()
        .map(|_| Box::new(RandomPolicy::always_accept(3)) as Box<dyn Admission>)
        .collect();
    run_policy("always-accept", &traces, always);

    let random: Vec<Box<dyn Admission>> = traces
        .iter()
        .enumerate()
        .map(|(i, _)| Box::new(RandomPolicy::new(0.2, i as u64)) as Box<dyn Admission>)
        .collect();
    run_policy("random-20%", &traces, random);

    let oracle: Vec<Box<dyn Admission>> = traces
        .iter()
        .map(|_| Box::new(CpuReadyOracle::new(CPU_READY_IDX, 1000.0)) as Box<dyn Admission>)
        .collect();
    run_policy("oracle", &traces, oracle);

    // --- Spike-prediction headline (Figure 6 criterion) ----------------
    let tr = &traces[1];
    let mut node = NodeScheduler::new(d, RejectConfig::default());
    let mut raised = Vec::with_capacity(steps);
    for t in 0..steps {
        node.observe(tr.features(t));
        raised.push(node.rejection_raised());
    }
    let mut spikes = 0;
    let mut predicted = 0;
    for t in 0..steps {
        if tr.cpu_ready(t) >= 1000.0 {
            spikes += 1;
            let lo = t.saturating_sub(5);
            if raised[lo..=t].iter().any(|&r| r) {
                predicted += 1;
            }
        }
    }
    println!(
        "\nheadline (node 1): {predicted}/{spikes} CPU Ready spikes preceded by a rejection raise ({:.0}%), downtime {:.1}%",
        100.0 * predicted as f64 / spikes.max(1) as f64,
        100.0 * node.stats().downtime()
    );
}
