//! Baseline streaming-PCA methods (paper §7 evaluation).
//!
//! The paper compares PRONTO's embedding engine (FPCA-Edge) against three
//! established streaming subspace trackers:
//!
//! * **SPIRIT** (Papadimitriou, Sun, Faloutsos 2005) — PAST-style recursive
//!   least squares with energy-based rank adaptation; produces (approximate)
//!   singular values.
//! * **Frequent Directions** (Liberty 2013) — deterministic matrix sketching;
//!   produces a basis but no usable spectrum.
//! * **Block Power Method** (Mitliagkas, Caramanis, Jain 2013) — memory-
//!   limited streaming PCA via block power iterations; no spectrum either.
//!
//! All four implement [`StreamingEmbedding`], the interface the scheduler's
//! Reject-Job consumes. Methods that cannot produce singular values fall
//! back to the paper's synthetic decay spectrum σ_r = 1/r
//! ([`decay_spectrum`]), exactly as §7 prescribes.

mod frequent_directions;
mod power_method;
mod spirit;

pub use frequent_directions::FrequentDirections;
pub use power_method::BlockPowerMethod;
pub use spirit::{Spirit, SpiritConfig};

use crate::fpca::{FpcaEdge, Subspace};

/// The streaming interface Reject-Job consumes: feed observations one at a
/// time, read back the current `(U, Σ)` estimate.
pub trait StreamingEmbedding {
    /// Consume one d-dimensional observation.
    fn observe(&mut self, y: &[f64]);

    /// Current subspace estimate (may be empty before warmup).
    fn estimate(&self) -> Subspace;

    /// Ambient dimension d.
    fn dim(&self) -> usize;

    /// Current tracked rank.
    fn rank(&self) -> usize;

    /// Short method tag used in tables/figures ("PRONTO", "SP", "FD", "PM").
    fn name(&self) -> &'static str;

    /// Whether the method produces its own (approximate) singular values.
    /// When `false`, [`Subspace::sigma`] holds the synthetic σ_r = 1/r decay.
    fn has_spectrum(&self) -> bool;

    /// Monotone counter that changes whenever [`estimate`] would return a
    /// different subspace; `None` means "unknown — assume it changes every
    /// observation". Block methods (FPCA, PM) bump it once per block, which
    /// lets the scheduler cache the estimate between refreshes instead of
    /// cloning it every timestep (§Perf).
    ///
    /// [`estimate`]: StreamingEmbedding::estimate
    fn version(&self) -> Option<u64> {
        None
    }

    /// Absorb a (possibly stale) merged global subspace pulled from the
    /// federation (§5.2 transient-node seeding). `forget` down-weights the
    /// global side. Methods without a meaningful way to ingest external
    /// state ignore the pull — the default is a no-op.
    fn absorb_estimate(&mut self, _global: &Subspace, _forget: f64) {}
}

/// The paper's fallback spectrum for methods without singular values:
/// σ_r = 1/r, r = 1…k.
pub fn decay_spectrum(k: usize) -> Vec<f64> {
    (1..=k).map(|r| 1.0 / r as f64).collect()
}

impl StreamingEmbedding for FpcaEdge {
    fn observe(&mut self, y: &[f64]) {
        FpcaEdge::observe(self, y);
    }

    fn estimate(&self) -> Subspace {
        FpcaEdge::estimate(self).clone()
    }

    fn dim(&self) -> usize {
        FpcaEdge::dim(self)
    }

    fn rank(&self) -> usize {
        FpcaEdge::rank(self)
    }

    fn name(&self) -> &'static str {
        "PRONTO"
    }

    fn has_spectrum(&self) -> bool {
        true
    }

    fn version(&self) -> Option<u64> {
        Some((self.blocks_processed() + self.external_pulls()) as u64)
    }

    fn absorb_estimate(&mut self, global: &Subspace, forget: f64) {
        FpcaEdge::pull_global_estimate(self, global, forget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_spectrum_values() {
        let s = decay_spectrum(4);
        assert_eq!(s, vec![1.0, 0.5, 1.0 / 3.0, 0.25]);
    }

    #[test]
    fn fpca_edge_implements_trait() {
        let mut e: Box<dyn StreamingEmbedding> =
            Box::new(FpcaEdge::new(8, crate::fpca::FpcaEdgeConfig::default()));
        assert_eq!(e.name(), "PRONTO");
        assert!(e.has_spectrum());
        for _ in 0..40 {
            e.observe(&[1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        }
        assert_eq!(e.estimate().dim(), 8);
    }
}
