//! End-to-end prediction-quality tests: a synthetic-oracle run scored
//! through the full report path, and byte-parity of `EVAL_quality.json`
//! across trace sources, thread widths, and repeat runs — the artifact's
//! core contract (the document records neither setting, so identical
//! bytes are the witness).

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::scheduler::JobOutcome;
use pronto::sim::{score_report, SignalCapture, SimReport};

fn argv(args: &[&str]) -> Vec<String> {
    args.iter().map(|s| s.to_string()).collect()
}

#[test]
fn synthetic_oracle_report_scores_perfectly_with_exact_lead() {
    // Hand-built capture: two nodes, spikes every 13 steps, the raise
    // indicator shifted exactly 2 steps early. Spacing 13 > left_span(10)
    // = 4, so no spike can inherit a neighbour's raise: precision =
    // recall = 1.0 and every lead is exactly 2.
    let steps = 130;
    let mut capture = SignalCapture::default();
    for node in 0..2usize {
        let mut spikes = vec![false; steps];
        let mut raised = vec![false; steps];
        for t in (10 + node..steps - 5).step_by(13) {
            spikes[t] = true;
            raised[t - 2] = true;
        }
        capture.spikes.push(spikes);
        capture.raised.push(raised);
    }
    // Engine rejections landing right on the earliest raise onsets (node
    // 0 first raises at 8, node 1 at 9): those two onsets score latency
    // 0; every later onset has no rejection at/after it and is censored.
    let report = SimReport {
        scenario: "synthetic".into(),
        nodes: 2,
        steps,
        seed: 7,
        outcomes: vec![
            JobOutcome::Rejected { at: 8 },
            JobOutcome::Rejected { at: 9 },
        ],
        signal_capture: Some(capture),
        ..Default::default()
    };
    let row = score_report(&report, 10, "ORACLE");
    assert_eq!(row.precision, 1.0);
    assert_eq!(row.recall, 1.0);
    assert_eq!(row.f1, 1.0);
    assert_eq!(row.false_positive_rate, 0.0);
    assert!(row.spikes > 0 && row.spikes == row.predicted_spikes);
    assert_eq!(row.mean_lead_steps, 2.0);
    assert_eq!(row.lead_p50, 2.0);
    assert_eq!(row.lead_p99, 2.0);
    // The earliest onsets (8 on node 0, 9 on node 1) meet rejections at
    // 0 latency; later onsets are censored (no rejection after them)
    // and drop out.
    assert_eq!(row.decision_samples, 2);
    assert_eq!(row.mean_decision_latency_steps, 0.0);
    assert_eq!(row.recall_node_p90, 1.0);
    assert_eq!(row.precision_node_p50, 1.0);
}

/// Run `pronto eval --scenario …` to a temp file and return the artifact
/// bytes.
fn eval_bytes(dir: &std::path::Path, label: &str, extra: &[&str]) -> String {
    let out = dir.join(format!("EVAL_{label}.json"));
    let out_s = out.to_string_lossy().to_string();
    let mut args = argv(&[
        "eval",
        "--scenario",
        "capacity",
        "--nodes",
        "6",
        "--steps",
        "300",
        "--method",
        "pronto,sp",
        "--out",
        &out_s,
    ]);
    args.extend(extra.iter().map(|s| s.to_string()));
    pronto::cli::run(&args).expect("eval run failed");
    std::fs::read_to_string(&out).expect("artifact written")
}

#[test]
fn eval_quality_bytes_identical_across_sources_threads_and_repeats() {
    let dir = std::env::temp_dir().join("pronto_eval_quality_parity");
    std::fs::create_dir_all(&dir).unwrap();

    let baseline = eval_bytes(&dir, "mat1", &["--trace-source", "materialized"]);
    let repeat = eval_bytes(&dir, "mat1b", &["--trace-source", "materialized"]);
    assert_eq!(baseline, repeat, "repeat run diverged");

    let streamed = eval_bytes(&dir, "stream1", &["--trace-source", "stream"]);
    assert_eq!(baseline, streamed, "streaming trace source diverged");

    let threaded = eval_bytes(
        &dir,
        "mat4",
        &["--trace-source", "materialized", "--threads", "4"],
    );
    assert_eq!(baseline, threaded, "threads=4 diverged");

    let streamed_threaded =
        eval_bytes(&dir, "stream4", &["--trace-source", "stream", "--threads", "4"]);
    assert_eq!(baseline, streamed_threaded, "stream+threads diverged");

    // Sanity: the document actually carries rows for both methods and a
    // nonzero spike population (capacity's calibrated traces spike).
    let doc = pronto::ser::parse_json(&baseline).expect("valid artifact");
    let rows = doc.get("rows").and_then(pronto::ser::JsonValue::as_array).unwrap();
    assert_eq!(rows.len(), 2);
    assert!(
        rows.iter().all(|r| r
            .get("spikes")
            .and_then(pronto::ser::JsonValue::as_usize)
            .unwrap()
            > 0),
        "no ground-truth spikes captured"
    );

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn different_seeds_produce_different_rows() {
    let dir = std::env::temp_dir().join("pronto_eval_quality_seeds");
    std::fs::create_dir_all(&dir).unwrap();
    let a = eval_bytes(&dir, "s1", &["--seed", "1"]);
    let b = eval_bytes(&dir, "s2", &["--seed", "2"]);
    assert_ne!(a, b, "seed must drive the rows");
    std::fs::remove_dir_all(&dir).ok();
}
