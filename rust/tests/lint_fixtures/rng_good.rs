// Fixture: seeds derived through the registry-tagged helpers.
pub fn tagged(seed: u64) -> u64 {
    crate::rng::stream_seed(seed, crate::rng::streams::ARRIVALS)
}

pub fn per_node(seed: u64, node: usize) -> u64 {
    crate::rng::node_stream_seed(seed, crate::rng::streams::DISPATCH, node)
}
