//! PJRT runtime: load and execute the AOT-compiled HLO artifacts.
//!
//! The bridge between the build-time Python world and the request-path
//! Rust world: [`manifest`] parses `artifacts/manifest.json` (with the
//! in-crate JSON parser — no serde in this environment), [`client`] wraps
//! the `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → compile → execute), and [`engine`] exposes typed entry points for the
//! three artifacts (`fpca_update`, `merge_subspaces`, `project_detect`)
//! plus an [`engine::XlaFpca`] adapter implementing
//! [`crate::baselines::StreamingEmbedding`] so the artifact-backed path
//! drops into every scheduler/bench unchanged.

pub mod client;
pub mod engine;
pub mod manifest;

pub use client::XlaRuntime;
pub use engine::{xla_merge, XlaFpca, XlaProjectDetect};
pub use manifest::{ArtifactEntry, Manifest};

/// Default artifacts directory, relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory: `$PRONTO_ARTIFACTS`, else `artifacts/`
/// relative to the current dir, else relative to the crate manifest dir.
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("PRONTO_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACTS_DIR);
    if cwd.join("manifest.json").exists() {
        return cwd;
    }
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACTS_DIR)
}

/// True when compiled artifacts are present (tests gate on this so the
/// suite still passes before `make artifacts`).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Process-wide shared runtime. XLA compilation of the artifacts is
/// expensive; tests, benches, and the CLI all share this single compiled
/// instance. Returns `None` when artifacts are absent or compilation fails
/// (callers degrade to the native path).
pub fn shared_runtime() -> Option<std::sync::Arc<XlaRuntime>> {
    use once_cell::sync::Lazy;
    static RT: Lazy<Option<std::sync::Arc<XlaRuntime>>> = Lazy::new(|| {
        if !artifacts_available() {
            return None;
        }
        match XlaRuntime::load_default() {
            Ok(rt) => Some(std::sync::Arc::new(rt)),
            Err(e) => {
                eprintln!("warn: XLA runtime unavailable ({e}); using native path");
                None
            }
        }
    });
    RT.clone()
}
