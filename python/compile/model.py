"""L2: the PRONTO compute graphs, AOT-lowered to HLO artifacts.

Three jitted functions, all static-shaped, all calling the L1 Pallas
kernels, all free of LAPACK custom-calls (see ``linalg.py``):

* ``fpca_update`` — one FPCA-Edge block update (Algorithm 5 at fixed rank):
  SVD_r of [λ·UΣ | B] via Gram + orthogonal iteration. Handles the empty
  estimate (Σ = 0) transparently — the first block reduces to SVD_r(B).
* ``merge_subspaces`` — aggregator merge (Algorithm 3/4 semantics):
  SVD_r of [λ₁·U₁Σ₁ | λ₂·U₂Σ₂].
* ``project_detect`` — a block of Reject-Job (Algorithm 1): project b
  observations onto (U, Σ), run the streaming z-score filter as a
  ``lax.scan``, and emit per-step ternary spike flags plus the rejection
  signal. State (the dampened lag buffer + count) threads through so the
  Rust runtime can call block after block.

The paper's evaluation fixes r = 4 (§7.1); rank *adaptation* (Eq. 7) is a
per-block, data-dependent reshape and lives in the Rust native path — the
artifact path compiles one module per (d, r, b) configuration instead
(`aot.py` emits the default d=52, r=4, b=32, lag=10).
"""

import jax
import jax.numpy as jnp

from .kernels.projection import project_block
from .linalg import svd_topk

# The z-score constants of Algorithm 1.
ZSCORE_ALPHA = 3.5
ZSCORE_BETA = 0.5
REJECT_THRESHOLD = 1.0


def fpca_update(u, s, block, forget):
    """One FPCA-Edge block update at fixed rank.

    Args:
      u: (d, r) current orthonormal estimate (zeros when empty).
      s: (r,) current singular values (zeros when empty).
      block: (d, b) new observations, one column per timestep.
      forget: scalar λ ∈ (0, 1] down-weighting the previous estimate.

    Returns:
      (u', s'): the updated rank-r estimate of SVD_r([λ·U diag(S) | B]).
    """
    d, r = u.shape
    m = jnp.concatenate([forget * u * s[None, :], block], axis=1)
    u2, s2, _ = svd_topk(m, r)
    return u2, s2


def merge_subspaces(u1, s1, u2, s2, forget):
    """Aggregator merge: SVD_r([λ·U₁Σ₁ | U₂Σ₂]) (Algorithm 3 semantics;
    Algorithm 4 is the same operator factored to avoid Vᵀ — our Gram-based
    svd_topk never forms Vᵀ either)."""
    r = u1.shape[1]
    m = jnp.concatenate([forget * u1 * s1[None, :], u2 * s2[None, :]], axis=1)
    um, sm, _ = svd_topk(m, r)
    return um, sm


def _zscore_step(carry, p_row, *, lag):
    """One timestep of the multi-lane z-score filter (Algorithm 1 body).

    carry: (buf (r, lag) dampened history, seen scalar int32)
    p_row: (r,) projections at this timestep.
    Returns new carry and (flags (r,) in {−1,0,+1} float32).
    """
    buf, seen = carry
    warmed = seen >= lag
    mean = jnp.mean(buf, axis=1)
    std = jnp.std(buf, axis=1)
    dev = p_row - mean
    is_spike = warmed & (jnp.abs(dev) > ZSCORE_ALPHA * std) & (std > 0)
    flags = jnp.where(is_spike, jnp.sign(dev), 0.0).astype(p_row.dtype)
    # Dampened entry for flagged lanes: β·x + (1−β)·previous.
    last = buf[:, -1]
    entering = jnp.where(
        is_spike, ZSCORE_BETA * p_row + (1.0 - ZSCORE_BETA) * last, p_row
    )
    buf = jnp.concatenate([buf[:, 1:], entering[:, None]], axis=1)
    return (buf, seen + 1), flags


def project_detect(u, s, y_block, buf, seen):
    """A block of Reject-Job evaluations.

    Args:
      u: (d, r) embedding; s: (r,) singular values.
      y_block: (b, d) observations, one row per timestep.
      buf: (r, lag) dampened-history state of the z-score filter.
      seen: () int32 — observations consumed so far.

    Returns:
      flags: (b, r) ternary spike indicators,
      reject: (b,) float32 {0, 1} rejection signal per timestep,
      buf', seen': threaded filter state.
    """
    lag = buf.shape[1]
    # L1 kernel: P = Y·U (b × r).
    p = project_block(y_block, u)

    (buf, seen), flags = jax.lax.scan(
        lambda c, row: _zscore_step(c, row, lag=lag), (buf, seen), p
    )

    # Weighted spike sum with normalized spectrum (RejectConfig parity):
    # R_s = Σ b_i σ_i / Σσ;  reject ⇔ R_s ≥ tr · σ₁/Σσ.
    total = jnp.sum(s)
    denom = jnp.where(total > 0, total, 1.0)
    rs = jnp.dot(flags, s) / denom
    tr = REJECT_THRESHOLD * s[0] / denom
    reject = (rs >= tr).astype(y_block.dtype)
    # Before warmup Algorithm 1 always returns false; the scan's per-step
    # `warmed` gate already zeroes flags, so rs = 0 < tr ⇒ reject = 0,
    # except when tr ≤ 0 (empty spectrum) — force accept there.
    reject = jnp.where(total > 0, reject, jnp.zeros_like(reject))
    return flags, reject, buf, seen
