//! Figure 6: empirical CDFs of left-sided (6a) and right-sided (6b)
//! rejection raises around CPU Ready spikes, per embedding method.
//!
//! Paper shape: left-sided counts dominate right-sided; PRONTO and FD
//! find the most left-sided spikes, then PM and SP.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::bench::experiments::{figure67_fleets, ExperimentScale};
use pronto::bench::Table;
use pronto::sim::EvalConfig;

fn main() {
    let scale = ExperimentScale::from_env();
    let fleets = figure67_fleets(&scale, &EvalConfig::default());

    for (fig, side) in [("6a", "left"), ("6b", "right")] {
        let mut t = Table::new(
            &format!("Figure {fig}: CDF of {side}-sided raises per CPU Ready spike"),
            &["count", "PRONTO", "SP", "FD", "PM"],
        );
        let max_count = 6usize;
        let mut cdfs: Vec<_> = fleets
            .iter()
            .map(|f| if side == "left" { f.left_cdf() } else { f.right_cdf() })
            .collect();
        for c in 0..=max_count {
            let mut row = vec![format!("{c}")];
            for cdf in cdfs.iter_mut() {
                row.push(if cdf.is_empty() {
                    "-".into()
                } else {
                    format!("{:.3}", cdf.eval(c as f64))
                });
            }
            t.row(&row);
        }
        t.print();
        t.maybe_write_csv(&format!("fig{fig}_{side}_cdf"));
    }

    println!("\nper-method mean prediction rate (>=1 left-sided raise):");
    for f in &fleets {
        println!(
            "  {:<8} {:.3}   mean downtime {:.3}",
            f.method,
            f.mean_prediction_rate(),
            f.mean_downtime()
        );
    }
    println!("\nshape: CDF at count=0 lowest for PRONTO/FD (they catch the most spikes).");
}
