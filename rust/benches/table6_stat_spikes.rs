//! Table 6: spike-alarm accuracy with statistical thresholds
//! (μ+3σ / xbar UCL / median).
//!
//! Paper shape: μ+3σ (rare, well-defined spikes) scores highest (~0.975);
//! xbar mid; median worst (~0.49, half the data are "spikes").

use pronto::bench::experiments::{spike_tables, ExperimentScale};
use pronto::bench::Table;
use pronto::forecast::SpikeThreshold;

fn main() {
    let scale = ExperimentScale::from_env();
    let (rows, pct) = spike_tables(
        &scale,
        &[
            SpikeThreshold::MeanPlus3Std,
            SpikeThreshold::XBar,
            SpikeThreshold::Median,
        ],
    );
    let mut t = Table::new(
        "Table 6: alarm accuracy, statistical spike thresholds",
        &["method", "mu+3sigma", "xbar", "median"],
    );
    for (name, c) in rows {
        t.row(&[name, format!("{:.4}", c[0]), format!("{:.4}", c[1]), format!("{:.4}", c[2])]);
    }
    t.row(&[
        "% of spikes".into(),
        format!("{:.2}", pct[0]),
        format!("{:.2}", pct[1]),
        format!("{:.2}", pct[2]),
    ]);
    t.print();
    t.maybe_write_csv("table6");
    println!("\npaper reference: best 0.9754/0.6926/0.4903; spikes 4.6/49.1/24.91%");
}
