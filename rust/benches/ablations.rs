//! Ablations over PRONTO's design choices (DESIGN.md §7):
//!
//! * signed (Algorithm 1 verbatim) vs absolute spike flags in R_s;
//! * online feature standardization on/off;
//! * sliding-window size w ∈ {10, 20, 50} (the paper's practical range);
//! * embedding rank r ∈ {2, 4, 8} (paper fixes 4, reports little gain above);
//! * FPCA block size b ∈ {16, 32, 64}.
//!
//! Metric: fleet mean prediction rate (≥1 left-sided raise per CPU Ready
//! spike) and mean downtime — the Figure 6/7 axes.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::bench::Table;
use pronto::fpca::{FpcaEdge, FpcaEdgeConfig};
use pronto::scheduler::{NodeScheduler, RejectConfig};
use pronto::sim::{evaluate_method, EvalConfig};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

fn fleet(n: usize, steps: usize) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), 4242);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 8, v, steps)).collect()
}

struct Variant {
    label: String,
    fpca: FpcaEdgeConfig,
    eval: EvalConfig,
    standardize: bool,
}

fn run(traces: &[VmTrace], v: &Variant) -> (f64, f64) {
    let d = traces[0].dim();
    let mut pred = 0.0;
    let mut down = 0.0;
    for tr in traces {
        let ev = if v.standardize {
            evaluate_method(FpcaEdge::new(d, v.fpca), tr, &v.eval)
        } else {
            // evaluate_method drives NodeScheduler internally with the
            // standardizer on; replicate its loop with it off.
            let node = NodeScheduler::with_embedding(FpcaEdge::new(d, v.fpca), v.eval.reject)
                .without_standardizer();
            eval_with_node(node, tr, &v.eval)
        };
        pred += ev.prediction_rate();
        down += ev.downtime;
    }
    (pred / traces.len() as f64, down / traces.len() as f64)
}

fn eval_with_node(
    mut node: NodeScheduler<FpcaEdge>,
    trace: &VmTrace,
    cfg: &EvalConfig,
) -> pronto::sim::NodeEvaluation {
    // Mirror of sim::eval::evaluate_method with a pre-built node.
    let t_len = trace.len();
    let mut raised = vec![false; t_len];
    for t in 0..t_len {
        node.observe(trace.features(t));
        raised[t] = node.rejection_raised();
    }
    let half = cfg.window / 2;
    let mut left_counts = Vec::new();
    let mut right_counts = Vec::new();
    let mut ready_spikes = 0usize;
    for t in 0..t_len {
        if trace.cpu_ready(t) < cfg.ready_threshold {
            continue;
        }
        ready_spikes += 1;
        let lo = t.saturating_sub(half);
        left_counts.push(raised[lo..=t].iter().filter(|&&r| r).count());
        let hi = (t + half).min(t_len - 1);
        right_counts.push(if t < t_len - 1 {
            raised[t + 1..=hi].iter().filter(|&&r| r).count()
        } else {
            0
        });
    }
    pronto::sim::NodeEvaluation {
        method: "PRONTO",
        ready_spikes,
        rejection_raises: raised.iter().filter(|&&r| r).count(),
        left_counts,
        right_counts,
        downtime: node.stats().downtime(),
        steps: t_len,
    }
}

fn main() {
    let quick = std::env::var("PRONTO_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let (n, steps) = if quick { (6, 4_000) } else { (16, 10_000) };
    let traces = fleet(n, steps);

    let base_fpca = FpcaEdgeConfig::default();
    let base_eval = EvalConfig::default();
    let mut variants: Vec<Variant> = Vec::new();

    variants.push(Variant {
        label: "baseline (abs flags, std on, w=10, r=4, b=32)".into(),
        fpca: base_fpca,
        eval: base_eval,
        standardize: true,
    });
    variants.push(Variant {
        label: "signed flags (Alg. 1 verbatim)".into(),
        fpca: base_fpca,
        eval: EvalConfig {
            reject: RejectConfig { signed_flags: true, ..base_eval.reject },
            ..base_eval
        },
        standardize: true,
    });
    variants.push(Variant {
        label: "standardizer off (raw counters)".into(),
        fpca: base_fpca,
        eval: base_eval,
        standardize: false,
    });
    for w in [20usize, 50] {
        variants.push(Variant {
            label: format!("window w={w}"),
            fpca: base_fpca,
            eval: EvalConfig { window: w, ..base_eval },
            standardize: true,
        });
    }
    for r in [2usize, 8] {
        variants.push(Variant {
            label: format!("rank r={r}"),
            fpca: FpcaEdgeConfig { initial_rank: r, max_rank: r.max(8), ..base_fpca },
            eval: base_eval,
            standardize: true,
        });
    }
    for b in [16usize, 64] {
        variants.push(Variant {
            label: format!("block b={b}"),
            fpca: FpcaEdgeConfig { block_size: b, ..base_fpca },
            eval: base_eval,
            standardize: true,
        });
    }

    let mut t = Table::new(
        "Ablations: PRONTO design choices (fleet means)",
        &["variant", "prediction rate", "downtime %"],
    );
    for v in &variants {
        let (pred, down) = run(&traces, v);
        t.row(&[
            v.label.clone(),
            format!("{pred:.3}"),
            format!("{:.2}", 100.0 * down),
        ]);
    }
    t.print();
    t.maybe_write_csv("ablations");
    println!("\nexpected: abs flags > signed (sign cancellation); standardizer on > off");
    println!("(mixed-unit counters); w>=10 similar (paper: 10–50 all workable); r=4 ~ r=8 >> r=2.");
}
