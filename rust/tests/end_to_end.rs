//! End-to-end integration: telemetry → node pipelines → federation →
//! simulator, all composed, plus CSV round-trips through the CLI surface.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::config::ProntoConfig;
use pronto::federation::{ConcurrentFederation, FederationTree, PushOutcome, TreeTopology};
use pronto::scheduler::{Admission, NodeScheduler, ProntoPolicy, RandomPolicy, RejectConfig};
use pronto::sim::{DataCenterSim, SimConfig};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 4, v, steps)).collect()
}

#[test]
fn full_pipeline_sim_with_pronto_policies() {
    let traces = fleet(6, 1_500, 11);
    let policies: Vec<Box<dyn Admission>> = traces
        .iter()
        .map(|t| {
            Box::new(ProntoPolicy::new(NodeScheduler::new(
                t.dim(),
                RejectConfig::default(),
            ))) as Box<dyn Admission>
        })
        .collect();
    let report = DataCenterSim::new(SimConfig::default(), traces, policies).run();
    assert!(report.jobs_arrived > 100);
    assert_eq!(report.jobs_arrived, report.jobs_accepted + report.jobs_rejected);
    // PRONTO must accept the clear majority (downtime is low by design;
    // the bound is loose because it rides on generator/admission defaults,
    // not on anything this test controls).
    assert!(report.acceptance_rate() > 0.6, "rate {}", report.acceptance_rate());
}

#[test]
fn pronto_beats_random_rejection_on_placement() {
    // Same traces + arrivals: PRONTO's informed rejections should yield
    // at-least-as-good placement quality as random 20% rejection, while
    // accepting more jobs.
    let traces = fleet(8, 4_000, 21);
    let pronto: Vec<Box<dyn Admission>> = traces
        .iter()
        .map(|t| {
            Box::new(ProntoPolicy::new(NodeScheduler::new(
                t.dim(),
                RejectConfig::default(),
            ))) as Box<dyn Admission>
        })
        .collect();
    let random: Vec<Box<dyn Admission>> = traces
        .iter()
        .enumerate()
        .map(|(i, _)| Box::new(RandomPolicy::new(0.2, i as u64)) as Box<dyn Admission>)
        .collect();
    // Single-probe dispatch so each node's admission decision is decisive.
    let cfg = SimConfig {
        probe: pronto::sim::ProbePolicy::RandomProbe,
        ..Default::default()
    };
    let rp = DataCenterSim::new(cfg.clone(), traces.clone(), pronto).run();
    let rr = DataCenterSim::new(cfg, traces, random).run();
    // The directional claim stays strict (PRONTO's low downtime must beat
    // blind 20% rejection); only the placement comparison carries slack,
    // since its absolute level rides on generator/admission defaults.
    assert!(
        rp.acceptance_rate() > rr.acceptance_rate(),
        "pronto accepts {:.3} vs random {:.3}",
        rp.acceptance_rate(),
        rr.acceptance_rate()
    );
    assert!(
        rp.placement_quality() + 0.05 >= rr.placement_quality(),
        "pronto placement {:.3} far below random {:.3}",
        rp.placement_quality(),
        rr.placement_quality()
    );
}

#[test]
fn federation_tree_and_concurrent_agree_on_global_rank() {
    let n = 8;
    let steps = 512;
    let traces = fleet(n, steps, 31);
    let d = traces[0].dim();

    // Single-threaded tree driven manually.
    let mut tree = FederationTree::new(TreeTopology::new(n, 4), d, 4, 0.0);
    for (leaf, tr) in traces.iter().enumerate() {
        let mut node = NodeScheduler::new(d, RejectConfig::default());
        for t in 0..steps {
            node.observe(tr.features(t));
        }
        let est = node.estimate();
        assert!(matches!(
            tree.push_from_leaf(leaf, &est),
            PushOutcome::Propagated { .. }
        ));
    }
    assert_eq!(tree.global_view().rank(), 4);

    // Concurrent runtime over the same traces.
    let report = ConcurrentFederation::new(TreeTopology::new(n, 4), 4, 0.0)
        .with_push_every(steps)
        .run(traces);
    assert_eq!(report.global_view.rank(), 4);
    // Energy scale of both global views should be comparable (same data).
    let s_tree = tree.global_view().sigma[0];
    let s_conc = report.global_view.sigma[0];
    let ratio = s_tree / s_conc;
    assert!(
        (0.5..2.0).contains(&ratio),
        "global views diverge: {s_tree} vs {s_conc}"
    );
}

#[test]
fn trace_csv_roundtrip_preserves_scheduling_behaviour() {
    let tr = fleet(1, 800, 41).pop().unwrap();
    let dir = std::env::temp_dir().join("pronto_it_csv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("vm.csv");
    tr.write_csv(&path).unwrap();
    let back = VmTrace::read_csv(&path, tr.vm_id, tr.cluster_id).unwrap();

    let run = |t: &VmTrace| -> (usize, usize) {
        let mut node = NodeScheduler::new(t.dim(), RejectConfig::default());
        let mut rejections = 0;
        for i in 0..t.len() {
            if !node.observe(t.features(i)) {
                rejections += 1;
            }
        }
        (t.len(), rejections)
    };
    let (n1, r1) = run(&tr);
    let (n2, r2) = run(&back);
    assert_eq!(n1, n2);
    // CSV stores 6 decimals; admission decisions must be identical.
    assert_eq!(r1, r2, "decisions diverged after CSV roundtrip");
    std::fs::remove_file(&path).ok();
}

#[test]
fn config_drives_cli_sim() {
    let dir = std::env::temp_dir().join("pronto_it_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("pronto.toml");
    std::fs::write(
        &cfg_path,
        "[pronto]\nnodes = 3\nsteps = 400\n\n[sim]\narrival_rate_per_step = 0.5\n",
    )
    .unwrap();
    let cfg = ProntoConfig::load(&cfg_path).unwrap();
    assert_eq!(cfg.nodes, 3);
    let argv = vec![
        "sim".to_string(),
        "--config".to_string(),
        cfg_path.to_string_lossy().to_string(),
        "--policy".to_string(),
        "always".to_string(),
    ];
    pronto::cli::run(&argv).unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn non_finite_telemetry_does_not_poison_the_pipeline() {
    // Failure injection: an exporter glitch emits NaN/∞ mid-stream; the
    // node (with the default standardizer) must keep producing boolean
    // decisions and a finite estimate.
    let tr = fleet(1, 1_000, 77).pop().unwrap();
    let mut node = NodeScheduler::new(tr.dim(), RejectConfig::default());
    for t in 0..tr.len() {
        if t % 97 == 13 {
            let mut bad = tr.features(t).to_vec();
            bad[3] = f64::NAN;
            bad[17] = f64::INFINITY;
            bad[40] = f64::NEG_INFINITY;
            node.observe(&bad);
        } else {
            node.observe(tr.features(t));
        }
    }
    let est = node.estimate();
    assert!(est.u.data().iter().all(|x| x.is_finite()), "estimate poisoned");
    assert!(est.sigma.iter().all(|x| x.is_finite()));
    assert!(node.stats().downtime() < 0.5);
}

#[test]
fn transient_node_bootstraps_from_global_view() {
    // §5.2: new/transient nodes pull the merged global estimate to seed
    // their local subspace. A fresh node seeded from the federation should
    // track the workload subspace immediately (no cold-start block).
    let n = 8;
    let steps = 1_024;
    let traces = fleet(n, steps, 51);
    let d = traces[0].dim();

    let mut tree = FederationTree::new(TreeTopology::new(n, 4), d, 4, 0.0);
    for (leaf, tr) in traces.iter().enumerate() {
        let mut node = NodeScheduler::new(d, RejectConfig::default());
        for t in 0..steps {
            node.observe(tr.features(t));
        }
        tree.push_from_leaf(leaf, &node.estimate());
    }

    // Fresh node joins: seed its embedding from the global view.
    let mut newcomer = pronto::fpca::FpcaEdge::new(d, pronto::fpca::FpcaEdgeConfig::default());
    assert!(newcomer.estimate().is_empty());
    newcomer.set_estimate(tree.global_view().clone());
    assert_eq!(newcomer.estimate().rank(), 4);

    // The seeded estimate must be close to what a veteran node learned
    // (same standardized feature space as the tree pushes).
    let mut veteran = NodeScheduler::new(d, RejectConfig::default());
    let tr = &traces[0];
    for t in 0..steps {
        veteran.observe(tr.features(t));
    }
    let dist = pronto::linalg::subspace_distance(
        &newcomer.estimate().truncate(1).u,
        &veteran.estimate().truncate(1).u,
    );
    assert!(dist < 0.75, "seeded newcomer too far from veterans: {dist}");
}
