"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every kernel in this package must agree with its oracle to float tolerance
across the pytest/hypothesis shape sweep (``python/tests/test_kernels.py``).
"""

import jax.numpy as jnp


def project_block_ref(y_block, u):
    """Reference for ``projection.project_block``: plain jnp matmul."""
    return jnp.dot(y_block, u)


def gram_ref(m):
    """Reference for ``projection.gram``."""
    return jnp.dot(m.T, m)


def matmul_ref(x, y):
    """Reference for ``projection.matmul_tiled``."""
    return jnp.dot(x, y)
