//! Fleet-level trace sources: materialized replay or windowed streaming.
//!
//! The discrete-event engine consumes telemetry through a [`TraceSource`]
//! rather than owning a `Vec<VmTrace>` directly. Two backings exist:
//!
//! * [`TraceSource::Materialized`] — the legacy path: every node's full
//!   trace in memory (`O(nodes × steps × dims)`), exactly what CSV replay
//!   and the existing tests construct.
//! * [`TraceSource::Streaming`] — per-node [`VmTraceStream`] generators
//!   plus a small ring buffer per node (`O(nodes × (window + state))`
//!   memory, independent of the horizon). The engine's access pattern —
//!   monotone per-step consumption with a bounded look-ahead for spike
//!   scoring — fits a sliding window, so multi-thousand-node ×
//!   multi-thousand-step fleets run without materializing full-horizon
//!   traces.
//!
//! Both backings produce **bit-identical** metric vectors for the same
//! generator config/seed/membership, which is what makes `--json` reports
//! byte-comparable across the two paths (regression-tested per catalog
//! scenario).

use crate::telemetry::catalog::CPU_READY_IDX;
use crate::telemetry::generator::{TraceGenerator, VmTraceStream};
use crate::telemetry::trace::VmTrace;

/// Cluster membership of a generated fleet: node `v` lives in cluster
/// `v / fanout`. One definition shared by the CLI (both trace-source
/// modes) and the benches, because streaming-vs-materialized byte parity
/// depends on every caller agreeing on this mapping.
pub fn fleet_members(nodes: usize, fanout: usize) -> Vec<(usize, usize)> {
    let fanout = fanout.max(1);
    (0..nodes).map(|v| (v / fanout, v)).collect()
}

/// A fleet of per-node telemetry streams the engine can drive.
pub enum TraceSource {
    /// Full traces in memory (legacy path; CSV replay, tests).
    Materialized(Vec<VmTrace>),
    /// On-demand generation with a sliding window per node.
    Streaming(StreamingFleet),
}

impl TraceSource {
    /// Wrap pre-materialized traces (the historical engine input).
    pub fn materialized(traces: Vec<VmTrace>) -> Self {
        TraceSource::Materialized(traces)
    }

    /// Open one generator stream per `(cluster_id, vm_id)` membership,
    /// with `horizon` total steps and reads allowed up to `lookahead`
    /// steps past the newest step previously read (the engine passes its
    /// scoring window).
    pub fn streaming(
        gen: &TraceGenerator,
        members: &[(usize, usize)],
        horizon: usize,
        lookahead: usize,
    ) -> Self {
        TraceSource::Streaming(StreamingFleet::new(gen, members, horizon, lookahead))
    }

    /// Number of nodes in the fleet.
    pub fn nodes(&self) -> usize {
        match self {
            TraceSource::Materialized(tr) => tr.len(),
            TraceSource::Streaming(s) => s.streams.len(),
        }
    }

    /// Feature dimension (of node 0; the engine validates non-emptiness).
    pub fn dim(&self) -> usize {
        match self {
            TraceSource::Materialized(tr) => tr.first().map_or(0, VmTrace::dim),
            TraceSource::Streaming(s) => s.dim,
        }
    }

    /// Steps available to drive: the shortest trace (materialized) or the
    /// construction horizon (streaming).
    pub fn len(&self) -> usize {
        match self {
            TraceSource::Materialized(tr) => {
                tr.iter().map(VmTrace::len).min().unwrap_or(0)
            }
            TraceSource::Streaming(s) => s.horizon,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Is this the windowed streaming backing?
    pub fn is_streaming(&self) -> bool {
        matches!(self, TraceSource::Streaming(_))
    }

    /// Resident trace-storage footprint in bytes: the sliding-window
    /// ring (streaming) or the full materialized columns. This is the
    /// figure that makes the 100k-node `large-fleet` bench row viable —
    /// streaming keeps it at `nodes × window × dim × 8` regardless of
    /// horizon (≈ 300 MB at 100k nodes), where materializing the same
    /// run would scale with `steps` instead.
    pub fn buffered_bytes(&self) -> usize {
        match self {
            TraceSource::Materialized(tr) => tr
                .iter()
                .map(|t| t.len() * t.dim() * std::mem::size_of::<f64>())
                .sum(),
            TraceSource::Streaming(s) => s.ring_bytes(),
        }
    }

    /// Metric vector of `node` at `step`. Streaming: `step` must lie
    /// within the sliding window (never more than `lookahead` past the
    /// newest step read so far, never behind the window's tail).
    #[inline]
    pub fn features(&mut self, node: usize, step: usize) -> &[f64] {
        match self {
            TraceSource::Materialized(tr) => tr[node].features(step),
            TraceSource::Streaming(s) => s.column(node, step),
        }
    }

    /// Split the source into one [`NodeView`] per node, each owning that
    /// node's state exclusively (the trace slice, or the generator stream
    /// + ring segment + frontier). The views are `Send` and mutate
    /// disjoint storage, which is what lets the engine's observe loop
    /// shard nodes across worker threads while every per-node read stays
    /// bit-identical to the sequential path (same stream, same
    /// advancement code — only the *interleaving across nodes* changes,
    /// and no node ever reads another node's state).
    pub fn node_views(&mut self) -> Vec<NodeView<'_>> {
        match self {
            TraceSource::Materialized(tr) => {
                tr.iter().map(NodeView::Materialized).collect()
            }
            TraceSource::Streaming(s) => s.node_views(),
        }
    }

    /// CPU Ready value of `node` at `step` (same window rules).
    #[inline]
    pub fn cpu_ready(&mut self, node: usize, step: usize) -> f64 {
        match self {
            TraceSource::Materialized(tr) => tr[node].cpu_ready(step),
            TraceSource::Streaming(s) => s.column(node, step)[CPU_READY_IDX],
        }
    }

    /// Does `node`'s CPU Ready reach `threshold` anywhere in `lo..=hi`?
    /// (The engine's ground-truth spike scorer.)
    pub fn spike_within(&mut self, node: usize, lo: usize, hi: usize, threshold: f64) -> bool {
        (lo..=hi).any(|t| self.cpu_ready(node, t) >= threshold)
    }
}

/// Per-node generator streams with a flat ring of the last `window`
/// columns each. Total memory is `nodes × window × dim` doubles plus the
/// O(dim) stream states — no dependence on the horizon.
pub struct StreamingFleet {
    streams: Vec<VmTraceStream>,
    /// Ring storage, laid out `[node][slot][dim]`.
    ring: Vec<f64>,
    /// Per node: next step the stream will generate (steps
    /// `frontier - window .. frontier` are buffered).
    frontier: Vec<usize>,
    window: usize,
    dim: usize,
    horizon: usize,
}

impl StreamingFleet {
    fn new(
        gen: &TraceGenerator,
        members: &[(usize, usize)],
        horizon: usize,
        lookahead: usize,
    ) -> Self {
        let streams: Vec<VmTraceStream> = members
            .iter()
            .map(|&(cluster, vm)| gen.stream_vm_in_cluster(cluster, vm))
            .collect();
        let dim = gen.config().dim;
        // The engine reads step s for every node after peeking at most
        // `lookahead` steps past s on some node; +2 keeps the current and
        // next step resident alongside the full look-ahead span.
        let window = lookahead + 2;
        Self {
            ring: vec![0.0; streams.len() * window * dim],
            frontier: vec![0; streams.len()],
            streams,
            window,
            dim,
            horizon,
        }
    }

    /// Buffered doubles (diagnostics: memory is window-, not
    /// horizon-proportional).
    pub fn buffered_len(&self) -> usize {
        self.ring.len()
    }

    /// Ring footprint in bytes (`nodes × window × dim × 8`).
    pub fn ring_bytes(&self) -> usize {
        self.ring.len() * std::mem::size_of::<f64>()
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// The column of `node` at `step`, advancing the node's stream as
    /// needed. Panics when `step` has already slid out of the window —
    /// that is an engine access-pattern bug, not a recoverable condition.
    fn column(&mut self, node: usize, step: usize) -> &[f64] {
        let span = self.window * self.dim;
        let chunk = &mut self.ring[node * span..(node + 1) * span];
        advance_node(
            &mut self.streams[node],
            chunk,
            &mut self.frontier[node],
            self.window,
            self.dim,
            self.horizon,
            node,
            step,
        );
        let at = (step % self.window) * self.dim;
        &chunk[at..at + self.dim]
    }

    /// Per-node views over disjoint slices of the fleet state (see
    /// [`TraceSource::node_views`]).
    fn node_views(&mut self) -> Vec<NodeView<'_>> {
        let (window, dim, horizon) = (self.window, self.dim, self.horizon);
        let span = window * dim;
        self.ring
            .chunks_mut(span)
            .zip(self.streams.iter_mut())
            .zip(self.frontier.iter_mut())
            .enumerate()
            .map(|(node, ((ring, stream), frontier))| {
                NodeView::Streaming(StreamNodeView {
                    stream,
                    ring,
                    frontier,
                    window,
                    dim,
                    horizon,
                    node,
                })
            })
            .collect()
    }
}

/// Advance one node's generator until `step` is buffered in its ring
/// chunk. Shared by the whole-fleet accessor and the per-node
/// [`StreamNodeView`], so both run the exact same per-step code (the
/// bit-identity across sequential and sharded access rests on this).
#[allow(clippy::too_many_arguments)]
fn advance_node(
    stream: &mut VmTraceStream,
    chunk: &mut [f64],
    frontier: &mut usize,
    window: usize,
    dim: usize,
    horizon: usize,
    node: usize,
    step: usize,
) {
    assert!(step < horizon, "streaming read past the horizon");
    while *frontier <= step {
        let t = *frontier;
        let at = (t % window) * dim;
        stream.next_into(&mut chunk[at..at + dim]);
        *frontier = t + 1;
    }
    assert!(
        step + window >= *frontier,
        "streaming read of step {step} on node {node} fell out of the \
         window (frontier {}, window {})",
        *frontier,
        window
    );
}

/// Exclusive handle on one node's telemetry: a trace borrow
/// (materialized) or the node's generator stream + ring segment
/// (streaming). Obtained via [`TraceSource::node_views`]; `Send`, so a
/// worker thread can own a contiguous run of nodes during the parallel
/// observe loop.
pub enum NodeView<'a> {
    /// Read-only slice of a fully materialized trace.
    Materialized(&'a VmTrace),
    /// Mutable per-node streaming state.
    Streaming(StreamNodeView<'a>),
}

impl NodeView<'_> {
    /// Metric vector at `step` (same window rules as
    /// [`TraceSource::features`]).
    #[inline]
    pub fn features(&mut self, step: usize) -> &[f64] {
        match self {
            NodeView::Materialized(tr) => tr.features(step),
            NodeView::Streaming(v) => v.features(step),
        }
    }
}

/// The streaming half of a [`NodeView`]: this node's generator stream,
/// its `window × dim` ring segment, and its frontier — all disjoint from
/// every other node's.
pub struct StreamNodeView<'a> {
    stream: &'a mut VmTraceStream,
    ring: &'a mut [f64],
    frontier: &'a mut usize,
    window: usize,
    dim: usize,
    horizon: usize,
    node: usize,
}

impl StreamNodeView<'_> {
    fn features(&mut self, step: usize) -> &[f64] {
        advance_node(
            self.stream,
            self.ring,
            self.frontier,
            self.window,
            self.dim,
            self.horizon,
            self.node,
            step,
        );
        let at = (step % self.window) * self.dim;
        &self.ring[at..at + self.dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::GeneratorConfig;

    fn generator() -> TraceGenerator {
        TraceGenerator::new(GeneratorConfig::default(), 4321)
    }

    fn members(n: usize) -> Vec<(usize, usize)> {
        (0..n).map(|v| (v / 4, v)).collect()
    }

    #[test]
    fn streaming_matches_materialized_under_engine_access_pattern() {
        let g = generator();
        let n = 3;
        let steps = 200;
        let lookahead = 5;
        let traces: Vec<VmTrace> = members(n)
            .iter()
            .map(|&(c, v)| g.generate_vm_in_cluster(c, v, steps))
            .collect();
        let mut src = TraceSource::streaming(&g, &members(n), steps, lookahead);
        assert!(src.is_streaming());
        assert_eq!(src.nodes(), n);
        assert_eq!(src.dim(), traces[0].dim());
        assert_eq!(src.len(), steps);
        for step in 0..steps {
            for (node, tr) in traces.iter().enumerate() {
                assert_eq!(src.features(node, step), tr.features(step));
            }
            // Interleave look-aheads like the engine's spike scorer does.
            let hi = (step + lookahead).min(steps - 1);
            for node in 0..n {
                assert_eq!(src.cpu_ready(node, hi), traces[node].cpu_ready(hi));
                assert_eq!(
                    src.spike_within(node, step, hi, 1000.0),
                    (step..=hi).any(|t| traces[node].cpu_ready(t) >= 1000.0)
                );
            }
        }
    }

    #[test]
    fn streaming_memory_is_window_bounded() {
        let g = generator();
        let src = TraceSource::streaming(&g, &members(4), 1_000_000, 5);
        let TraceSource::Streaming(fleet) = &src else { panic!("not streaming") };
        // 4 nodes × (5 + 2) window slots × 52 dims — horizon-independent.
        assert_eq!(fleet.window(), 7);
        assert_eq!(fleet.buffered_len(), 4 * 7 * 52);
        assert_eq!(fleet.ring_bytes(), 4 * 7 * 52 * 8);
        assert_eq!(src.buffered_bytes(), 4 * 7 * 52 * 8);
    }

    #[test]
    fn materialized_buffered_bytes_scale_with_the_horizon() {
        // The footprint contrast behind the 100k-node scale row: the
        // streaming ring is horizon-independent, materialized storage
        // is not.
        let g = generator();
        let steps = 50;
        let traces: Vec<VmTrace> = members(2)
            .iter()
            .map(|&(c, v)| g.generate_vm_in_cluster(c, v, steps))
            .collect();
        let dim = traces[0].dim();
        let src = TraceSource::materialized(traces);
        assert_eq!(src.buffered_bytes(), 2 * steps * dim * 8);
        let stream = TraceSource::streaming(&g, &members(2), steps, 5);
        assert!(stream.buffered_bytes() < src.buffered_bytes());
    }

    #[test]
    fn lagging_nodes_catch_up_after_idle_gaps() {
        // A node that is not read for a while (dead during churn) must
        // resume with the same columns as the materialized trace.
        let g = generator();
        let steps = 300;
        let tr = g.generate_vm_in_cluster(0, 1, steps);
        let mut src = TraceSource::streaming(&g, &members(2), steps, 5);
        assert_eq!(src.features(1, 0), tr.features(0));
        // Node 0 advances far ahead; node 1 stays untouched.
        for step in 1..250 {
            src.features(0, step);
        }
        assert_eq!(src.features(1, 249), tr.features(249));
    }

    #[test]
    #[should_panic(expected = "fell out of the window")]
    fn reads_behind_the_window_panic() {
        let g = generator();
        let mut src = TraceSource::streaming(&g, &members(1), 500, 3);
        src.features(0, 400);
        src.features(0, 10);
    }

    #[test]
    fn fleet_members_is_the_shared_membership_rule() {
        assert_eq!(
            fleet_members(5, 2),
            vec![(0, 0), (0, 1), (1, 2), (1, 3), (2, 4)]
        );
        assert!(fleet_members(0, 4).is_empty());
        // A degenerate fanout clamps to 1 instead of dividing by zero.
        assert_eq!(fleet_members(2, 0), vec![(0, 0), (1, 1)]);
    }

    #[test]
    fn node_views_are_send_and_bit_identical_to_whole_source_reads() {
        fn assert_send<T: Send>(_: &T) {}
        let g = generator();
        let n = 3;
        let steps = 240;
        let lookahead = 5;
        let traces: Vec<VmTrace> = members(n)
            .iter()
            .map(|&(c, v)| g.generate_vm_in_cluster(c, v, steps))
            .collect();
        for streaming in [false, true] {
            let mut src = if streaming {
                TraceSource::streaming(&g, &members(n), steps, lookahead)
            } else {
                TraceSource::materialized(traces.clone())
            };
            let mut views = src.node_views();
            assert_eq!(views.len(), n);
            assert_send(&views);
            // Drive the views in a deliberately skewed interleaving (node
            // 2 far ahead of node 0) — per-node columns must still equal
            // the materialized reference exactly.
            for step in 0..steps / 2 {
                assert_eq!(views[2].features(step * 2), traces[2].features(step * 2));
                assert_eq!(views[0].features(step), traces[0].features(step));
                assert_eq!(views[1].features(step), traces[1].features(step));
            }
            drop(views);
            // The parent source continues from the views' frontiers.
            let hi = steps - 1;
            for (node, tr) in traces.iter().enumerate() {
                assert_eq!(src.features(node, hi), tr.features(hi), "node {node}");
            }
        }
    }

    #[test]
    fn materialized_source_wraps_traces() {
        let g = generator();
        let traces: Vec<VmTrace> = members(2)
            .iter()
            .map(|&(c, v)| g.generate_vm_in_cluster(c, v, 50))
            .collect();
        let expect = traces[1].cpu_ready(7);
        let mut src = TraceSource::materialized(traces);
        assert!(!src.is_streaming());
        assert_eq!(src.nodes(), 2);
        assert_eq!(src.len(), 50);
        assert_eq!(src.cpu_ready(1, 7), expect);
    }
}
