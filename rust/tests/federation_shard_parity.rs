//! Sharded-merge determinism: the batched federation fan-in
//! (`FederationTree::push_from_leaves`) shards level-0 aggregation
//! across the observe pool, and the engine flushes each tick's pushes
//! through it — so every catalog scenario must produce **byte-identical**
//! reports at every `--threads` width.
//!
//! Two layers of evidence:
//!
//! * engine-level byte identity — the full scenario catalog at observe
//!   widths 1/2/4/7 (1 is the inline sequential path; 7 leaves ragged
//!   aggregator-group shards) renders the same `SimReport` JSON;
//! * a bracket-order regression on `merge_subspaces` — the fan-in's
//!   left-to-right fold is *not* bitwise-associative, which is exactly
//!   why the tree pins the reduction order instead of merging in
//!   arrival order. (`federation::tree` pins batched ≡ sequential at
//!   the unit level; this pins the *reason* the order is load-bearing.)
//!
//! Seeded and replayable via `PRONTO_PROP_SEED` / `PRONTO_PROP_CASES`.

// Index loops over parallel same-length arrays are the house style
// here; see the scoped allow note in rust/src/lib.rs.
#![allow(clippy::needless_range_loop)]

use pronto::fpca::{merge_subspaces, MergeOptions, Subspace};
use pronto::proptest::{gen_orthonormal, gen_spectrum};
use pronto::rng::Xoshiro256;
use pronto::scheduler::{Admission, RandomPolicy};
use pronto::sim::{DiscreteEventEngine, Scenario, CATALOG};
use pronto::telemetry::{GeneratorConfig, TraceGenerator, VmTrace};

fn fleet(n: usize, steps: usize, seed: u64) -> Vec<VmTrace> {
    let gen = TraceGenerator::new(GeneratorConfig::default(), seed);
    (0..n).map(|v| gen.generate_vm_in_cluster(v / 4, v, steps)).collect()
}

fn policies(n: usize, seed: u64) -> Vec<Box<dyn Admission>> {
    (0..n)
        .map(|i| Box::new(RandomPolicy::new(0.3, seed ^ i as u64)) as Box<dyn Admission>)
        .collect()
}

#[test]
fn every_catalog_scenario_is_byte_identical_at_every_width() {
    // The acceptance criterion of the sharding work: reports are a pure
    // function of (scenario, seed), never of the worker count. Width 1
    // exercises the inline sequential path of `push_from_leaves`; the
    // prime width leaves a ragged final shard.
    let nodes = 6;
    let steps = 800;
    let run = |name: &str, threads: usize| {
        let scenario = Scenario::named(name)
            .unwrap()
            .with_nodes(nodes)
            .with_steps(steps)
            .with_seed(0xFEED)
            .with_threads(threads);
        let tr = fleet(nodes, steps, 31);
        DiscreteEventEngine::new(scenario, tr, policies(nodes, 77)).run()
    };
    for name in CATALOG {
        let baseline = run(name, 1).to_json_string();
        for threads in [2, 4, 7] {
            let wide = run(name, threads).to_json_string();
            assert_eq!(
                baseline, wide,
                "scenario '{name}': report at {threads} threads differs from width 1"
            );
        }
    }
}

#[test]
fn merge_fan_in_bracket_order_is_load_bearing() {
    // `merge_subspaces` is not bitwise-associative: (A⊕B)⊕C and A⊕(B⊕C)
    // run the randomized-SVD iteration over *different* panels, so their
    // low-order bits diverge. That non-associativity is why
    // `FederationTree::reduce_upward` folds children strictly left to
    // right — any arrival-order or tree-shape dependence would leak into
    // the report bytes. A handful of trials guards against the (measure-
    // zero, but cheap to tolerate) case where one draw happens to agree.
    let opts = MergeOptions::rank(3);
    let mut diverged = 0usize;
    for trial in 0..8u64 {
        let mut rng = Xoshiro256::seed_from_u64(0xB0AC + trial);
        let d = 10;
        let gen = |rng: &mut Xoshiro256| {
            let u = gen_orthonormal(rng, d, 3);
            let s = gen_spectrum(rng, 3);
            Subspace::new(u, s)
        };
        let (a, b, c) = (gen(&mut rng), gen(&mut rng), gen(&mut rng));
        let left = merge_subspaces(&merge_subspaces(&a, &b, opts), &c, opts);
        let right = merge_subspaces(&a, &merge_subspaces(&b, &c, opts), opts);
        // The fold itself must be exactly reproducible...
        let left2 = merge_subspaces(&merge_subspaces(&a, &b, opts), &c, opts);
        assert!(
            bits_equal(&left, &left2),
            "trial {trial}: left fold is not reproducible bit-for-bit"
        );
        // ...while the alternative bracketing generally is a different
        // computation.
        if !bits_equal(&left, &right) {
            diverged += 1;
        }
    }
    assert!(
        diverged > 0,
        "all {diverged}/8 bracketings agreed bitwise — associativity assumption changed; \
         revisit whether the fan-in still needs a pinned reduction order"
    );
}

fn bits_equal(x: &Subspace, y: &Subspace) -> bool {
    x.u.data().len() == y.u.data().len()
        && x.sigma.len() == y.sigma.len()
        && x.u
            .data()
            .iter()
            .zip(y.u.data())
            .all(|(a, b)| a.to_bits() == b.to_bits())
        && x.sigma.iter().zip(&y.sigma).all(|(a, b)| a.to_bits() == b.to_bits())
}
