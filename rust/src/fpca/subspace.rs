//! The `(U, Σ)` principal-subspace estimate.

use crate::linalg::Mat;

/// A rank-r principal subspace estimate: orthonormal basis `U ∈ ℝ^{d×r}`
/// with associated singular values `sigma` (descending). This is the only
/// state FPCA-Edge keeps per node and the only structure the federation
/// tree propagates — memory is O(d·r), as the paper requires.
#[derive(Debug, Clone)]
pub struct Subspace {
    /// Orthonormal columns spanning the estimate.
    pub u: Mat,
    /// Singular values, one per column of `u`, descending.
    pub sigma: Vec<f64>,
}

impl Subspace {
    /// The empty estimate (paper: `(U, Σ) ← (0, 0)` at initialization).
    pub fn empty(d: usize) -> Self {
        Self { u: Mat::zeros(d, 0), sigma: Vec::new() }
    }

    pub fn new(u: Mat, sigma: Vec<f64>) -> Self {
        assert_eq!(u.cols(), sigma.len(), "basis/spectrum arity mismatch");
        Self { u, sigma }
    }

    /// Ambient dimension d.
    pub fn dim(&self) -> usize {
        self.u.rows()
    }

    /// Current rank r.
    pub fn rank(&self) -> usize {
        self.u.cols()
    }

    pub fn is_empty(&self) -> bool {
        self.rank() == 0
    }

    /// Project a feature vector onto the subspace: `p = yᵀU ∈ ℝ^r`.
    /// This is the per-timestep hot operation of Reject-Job.
    pub fn project(&self, y: &[f64]) -> Vec<f64> {
        self.u.transpose_matvec(y)
    }

    /// Projection without allocation (hot path) — routed through the
    /// column-jammed [`Mat::transpose_matvec_into`] kernel, which performs
    /// the same row-ascending dot per component as the historical loop
    /// here did (bit-identical results).
    pub fn project_into(&self, y: &[f64], out: &mut [f64]) {
        assert!(out.len() >= self.rank());
        let r = self.rank();
        self.u.transpose_matvec_into(y, &mut out[..r]);
    }

    /// Truncate to at most `r` leading components.
    pub fn truncate(&self, r: usize) -> Subspace {
        let k = r.min(self.rank());
        Subspace { u: self.u.take_cols(k), sigma: self.sigma[..k].to_vec() }
    }

    /// Energy ratio of the r-th component (Eq. 7):
    /// `E_r = σ_r / Σ_{i≤r} σ_i`. Returns 0 for an empty estimate.
    pub fn energy_ratio(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        let total: f64 = self.sigma.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.sigma[self.rank() - 1] / total
    }

    /// Frobenius-scale difference between two subspace iterates, used for
    /// the ε-gated upward propagation heuristic ("absdiff" in Algorithm 2).
    /// Ranks may differ; the shorter basis is compared against the leading
    /// columns of the longer one, and leftover columns count in full.
    pub fn abs_diff(&self, other: &Subspace) -> f64 {
        assert_eq!(self.dim(), other.dim());
        let (a, b) = if self.rank() <= other.rank() { (self, other) } else { (other, self) };
        let mut acc = 0.0f64;
        for j in 0..a.rank() {
            // Column sign is arbitrary in an SVD basis: compare up to sign.
            let ca = a.u.col(j);
            let cb = b.u.col(j);
            let mut dplus = 0.0;
            let mut dminus = 0.0;
            for k in 0..ca.len() {
                dplus += (ca[k] - cb[k]).powi(2);
                dminus += (ca[k] + cb[k]).powi(2);
            }
            acc += dplus.min(dminus);
        }
        for j in a.rank()..b.rank() {
            acc += b.u.col(j).iter().map(|x| x * x).sum::<f64>();
        }
        acc.sqrt()
    }

    /// Reconstruction `U diag(σ)` (d × r) — the scaled basis that merges
    /// consume.
    pub fn scaled_basis(&self) -> Mat {
        self.u.mul_diag(&self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::orthonormality_error;
    use crate::proptest::{forall, gen_orthonormal, gen_spectrum};

    #[test]
    fn empty_subspace_basics() {
        let s = Subspace::empty(10);
        assert_eq!(s.dim(), 10);
        assert_eq!(s.rank(), 0);
        assert!(s.is_empty());
        assert_eq!(s.energy_ratio(), 0.0);
        assert!(s.project(&vec![1.0; 10]).is_empty());
    }

    #[test]
    fn project_matches_manual_dot() {
        let u = Mat::from_rows(3, 2, &[1.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let s = Subspace::new(u, vec![2.0, 1.0]);
        let p = s.project(&[3.0, 4.0, 5.0]);
        assert_eq!(p, vec![3.0, 4.0]);
        let mut out = [0.0; 2];
        s.project_into(&[3.0, 4.0, 5.0], &mut out);
        assert_eq!(out, [3.0, 4.0]);
    }

    #[test]
    fn energy_ratio_known() {
        let u = Mat::from_rows(3, 2, &[1.0, 0.0, 0.0, 1.0, 0.0, 0.0]);
        let s = Subspace::new(u, vec![3.0, 1.0]);
        assert!((s.energy_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn abs_diff_zero_for_identical_and_sign_flips() {
        forall("abs_diff sign invariance", |rng| {
            let d = 6 + rng.gen_range(20);
            let r = 1 + rng.gen_range(4);
            let u = gen_orthonormal(rng, d, r);
            let sig = gen_spectrum(rng, r);
            let s1 = Subspace::new(u.clone(), sig.clone());
            let mut flipped = u.clone();
            for x in flipped.col_mut(0) {
                *x = -*x;
            }
            let s2 = Subspace::new(flipped, sig);
            let d12 = s1.abs_diff(&s2);
            if d12 < 1e-10 {
                Ok(())
            } else {
                Err(format!("sign flip not invariant: {d12}"))
            }
        });
    }

    #[test]
    fn abs_diff_counts_rank_mismatch() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(5);
        let u = gen_orthonormal(&mut rng, 10, 3);
        let s3 = Subspace::new(u.clone(), vec![3.0, 2.0, 1.0]);
        let s2 = Subspace::new(u.take_cols(2), vec![3.0, 2.0]);
        // Extra orthonormal column has unit norm → diff ≈ 1.
        assert!((s3.abs_diff(&s2) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn truncate_keeps_leading() {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(6);
        let u = gen_orthonormal(&mut rng, 8, 4);
        let s = Subspace::new(u, vec![4.0, 3.0, 2.0, 1.0]);
        let t = s.truncate(2);
        assert_eq!(t.rank(), 2);
        assert_eq!(t.sigma, vec![4.0, 3.0]);
        assert!(orthonormality_error(&t.u) < 1e-10);
    }
}
