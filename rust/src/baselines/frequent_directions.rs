//! Frequent Directions matrix sketching (Liberty, KDD 2013).
//!
//! Maintains a sketch `S ∈ ℝ^{ℓ×d}` (ℓ = 2r rows here) such that
//! `‖AᵀA − SᵀS‖₂ ≤ ‖A‖_F² / (ℓ − r)`. Each time the sketch fills, its SVD
//! is taken and all squared singular values are shrunk by the (r+1)-th —
//! the "frequent items for matrices" step. The top-r right singular vectors
//! of the sketch are the embedding basis.
//!
//! FD is deterministic and has strong guarantees, but the shrinkage
//! destroys the spectrum's scale, so (per the paper §7) it cannot provide
//! usable singular values and PRONTO's weighting falls back to σ_r = 1/r.

use super::{decay_spectrum, StreamingEmbedding};
use crate::fpca::Subspace;
use crate::linalg::{svd_truncated, Mat};

/// Frequent Directions sketcher.
#[derive(Debug, Clone)]
pub struct FrequentDirections {
    d: usize,
    /// Embedding rank r exposed to the scheduler.
    r: usize,
    /// Sketch rows ℓ (2r): stored as an ℓ × d row buffer (each row one
    /// sketch direction, scaled).
    sketch: Mat, // ℓ x d, row i = sketch row
    /// Rows currently occupied.
    filled: usize,
    seen: usize,
}

impl FrequentDirections {
    pub fn new(d: usize, r: usize) -> Self {
        assert!(r >= 1 && 2 * r <= d.max(2 * r), "rank too large");
        let ell = 2 * r;
        Self { d, r, sketch: Mat::zeros(ell, d), filled: 0, seen: 0 }
    }

    fn ell(&self) -> usize {
        self.sketch.rows()
    }

    /// The shrink step: SVD the sketch, subtract σ_{r+1}² from all squared
    /// singular values, and rebuild the sketch with the top rows.
    fn shrink(&mut self) {
        // SVD of the ℓ × d sketch.
        let svd = svd_truncated(&self.sketch, self.ell());
        let k = svd.sigma.len();
        let delta = if k > self.r { svd.sigma[self.r].powi(2) } else { 0.0 };
        let mut new_sketch = Mat::zeros(self.ell(), self.d);
        let mut row = 0usize;
        for j in 0..k.min(self.r) {
            let s2 = (svd.sigma[j].powi(2) - delta).max(0.0);
            if s2 <= 0.0 {
                continue;
            }
            let s = s2.sqrt();
            // Row = s * v_jᵀ (v columns are right singular vectors in ℝ^d).
            for i in 0..self.d {
                new_sketch.set(row, i, s * svd.v.get(i, j));
            }
            row += 1;
        }
        self.sketch = new_sketch;
        self.filled = row;
    }
}

impl StreamingEmbedding for FrequentDirections {
    fn observe(&mut self, y: &[f64]) {
        assert_eq!(y.len(), self.d);
        if self.filled == self.ell() {
            self.shrink();
        }
        for (i, &v) in y.iter().enumerate() {
            self.sketch.set(self.filled, i, v);
        }
        self.filled += 1;
        self.seen += 1;
    }

    fn estimate(&self) -> Subspace {
        if self.seen < self.r {
            return Subspace::empty(self.d);
        }
        // Basis = top-r right singular vectors of the sketch.
        let svd = svd_truncated(&self.sketch, self.r);
        // Columns of svd.v live in ℝ^d.
        let mut u = Mat::zeros(self.d, self.r);
        for j in 0..svd.v.cols().min(self.r) {
            for i in 0..self.d {
                u.set(i, j, svd.v.get(i, j));
            }
        }
        Subspace::new(u, decay_spectrum(self.r))
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn rank(&self) -> usize {
        self.r
    }

    fn name(&self) -> &'static str {
        "FD"
    }

    fn has_spectrum(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::subspace_distance;
    use crate::proptest::{forall, gen_low_rank};

    #[test]
    fn sketch_never_exceeds_ell_rows() {
        let mut fd = FrequentDirections::new(10, 3);
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(1);
        for _ in 0..100 {
            let y: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
            fd.observe(&y);
            assert!(fd.filled <= fd.ell());
        }
    }

    #[test]
    fn covariance_error_bound_holds() {
        // ‖AᵀA − SᵀS‖₂ ≤ ‖A‖_F²/(ℓ−r). We check the (looser) Frobenius
        // surrogate on random data.
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(2);
        let d = 12;
        let n = 200;
        let a = crate::proptest::gen_mat(&mut rng, d, n); // columns = samples
        let mut fd = FrequentDirections::new(d, 4);
        for t in 0..n {
            fd.observe(a.col(t));
        }
        fd.shrink();
        // AᵀA over features: a is d×n with samples as columns → covariance
        // C = A Aᵀ (d×d). Sketch rows are in ℝ^d: C_s = SᵀS.
        let c = a.matmul(&a.transpose());
        let cs = fd.sketch.transpose_mul(&fd.sketch); // wait: sketch is ℓ×d
        let diff = crate::linalg::frob_diff(&c, &cs);
        let bound = a.frob_norm().powi(2) / (fd.ell() - fd.r) as f64;
        // Frobenius ≤ sqrt(rank)·spectral; allow that slack.
        assert!(
            diff <= bound * (d as f64).sqrt(),
            "diff={diff} bound(frob-slack)={}",
            bound * (d as f64).sqrt()
        );
    }

    #[test]
    fn recovers_low_rank_subspace() {
        forall("fd recovers subspace", |rng| {
            let d = 10 + rng.gen_range(14);
            let data = gen_low_rank(rng, d, 400, 2, 0.01);
            let mut fd = FrequentDirections::new(d, 2);
            for t in 0..data.cols() {
                fd.observe(data.col(t));
            }
            let truth = crate::linalg::svd_truncated(&data, 2);
            let dist = subspace_distance(&fd.estimate().u, &truth.u);
            if dist < 0.2 {
                Ok(())
            } else {
                Err(format!("distance {dist}"))
            }
        });
    }

    #[test]
    fn uses_decay_spectrum() {
        let mut fd = FrequentDirections::new(8, 4);
        for _ in 0..20 {
            fd.observe(&[1.0; 8]);
        }
        let est = fd.estimate();
        assert_eq!(est.sigma, decay_spectrum(4));
        assert!(!fd.has_spectrum());
    }
}
