// Fixture: unknown rule names are rejected.
// pronto-lint: allow(no-such-rule) — the rule list is closed
pub fn nothing() {}
