//! Checked-in registries the lint rules resolve against.
//!
//! Three kinds of facts live here: *path classification* (which modules
//! are deterministic engine paths, what counts as vendored or test code),
//! the *environment-variable registry* (every `PRONTO_*` key the tree is
//! allowed to read), and the *report-schema manifest* (every key a
//! serialized report may emit). The RNG stream-tag registry itself lives
//! with the RNG substrate in [`crate::rng::streams`] — the lint checks it
//! for uniqueness at runtime rather than duplicating it here.

/// Rule identifiers, as written in `pronto-lint: allow(<rule>)` pragmas.
pub const RULES: &[&str] = &[
    "wall-clock",
    "rng-discipline",
    "unordered-iter",
    "env-registry",
    "unsafe-audit",
    "schema-pin",
];

/// Top-level `src/` modules where wall-clock reads (`Instant`,
/// `SystemTime`) are banned: everything that must replay byte-identically
/// from a seed. `bench` and `cli` stay free to time things.
pub const WALL_CLOCK_BANNED: &[&str] = &[
    "sim",
    "scheduler",
    "federation",
    "fpca",
    "detect",
    "telemetry",
    "rng",
];

/// Engine modules where RNG construction must route through
/// `rng::stream_seed` / `rng::node_stream_seed` instead of hand-mixing
/// seeds. `rng` itself is exempt — it *is* the blessed implementation.
pub const RNG_DISCIPLINE: &[&str] = &[
    "sim",
    "scheduler",
    "federation",
    "fpca",
    "detect",
    "telemetry",
];

/// Every environment variable the tree may read. `pronto lint` rejects
/// any `PRONTO_*` string literal whose leading key is not listed here —
/// adding a knob means registering it (and documenting it in the README).
pub const ENV_KEYS: &[&str] = &[
    "PRONTO_ARTIFACTS",
    "PRONTO_BENCH_CSV_DIR",
    "PRONTO_BENCH_JSON",
    "PRONTO_BENCH_QUICK",
    "PRONTO_EVENT_QUEUE",
    "PRONTO_LINALG",
    "PRONTO_PROP_CASES",
    "PRONTO_PROP_SEED",
];

/// The only files allowed to mutate the environment: the backing-parity
/// suites (event-queue wheel/heap, linalg blocked/scalar) each run as an
/// isolated test binary precisely so their `set_var` cannot race other
/// tests.
pub const SET_VAR_ALLOWED_FILES: &[&str] =
    &["tests/linalg_oracle_parity.rs", "tests/queue_wheel_parity.rs"];

/// Files whose `insert("key", …)` literals form the serialized report
/// surface; every key must appear in [`REPORT_KEYS`] (or match a
/// [`REPORT_KEY_PREFIXES`] entry for `format!`-built dynamic keys).
pub const SCHEMA_FILES: &[&str] = &[
    "src/sim/engine.rs",
    "src/sim/quality.rs",
    "src/bench/engine.rs",
    "src/bench/sweep.rs",
];

/// The pinned report-schema manifest: the union of keys emitted by
/// `SimReport::to_json`, `QualityRow::to_json` / `quality_report`,
/// `EngineBenchRun::to_json` / `bench_engine_report`, and
/// `SweepRow::to_json` / `sweep_report`. Sorted; the registry test
/// enforces order and uniqueness. Renaming or adding a report key is a
/// schema change and must be made here, on purpose.
pub const REPORT_KEYS: &[&str] = &[
    "acceptance_rate",
    "antagonist_jobs_arrived",
    "antagonist_jobs_rejected",
    "antagonist_slo_attained",
    "antagonist_slo_attainment",
    "antagonist_slo_total",
    "bad_accepts",
    "bench",
    "decision_p50",
    "decision_p90",
    "decision_p99",
    "decision_samples",
    "eval",
    "events",
    "events_per_sec",
    "f1",
    "failure_rate",
    "failure_rates",
    "false_positive_rate",
    "federation_late_drops",
    "federation_partition_drops",
    "federation_pushes",
    "federation_stale_replays",
    "federation_suppressed",
    "good_accepts",
    "jobs_accepted",
    "jobs_arrived",
    "jobs_completed",
    "jobs_displaced",
    "jobs_dropped",
    "jobs_migrated",
    "jobs_preempted",
    "jobs_queued",
    "jobs_rejected",
    "jobs_still_queued",
    "jobs_still_running",
    "jobs_unplaceable",
    "justified_rejections",
    "lead_p50",
    "lead_p90",
    "lead_p99",
    "mean_decision_latency_steps",
    "mean_downtime",
    "mean_lead_steps",
    "mean_push_latency_steps",
    "mean_queue_delay_steps",
    "mean_utilization",
    "method",
    "methods",
    "node_joins",
    "node_leaves",
    "nodes",
    "outcomes_digest",
    "partition_events",
    "peak_inflight",
    "peak_queue_len",
    "placement_quality",
    "policies",
    "policy",
    "precision",
    "precision_node_p50",
    "precision_node_p90",
    "predicted_spikes",
    "primary_jobs_rejected",
    "primary_slo_attained",
    "primary_slo_total",
    "quick",
    "rack_outages",
    "raises",
    "recall",
    "recall_node_p50",
    "recall_node_p90",
    "rejection_precision",
    "rows",
    "runs",
    "scale_rows",
    "scenario",
    "scenarios",
    "schema_version",
    "seed",
    "sizes",
    "slo_attained",
    "slo_attainment",
    "slo_total",
    "spikes",
    "steps",
    "threads",
    "trace_source",
    "true_positive_raises",
    "wall_ms",
    "window",
];

/// Allowed prefixes for dynamic keys built with `format!` (per-priority
/// queue-delay percentiles: `queue_delay_p0`, `queue_delay_p1`, ...).
pub const REPORT_KEY_PREFIXES: &[&str] = &["queue_delay_p"];

/// The SplitMix64 gamma — any integer literal starting with these hex
/// digits in an engine path is hand-rolled stream mixing.
pub const STREAM_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// Normalize a path for rule matching: forward slashes, no leading `./`.
pub fn norm_path(path: &str) -> String {
    let p = path.replace('\\', "/");
    let mut s = p.as_str();
    while let Some(rest) = s.strip_prefix("./") {
        s = rest;
    }
    s.to_string()
}

/// Vendored crates keep their upstream style; only `unsafe-audit`
/// applies to them.
pub fn is_vendor(path: &str) -> bool {
    path.split('/').any(|seg| seg == "vendor")
}

/// Whole-file test context: integration tests and criterion-style bench
/// drivers (`tests/`, `benches/` directory segments).
pub fn is_test_path(path: &str) -> bool {
    path.split('/').any(|seg| seg == "tests" || seg == "benches")
}

/// The top-level module a `src/` file belongs to (`src/sim/engine.rs` →
/// `sim`, `src/rng.rs` → `rng`). `None` outside a `src/` tree or inside
/// `vendor/`.
pub fn src_module(path: &str) -> Option<String> {
    if is_vendor(path) {
        return None;
    }
    let segs: Vec<&str> = path.split('/').collect();
    let at = segs.iter().position(|&s| s == "src")?;
    let next = segs.get(at + 1)?;
    Some(next.trim_end_matches(".rs").to_string())
}

/// True when `path` is one of the schema-pinned report serializers.
pub fn is_schema_file(path: &str) -> bool {
    SCHEMA_FILES.iter().any(|s| path.ends_with(s))
}

/// Extract the leading `KEY_LIKE` portion of a `PRONTO_*` string literal
/// (so `"PRONTO_EVENT_QUEUE=heap …"` in a usage message still resolves
/// to its key).
pub fn leading_env_key(content: &str) -> &str {
    let end = content
        .find(|c: char| !(c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_'))
        .unwrap_or(content.len());
    &content[..end]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_keys_sorted_and_unique() {
        for w in REPORT_KEYS.windows(2) {
            assert!(w[0] < w[1], "REPORT_KEYS out of order at {:?}", w);
        }
        for w in ENV_KEYS.windows(2) {
            assert!(w[0] < w[1], "ENV_KEYS out of order at {:?}", w);
        }
    }

    #[test]
    fn module_classification() {
        assert_eq!(src_module("rust/src/sim/engine.rs").as_deref(), Some("sim"));
        assert_eq!(src_module("src/rng.rs").as_deref(), Some("rng"));
        assert_eq!(src_module("./src/cli/mod.rs").as_deref(), Some("cli"));
        assert_eq!(src_module("examples/quickstart.rs"), None);
        assert_eq!(src_module("rust/vendor/minipool/src/lib.rs"), None);
        assert!(is_vendor("rust/vendor/anyhow/src/lib.rs"));
        assert!(is_test_path("rust/tests/determinism.rs"));
        assert!(is_test_path("rust/benches/hotpath.rs"));
        assert!(!is_test_path("rust/src/sim/engine.rs"));
    }

    #[test]
    fn env_key_extraction() {
        assert_eq!(leading_env_key("PRONTO_EVENT_QUEUE=heap cargo test"), "PRONTO_EVENT_QUEUE");
        // pronto-lint: allow(env-registry) — deliberately unregistered key text
        assert_eq!(leading_env_key("PRONTO_NOPE"), "PRONTO_NOPE");
    }
}
