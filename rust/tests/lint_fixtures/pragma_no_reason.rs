// Fixture: a pragma with no reason suppresses nothing and is flagged.
pub fn timed_ms() -> u128 {
    // pronto-lint: allow(wall-clock)
    std::time::Instant::now().elapsed().as_millis()
}
